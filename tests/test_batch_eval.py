"""Batched evaluation engine (PR 5): packed layer arrays + the
scalar-vs-batched equivalence contract.

The contract: ``evaluate_rav_batch`` must reproduce the scalar reference
``evaluate_rav`` exactly on every discrete decision (stage PF splits,
strategy choice, resource usage, feasibility) and to <=1e-9 relative on
float objectives (NumPy pairwise summation vs Python's sequential sum is
the only permitted difference).
"""

import numpy as np
import pytest

from repro.core import KU115, RAV, ZC706, PSOConfig, evaluate_rav, optimize
from repro.core.batch_eval import evaluate_rav_batch
from repro.core.generic_model import GenericDesign
from repro.core.layer_arrays import pack_layers
from repro.core.local_opt import _segment_after
from repro.core.netinfo import LayerInfo, NetInfo, mobilenet, vgg16

FLOAT_FIELDS = ("throughput_ips", "gops", "dsp_eff", "latency_s")


def random_ravs(n: int, sp_max: int, batch_max: int, seed: int) -> list[RAV]:
    rng = np.random.default_rng(seed)
    return [RAV(int(rng.integers(0, sp_max + 1)),
                int(rng.integers(1, batch_max + 1)),
                float(rng.uniform(0.05, 0.95)),
                float(rng.uniform(0.05, 0.95)),
                float(rng.uniform(0.05, 0.95))) for _ in range(n)]


def assert_equivalent(scalar, batched):
    """Discrete fields exact, float objectives <=1e-9 relative."""
    assert batched.rav == scalar.rav
    assert batched.pipeline.batch == scalar.pipeline.batch
    assert batched.pipeline.stages == scalar.pipeline.stages
    assert batched.generic == scalar.generic
    assert batched.dsp_used == scalar.dsp_used
    assert batched.bram_used == scalar.bram_used
    assert batched.feasible == scalar.feasible
    for f in FLOAT_FIELDS:
        assert getattr(batched, f) == pytest.approx(
            getattr(scalar, f), rel=1e-9, abs=1e-12), f


# ---------------------------------------------------------------------------
# The randomized equivalence sweep: 2 nets x 2 precisions x >=200 RAVs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("net_fn,fpga", [(lambda: vgg16(64), ZC706),
                                         (mobilenet, KU115)])
@pytest.mark.parametrize("prec", [16, 8])
def test_equivalence_sweep(net_fn, fpga, prec):
    """60 random RAVs per (net, fpga, precision) combination — 240 across
    the grid — must agree between the scalar and batched engines."""
    net = net_fn()
    ravs = random_ravs(60, len(net.major_layers), 8, seed=prec)
    batched = evaluate_rav_batch(net, fpga, ravs, prec, prec)
    for rav, b in zip(ravs, batched):
        assert_equivalent(evaluate_rav(net, fpga, rav, prec, prec), b)


def test_equivalence_extreme_splits():
    """The degenerate RAVs: pure-generic (sp=0), pure-pipeline (sp=max),
    starved resource fractions, and batch > 1."""
    net = vgg16(224)
    sp_max = len(net.major_layers)
    cases = [RAV(0, 1, 0.0, 0.0, 0.0), RAV(0, 8, 0.5, 0.5, 0.5),
             RAV(sp_max, 1, 0.95, 0.95, 0.95), RAV(sp_max, 4, 0.05, 0.05, 0.05),
             RAV(6, 2, 0.05, 0.95, 0.05), RAV(6, 2, 0.95, 0.05, 0.95)]
    batched = evaluate_rav_batch(net, KU115, cases)
    for rav, b in zip(cases, batched):
        assert_equivalent(evaluate_rav(net, KU115, rav), b)


def test_equivalence_grouped_conv():
    """Grouped (non-depthwise) convolutions take the generic kernels'
    ``c // groups`` path; the builder never emits them, so build one by
    hand and sweep it."""
    layers = (LayerInfo("conv1", "conv", 56, 56, 3, 64, 3, 3),
              LayerInfo("g1", "conv", 56, 56, 64, 128, 3, 3, 1, 4),
              LayerInfo("pool1", "pool", 28, 28, 128, 128, 2, 2, 2),
              LayerInfo("g2", "conv", 28, 28, 128, 256, 3, 3, 1, 8),
              LayerInfo("fc1", "fc", 1, 1, 28 * 28 * 256, 100))
    net = NetInfo("grouped", (56, 56), 3, layers)
    for rav in random_ravs(25, len(net.major_layers), 4, seed=3):
        b, = evaluate_rav_batch(net, ZC706, [rav])
        assert_equivalent(evaluate_rav(net, ZC706, rav), b)


def test_batch_results_in_input_order():
    net = vgg16(64)
    ravs = random_ravs(16, len(net.major_layers), 4, seed=9)
    out = evaluate_rav_batch(net, KU115, ravs)
    assert [d.rav for d in out] == ravs


# ---------------------------------------------------------------------------
# Packed layer arrays
# ---------------------------------------------------------------------------


def test_packed_columns_match_layerinfo():
    """Every packed column equals the LayerInfo method it was lowered
    from, across conv / dwconv / pool / fc layers at both precisions."""
    for net in (mobilenet(), vgg16(32)):
        for prec in (16, 8):
            p = pack_layers(net, prec, prec)
            for i, l in enumerate(net.layers):
                assert p.macs[i] == l.macs
                assert p.weight_bytes[i] == l.weight_bytes(prec)
                assert p.ifm_bytes[i] == l.ifm_bytes(prec)
                assert p.ofm_bytes[i] == l.ofm_bytes(prec)
                assert bool(p.is_pool[i]) == (l.kind == "pool")
                assert bool(p.is_dw[i]) == (l.kind == "dwconv")
                assert p.groups[i] == l.groups
            assert p.total_ops == net.total_ops


def test_packed_segments_match_segment_after():
    """layers[seg_start[sp]:] must be exactly ``_segment_after(net, sp)``
    for every split point, and the suffix maxima must match the segment's
    channel maxima — on a pool-interleaved net and a dwconv net."""
    for net in (vgg16(64), mobilenet()):
        p = pack_layers(net, 16, 16)
        for sp in range(p.n_major + 1):
            start, c_max, k_max = p.segment(sp)
            seg = _segment_after(net, sp)
            assert list(net.layers[start:]) == seg
            assert c_max == (max(l.c for l in seg) if seg else 0)
            assert k_max == (max(l.k for l in seg) if seg else 0)


def test_packed_native_vs_resized_inputs():
    """Resized inputs repack (different geometry), native fixed-topology
    nets pack at their published input; packing is cached per identity."""
    small, big = vgg16(64), vgg16(224)
    p_small, p_big = pack_layers(small, 16, 16), pack_layers(big, 16, 16)
    assert p_small.n_major == p_big.n_major == 13
    # 224/64 = 3.5x linear -> 12.25x the pixels layer for layer.
    assert p_big.h[0] * p_big.w[0] == p_small.h[0] * p_small.w[0] * 49 // 4
    native = mobilenet()
    p_native = pack_layers(native, 16, 16)
    assert (p_native.h[0], p_native.w[0]) == (112, 112)  # stride-2 stem
    # lru cache: same NetInfo + precision -> same PackedLayers instance.
    assert pack_layers(small, 16, 16) is p_small
    assert pack_layers(small, 8, 8) is not p_small


# ---------------------------------------------------------------------------
# Regressions + integration
# ---------------------------------------------------------------------------


def test_pool_spill_zero_bandwidth_is_inf_not_crash():
    """generic_model regression: the pool-spill branch used to divide by
    ``bw_bytes`` unguarded; with zero bandwidth it must report an infinite
    latency like the conv branch, not raise ZeroDivisionError."""
    pool = LayerInfo("pool", "pool", 112, 112, 256, 256, 2, 2, 2)
    g = GenericDesign(8, 8, 16, 16, bram=8, bw_bytes=0.0)
    assert not g._fm_fits(pool)          # tiny BRAM: the fm must spill
    assert g.layer_latency(pool, 2e8) == float("inf")
    # and a fitting pool stays free even with no bandwidth at all
    small = LayerInfo("pool", "pool", 4, 4, 8, 8, 2, 2, 2)
    big_buf = GenericDesign(8, 8, 16, 16, bram=2000, bw_bytes=0.0)
    assert big_buf.layer_latency(small, 2e8) == 0.0


def test_explore_trajectory_unchanged_by_batched_engine():
    """Wiring the batched engine into explore() must not move the PSO:
    same per-iteration history, evaluation count, and best RAV as the
    scalar fitness hook."""
    from repro.core import explore
    net = vgg16(64)
    cfg = PSOConfig(population=14, iterations=12, seed=5)
    res = explore(net, ZC706, cfg=cfg)

    def scalar_hook(ravs):
        return [evaluate_rav(net, ZC706, r).fitness for r in ravs]

    ref = optimize(sp_max=len(net.major_layers), batch_max=1, cfg=cfg,
                   batch_fitness_fn=scalar_hook)
    assert res.pso.best_rav == ref.best_rav
    assert res.pso.history == ref.history
    assert res.pso.evaluations == ref.evaluations
