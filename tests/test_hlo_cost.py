"""Fixture + property tests for the :mod:`repro.launch.hlo_cost` parser.

The fixtures are committed HLO *text* (no jax compile needed), covering
both print versions the parser must survive — older XLA's bare ``%name``
operand references and newer XLA's inlined-shape operands — plus the
accounting rules that distinguish this parser from XLA's own
``cost_analysis()``: while bodies multiplied by ``known_trip_count``,
descent into fusion computations, and collective traffic (``-start``
result tuples halved, ``-done`` not double-counted).

The randomized sweeps use seeded stdlib/numpy generation (same idiom as
``test_pareto_properties.py``) so every counterexample replays from the
seed in the assertion message.
"""
import random

import pytest

from repro.launch.hlo_cost import exact_cost

SEEDS = range(10)


# ---------------------------------------------------------------------------
# fixture builders: the same graph in both HLO print versions
# ---------------------------------------------------------------------------


def _dot_entry(m: int, k: int, n: int, typed: bool) -> str:
    """A single-dot ENTRY; ``typed`` selects the newer print version that
    inlines each operand's shape (dims/layouts contain commas)."""
    lhs = f"f32[{m},{k}]{{1,0}} %a" if typed else "%a"
    rhs = f"f32[{k},{n}]{{1,0}} %b" if typed else "%b"
    return f"""\
ENTRY %main.1 (a: f32[{m},{k}], b: f32[{k},{n}]) -> f32[{m},{n}] {{
  %a = f32[{m},{k}]{{1,0}} parameter(0)
  %b = f32[{k},{n}]{{1,0}} parameter(1)
  ROOT %dot.1 = f32[{m},{n}]{{1,0}} dot({lhs}, {rhs}), \
lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
}}
"""


def _while_module(d: int, trip: int, escaped: bool, typed: bool) -> str:
    """A while loop whose body is one ``d x d`` dot, with the trip count
    in the backend config — plain or JSON-escaped, as both appear in
    real ``as_text()`` output depending on XLA version."""
    if escaped:
        bc = ('backend_config="{\\"known_trip_count\\":'
              f'{{\\"n\\":\\"{trip}\\"}}}}"')
    else:
        bc = f'backend_config={{"known_trip_count":{{"n":"{trip}"}}}}'
    p = f"f32[{d},{d}]{{1,0}} %p.1" if typed else "%p.1"
    arg = f"f32[{d},{d}]{{1,0}} %arg.0" if typed else "%arg.0"
    return f"""\
HloModule while_test

%body (p.1: f32[{d},{d}]) -> f32[{d},{d}] {{
  %p.1 = f32[{d},{d}]{{1,0}} parameter(0)
  ROOT %dot.2 = f32[{d},{d}]{{1,0}} dot({p}, {p}), \
lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
}}

%cond (p.2: f32[{d},{d}]) -> pred[] {{
  %p.2 = f32[{d},{d}]{{1,0}} parameter(0)
  ROOT %lt.1 = pred[] constant(true)
}}

ENTRY %main.9 (arg.0: f32[{d},{d}]) -> f32[{d},{d}] {{
  %arg.0 = f32[{d},{d}]{{1,0}} parameter(0)
  ROOT %while.1 = f32[{d},{d}]{{1,0}} while({arg}), condition=%cond, \
body=%body, {bc}
}}
"""


_FUSION_MODULE = """\
HloModule fusion_test

%fused_computation (param_0: f32[32,16], param_1: f32[16,8]) -> f32[32,8] {
  %param_0 = f32[32,16]{1,0} parameter(0)
  %param_1 = f32[16,8]{1,0} parameter(1)
  ROOT %dot.3 = f32[32,8]{1,0} dot(%param_0, %param_1), \
lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main.5 (a: f32[32,16], b: f32[16,8]) -> f32[32,8] {
  %a = f32[32,16]{1,0} parameter(0)
  %b = f32[16,8]{1,0} parameter(1)
  ROOT %fusion.1 = f32[32,8]{1,0} fusion(%a, %b), kind=kLoop, \
calls=%fused_computation
}
"""


_COLLECTIVE_MODULE = """\
HloModule collective_test

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}

ENTRY %main.7 (a: f32[1024]) -> f32[4096] {
  %a = f32[1024]{0} parameter(0)
  %ar.1 = f32[1024]{0} all-reduce(%a), replica_groups={}, to_apply=%add
  %ags.1 = (f32[1024]{0}, f32[4096]{0}) all-gather-start(%ar.1), dimensions={0}
  ROOT %agd.1 = f32[4096]{0} all-gather-done(%ags.1)
}
"""


# ---------------------------------------------------------------------------
# fixtures: print versions
# ---------------------------------------------------------------------------


def test_both_print_versions_count_identical_flops():
    want = 2 * 128 * 32 * 64
    bare = exact_cost(_dot_entry(128, 64, 32, typed=False))
    inlined = exact_cost(_dot_entry(128, 64, 32, typed=True))
    assert bare.flops == pytest.approx(want, rel=1e-9)
    assert inlined.flops == pytest.approx(want, rel=1e-9)
    assert bare.mem_bytes == inlined.mem_bytes > 0


def test_inlined_shape_operands_survive_top_level_comma_split():
    """The typed print puts commas inside operand shapes; a naive
    ``split(",")`` would tear ``f32[128,64]{1,0} %a`` apart and lose the
    contraction dim. mem accounting must also resolve both operand
    styles to the same byte counts."""
    ec = exact_cost(_dot_entry(128, 64, 32, typed=True))
    # dot: result + both operand tensors, all f32
    want_mem = 4 * (128 * 32 + 128 * 64 + 64 * 32)
    assert ec.mem_bytes == want_mem


# ---------------------------------------------------------------------------
# fixtures: while trip counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("escaped", [False, True])
@pytest.mark.parametrize("typed", [False, True])
def test_while_body_multiplied_by_trip_count(escaped, typed):
    ec = exact_cost(_while_module(64, trip=9, escaped=escaped, typed=typed))
    assert ec.flops == pytest.approx(9 * 2 * 64 ** 3, rel=1e-9)


def test_while_without_trip_config_counts_body_once():
    text = _while_module(32, trip=5, escaped=False, typed=False)
    text = text.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "")
    ec = exact_cost(text)
    assert ec.flops == pytest.approx(2 * 32 ** 3, rel=1e-9)


# ---------------------------------------------------------------------------
# fixtures: fusion descent
# ---------------------------------------------------------------------------


def test_fusion_body_flops_counted_through_calls():
    ec = exact_cost(_FUSION_MODULE)
    assert ec.flops == pytest.approx(2 * 32 * 8 * 16, rel=1e-9)


def test_fusion_body_memory_stays_in_vmem():
    """HBM traffic is accounted at fusion granularity: the ENTRY's fusion
    op contributes its result + operand bytes; the body's internal ops
    stream through VMEM and must contribute nothing."""
    ec = exact_cost(_FUSION_MODULE)
    want = 4 * (32 * 8 + 32 * 16 + 16 * 8)  # fusion result + two operands
    assert ec.mem_bytes == want


def test_branch_computations_descend_once_each():
    text = """\
%branch_a (pa: f32[16,16]) -> f32[16,16] {
  %pa = f32[16,16]{1,0} parameter(0)
  ROOT %dot.a = f32[16,16]{1,0} dot(%pa, %pa), \
lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%branch_b (pb: f32[16,16]) -> f32[16,16] {
  %pb = f32[16,16]{1,0} parameter(0)
  ROOT %dot.b = f32[16,16]{1,0} dot(%pb, %pb), \
lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main.3 (i: s32[], x: f32[16,16]) -> f32[16,16] {
  %i = s32[] parameter(0)
  %x = f32[16,16]{1,0} parameter(1)
  ROOT %cond.1 = f32[16,16]{1,0} conditional(%i, %x, %x), \
branch_computations={%branch_a, %branch_b}
}
"""
    ec = exact_cost(text)
    assert ec.flops == pytest.approx(2 * 2 * 16 ** 3, rel=1e-9)


# ---------------------------------------------------------------------------
# fixtures: collective traffic
# ---------------------------------------------------------------------------


def test_collective_traffic_start_halved_done_skipped():
    ec = exact_cost(_COLLECTIVE_MODULE)
    assert ec.coll_bytes["all-reduce"] == 1024 * 4
    # -start result is the (operand, result) tuple -> halved
    assert ec.coll_bytes["all-gather"] == (1024 + 4096) * 4 // 2
    assert ec.coll_bytes["reduce-scatter"] == 0.0
    assert ec.coll_total == 1024 * 4 + (1024 + 4096) * 4 // 2


# ---------------------------------------------------------------------------
# seeded property sweeps (stdlib random; no extra dependencies)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_property_dot_flops_match_analytic(seed):
    rng = random.Random(seed)
    m, k, n = (rng.randint(1, 96) for _ in range(3))
    want = 2 * m * k * n
    for typed in (False, True):
        ec = exact_cost(_dot_entry(m, k, n, typed=typed))
        assert ec.flops == pytest.approx(want, rel=1e-9), \
            f"seed={seed} dims=({m},{k},{n}) typed={typed}"


@pytest.mark.parametrize("seed", SEEDS)
def test_property_trip_count_scales_linearly(seed):
    rng = random.Random(1000 + seed)
    d = rng.randint(2, 48)
    trip = rng.randint(1, 40)
    ec = exact_cost(_while_module(d, trip, escaped=bool(rng.getrandbits(1)),
                                  typed=bool(rng.getrandbits(1))))
    assert ec.flops == pytest.approx(trip * 2 * d ** 3, rel=1e-9), \
        f"seed={seed} d={d} trip={trip}"


@pytest.mark.parametrize("seed", SEEDS)
def test_property_all_reduce_bytes_match_result_size(seed):
    rng = random.Random(2000 + seed)
    numel = rng.randint(1, 1 << 16)
    text = f"""\
%add (x: f32[], y: f32[]) -> f32[] {{
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}}

ENTRY %main.2 (a: f32[{numel}]) -> f32[{numel}] {{
  %a = f32[{numel}]{{0}} parameter(0)
  ROOT %ar.1 = f32[{numel}]{{0}} all-reduce(%a), replica_groups={{}}, \
to_apply=%add
}}
"""
    ec = exact_cost(text)
    assert ec.coll_bytes["all-reduce"] == numel * 4, f"seed={seed} n={numel}"
