"""Elastic rescaling: a checkpoint written under one mesh topology must
restore onto a different topology (the node-loss recovery path), verified
on real multi-device meshes in a subprocess."""
import os
import subprocess
import sys

SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import store
from repro.configs import get_config
from repro.models import api
from repro.parallel import sharding as shd

cfg = get_config("starcoder2-3b").reduced()
with tempfile.TemporaryDirectory() as d:
    # "before": params laid out on a 4x2 (data, model) mesh
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    specs_a = shd.param_pspecs(
        jax.eval_shape(lambda: api.init_params(jax.random.key(0), cfg)), mesh_a)
    shard_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s), specs_a)
    with mesh_a:
        params = jax.jit(lambda: api.init_params(jax.random.key(0), cfg),
                         out_shardings=shard_a)()
    store.save(d, 7, params, meta={"mesh": "4x2"})

    # "after": two nodes lost -> restore onto a 2x2 mesh
    devs = np.array(jax.devices()[:4]).reshape(2, 2)
    mesh_b = jax.sharding.Mesh(devs, ("data", "model"))
    specs_b = shd.param_pspecs(params, mesh_b)
    shard_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs_b)
    restored = store.restore(d, 7, params, shard_b)

    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for leaf, sh in zip(jax.tree.leaves(restored), jax.tree.leaves(
            shard_b, is_leaf=lambda x: isinstance(x, NamedSharding))):
        assert leaf.sharding == sh
print("RESHARD_OK")
"""


def test_elastic_reshard_across_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "RESHARD_OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
