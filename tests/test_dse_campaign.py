"""Tests for repro.dse: Pareto properties, multi-objective evaluation,
the JSONL store, and campaign resume/memoization accounting."""
import json

import pytest

from repro.core import KU115, RAV, ZC706, evaluate_rav
from repro.core.netinfo import vgg16
from repro.dse import (CampaignCell, Objectives, ResultStore, cell_seed,
                       expand_cells, non_dominated, nondominated_sort,
                       pareto_front, rav_hash, run_campaign, run_cell,
                       scalarized_objective)
from repro.dse.campaign import build_net
from repro.dse.cli import main as cli_main, parse_inputs, parse_weights
from repro.dse.pareto import dominates

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Pareto
# ---------------------------------------------------------------------------


def test_dominates_basic():
    assert dominates((2.0, 2.0), (1.0, 2.0))
    assert not dominates((1.0, 2.0), (2.0, 1.0))   # incomparable
    assert not dominates((1.0, 1.0), (1.0, 1.0))   # needs a strict win


def test_non_dominated_keeps_duplicates_and_order():
    vecs = [(1.0, 1.0), (2.0, 0.0), (1.0, 1.0), (0.0, 0.0)]
    assert non_dominated(vecs) == [0, 1, 2]


def test_pareto_front_maps_items():
    items = ["a", "b", "c"]
    vecs = [(1.0, 0.0), (0.0, 1.0), (0.0, 0.5)]
    assert pareto_front(items, vecs) == ["a", "b"]


if HAVE_HYPOTHESIS:

    vec_lists = st.lists(
        st.tuples(st.integers(0, 5), st.integers(0, 5), st.integers(0, 5)),
        min_size=1, max_size=24)

    @given(vec_lists)
    @settings(max_examples=200, deadline=None)
    def test_frontier_is_mutually_nondominated(vecs):
        front = non_dominated(vecs)
        assert front, "frontier of a nonempty set is nonempty"
        for i in front:
            for j in front:
                assert not dominates(vecs[i], vecs[j])

    @given(vec_lists)
    @settings(max_examples=200, deadline=None)
    def test_dominated_points_are_excluded_and_covered(vecs):
        front = set(non_dominated(vecs))
        for i, v in enumerate(vecs):
            if i in front:
                continue
            # every excluded point is dominated by some frontier point
            assert any(dominates(vecs[j], v) for j in front)

    @given(vec_lists)
    @settings(max_examples=100, deadline=None)
    def test_nondominated_sort_partitions(vecs):
        fronts = nondominated_sort(vecs)
        flat = [i for f in fronts for i in f]
        assert sorted(flat) == list(range(len(vecs)))
        for k, front in enumerate(fronts[1:], start=1):
            for i in front:
                assert any(dominates(vecs[j], vecs[i])
                           for j in fronts[k - 1])


# ---------------------------------------------------------------------------
# Objectives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rav", [
    RAV(0, 1, 0.0, 0.0, 0.0),
    RAV(3, 1, 0.4, 0.4, 0.4),
    RAV(6, 2, 0.5, 0.5, 0.5),
    RAV(13, 1, 0.95, 0.95, 0.95),
])
def test_default_scalarization_equals_old_scalar_path(rav):
    """Multi-objective evaluate_rav + default weights == the old
    throughput-only fitness, bit for bit."""
    d = evaluate_rav(vgg16(64), ZC706, rav)
    o = Objectives.from_design(d)
    assert o.scalarize() == d.fitness
    assert scalarized_objective()(d) == d.fitness


def test_objectives_roundtrip_and_canonical_signs():
    d = evaluate_rav(vgg16(64), KU115, RAV(6, 1, 0.5, 0.5, 0.5))
    o = Objectives.from_design(d)
    assert Objectives.from_dict(o.as_dict()) == o
    canon = o.canonical()
    assert canon[0] == o.throughput_ips          # maximized: unchanged
    assert canon[2] == -o.latency_s              # minimized: negated
    assert canon[4] == -o.bram_used
    assert o.latency_s > 0


def test_scalarize_rejects_unknown_objective():
    o = Objectives(1.0, 1.0, 1.0, 1.0, 1.0)
    with pytest.raises(KeyError):
        o.scalarize({"nope": 1.0})


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------


def test_store_roundtrip_and_torn_line(tmp_path):
    p = tmp_path / "s.jsonl"
    s = ResultStore(p)
    s.put({"cell_key": "a", "x": 1})
    s.put({"cell_key": "b", "x": 2})
    s.put({"cell_key": "a", "x": 3})  # last wins
    with p.open("a") as f:
        f.write('{"cell_key": "c", "x":')  # killed mid-append
    s2 = ResultStore(p)
    assert len(s2) == 2
    assert s2.get("a")["x"] == 3
    assert s2.get("b")["x"] == 2
    assert "c" not in s2


def test_rav_hash_matches_pso_cache_resolution():
    a = rav_hash(RAV(3, 1, 0.501, 0.5, 0.5))
    b = rav_hash(RAV(3, 1, 0.499, 0.5, 0.5))
    c = rav_hash(RAV(3, 1, 0.6, 0.5, 0.5))
    assert a == b
    assert a != c


# ---------------------------------------------------------------------------
# Campaign
# ---------------------------------------------------------------------------

_FAST = dict(population=6, iterations=4)


def _small_cells():
    return expand_cells(["vgg16"], [(64, 64)], ["zc706"], [16, 8], [1, 2])


def test_expand_cells_cross_product_and_native_inputs():
    cells = expand_cells(["vgg16", "alexnet"], [(64, 64), (128, 128)],
                         ["ku115"], [16], [1])
    keys = [c.key for c in cells]
    assert len(keys) == len(set(keys))
    # vgg16 crosses with both inputs; alexnet is fixed-topology -> native
    assert sum(c.net == "vgg16" for c in cells) == 2
    assert [c for c in cells if c.net == "alexnet"][0].key == \
        "net=alexnet|in=native|fpga=ku115|prec=16|bmax=1"
    with pytest.raises(KeyError):
        expand_cells(["vgg16"], [(64, 64)], ["nofpga"], [16], [1])
    with pytest.raises(KeyError):
        build_net("notanet")


def test_cell_seed_deterministic_and_distinct():
    cells = _small_cells()
    seeds = [cell_seed(0, c) for c in cells]
    assert seeds == [cell_seed(0, c) for c in cells]
    assert len(set(seeds)) == len(seeds)
    assert cell_seed(1, cells[0]) != cell_seed(0, cells[0])


def test_campaign_resume_does_zero_new_evaluations(tmp_path):
    store = tmp_path / "c.jsonl"
    cells = _small_cells()
    r1 = run_campaign(cells, str(store), **_FAST)
    assert r1.new_cells == len(cells)
    assert r1.new_evaluations > 0
    assert all(rec is not None for rec in r1.records)

    # Re-running a finished campaign is pure memoization.
    r2 = run_campaign(cells, str(store), **_FAST)
    assert r2.new_cells == 0
    assert r2.new_evaluations == 0
    assert r2.reused_cells == len(cells)
    assert r2.records == r1.records


def test_campaign_config_change_invalidates_stored_cells(tmp_path):
    """A store must not serve results searched under different PSO settings
    or objective weights as if they answered the new request."""
    store = tmp_path / "c.jsonl"
    cells = _small_cells()[:2]
    run_campaign(cells, str(store), **_FAST)

    deeper = run_campaign(cells, str(store), population=8, iterations=6)
    assert deeper.new_cells == len(cells)
    assert deeper.new_evaluations > 0

    reweighted = run_campaign(cells, str(store), population=8, iterations=6,
                              weights={"dsp_eff": 1.0})
    assert reweighted.new_cells == len(cells)

    # matching config again -> pure reuse
    again = run_campaign(cells, str(store), population=8, iterations=6,
                         weights={"dsp_eff": 1.0})
    assert again.new_cells == 0
    assert again.new_evaluations == 0


def test_campaign_killed_and_rerun_reuses_partial_store(tmp_path):
    store = tmp_path / "c.jsonl"
    cells = _small_cells()
    # "killed" campaign: only the first two cells finished
    run_campaign(cells[:2], str(store), **_FAST)
    evals_done = sum(r["evaluations"] for r in ResultStore(store))
    r = run_campaign(cells, str(store), **_FAST)
    assert r.reused_cells == 2
    assert r.new_cells == len(cells) - 2
    total = sum(r["evaluations"] for r in ResultStore(store))
    assert r.new_evaluations == total - evals_done


def test_campaign_workers_match_serial(tmp_path):
    cells = _small_cells()[:2]
    serial = run_campaign(cells, str(tmp_path / "a.jsonl"), **_FAST)
    pooled = run_campaign(cells, str(tmp_path / "b.jsonl"), workers=2, **_FAST)
    for a, b in zip(serial.records, pooled.records):
        assert a["rav"] == b["rav"]
        assert a["objectives"] == b["objectives"]
        assert a["evaluations"] == b["evaluations"]


def test_campaign_store_deterministic_across_worker_counts(tmp_path):
    """Same seed, --workers 1 vs --workers 2: the stores are byte-identical
    modulo record order — pool scheduling may only reorder appends, never
    change a record. Wall-clock (``search_time_s``) is the one volatile
    field and is stripped before comparing."""
    cells = _small_cells()
    run_campaign(cells, str(tmp_path / "w1.jsonl"), base_seed=7, **_FAST)
    run_campaign(cells, str(tmp_path / "w2.jsonl"), base_seed=7, workers=2,
                 **_FAST)

    def canonical(path):
        lines = []
        for rec in ResultStore(path):
            rec.pop("search_time_s", None)
            lines.append(json.dumps(rec, sort_keys=True))
        return sorted(lines)

    assert canonical(tmp_path / "w1.jsonl") == canonical(tmp_path / "w2.jsonl")


def test_run_cell_record_schema(tmp_path):
    cell = CampaignCell("vgg16", 64, 64, "zc706", 16, 1)
    rec = run_cell(cell, **_FAST)
    assert rec["cell_key"] == cell.key
    assert rec["rav_hash"] == rav_hash(RAV(**rec["rav"]))
    assert rec["search"] == {"base_seed": 0, "population": 6,
                             "iterations": 4, "weights": None}
    assert set(rec["objectives"]) >= {"throughput_ips", "gops", "latency_s",
                                      "dsp_eff", "bram_used", "feasible"}
    json.dumps(rec)  # JSONL-serializable


def test_campaign_report_frontier_and_ranking(tmp_path):
    cells = _small_cells()
    r = run_campaign(cells, str(tmp_path / "c.jsonl"), **_FAST)
    front = r.frontier()
    assert front
    for rec in front:
        assert len(rec["objectives"]) >= 3
    ranked = r.ranked()
    scores = [Objectives.from_dict(x["objectives"]).scalarize()
              for x in ranked]
    assert scores == sorted(scores, reverse=True)
    # frontier members are mutually non-dominated
    vecs = [Objectives.from_dict(x["objectives"]).canonical() for x in front]
    for i, a in enumerate(vecs):
        assert not any(dominates(b, a) for j, b in enumerate(vecs) if j != i)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_parsers():
    assert parse_inputs("224,320x480") == [(224, 224), (320, 480)]
    assert parse_weights("") is None
    assert parse_weights("throughput_ips=1,dsp_eff=500") == {
        "throughput_ips": 1.0, "dsp_eff": 500.0}


def test_cli_end_to_end(tmp_path, capsys):
    store = tmp_path / "cli.jsonl"
    argv = ["--nets", "vgg16", "--inputs", "64", "--fpgas", "zc706",
            "--precisions", "16,8", "--store", str(store),
            "--population", "6", "--iterations", "4",
            "--frontier-json", str(tmp_path / "front.json")]
    report = cli_main(argv)
    out = capsys.readouterr().out
    assert "Pareto frontier" in out
    assert store.exists()
    front = json.loads((tmp_path / "front.json").read_text())
    assert front and all(len(r["objectives"]) >= 3 for r in front)
    # second invocation resumes from the store
    report2 = cli_main(argv)
    assert report2.new_evaluations == 0
    assert report2.reused_cells == len(report.cells)
