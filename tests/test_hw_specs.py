"""Edge-case tests for :mod:`repro.core.hw_specs`: budget boundary
semantics (``CostEnvelope``), cross-family costing (``pod_cost``),
precision mapping (``alpha_for``), and the calibration scaling hook
(``scaled_spec``)."""
import dataclasses

import pytest

from repro.core.hw_specs import (A100_80G, FPGAS, GPUS, H100, KU115, TPU_V5E,
                                 TPUS, ZC706, CostEnvelope, FPGASpec,
                                 alpha_for, pod_cost, scaled_spec)


# ---------------------------------------------------------------------------
# CostEnvelope boundaries
# ---------------------------------------------------------------------------


def test_envelope_unbounded_admits_everything():
    env = CostEnvelope()
    assert env.admits(1e12, 1e12)
    assert env.capped_axes() == ()
    assert env.describe() == "unbounded"


def test_envelope_admits_exactly_at_cap():
    env = CostEnvelope(usd_per_hour=100.0, watts=5000.0)
    assert env.admits(100.0, 5000.0)
    assert env.admits(0.0, 0.0)


def test_envelope_relative_epsilon_boundary():
    """Float sums that land *at* budget (within the 1e-9 relative slack)
    must not flap infeasible; anything past the slack must."""
    cap = 100.0
    env = CostEnvelope(usd_per_hour=cap)
    assert env.admits(cap * (1 + 0.5e-9), 0.0)   # inside the slack
    assert not env.admits(cap * (1 + 1e-8), 0.0)  # past it
    env_w = CostEnvelope(watts=cap)
    assert env_w.admits(0.0, cap * (1 + 0.5e-9))
    assert not env_w.admits(0.0, cap * (1 + 1e-8))


def test_envelope_each_axis_caps_independently():
    env = CostEnvelope(usd_per_hour=10.0, watts=1000.0)
    assert not env.admits(11.0, 1.0)
    assert not env.admits(1.0, 1001.0)
    only_watts = CostEnvelope(watts=1000.0)
    assert only_watts.admits(1e9, 999.0)
    assert only_watts.capped_axes() == ("watts",)


def test_envelope_capped_axes_order_and_describe():
    env = CostEnvelope(usd_per_hour=150.0, watts=40000.0)
    assert env.capped_axes() == ("usd_per_hour", "watts")
    assert env.describe() == "$150/h and 40000 W"
    assert CostEnvelope(usd_per_hour=2.5).describe() == "$2.5/h"


# ---------------------------------------------------------------------------
# pod_cost across all three spec families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [KU115, ZC706, TPU_V5E, A100_80G, H100],
                         ids=lambda s: s.name)
def test_pod_cost_scales_linearly_per_family(spec):
    w1, d1 = pod_cost(spec)
    assert (w1, d1) == (spec.tdp_watts, spec.usd_per_hour)
    w8, d8 = pod_cost(spec, 8)
    assert w8 == pytest.approx(8 * w1) and d8 == pytest.approx(8 * d1)


def test_every_registered_part_carries_cost_metadata():
    for spec in list(FPGAS.values()) + list(TPUS.values()) + \
            list(GPUS.values()):
        w, d = pod_cost(spec, 2)
        assert w > 0 and d > 0


# ---------------------------------------------------------------------------
# alpha_for precision mapping
# ---------------------------------------------------------------------------


def test_alpha_for_precision_boundaries():
    assert alpha_for(16) == 2
    assert alpha_for(8) == 4    # 8-bit packs two MACs per DSP
    assert alpha_for(9) == 2    # strictly-above-8 falls back
    assert alpha_for(4) == 4
    assert alpha_for(32) == 2


# ---------------------------------------------------------------------------
# scaled_spec (the calibration hook)
# ---------------------------------------------------------------------------


def test_scaled_spec_identity_returns_same_object():
    for spec in (KU115, TPU_V5E, H100):
        assert scaled_spec(spec) is spec
        assert scaled_spec(spec, 1.0, 1.0) is spec


def test_scaled_spec_fpga_scales_clock_and_bandwidth_only():
    s = scaled_spec(KU115, 0.9, 0.8)
    assert s.freq_mhz == pytest.approx(KU115.freq_mhz * 0.9)
    assert s.bw_gbps == pytest.approx(KU115.bw_gbps * 0.8)
    assert (s.dsp, s.bram18k, s.usable_frac) == \
        (KU115.dsp, KU115.bram18k, KU115.usable_frac)
    assert KU115.freq_mhz == 200.0  # frozen source untouched


def test_scaled_spec_tpu_gpu_scale_flops_and_hbm_bw_only():
    t = scaled_spec(TPU_V5E, 0.75, 0.85)
    assert t.peak_flops == pytest.approx(TPU_V5E.peak_flops * 0.75)
    assert t.hbm_bw == pytest.approx(TPU_V5E.hbm_bw * 0.85)
    assert (t.hbm_bytes, t.ici_bw) == (TPU_V5E.hbm_bytes, TPU_V5E.ici_bw)
    g = scaled_spec(H100, 0.5)
    assert g.peak_flops == pytest.approx(H100.peak_flops * 0.5)
    assert (g.hbm_bw, g.nvlink_bw, g.sm_count) == \
        (H100.hbm_bw, H100.nvlink_bw, H100.sm_count)


def test_scaled_spec_rejects_unknown_families():
    with pytest.raises(TypeError):
        scaled_spec(object(), 0.9, 0.9)


def test_scaled_spec_preserves_derived_fpga_properties():
    s = scaled_spec(KU115, 0.5, 1.0)
    assert s.freq == pytest.approx(KU115.freq * 0.5)
    assert s.dsp_usable == KU115.dsp_usable
    assert s.peak_gops() == pytest.approx(KU115.peak_gops() * 0.5)


def test_fpga_usable_fractions_floor_to_int():
    odd = FPGASpec("odd", dsp=999, bram18k=333, bw_gbps=10.0,
                   usable_frac=0.85)
    assert odd.dsp_usable == int(999 * 0.85)
    assert odd.bram_usable == int(333 * 0.85)
    assert odd.bram_bits == 333 * 18 * 1024
    assert dataclasses.replace(odd, usable_frac=1.0).dsp_usable == 999
