"""Tests for the TPU cost model, planner (the DSE retarget), and the
HLO exact-cost parser."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.core.hw_specs import TPU_V5E
from repro.core.tpu_model import (MeshDesc, analytic_roofline, model_flops,
                                  kv_cache_bytes)
from repro.core.tpu_planner import best_plan, candidate_meshes, plan_arch
from repro.launch.hlo_cost import exact_cost


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_model_flops_train_matches_6nd_rule():
    cfg = get_config("starcoder2-15b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    n = cfg.param_count()
    d = shape.global_batch * shape.seq_len
    # with full remat the napkin rule is 8*N*D (+ attention extra)
    assert 0.8 * 8 * n * d < mf < 1.6 * 8 * n * d


def test_model_flops_moe_uses_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    shape = SHAPES["train_4k"]
    mf = model_flops(kimi, shape)
    d = shape.global_batch * shape.seq_len
    assert mf < 8 * kimi.param_count() * d * 0.2, \
        "MoE flops must reflect active (top-k) params, not total"


def test_decode_flops_scale_with_context_for_attention_but_not_ssm():
    dense = get_config("starcoder2-3b")
    ssm = get_config("xlstm-350m")
    d32 = model_flops(dense, SHAPES["decode_32k"])
    s32 = model_flops(ssm, SHAPES["decode_32k"])
    import dataclasses
    short = dataclasses.replace(SHAPES["decode_32k"], seq_len=1024)
    assert model_flops(dense, short) < d32  # KV reads shrink with context
    assert model_flops(ssm, short) == pytest.approx(s32, rel=1e-6)


def test_kv_cache_bytes_window_bounded():
    danube = get_config("h2o-danube-3-4b")  # SWA window 4096
    long = kv_cache_bytes(danube, SHAPES["long_500k"])
    short = kv_cache_bytes(danube, SHAPES["decode_32k"])
    # ring buffer: cache does not grow past the window
    assert long <= short  # batch 1 vs 128 dominates; window caps slots


def test_roofline_terms_positive_and_bounded():
    for arch in ("nemotron-4-340b", "kimi-k2-1t-a32b", "whisper-base"):
        cfg = get_config(arch)
        rl = analytic_roofline(cfg, SHAPES["train_4k"], MeshDesc.single_pod())
        assert rl.t_compute > 0 and rl.t_memory > 0 and rl.t_collective > 0
        assert rl.bound in ("compute", "memory", "collective")


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


def test_candidate_meshes_powers_of_two():
    for chips, dp, tp in candidate_meshes(64):
        assert dp * tp == chips
        assert chips & (chips - 1) == 0


def test_planner_right_sizes_small_models():
    whisper = best_plan(get_config("whisper-base"), SHAPES["decode_32k"])
    nemotron = best_plan(get_config("nemotron-4-340b"), SHAPES["decode_32k"])
    assert whisper.n_chips < nemotron.n_chips, \
        "a 70M model must not get as many chips as a 340B model"


def test_planner_respects_hbm():
    plan = best_plan(get_config("kimi-k2-1t-a32b"), SHAPES["decode_32k"])
    if plan.fits:
        assert plan.hbm_per_chip <= TPU_V5E.hbm_bytes * 0.9


def test_planner_train_prefers_feasible():
    plans = plan_arch(get_config("starcoder2-3b"), SHAPES["train_4k"])
    assert plans[0].fits
    # objective: throughput/chip — best plan should beat a 1-chip-per-way
    # degenerate plan on step*chips
    worst = plans[-1]
    assert (plans[0].predicted_step_s * plans[0].n_chips
            <= worst.predicted_step_s * worst.n_chips)


# ---------------------------------------------------------------------------
# HLO cost parser
# ---------------------------------------------------------------------------


def test_exact_cost_counts_scan_trips():
    def net(x, ws):
        h, _ = jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), x, ws)
        return h.sum()

    c = jax.jit(net).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)).compile()
    ec = exact_cost(c.as_text())
    assert ec.flops == pytest.approx(7 * 2 * 128 ** 3, rel=1e-6)


def test_exact_cost_matches_unrolled():
    """The parser's scan accounting must equal a python-loop lowering."""
    def scanned(x, ws):
        h, _ = jax.lax.scan(lambda h, w: (h @ w, None), x, ws)
        return h.sum()

    def unrolled(x, ws):
        h = x
        for i in range(5):
            h = h @ ws[i]
        return h.sum()

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    es = exact_cost(jax.jit(scanned).lower(xs, ws).compile().as_text())
    eu = exact_cost(jax.jit(unrolled).lower(xs, ws).compile().as_text())
    assert es.flops == pytest.approx(eu.flops, rel=1e-6)


def test_exact_cost_batched_dot():
    c = jax.jit(lambda a, b: jnp.einsum("bij,bjk->bik", a, b)).lower(
        jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
        jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)).compile()
    ec = exact_cost(c.as_text())
    assert ec.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=1e-6)


def test_exact_cost_is_hlo_print_version_aware():
    """Same graph, both operand print styles: older XLA prints bare %name
    references, newer XLA inlines each operand's shape. The parser must
    count identical flops for both."""
    untyped = """\
ENTRY %main.4 (Arg_0.1: f32[128,64], Arg_1.2: f32[64,32]) -> f32[128,32] {
  %Arg_0.1 = f32[128,64]{1,0} parameter(0)
  %Arg_1.2 = f32[64,32]{1,0} parameter(1)
  ROOT %dot.3 = f32[128,32]{1,0} dot(%Arg_0.1, %Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    typed = """\
ENTRY %main.4 (Arg_0.1: f32[128,64], Arg_1.2: f32[64,32]) -> f32[128,32] {
  %Arg_0.1 = f32[128,64]{1,0} parameter(0)
  %Arg_1.2 = f32[64,32]{1,0} parameter(1)
  ROOT %dot.3 = f32[128,32]{1,0} dot(f32[128,64]{1,0} %Arg_0.1, f32[64,32]{1,0} %Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    want = 2 * 128 * 32 * 64
    eu, et = exact_cost(untyped), exact_cost(typed)
    assert eu.flops == pytest.approx(want, rel=1e-6)
    assert et.flops == pytest.approx(want, rel=1e-6)
    assert eu.mem_bytes == et.mem_bytes > 0
