"""Store v2 tests: sharded layout, streaming reader, compaction, no-op
put skipping, migration, and — above all — that v1 single-file stores
keep working byte-for-byte.

The multi-worker claim under test: two campaign processes appending to
their own shards of one ``<store>.d/`` directory must produce the SAME
report (modulo ``search_time_s`` timing) as one process writing a v1
file, and a resumed run against either layout reuses every cell.
"""
import json
import os
import warnings
from pathlib import Path

import pytest

from repro.dse.campaign import expand_cells, run_campaign
from repro.dse.store import (CampaignStore, ResultStore, main as store_main,
                             open_store, shard_name, sharded_dir_for)

CELLS = expand_cells(["vgg16"], [(224, 224)], ["ku115", "zcu102"], [16], [1])
FAST = dict(population=4, iterations=2)


def scrub(rec):
    """A record with volatile timing removed (everything else must be
    bit-stable across layouts and resumes)."""
    return {k: v for k, v in rec.items() if k != "search_time_s"}


# ---------------------------------------------------------------------------
# v1 compatibility
# ---------------------------------------------------------------------------


def test_v1_resume_is_byte_identical(tmp_path):
    p = tmp_path / "v1.jsonl"
    r1 = run_campaign(CELLS, str(p), **FAST)
    blob = p.read_bytes()
    r2 = run_campaign(CELLS, str(p), **FAST)
    assert p.read_bytes() == blob          # resume appended NOTHING
    assert r2.reused_cells == len(CELLS)
    assert r2.new_evaluations == 0
    assert [scrub(a) for a in r1.records] == [scrub(b) for b in r2.records]


def test_v1_handwritten_file_streams_in_order(tmp_path):
    p = tmp_path / "legacy.jsonl"
    rows = [{"cell_key": f"k{i}", "i": i} for i in range(5)]
    rows.append({"cell_key": "k1", "i": 99})   # last-wins rewrite
    p.write_text("".join(json.dumps(r, sort_keys=True) + "\n" for r in rows))
    s = open_store(str(p))
    assert not s.sharded
    got = list(s.iter_records())
    assert [r["cell_key"] for r in got] == ["k0", "k1", "k2", "k3", "k4"]
    assert s.get("k1") == {"cell_key": "k1", "i": 99}   # last wins
    assert len(s) == 5


def test_records_emits_deprecation_warning(tmp_path):
    p = tmp_path / "v1.jsonl"
    s = ResultStore(p)
    s.put({"cell_key": "a", "v": 1})
    with pytest.warns(DeprecationWarning, match="iter_records"):
        recs = s.records()
    assert recs == [{"cell_key": "a", "v": 1}]


# ---------------------------------------------------------------------------
# no-op puts
# ---------------------------------------------------------------------------


def test_noop_put_skips_append(tmp_path):
    p = tmp_path / "v1.jsonl"
    s = CampaignStore(p)
    s.put({"cell_key": "a", "v": 1})
    blob = p.read_bytes()
    s.put({"cell_key": "a", "v": 1})       # identical -> skipped
    assert p.read_bytes() == blob
    assert s.noop_puts == 1
    s.put({"cell_key": "a", "v": 2})       # changed -> appended
    assert p.read_bytes() != blob
    assert s.noop_puts == 1
    assert s.get("a") == {"cell_key": "a", "v": 2}


# ---------------------------------------------------------------------------
# sharded layout
# ---------------------------------------------------------------------------


def test_sharded_two_workers_match_single_file(tmp_path):
    single = run_campaign(CELLS, str(tmp_path / "one.jsonl"), **FAST)
    shared = str(tmp_path / "multi.d")
    # two "hosts", each appending its slice to its own shard
    run_campaign(CELLS[:1], shared, shard=0, **FAST)
    run_campaign(CELLS[1:], shared, shard=1, **FAST)
    d = sharded_dir_for(Path(shared))
    assert (d / shard_name(0)).exists() and (d / shard_name(1)).exists()
    # a resumed full run against the merged shards reuses everything...
    merged = run_campaign(CELLS, shared, shard=0, **FAST)
    assert merged.reused_cells == len(CELLS)
    assert merged.new_evaluations == 0
    # ...and reports exactly what the single-file campaign reported
    assert [scrub(r) for r in merged.records] == \
        [scrub(r) for r in single.records]


def test_auto_layout_detection(tmp_path):
    d = tmp_path / "store.d"
    s = open_store(str(d), shard=3)
    s.put({"cell_key": "a", "v": 1})
    assert s.sharded
    assert (d / shard_name(3)).exists()
    # plain path next to an existing .d dir resolves to the dir
    s2 = open_store(str(tmp_path / "store"))
    assert s2.sharded
    assert s2.get("a") == {"cell_key": "a", "v": 1}


def test_compact_is_last_wins_and_idempotent(tmp_path):
    shared = str(tmp_path / "c.d")
    s0 = open_store(shared, shard=0)
    s1 = open_store(shared, shard=1)
    for i in range(20):
        s0.put({"cell_key": f"k{i}", "v": i})
    for i in range(5, 15):
        s1.put({"cell_key": f"k{i}", "v": 100 + i})
    fresh = open_store(shared, shard=0)
    before = [(r["cell_key"], r["v"]) for r in fresh.iter_records()]
    n = fresh.compact()
    assert n == 20
    d = sharded_dir_for(Path(shared))
    assert sorted(f.name for f in d.glob("shard-*.jsonl")) == [shard_name(0)]
    after = [(r["cell_key"], r["v"]) for r in fresh.iter_records()]
    assert after == before
    blob = (d / shard_name(0)).read_bytes()
    assert fresh.compact() == 20           # idempotent
    assert (d / shard_name(0)).read_bytes() == blob
    # a reopened store sees the same records
    again = open_store(shared)
    assert [(r["cell_key"], r["v"]) for r in again.iter_records()] == before


def test_compact_cli_and_report_stability(tmp_path, capsys):
    from repro.dse.report import render_report
    shared = str(tmp_path / "r.d")
    run_campaign(CELLS[:1], shared, shard=0, **FAST)
    run_campaign(CELLS[1:], shared, shard=1, **FAST)
    before = render_report(open_store(shared).iter_records(),
                           title="compaction check")
    assert store_main(["compact", shared]) == 0
    capsys.readouterr()
    after = render_report(open_store(shared).iter_records(),
                          title="compaction check")
    assert after == before


def test_migrate_cli_v1_to_sharded(tmp_path, capsys):
    src = tmp_path / "src.jsonl"
    s = CampaignStore(src)
    for i in range(7):
        s.put({"cell_key": f"k{i}", "v": i})
    dst = tmp_path / "dst.d"
    assert store_main(["migrate", str(src), str(dst)]) == 0
    capsys.readouterr()
    out = open_store(str(dst))
    assert out.sharded
    assert [r["v"] for r in out.iter_records()] == list(range(7))


def test_info_cli(tmp_path, capsys):
    p = tmp_path / "v1.jsonl"
    CampaignStore(p).put({"cell_key": "a", "v": 1})
    assert store_main(["info", str(p)]) == 0
    out = capsys.readouterr().out
    assert "v1" in out and "1" in out


# ---------------------------------------------------------------------------
# crash consistency
# ---------------------------------------------------------------------------

_KILLED_WRITER = """
import json, os, sys, time
from repro.dse.store import open_store, shard_name, sharded_dir_for

store, sentinel = sys.argv[1], sys.argv[2]
s = open_store(store, shard=1)
for i in range(6):
    s.put({"cell_key": f"k{i}", "v": i})
# now die mid-append: half a record, flushed, no newline — exactly what
# SIGKILL/OOM leaves behind
half = json.dumps({"cell_key": "k-torn", "v": 999})[: 20]
with (sharded_dir_for(store) / shard_name(1)).open("a") as f:
    f.write(half)
    f.flush()
    os.fsync(f.fileno())
open(sentinel, "w").write("ready")
time.sleep(120)       # parent kills us here
"""


def test_sharded_writer_killed_mid_append_heals(tmp_path):
    """Kill a shard-writer process that died halfway through an append:
    the torn final line is tolerated silently (not counted corrupt),
    every completed record survives, and the next writer appends
    normally."""
    import subprocess
    import sys
    import time

    shared = str(tmp_path / "crash.d")
    sentinel = tmp_path / "writer-ready"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.Popen([sys.executable, "-c", _KILLED_WRITER,
                             shared, str(sentinel)], env=env)
    try:
        deadline = time.time() + 60
        while not sentinel.exists():
            assert time.time() < deadline, "writer never reached the torn append"
            assert proc.poll() is None, "writer died early"
            time.sleep(0.05)
        proc.kill()
    finally:
        proc.wait(timeout=30)

    with warnings.catch_warnings():
        warnings.simplefilter("error")         # torn tail must NOT warn
        survivor = open_store(shared, shard=2)
    assert survivor.corrupt_lines == 0
    assert survivor.skipped_lines == 1         # the torn line, dropped
    assert [r["v"] for r in survivor.iter_records()] == list(range(6))
    assert survivor.get("k-torn") is None      # partial append re-runs
    survivor.put({"cell_key": "k-torn", "v": 7})
    reread = open_store(shared)
    assert reread.get("k-torn")["v"] == 7


def test_mid_file_corruption_counts_and_warns(tmp_path):
    shared = str(tmp_path / "bad.d")
    s = open_store(shared, shard=0)
    for i in range(3):
        s.put({"cell_key": f"k{i}", "v": i})
    f = sharded_dir_for(Path(shared)) / shard_name(0)
    lines = f.read_text().splitlines()
    lines[1] = lines[1][: len(lines[1]) // 2]     # damage a MIDDLE line
    f.write_text("\n".join(lines) + "\n")
    with pytest.warns(RuntimeWarning, match="1 corrupt non-final"):
        again = open_store(shared)
    assert again.corrupt_lines == 1
    assert sorted(r["v"] for r in again.iter_records()) == [0, 2]


def test_compact_drops_superseded_quarantine(tmp_path):
    """A quarantined cell later retried to success: compaction keeps only
    the last-wins success line — the failure leaves no trace in the
    compacted store."""
    from repro.dse.store import is_ok

    shared = str(tmp_path / "q.d")
    s = open_store(shared, shard=0)
    s.put({"cell_key": "cell-a", "status": "failed", "quarantine_schema": 1,
           "error_type": "RuntimeError", "attempts": 3, "evaluations": 0})
    s.put({"cell_key": "cell-b", "v": 1})
    s.put({"cell_key": "cell-a", "v": 2,
           "objectives": {"feasible": True}})    # --retry-failed success
    fresh = open_store(shared)
    assert fresh.compact() == 2
    recs = {r["cell_key"]: r for r in open_store(shared).iter_records()}
    assert len(recs) == 2
    assert is_ok(recs["cell-a"]) and recs["cell-a"]["v"] == 2
    blob = (sharded_dir_for(Path(shared)) / shard_name(0)).read_text()
    assert '"failed"' not in blob
