"""Tests for the CUDA retarget: the GPU roofline model/planner, the
``cuda`` campaign backend, the normalized cross-backend objectives, and
the report compare mode."""
import json

import pytest

from repro.configs import SHAPES, get_config
from repro.core.gpu_model import (GPUS, NVLINK_EFFICIENCY, analytic_roofline,
                                  collective_bw)
from repro.core.gpu_planner import best_plan, evaluate_point, plan_arch
from repro.core.hw_specs import A100_40G, A100_80G, H100, TPU_V5E
from repro.core.tpu_model import MeshDesc
from repro.core.tpu_planner import evaluate_point as tpu_evaluate_point
from repro.dse import (NORMALIZED_OBJECTIVES, canonical_vector, diverse_front,
                       normalized_throughput, run_campaign, scalarize_values)
from repro.dse.backends import BACKENDS, CUDACell, get_backend
from repro.dse.cli import main as cli_main
from repro.dse.report import render_compare, render_report
from repro.dse.store import ResultStore


# ---------------------------------------------------------------------------
# gpu_model: the SM/HBM/NVLink roofline
# ---------------------------------------------------------------------------


def test_gpu_spec_table_has_required_parts():
    assert {"a100-40g", "a100-80g", "h100"} <= set(GPUS)
    for g in GPUS.values():
        assert g.peak_flops > 0 and g.hbm_bw > 0 and g.nvlink_bw > 0
        assert g.tdp_watts > 0 and g.usd_per_hour > 0
    assert A100_80G.hbm_bytes == 2 * A100_40G.hbm_bytes
    assert H100.peak_flops > A100_80G.peak_flops


def test_gpu_roofline_terms_positive_and_bound_named():
    cfg, shape = get_config("starcoder2-3b"), SHAPES["train_4k"]
    rl = analytic_roofline(cfg, shape, MeshDesc(8, 8, 1), A100_80G)
    assert rl.t_compute > 0 and rl.t_memory > 0 and rl.t_collective > 0
    assert rl.bound in ("compute", "memory", "collective")
    assert rl.step_time == max(rl.t_compute, rl.t_memory, rl.t_collective)


def test_gpu_roofline_h100_beats_a100_at_same_mesh():
    cfg, shape = get_config("starcoder2-3b"), SHAPES["train_4k"]
    mesh = MeshDesc(8, 8, 1)
    a = analytic_roofline(cfg, shape, mesh, A100_80G)
    h = analytic_roofline(cfg, shape, mesh, H100)
    assert h.step_time < a.step_time


def test_gpu_collective_bw_drops_across_node_boundary():
    """A mesh inside one NVSwitch domain runs collectives at NVLink rate;
    one that spans nodes is gated by the per-GPU IB NIC."""
    within = collective_bw(MeshDesc(8, 8, 1), H100)
    across = collective_bw(MeshDesc(16, 16, 1), H100)
    assert within == NVLINK_EFFICIENCY * H100.nvlink_bw
    assert across == NVLINK_EFFICIENCY * H100.ib_bw
    assert across < within


# ---------------------------------------------------------------------------
# gpu_planner: parallel to tpu_planner
# ---------------------------------------------------------------------------


def test_gpu_evaluate_point_mirrors_tpu_shape():
    cfg, shape = get_config("starcoder2-3b"), SHAPES["train_4k"]
    g = evaluate_point(cfg, shape, 8, 8, 1, "full", 1, A100_80G)
    t = tpu_evaluate_point(cfg, shape, 8, 8, 1, "full", 1, TPU_V5E)
    # same fields describe both plans (plus the GPU part name)
    assert g.gpu == "a100-80g" and g.n_gpus == 8
    assert (g.dp, g.tp, g.remat, g.microbatches) == \
        (t.dp, t.tp, t.remat, t.microbatches)
    # identical workload napkin: same HBM demand model on both sides
    assert g.hbm_per_gpu == t.hbm_per_chip
    assert 0 < g.mfu <= 1.0
    assert "a100-80g" in g.pretty()


def test_gpu_hbm_fit_gate_uses_part_capacity():
    """The same mapping overflows the 40G part but fits the 80G part —
    HBM demand is workload-side, the gate is hardware-side."""
    cfg, shape = get_config("starcoder2-3b"), SHAPES["train_4k"]
    small = evaluate_point(cfg, shape, 8, 8, 1, "none", 2, A100_40G)
    big = evaluate_point(cfg, shape, 8, 8, 1, "none", 2, A100_80G)
    assert small.hbm_per_gpu == big.hbm_per_gpu
    assert small.hbm_per_gpu > A100_40G.hbm_bytes * 0.9
    assert not small.fits and big.fits


def test_mfu_excludes_recompute_flops():
    """A compute-bound full-remat training design spends 8ND of compute
    per 6ND of model work: MFU must report 0.75, and the normalized
    delivered TFLOP/s must stay below the datasheet peak."""
    cfg, shape = get_config("xlstm-350m"), SHAPES["train_4k"]
    full = evaluate_point(cfg, shape, 8, 8, 1, "full", 1, H100)
    none = evaluate_point(cfg, shape, 8, 8, 1, "none", 1, H100)
    if full.roofline.bound == "compute":
        assert full.mfu == pytest.approx(0.75)
    assert none.mfu <= 1.0
    # and on the TPU side the same accounting holds
    t = tpu_evaluate_point(cfg, shape, 8, 8, 1, "full", 1, TPU_V5E)
    if t.roofline.bound == "compute":
        assert t.mfu == pytest.approx(0.75)


def test_gpu_plan_arch_sorts_feasible_first():
    cfg, shape = get_config("xlstm-350m"), SHAPES["train_4k"]
    plans = plan_arch(cfg, shape, A100_80G, max_gpus=32)
    assert plans
    feas_flags = [p.fits for p in plans]
    assert feas_flags == sorted(feas_flags, reverse=True), \
        "all feasible plans must sort before all infeasible ones"
    assert best_plan(cfg, shape, hw=A100_80G, max_gpus=32).pretty() == \
        plans[0].pretty()


# ---------------------------------------------------------------------------
# cuda backend: cells, records, campaigns
# ---------------------------------------------------------------------------


def test_cuda_expand_cells_axes_validation_and_collapse():
    be = get_backend("cuda")
    cells = be.expand_cells(archs=["starcoder2-3b"],
                            shapes=["train_4k", "decode_32k"],
                            gpus=[8, 16], gpu_types=("a100-80g", "h100"),
                            remats=("full", "none"), microbatches=(1, 2))
    keys = [c.key for c in cells]
    assert len(keys) == len(set(keys))
    # train: 2 types x 2 counts x 2 remats x 2 mb = 16; decode collapses
    assert sum(c.shape == "train_4k" for c in cells) == 16
    decode = [c for c in cells if c.shape == "decode_32k"]
    assert len(decode) == 4
    assert all(c.remat == "none" and c.microbatches == 1 for c in decode)
    with pytest.raises(KeyError):
        be.expand_cells(archs=["starcoder2-3b"], shapes=["train_4k"],
                        gpus=[8], gpu_types=("rtx4090",))
    with pytest.raises(ValueError):
        be.expand_cells(archs=["starcoder2-3b"], shapes=["train_4k"],
                        gpus=[12])
    # spec-disabled combos skipped (full attention at 500k context)
    long = be.expand_cells(archs=["starcoder2-3b", "xlstm-350m"],
                           shapes=["long_500k"], gpus=[8])
    assert {c.arch for c in long} == {"xlstm-350m"}


def test_cuda_run_cell_schema_and_determinism():
    be = get_backend("cuda")
    cell = CUDACell("starcoder2-3b", "train_4k", "h100", 16, "full", 2)
    rec = be.run_cell(cell)
    assert rec["backend"] == "cuda"
    assert rec["cell_key"] == cell.key
    assert rec["cell"]["gpu"] == "h100"
    assert set(rec["objectives"]) == {"step_time_s", "mfu", "hbm_gib",
                                      "gpus", "watts", "feasible"}
    assert rec["objectives"]["watts"] == 16 * 700.0
    assert rec["plan"]["dp"] * rec["plan"]["tp"] == 16
    assert rec["evaluations"] > 0
    json.dumps(rec)  # JSONL-serializable
    assert be.run_cell(cell)["objectives"] == rec["objectives"]
    with pytest.raises(ValueError):
        be.run_cell(CUDACell("xlstm-350m", "train_4k", "h100", 12,
                             "full", 1))


def test_cuda_campaign_resume_and_search_config_rejection(tmp_path):
    """A stored cell only counts as done under the SAME search config;
    re-weighting re-runs every cell instead of serving stale mappings."""
    be = get_backend("cuda")
    store = tmp_path / "c.jsonl"
    cells = be.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                            gpus=[8, 16], gpu_types=("a100-80g",),
                            remats=("full",), microbatches=(1,))
    r1 = run_campaign(cells, str(store), backend="cuda")
    assert r1.new_cells == len(cells) and r1.new_evaluations > 0
    r2 = run_campaign(cells, str(store), backend="cuda")
    assert r2.new_cells == 0 and r2.new_evaluations == 0
    r3 = run_campaign(cells, str(store), backend="cuda",
                      weights={"watts": 1.0})
    assert r3.new_cells == len(cells)
    # pso knobs are irrelevant to the deterministic enumeration
    r4 = run_campaign(cells, str(store), backend="cuda",
                      weights={"watts": 1.0}, population=99)
    assert r4.new_cells == 0


def test_cuda_cli_end_to_end(tmp_path, capsys):
    store = tmp_path / "cuda.jsonl"
    argv = ["--backend", "cuda", "--archs", "xlstm-350m",
            "--shapes", "train_4k", "--gpus", "8",
            "--gpu-types", "a100-80g,h100", "--remats", "full",
            "--microbatches", "1", "--store", str(store)]
    report = cli_main(argv)
    out = capsys.readouterr().out
    assert "campaign[cuda]" in out and "Pareto frontier" in out
    assert store.exists()
    assert ResultStore(store).backends() == ["cuda"]
    report2 = cli_main(argv)
    assert report2.new_evaluations == 0
    assert report2.reused_cells == len(report.cells)


# ---------------------------------------------------------------------------
# normalized cross-backend objectives
# ---------------------------------------------------------------------------


def test_normalized_throughput_helper():
    n = normalized_throughput(10.0, watts=500.0, usd_per_hour=2.0,
                              peak_tflops=40.0)
    assert n["tflops"] == 10.0
    assert n["tflops_per_watt"] == pytest.approx(0.02)
    assert n["tflops_per_dollar"] == pytest.approx(5.0)
    assert n["tflops_per_peak"] == pytest.approx(0.25)
    assert n["feasible"] is True
    assert canonical_vector(n, NORMALIZED_OBJECTIVES) == \
        (10.0, pytest.approx(0.02), pytest.approx(5.0), pytest.approx(0.25))
    assert scalarize_values({**n, "feasible": False},
                            NORMALIZED_OBJECTIVES) == 0.0


def test_every_backend_normalizes_its_own_records():
    fpga_rec = {
        "cell": {"net": "vgg16", "h": 64, "w": 64, "fpga": "ku115",
                 "precision": 16, "batch_max": 1},
        "objectives": {"throughput_ips": 100.0, "gops": 2000.0,
                       "latency_s": 0.01, "dsp_eff": 0.8,
                       "bram_used": 100.0, "feasible": True},
    }
    tpu_rec = {
        "cell": {"arch": "a", "shape": "s", "chips": 8, "remat": "full",
                 "microbatches": 1},
        "objectives": {"step_time_s": 1.0, "mfu": 0.5, "hbm_gib": 4.0,
                       "chips": 8.0, "feasible": True},
    }
    cuda_rec = {
        "cell": {"arch": "a", "shape": "s", "gpu": "h100", "gpus": 8,
                 "remat": "full", "microbatches": 1},
        "objectives": {"step_time_s": 1.0, "mfu": 0.5, "hbm_gib": 4.0,
                       "gpus": 8.0, "watts": 5600.0, "feasible": True},
    }
    for name, rec in (("fpga", fpga_rec), ("tpu", tpu_rec),
                      ("cuda", cuda_rec)):
        norm = get_backend(name).normalized(rec)
        assert set(norm) == {s.name for s in NORMALIZED_OBJECTIVES} | \
            {"feasible"}
        assert all(v >= 0 for k, v in norm.items() if k != "feasible")
    # spot-check the arithmetic against the spec tables
    assert get_backend("fpga").normalized(fpga_rec)["tflops"] == \
        pytest.approx(2.0)
    tpu_norm = get_backend("tpu").normalized(tpu_rec)
    assert tpu_norm["tflops"] == \
        pytest.approx(0.5 * 8 * TPU_V5E.peak_flops / 1e12)
    assert tpu_norm["tflops_per_peak"] == pytest.approx(0.5)  # == MFU
    cuda_norm = get_backend("cuda").normalized(cuda_rec)
    assert cuda_norm["tflops_per_watt"] == \
        pytest.approx(0.5 * 8 * H100.peak_flops / 1e12 / 5600.0)


def test_normalized_frontier_compares_across_backends():
    """Records from different backends land on ONE frontier in
    normalized units."""
    recs = [get_backend("tpu").run_cell(c) for c in
            get_backend("tpu").expand_cells(archs=["xlstm-350m"],
                                            shapes=["train_4k"], chips=[8],
                                            remats=("full",),
                                            microbatches=(1,))]
    recs += [get_backend("cuda").run_cell(c) for c in
             get_backend("cuda").expand_cells(archs=["xlstm-350m"],
                                              shapes=["train_4k"], gpus=[8],
                                              gpu_types=("a100-80g", "h100"),
                                              remats=("full",),
                                              microbatches=(1,))]
    norms = [get_backend(r["backend"]).normalized(r) for r in recs]
    vecs = [canonical_vector(n, NORMALIZED_OBJECTIVES) for n in norms]
    front = diverse_front(vecs)
    assert front  # one comparable frontier exists
    assert len({recs[i]["backend"] for i in range(len(recs))}) == 2


# ---------------------------------------------------------------------------
# report: cross-backend section + compare mode
# ---------------------------------------------------------------------------


def _mini_stores(tmp_path):
    tpu_store = tmp_path / "tpu.jsonl"
    cuda_store = tmp_path / "cuda.jsonl"
    be_t, be_c = get_backend("tpu"), get_backend("cuda")
    run_campaign(be_t.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                                   chips=[8], remats=("full",),
                                   microbatches=(1,)),
                 str(tpu_store), backend="tpu")
    run_campaign(be_c.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                                   gpus=[8], gpu_types=("a100-80g", "h100"),
                                   remats=("full",), microbatches=(1,)),
                 str(cuda_store), backend="cuda")
    return tpu_store, cuda_store


def test_mixed_store_report_gets_cross_backend_section(tmp_path):
    tpu_store, cuda_store = _mini_stores(tmp_path)
    mixed = [*ResultStore(tpu_store).iter_records(),
             *ResultStore(cuda_store).iter_records()]
    md = render_report(mixed)
    assert "## Cross-backend frontier (normalized objectives)" in md
    assert "### Backend champions" in md
    assert "`tflops`" in md
    # single-backend stores do NOT get the section
    md_single = render_report(ResultStore(tpu_store).iter_records())
    assert "Cross-backend frontier" not in md_single


def test_render_compare_winner_deltas_and_trajectories(tmp_path):
    tpu_store, cuda_store = _mini_stores(tmp_path)
    md = render_compare([("tpu", ResultStore(tpu_store).iter_records()),
                         ("cuda", ResultStore(cuda_store).iter_records())])
    assert "## Per-workload winner deltas" in md
    assert "## Objective trajectories" in md
    assert "## Cross-backend frontier (normalized objectives)" in md
    # the shared workload appears with a winner column filled in
    assert "xlstm-350m/train_4k" in md
    assert "| winner |" not in md.split("Per-workload winner deltas")[0]
    with pytest.raises(ValueError):
        render_compare([("only", ResultStore(tpu_store).iter_records())])


def test_report_compare_cli(tmp_path):
    from repro.dse.report import main as report_main
    tpu_store, cuda_store = _mini_stores(tmp_path)
    out = tmp_path / "cmp.md"
    rc = report_main(["--compare", str(tpu_store), str(cuda_store),
                      "--out", str(out)])
    assert rc == 0
    md = out.read_text()
    for section in ("Per-workload winner deltas", "Objective trajectories",
                    "Cross-backend frontier"):
        assert section in md


def test_backend_registry_includes_cuda():
    assert "cuda" in BACKENDS
    assert BACKENDS["cuda"].objective_names() == (
        "step_time_s", "mfu", "hbm_gib", "gpus", "watts")
