"""Tests for backend-agnostic campaigns: the backend protocol (fpga
byte-compat + tpu cells), crowding-distance frontier diversity, and the
Markdown report generator."""
import json
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core.tpu_planner import plan_arch
from repro.dse import (CampaignReport, crowding_distance, canonical_vector,
                       dominates, run_campaign, scalarize_values,
                       select_diverse)
from repro.dse.backends import (BACKENDS, TPUCell, get_backend,
                                record_backend, run_cell_by_backend)
from repro.dse.campaign import CampaignCell, _search_config, run_cell
from repro.dse.cli import main as cli_main
from repro.dse.objectives import OBJECTIVES
from repro.dse.report import fixture_records, render_report
from repro.dse.report import main as report_main
from repro.dse.store import ResultStore

_FAST = dict(population=6, iterations=4)


# ---------------------------------------------------------------------------
# crowding distance / diverse selection
# ---------------------------------------------------------------------------


def test_crowding_distance_boundaries_are_infinite():
    vecs = [(0.0, 10.0), (5.0, 5.0), (10.0, 0.0)]
    cd = crowding_distance(vecs)
    assert cd[0] == math.inf and cd[2] == math.inf
    assert 0.0 < cd[1] < math.inf
    assert crowding_distance([(1.0, 2.0)]) == [math.inf]
    assert crowding_distance([]) == []


def test_crowding_distance_ranks_lonely_above_clumped():
    # b is in the middle of a clump; c sits alone in a gap
    vecs = [(0.0, 1.0), (0.48, 0.52), (0.5, 0.5), (0.52, 0.48), (1.0, 0.0)]
    cd = crowding_distance(vecs)
    clump_mid = cd[2]
    assert all(cd[i] == math.inf for i in (0, 4))
    assert cd[1] > clump_mid and cd[3] > clump_mid  # clump edges less crowded


def test_crowding_distance_degenerate_objective_ignored():
    vecs = [(0.0, 7.0), (0.5, 7.0), (1.0, 7.0)]
    cd = crowding_distance(vecs)
    assert cd[0] == cd[2] == math.inf
    assert cd[1] < math.inf  # dim 1 (constant) contributed nothing


def test_degenerate_objective_does_not_shield_interior_points():
    """A constant objective (e.g. a campaign run at a single --chips
    value) must not hand out spurious inf and let an interior point
    outlive a true extreme under truncation."""
    vecs = [(0.5, 0.5, 7.0), (0.0, 1.0, 7.0), (1.0, 0.0, 7.0)]
    cd = crowding_distance(vecs)
    assert cd[1] == cd[2] == math.inf
    assert cd[0] < math.inf
    assert set(select_diverse(vecs, 2)) == {1, 2}, \
        "both true extremes must survive k=2 truncation"
    # identical duplicates are equally (finitely) crowded
    assert crowding_distance([(1.0, 1.0), (1.0, 1.0)]) == [0.0, 0.0]


def test_select_diverse_returns_spread_not_clump():
    # first front: two extremes + a 3-point clump near the middle
    front = [(0.0, 10.0), (5.0, 5.0), (5.05, 4.95), (4.95, 5.05),
             (10.0, 0.0)]
    picked = select_diverse(front, 3)
    assert 0 in picked and 4 in picked, "extremes must survive truncation"
    assert len([i for i in picked if i in (1, 2, 3)]) == 1, \
        "only one member of the clump should survive"


def test_select_diverse_rank_ties_broken_by_spread_then_index():
    # duplicated clump points have identical crowding -> index breaks tie
    front = [(0.0, 1.0), (0.5, 0.5), (0.5, 0.5), (1.0, 0.0)]
    picked = select_diverse(front, 4)
    assert picked[:2] in ([0, 3], [0, 1]) or picked[0] == 0
    assert picked == select_diverse(front, 4)  # deterministic
    # crowding order puts the inf-distance extremes before the clump
    assert set(picked[:2]) == {0, 3}
    assert picked[2:] == [1, 2]  # equal crowding -> input order


def test_select_diverse_fills_from_later_fronts():
    vecs = [(1.0, 1.0), (0.0, 0.0), (0.5, 0.5)]  # fronts: [0], [2], [1]
    assert select_diverse(vecs, 2) == [0, 2]
    assert select_diverse(vecs, 3) == [0, 2, 1]
    assert select_diverse(vecs, 0) == []
    assert select_diverse(vecs, 99) == [0, 2, 1]


# ---------------------------------------------------------------------------
# generic objective helpers
# ---------------------------------------------------------------------------


def test_canonical_vector_and_scalarize_values_generic():
    be = get_backend("tpu")
    obj = {"step_time_s": 2.0, "mfu": 0.5, "hbm_gib": 4.0, "chips": 8.0,
           "feasible": True}
    assert canonical_vector(obj, be.objectives) == (-2.0, 0.5, -4.0, -8.0)
    assert be.scalarize(obj) == -2.0  # default weights: step_time_s only
    assert be.scalarize(obj, {"mfu": 2.0}) == 1.0
    assert scalarize_values({**obj, "feasible": False}, be.objectives) == 0.0
    with pytest.raises(KeyError):
        be.scalarize(obj, {"gops": 1.0})  # fpga objective, wrong backend


# ---------------------------------------------------------------------------
# CLI axis parsing (edge cases)
# ---------------------------------------------------------------------------


def test_parse_inputs_edge_cases():
    from repro.dse.backends import parse_inputs
    assert parse_inputs("224") == [(224, 224)]
    assert parse_inputs("320x480") == [(320, 480)]
    assert parse_inputs(" 224 ,  320x480 ") == [(224, 224), (320, 480)]
    assert parse_inputs("320 x 480") == [(320, 480)]  # int() strips spaces
    assert parse_inputs("224x") == [(224, 224)]       # trailing x: square
    assert parse_inputs("") == []
    assert parse_inputs(", ,") == []
    for bad in ("abc", "x224", "320xx480", "320x480x640", "3.5"):
        with pytest.raises(ValueError, match="bad input size"):
            parse_inputs(bad)


def test_parse_weights_edge_cases():
    from repro.dse.backends import parse_weights
    assert parse_weights("") is None
    assert parse_weights("a=1,b=2.5") == {"a": 1.0, "b": 2.5}
    assert parse_weights(" a = 1 ") == {"a": 1.0}  # whitespace stripped
    # empty value and bare name both mean weight 1.0
    assert parse_weights("mfu=") == {"mfu": 1.0}
    assert parse_weights("mfu") == {"mfu": 1.0}
    assert parse_weights("step_time_s=-2") == {"step_time_s": -2.0}
    with pytest.raises(ValueError, match="bad weight token"):
        parse_weights("=5")
    with pytest.raises(ValueError, match="bad weight value"):
        parse_weights("mfu=fast")


# ---------------------------------------------------------------------------
# registry + fpga byte-compat
# ---------------------------------------------------------------------------


def test_backend_registry():
    assert set(BACKENDS) == {"fpga", "tpu", "cuda"}
    assert get_backend("fpga") is BACKENDS["fpga"]
    assert get_backend(BACKENDS["tpu"]) is BACKENDS["tpu"]
    with pytest.raises(KeyError):
        get_backend("npu")
    assert record_backend({"backend": "tpu"}) == "tpu"
    assert record_backend({}) == "fpga"  # legacy PR-1 records


def test_fpga_backend_is_byte_compatible_with_module_functions():
    be = get_backend("fpga")
    assert be.objectives is OBJECTIVES
    cell = CampaignCell("vgg16", 64, 64, "zc706", 16, 1)
    drop_time = lambda r: {k: v for k, v in r.items()
                           if k != "search_time_s"}
    via_backend = be.run_cell(cell, **_FAST)
    via_module = run_cell(cell, **_FAST)
    assert drop_time(via_backend) == drop_time(via_module)
    assert "backend" not in via_backend, \
        "fpga records must stay byte-compatible with PR-1 stores"
    assert be.search_config(base_seed=0, weights=None, **_FAST) == \
        _search_config(0, 6, 4, None) == via_backend["search"]
    assert drop_time(run_cell_by_backend("fpga", cell, 0, 6, 4, None)) == \
        drop_time(via_module)


# ---------------------------------------------------------------------------
# tpu backend
# ---------------------------------------------------------------------------


def test_tpu_expand_cells_axes_and_collapse():
    be = get_backend("tpu")
    cells = be.expand_cells(archs=["starcoder2-3b"],
                            shapes=["train_4k", "decode_32k"],
                            chips=[8, 16], remats=("full", "none"),
                            microbatches=(1, 2))
    keys = [c.key for c in cells]
    assert len(keys) == len(set(keys))
    # train: 2 chips x 2 remats x 2 mb = 8; decode collapses to (none, 1)
    assert sum(c.shape == "train_4k" for c in cells) == 8
    decode = [c for c in cells if c.shape == "decode_32k"]
    assert len(decode) == 2
    assert all(c.remat == "none" and c.microbatches == 1 for c in decode)


def test_tpu_expand_cells_skips_spec_disabled_combos():
    be = get_backend("tpu")
    # full attention at 500k context is disabled per spec; xlstm (ssm) runs
    cells = be.expand_cells(archs=["starcoder2-3b", "xlstm-350m"],
                            shapes=["long_500k"], chips=[8])
    assert {c.arch for c in cells} == {"xlstm-350m"}


def test_tpu_expand_cells_validation():
    be = get_backend("tpu")
    with pytest.raises(KeyError):
        be.expand_cells(archs=["notanarch"], shapes=["train_4k"], chips=[8])
    with pytest.raises(KeyError):
        be.expand_cells(archs=["xlstm-350m"], shapes=["noshape"], chips=[8])
    with pytest.raises(ValueError):
        be.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                        chips=[12])  # not a power of two
    with pytest.raises(ValueError):
        be.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                        chips=[8], remats=("sometimes",))
    # expand_cells can be bypassed (TPUCell is public); run_cell must not
    # silently evaluate inconsistent dp x tp splits of a non-2^k count
    with pytest.raises(ValueError):
        be.run_cell(TPUCell("xlstm-350m", "train_4k", 12, "full", 1))


def test_tpu_run_cell_schema_and_determinism():
    be = get_backend("tpu")
    cell = TPUCell("starcoder2-3b", "train_4k", 16, "full", 2)
    rec = be.run_cell(cell)
    assert rec["backend"] == "tpu"
    assert rec["cell_key"] == cell.key
    assert rec["cell"] == {"arch": "starcoder2-3b", "shape": "train_4k",
                           "chips": 16, "remat": "full", "microbatches": 2}
    assert set(rec["objectives"]) == {"step_time_s", "mfu", "hbm_gib",
                                      "chips", "feasible"}
    assert rec["plan"]["dp"] * rec["plan"]["tp"] == 16
    assert rec["evaluations"] > 0
    assert rec["search"] == {"weights": None}
    json.dumps(rec)  # JSONL-serializable
    rec2 = be.run_cell(cell)
    for k in ("objectives", "plan", "cell_key", "search", "fitness"):
        assert rec2[k] == rec[k]


def test_tpu_run_cell_picks_planner_best_mapping():
    """The cell's chosen dp x tp must match the exhaustive planner's best
    plan for the same (chips, remat, microbatches) slice."""
    cfg, shape = get_config("starcoder2-3b"), SHAPES["train_4k"]
    cell = TPUCell("starcoder2-3b", "train_4k", 16, "full", 1)
    rec = get_backend("tpu").run_cell(cell)
    slice_ = [p for p in plan_arch(cfg, shape, max_chips=16)
              if p.n_chips == 16 and p.remat == "full"
              and p.microbatches == 1]
    assert slice_, "planner slice must be non-empty"
    top = slice_[0]  # plan_arch sorts feasible-first, then step*chips
    assert (rec["plan"]["dp"], rec["plan"]["tp"]) == (top.dp, top.tp)
    assert rec["objectives"]["step_time_s"] == \
        pytest.approx(top.predicted_step_s)
    assert rec["objectives"]["feasible"] == top.fits


def test_tpu_campaign_resume_and_weight_invalidation(tmp_path):
    be = get_backend("tpu")
    store = tmp_path / "t.jsonl"
    cells = be.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                            chips=[8, 16], remats=("full",),
                            microbatches=(1,))
    r1 = run_campaign(cells, str(store), backend="tpu")
    assert r1.new_cells == len(cells) and r1.new_evaluations > 0
    r2 = run_campaign(cells, str(store), backend="tpu")
    assert r2.new_cells == 0 and r2.new_evaluations == 0
    # re-weighting changes the per-cell mapping choice -> re-runs
    r3 = run_campaign(cells, str(store), backend="tpu",
                      weights={"hbm_gib": 1.0})
    assert r3.new_cells == len(cells)
    # pso knobs are irrelevant to the deterministic planner -> still reused
    r4 = run_campaign(cells, str(store), backend="tpu",
                      weights={"hbm_gib": 1.0}, population=99, iterations=7)
    assert r4.new_cells == 0


def test_tpu_campaign_workers_match_serial(tmp_path):
    be = get_backend("tpu")
    cells = be.expand_cells(archs=["xlstm-350m"], shapes=["decode_32k"],
                            chips=[8, 16], remats=("none",),
                            microbatches=(1,))
    serial = run_campaign(cells, str(tmp_path / "a.jsonl"), backend="tpu")
    pooled = run_campaign(cells, str(tmp_path / "b.jsonl"), backend="tpu",
                          workers=2)
    for a, b in zip(serial.records, pooled.records):
        assert a["objectives"] == b["objectives"]
        assert a["plan"] == b["plan"]


def test_store_backend_filter(tmp_path):
    s = ResultStore(tmp_path / "m.jsonl")
    s.put({"cell_key": "a", "objectives": {}})                    # legacy fpga
    s.put({"cell_key": "b", "backend": "tpu", "objectives": {}})
    assert s.backends() == ["fpga", "tpu"]
    assert [r["cell_key"] for r in s.iter_records("fpga")] == ["a"]
    assert [r["cell_key"] for r in s.iter_records("tpu")] == ["b"]
    assert len(list(s.iter_records())) == 2


# ---------------------------------------------------------------------------
# CampaignReport.frontier(k)
# ---------------------------------------------------------------------------


def _tpu_report_from(records):
    return CampaignReport(cells=[], records=records, reused_cells=0,
                          new_cells=0, new_evaluations=0, wall_time_s=0.0,
                          backend=get_backend("tpu"))


def _tpu_rec(key, step, mfu, hbm=1.0, chips=8.0, feasible=True):
    return {"cell_key": key,
            "objectives": {"step_time_s": step, "mfu": mfu, "hbm_gib": hbm,
                           "chips": chips, "feasible": feasible}}


def test_frontier_k_returns_diverse_spread():
    recs = [
        _tpu_rec("fast", 1.0, 0.1),
        _tpu_rec("clump1", 5.0, 0.50),
        _tpu_rec("clump2", 5.01, 0.501),
        _tpu_rec("clump3", 4.99, 0.499),
        _tpu_rec("efficient", 10.0, 0.9),
        _tpu_rec("dominated", 11.0, 0.05),
        _tpu_rec("infeasible", 0.1, 0.99, feasible=False),
    ]
    rep = _tpu_report_from(recs)
    full = rep.frontier()
    assert {r["cell_key"] for r in full} >= {"fast", "efficient"}
    assert all(r["cell_key"] != "infeasible" for r in full)
    assert all(r["cell_key"] != "dominated" for r in full)
    top3 = rep.frontier(k=3)
    keys = [r["cell_key"] for r in top3]
    assert len(keys) == 3
    assert "fast" in keys and "efficient" in keys, \
        "extremes must survive k-truncation"
    assert sum(k.startswith("clump") for k in keys) <= 1, \
        "frontier(k) must thin the clump, not return it"
    # mutual non-domination within the selected front members
    be = get_backend("tpu")
    vecs = [be.canonical(r["objectives"]) for r in top3]
    for i, a in enumerate(vecs):
        assert not any(dominates(b, a) for j, b in enumerate(vecs) if j != i)


def test_frontier_k_tops_up_from_later_fronts():
    recs = [_tpu_rec("best", 1.0, 0.9), _tpu_rec("second", 2.0, 0.8),
            _tpu_rec("third", 3.0, 0.7)]
    rep = _tpu_report_from(recs)
    assert len(rep.frontier()) == 1
    assert [r["cell_key"] for r in rep.frontier(k=3)] == \
        ["best", "second", "third"]


# ---------------------------------------------------------------------------
# CLI end-to-end (tpu)
# ---------------------------------------------------------------------------


def test_cli_tpu_end_to_end(tmp_path, capsys):
    store = tmp_path / "tpu.jsonl"
    argv = ["--backend", "tpu", "--archs", "xlstm-350m",
            "--shapes", "train_4k", "--chips", "8,16",
            "--remats", "full,none", "--microbatches", "1",
            "--store", str(store),
            "--frontier-json", str(tmp_path / "front.json")]
    report = cli_main(argv)
    out = capsys.readouterr().out
    assert "campaign[tpu]" in out and "Pareto frontier" in out
    assert store.exists()
    front = json.loads((tmp_path / "front.json").read_text())
    assert front and all(r["backend"] == "tpu" for r in front)
    report2 = cli_main(argv)
    assert report2.new_evaluations == 0
    assert report2.reused_cells == len(report.cells)


# ---------------------------------------------------------------------------
# report generation
# ---------------------------------------------------------------------------


def test_render_report_from_fixture_store(tmp_path):
    store = ResultStore(tmp_path / "fix.jsonl")
    for rec in fixture_records():
        store.put(rec)
    out = tmp_path / "report.md"
    rc = report_main([str(store.path), "--out", str(out),
                      "--title", "fixture report"])
    assert rc == 0
    md = out.read_text()
    assert md.startswith("# fixture report")
    for section in ("## Backend `fpga`", "## Backend `tpu`",
                    "### Pareto frontier", "### Per-workload winners",
                    "### Objective trade-offs"):
        assert section in md
    # markdown tables must escape the cell-key axis separator
    assert "net=vgg16\\|in=" in md
    assert "| --- |" in md


def test_render_report_with_bench_appendix(tmp_path):
    bench = {"benchmarks": {"fig10": [
        {"name": "fig10_gops_224x224", "us_per_call": 123.4,
         "derived": "gops=4220(paper=4218)"}]}}
    bench_path = tmp_path / "bench.json"
    bench_path.write_text(json.dumps(bench))
    store = ResultStore(tmp_path / "fix.jsonl")
    for rec in fixture_records():
        store.put(rec)
    out = tmp_path / "r.md"
    assert report_main([str(store.path), "--bench", str(bench_path),
                        "--out", str(out)]) == 0
    md = out.read_text()
    assert "## Benchmark appendix" in md
    assert "fig10_gops_224x224" in md


def test_report_selftest():
    assert report_main(["--selftest"]) == 0


def test_report_requires_store(tmp_path):
    with pytest.raises(SystemExit):
        report_main([])
    with pytest.raises(SystemExit):
        report_main([str(tmp_path / "missing.jsonl")])


def test_render_report_marks_unknown_backend():
    md = render_report([{"cell_key": "x", "backend": "npu",
                         "objectives": {"feasible": True}}])
    assert "unknown backend" in md
