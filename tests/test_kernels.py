"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
all in interpret=True mode (kernel body executed on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (b, s, h, kv, hd, causal, window)
    (1, 128, 4, 2, 64, True, None),
    (2, 96, 4, 4, 32, True, None),       # ragged seq len
    (1, 256, 8, 2, 64, True, 64),        # sliding window
    (1, 64, 2, 2, 64, False, None),      # bidirectional (whisper encoder)
    (1, 128, 6, 2, 48, True, None),      # non-pow2 head count/dim
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, s, h, kv, hd, causal, win = case
    q = jnp.asarray(RNG.standard_normal((b, s, h, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=win, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_attention():
    """The kernel hook must agree with gqa_attention's einsum path."""
    from repro.kernels.flash_attention.ops import attn_fn
    from repro.models.layers import gqa_attention, init_attention
    d, h, kv = 64, 4, 2
    params = init_attention(jax.random.key(0), d, h, kv)
    x = jnp.asarray(RNG.standard_normal((2, 32, d)), jnp.float32)
    ref = gqa_attention(x, params, h, kv, rope=True)
    out = gqa_attention(x, params, h, kv, rope=True, attn_fn=attn_fn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

MM_CASES = [(256, 512, 256), (100, 300, 50), (64, 64, 64), (128, 1, 128),
            (33, 65, 17)]


@pytest.mark.parametrize("mkn", MM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(mkn, dtype):
    m, k, n = mkn
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    out = matmul(a, b, bm=64, bn=64, bk=128)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-3,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


if HAVE_HYP:

    @given(st.integers(1, 200), st.integers(1, 200), st.integers(1, 200))
    @settings(max_examples=12, deadline=None)
    def test_matmul_property_random_shapes(m, k, n):
        a = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
        out = matmul(a, b, bm=32, bn=32, bk=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                                   atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

SSD_CASES = [(2, 128, 4, 32, 16, 32), (1, 256, 2, 64, 32, 64),
             (1, 64, 8, 16, 64, 16)]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_matches_chunked_ref(case):
    b_, s, h, p, n, chunk = case
    x = jnp.asarray(RNG.standard_normal((b_, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, (b_, s, h)), jnp.float32)
    a_log = jnp.asarray(RNG.uniform(-1, 0.5, (h,)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b_, s, n)) * 0.3, jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b_, s, n)) * 0.3, jnp.float32)
    out = ssd(x, dt, a_log, bb, cc, chunk=chunk)
    ref = ssd_ref(x, dt, a_log, bb, cc, chunk=chunk)
    scale = float(jnp.abs(ref).max())
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(ref) / scale, atol=1e-5)


def test_ssd_chunk_invariance():
    """Chunk size is a tiling choice — results must not depend on it."""
    b_, s, h, p, n = 1, 128, 2, 16, 8
    x = jnp.asarray(RNG.standard_normal((b_, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, (b_, s, h)), jnp.float32)
    a_log = jnp.zeros((h,), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b_, s, n)) * 0.3, jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b_, s, n)) * 0.3, jnp.float32)
    o32 = ssd(x, dt, a_log, bb, cc, chunk=32)
    o128 = ssd(x, dt, a_log, bb, cc, chunk=128)
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o128), atol=1e-4)


def test_ssd_ref_matches_stepwise_recurrence():
    """The chunked oracle itself vs a token-by-token recurrence."""
    from repro.models.ssm import ssd_decode
    b_, s, h, p, n = 1, 32, 2, 8, 4
    x = jnp.asarray(RNG.standard_normal((b_, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, (b_, s, h)), jnp.float32)
    a_log = jnp.asarray(RNG.uniform(-1, 0.0, (h,)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b_, s, n)) * 0.3, jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b_, s, n)) * 0.3, jnp.float32)
    ref = ssd_ref(x, dt, a_log, bb, cc, chunk=8)
    state = jnp.zeros((b_, h, p, n), jnp.float32)
    outs = []
    for t in range(s):
        # both paths fold dt into the input term exactly once
        y, state = ssd_decode(state, x[:, t], dt[:, t],
                              a_log, bb[:, t], cc[:, t])
        outs.append(y)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

CONV_CASES = [(1, 16, 16, 16, 32, 3), (2, 3, 20, 24, 64, 5),
              (1, 8, 10, 10, 16, 1), (1, 64, 7, 9, 8, 7)]


@pytest.mark.parametrize("case", CONV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_matches_ref(case, dtype):
    n_, c, hh, ww, kk, r = case
    x = jnp.asarray(RNG.standard_normal((n_, c, hh, ww)), dtype)
    w = jnp.asarray(RNG.standard_normal((kk, c, r, r)) * 0.1, dtype)
    out = conv2d(x, w, bk=16)
    ref = conv2d_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
