"""Unit + property tests for the DNNExplorer core (analysis, models, DSE)."""

import pytest

from repro.core import (KU115, RAV, ZC706, PSOConfig, dnnbuilder_design,
                        evaluate_rav, explore, generic_only_design, optimize)
from repro.core.generic_model import GenericDesign
from repro.core.local_opt import dpu_proxy_design
from repro.core.netinfo import TABLE1_NETS, vgg16
from repro.core.pipeline_model import design_pipeline, split_pf

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Model analysis (netinfo)
# ---------------------------------------------------------------------------


def test_vgg16_total_ops_matches_published():
    # VGG-16 conv-only at 224x224 is ~30.7 GOP (paper Table 3 case 4:
    # 1702.3 GOP/s / 55.4 img/s = 30.7 GOP/frame).
    net = vgg16(224)
    assert net.total_ops / 1e9 == pytest.approx(30.7, rel=0.02)


def test_vgg16_layer_count():
    assert len(vgg16(224).major_layers) == 13
    assert len(vgg16(224, extra_per_group=5).major_layers) == 38


def test_ctc_scales_with_input_area():
    # Fig. 1: CTC medians grow ~256x from 32x32 to 512x512.
    import statistics
    m32 = statistics.median(vgg16(32).ctc_list())
    m512 = statistics.median(vgg16(512).ctc_list())
    assert m512 / m32 == pytest.approx(256, rel=0.01)


def test_table1_first_half_variance_dominates():
    # Table 1: V1/V2 >> 1 for all ten networks (paper min: 185.8).
    for name, fn in TABLE1_NETS.items():
        ratio = fn().half_variance_ratio()
        assert ratio > 50, f"{name}: V1/V2={ratio}"


# ---------------------------------------------------------------------------
# Pipeline model
# ---------------------------------------------------------------------------


def test_split_pf_bounds():
    for pf, c, k in [(1, 3, 64), (64, 3, 64), (512, 64, 128), (7, 5, 9)]:
        cpf, kpf = split_pf(pf, c, k)
        assert cpf <= c and kpf <= k
        assert cpf * kpf <= pf
        assert cpf >= 1 and kpf >= 1


def test_pipeline_design_fits_resources():
    net = vgg16(224)
    d = design_pipeline(list(net.major_layers), dsp_cap=2000, bram_cap=1500,
                        bw_bytes=10e9, freq=2e8, dw=16, ww=16)
    assert d.dsp() <= 2000
    assert d.bram() <= 1500


def test_pipeline_throughput_compute_bound_matches_eq4():
    net = vgg16(224)
    d = design_pipeline(list(net.major_layers), dsp_cap=4000, bram_cap=4000,
                        bw_bytes=1e12, freq=2e8, dw=16, ww=16)
    # With infinite BW, throughput == 1 / max stage latency (Eq. 4, batch=1).
    assert d.throughput_ips(2e8, 1e12) == pytest.approx(
        1.0 / d.max_comp_latency(2e8))


def test_pipeline_batch_amortizes_weight_bandwidth():
    # Small input => weight-stream bound at batch 1; batch=8 must improve.
    net = vgg16(32)
    layers = list(net.major_layers)
    d1 = design_pipeline(layers, 4000, 4000, 19.2e9, 2e8, 16, 16, batch=1)
    d8 = design_pipeline(layers, 4000, 4000, 19.2e9, 2e8, 16, 16, batch=8)
    assert d8.throughput_ips(2e8, 19.2e9) > 2 * d1.throughput_ips(2e8, 19.2e9)


# ---------------------------------------------------------------------------
# Generic model
# ---------------------------------------------------------------------------


def test_generic_tail_underutilization():
    """ceil(C/CPF) lane waste: a 3-channel layer on a 64-lane array must be
    ~21x slower than ideal — the paradigm-A weakness (Fig. 2a)."""
    from repro.core.netinfo import LayerInfo
    l3 = LayerInfo("l", "conv", 224, 224, 3, 64, 3, 3)
    l64 = LayerInfo("l", "conv", 224, 224, 64, 64, 3, 3)
    g = GenericDesign(64, 64, 16, 16, bram=2000, bw_bytes=1e12)
    t3 = g.layer_latency(l3, 2e8)
    t64 = g.layer_latency(l64, 2e8)
    # l64 has ~21.3x the MACs of l3 but must take the SAME time (one lane
    # pass each): equal cycle counts.
    assert t3 == pytest.approx(t64, rel=0.01)


def test_generic_strategy2_ws_helps_weight_heavy_layers():
    from repro.core.netinfo import LayerInfo
    # 1x1 fm with giant weights: WS (weights resident) must beat IS.
    l = LayerInfo("fc", "fc", 1, 1, 25088, 4096)
    g2 = GenericDesign(64, 64, 16, 16, bram=3000, bw_bytes=19.2e9, strategy=2)
    lat = g2.layer_latency(l, 2e8)
    w_bytes = l.weight_bytes(16)
    # WS loads weights exactly once: latency <= max(compute, w/BW) + eps.
    assert lat <= max(w_bytes / 19.2e9, g2._l_comp(l, 2e8)) * 1.01


def test_gfm_grouping_monotone_in_batch():
    from repro.core.netinfo import LayerInfo
    l = LayerInfo("c", "conv", 112, 112, 64, 128, 3, 3)
    g = GenericDesign(32, 32, 16, 16, bram=1000, bw_bytes=19.2e9)
    assert g.g_fm(l, 8) >= g.g_fm(l, 1)


# ---------------------------------------------------------------------------
# DSE
# ---------------------------------------------------------------------------


def test_evaluate_rav_deterministic():
    net = vgg16(128)
    rav = RAV(6, 2, 0.5, 0.5, 0.5)
    a = evaluate_rav(net, KU115, rav)
    b = evaluate_rav(net, KU115, rav)
    assert a.throughput_ips == b.throughput_ips
    assert a.dsp_used == b.dsp_used


def test_evaluate_rav_respects_resources():
    net = vgg16(224)
    for sp in (0, 4, 13):
        d = evaluate_rav(net, KU115, RAV(sp, 1, 0.6, 0.6, 0.6))
        if d.feasible:
            assert d.dsp_used <= KU115.dsp_usable
            assert d.bram_used <= KU115.bram_usable


def test_explorer_beats_or_matches_both_baselines():
    net = vgg16(224)
    res = explore(net, KU115, cfg=PSOConfig(population=16, iterations=20, seed=3))
    b = dnnbuilder_design(net, KU115)
    g = generic_only_design(net, KU115)
    assert res.design.gops >= 0.99 * max(b.gops, g.gops)


def test_explorer_reproduces_paper_case4_throughput():
    # Paper Table 3 case 4: 1702.3 GOP/s, 95.8% DSP efficiency at 224x224.
    net = vgg16(224)
    res = explore(net, KU115, cfg=PSOConfig(population=20, iterations=30, seed=1))
    assert res.design.gops == pytest.approx(1702.3, rel=0.05)
    assert res.design.dsp_eff > 0.90


def test_explorer_batch_recovers_small_input_throughput():
    # Paper Table 4 case 1: batching raises 32x32 from 368 to 1698 GOP/s.
    net = vgg16(32)
    r1 = explore(net, KU115, batch_max=1,
                 cfg=PSOConfig(population=20, iterations=30, seed=1))
    r8 = explore(net, KU115, batch_max=16,
                 cfg=PSOConfig(population=24, iterations=40, seed=1))
    assert r8.design.gops > 3 * r1.design.gops
    assert r8.design.gops == pytest.approx(1698.1, rel=0.10)


def test_pso_early_termination_and_improvement():
    calls = []

    def fitness(rav):
        calls.append(rav)
        return -abs(rav.sp - 5) - abs(rav.dsp_frac - 0.5)

    res = optimize(fitness, sp_max=13, batch_max=4,
                   cfg=PSOConfig(population=12, iterations=50, seed=0))
    assert res.best_rav.sp == 5
    assert res.iterations_run <= 50


def _reference_optimize(fitness_fn, sp_max, batch_max=1, cfg=None):
    """The pre-vectorization per-particle PSO loop, kept verbatim as the
    regression oracle for the NumPy/batched rewrite."""
    import numpy as np

    from repro.core.pso import PSOResult, _clip, _to_rav

    cfg = cfg or PSOConfig()
    rng = np.random.default_rng(cfg.seed)
    lo = np.array([0.0, 1.0, 0.05, 0.05, 0.05])
    hi = np.array([float(sp_max), float(batch_max), 0.95, 0.95, 0.95])

    pos = rng.uniform(lo, hi, size=(cfg.population, 5))
    pos[0] = [0.0, 1.0, 0.05, 0.05, 0.05]
    pos[1] = [sp_max / 2, 1.0, 0.5, 0.5, 0.5]
    pos[2] = [float(sp_max), 1.0, 0.95, 0.95, 0.95]
    vel = rng.uniform(-1, 1, size=(cfg.population, 5)) * (hi - lo) * 0.1

    cache, evals = {}, 0

    def fit(p):
        nonlocal evals
        rav = _to_rav(p)
        key = rav.as_tuple()
        key = (key[0], key[1], round(key[2], 2), round(key[3], 2),
               round(key[4], 2))
        if key not in cache:
            cache[key] = fitness_fn(rav)
            evals += 1
        return cache[key]

    pbest = pos.copy()
    pbest_fit = np.array([fit(p) for p in pos])
    g_idx = int(np.argmax(pbest_fit))
    gbest, gbest_fit = pbest[g_idx].copy(), float(pbest_fit[g_idx])

    history = [gbest_fit]
    stale = 0
    it = 0
    for it in range(1, cfg.iterations + 1):
        r1 = rng.random((cfg.population, 5))
        r2 = rng.random((cfg.population, 5))
        vel = (cfg.inertia * vel
               + cfg.c_local * r1 * (pbest - pos)
               + cfg.c_global * r2 * (gbest[None, :] - pos))
        pos = _clip(pos + vel, lo, hi)
        improved = False
        for i in range(cfg.population):
            f = fit(pos[i])
            if f > pbest_fit[i]:
                pbest[i], pbest_fit[i] = pos[i].copy(), f
            if f > gbest_fit:
                gbest, gbest_fit = pos[i].copy(), f
                improved = True
        history.append(gbest_fit)
        stale = 0 if improved else stale + 1
        if stale >= cfg.patience:
            break
    return PSOResult(_to_rav(gbest), gbest_fit, it, evals, history)


@pytest.mark.parametrize("seed", [0, 7])
def test_vectorized_pso_matches_old_loop(seed):
    """The vectorized + batched update must reproduce the old per-particle
    loop exactly: same best RAV, fitness, eval count, and history."""
    net = vgg16(64)

    def fitness(rav):
        return evaluate_rav(net, ZC706, rav).fitness

    cfg = PSOConfig(population=12, iterations=15, seed=seed)
    old = _reference_optimize(fitness, sp_max=13, batch_max=4, cfg=cfg)
    new = optimize(fitness, sp_max=13, batch_max=4, cfg=cfg)

    def batch_fitness(ravs):
        return [fitness(r) for r in ravs]

    batched = optimize(sp_max=13, batch_max=4, cfg=cfg,
                       batch_fitness_fn=batch_fitness)
    for res in (new, batched):
        assert res.best_rav == old.best_rav
        assert res.best_fitness == old.best_fitness
        assert res.evaluations == old.evaluations
        assert res.iterations_run == old.iterations_run
        assert res.history == old.history


def test_optimize_batch_hook_sees_whole_population():
    """The batched hook gets the uncached population in one call per
    iteration, not particle-by-particle."""
    calls = []

    def batch_fitness(ravs):
        calls.append(len(ravs))
        return [-abs(r.sp - 5) - abs(r.dsp_frac - 0.5) for r in ravs]

    cfg = PSOConfig(population=12, iterations=10, seed=0)
    res = optimize(sp_max=13, batch_max=4, cfg=cfg,
                   batch_fitness_fn=batch_fitness)
    assert res.best_rav.sp == 5
    # one call per iteration (plus the init), each covering many particles
    assert len(calls) <= res.iterations_run + 1
    assert max(calls) > 1


def test_optimize_requires_a_fitness():
    with pytest.raises(TypeError):
        optimize(sp_max=5)


def test_dpu_proxy_small_input_inefficiency():
    # Fig. 2a: fixed-geometry IP efficiency degrades with small inputs.
    from repro.core import ZCU102
    e32 = dpu_proxy_design(vgg16(32), ZCU102).dsp_eff
    e224 = dpu_proxy_design(vgg16(224), ZCU102).dsp_eff
    assert e224 > 2 * e32


if HAVE_HYPOTHESIS:

    @given(st.integers(1, 4096), st.integers(1, 2048), st.integers(1, 2048))
    @settings(max_examples=200, deadline=None)
    def test_split_pf_property(pf, c, k):
        cpf, kpf = split_pf(pf, c, k)
        assert 1 <= cpf <= max(1, c)
        assert 1 <= kpf <= max(1, k)
        assert cpf * kpf <= max(1, pf)

    @given(st.integers(0, 13), st.integers(1, 8),
           st.floats(0.05, 0.95), st.floats(0.05, 0.95), st.floats(0.05, 0.95))
    @settings(max_examples=30, deadline=None)
    def test_evaluate_rav_never_exceeds_chip(sp, batch, fd, fb, fw):
        net = vgg16(64)
        d = evaluate_rav(net, ZC706, RAV(sp, batch, fd, fb, fw))
        assert d.throughput_ips >= 0
        if d.feasible:
            assert d.dsp_used <= ZC706.dsp_usable
