"""The README quickstart block and the ``repro.dse`` docstring quickstart
are verbatim copies by design (ROADMAP), and the README's "Placement in
5 lines" block is a verbatim copy of the ``repro.dse.placement`` module
docstring's block the same way; this enforces both."""
from pathlib import Path

import repro.dse
import repro.dse.placement

ROOT = Path(__file__).resolve().parents[1]


def _readme_block(section_header: str) -> str:
    """The first ```console fence after a README section header."""
    text = (ROOT / "README.md").read_text()
    assert section_header in text, \
        f"README lost its {section_header!r} section"
    section = text.split(section_header, 1)[1]
    assert "```console\n" in section, \
        f"code fence missing under {section_header!r}"
    return section.split("```console\n", 1)[1].split("```", 1)[0].strip("\n")


def _docstring_block(doc: str) -> str:
    """The first 4-space literal block after a ``::`` marker, dedented."""
    block = doc.split("::\n", 1)[1]
    lines = []
    for line in block.splitlines():
        if line.startswith("    "):
            lines.append(line[4:])
        elif not line.strip():
            lines.append("")
        else:  # pragma: no cover - text after the block would end it
            break
    return "\n".join(lines).strip("\n")


def _readme_quickstart() -> str:
    return _readme_block("## DSE campaign quickstarts")


def _docstring_quickstart() -> str:
    doc = repro.dse.__doc__
    assert "Quickstart" in doc
    return _docstring_block(doc)


def test_readme_quickstart_matches_dse_docstring():
    readme, doc = _readme_quickstart(), _docstring_quickstart()
    assert readme == doc, (
        "README quickstart and repro/dse/__init__.py docstring quickstart "
        "have drifted; they are verbatim copies by design:\n"
        f"--- README ---\n{readme}\n--- docstring ---\n{doc}")


def test_quickstart_covers_all_backends_and_compare():
    block = _readme_quickstart()
    for needle in ("--backend tpu", "--backend cuda", "repro.dse.report",
                   "--compare"):
        assert needle in block


def test_readme_placement_matches_placement_docstring():
    readme = _readme_block("## Placement in 5 lines")
    doc = _docstring_block(repro.dse.placement.__doc__)
    assert readme == doc, (
        "README 'Placement in 5 lines' and the repro/dse/placement.py "
        "docstring block have drifted; they are verbatim copies by "
        f"design:\n--- README ---\n{readme}\n--- docstring ---\n{doc}")


def test_placement_snippet_is_five_lines_and_runnable_shape():
    block = _readme_block("## Placement in 5 lines")
    assert len(block.splitlines()) == 5, \
        "the snippet is advertised as five lines; keep it five"
    for needle in ("python -m repro.dse.placement", "--stores",
                   "--workloads", "--budget-usd", "--budget-watts",
                   "--out"):
        assert needle in block
