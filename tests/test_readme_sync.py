"""The README quickstart block and the ``repro.dse`` docstring quickstart
are verbatim copies by design (ROADMAP); this enforces it."""
from pathlib import Path

import repro.dse

ROOT = Path(__file__).resolve().parents[1]


def _readme_quickstart() -> str:
    text = (ROOT / "README.md").read_text()
    assert "## DSE campaign quickstarts" in text, \
        "README lost its quickstart section"
    section = text.split("## DSE campaign quickstarts", 1)[1]
    assert "```console\n" in section, "quickstart code fence missing"
    return section.split("```console\n", 1)[1].split("```", 1)[0].strip("\n")


def _docstring_quickstart() -> str:
    doc = repro.dse.__doc__
    assert "Quickstart" in doc
    block = doc.split("::\n", 1)[1]
    # dedent the 4-space literal block; stop at the docstring's end
    lines = []
    for line in block.splitlines():
        if line.startswith("    "):
            lines.append(line[4:])
        elif not line.strip():
            lines.append("")
        else:  # pragma: no cover - text after the block would end it
            break
    return "\n".join(lines).strip("\n")


def test_readme_quickstart_matches_dse_docstring():
    readme, doc = _readme_quickstart(), _docstring_quickstart()
    assert readme == doc, (
        "README quickstart and repro/dse/__init__.py docstring quickstart "
        "have drifted; they are verbatim copies by design:\n"
        f"--- README ---\n{readme}\n--- docstring ---\n{doc}")


def test_quickstart_covers_all_backends_and_compare():
    block = _readme_quickstart()
    for needle in ("--backend tpu", "--backend cuda", "repro.dse.report",
                   "--compare"):
        assert needle in block
