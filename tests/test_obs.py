"""Tests for repro.obs and its threading through the DSE stack:
tracer/span semantics, deterministic sidecar merging, schema validation,
Chrome export, the spawn-pool campaign integration (span nesting across
process boundaries), store corrupt-line accounting, convergence traces
riding resume, and the committed example health report's drift check.
"""
import json
import warnings
from pathlib import Path

import pytest

from repro.dse import (ResultStore, expand_cells, get_backend, run_campaign)
from repro.dse.obs import (events_for_store, example_health_md,
                           main as obs_main)
from repro.dse.report import (fixture_events, fixture_records,
                              health_section, render_report)
from repro.obs import (EVENTS_SCHEMA_VERSION, NULL, NullTracer, Tracer,
                       campaign_wall, chrome_path_for, chrome_trace,
                       counter_totals, events_dir_for, events_path_for,
                       load_events, merge_events, slowest_spans, span_totals,
                       validate_events, worker_tracer, worker_utilization)

_FAST = dict(population=6, iterations=4)


def _tpu_cells():
    be = get_backend("tpu")
    return be, be.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                               chips=[8, 16], remats=["full"],
                               microbatches=[1, 2])


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_null_tracer_is_total_noop(tmp_path):
    n = NullTracer()
    assert not n.enabled and not NULL.enabled
    with n.span("anything", k=1):
        n.count("c", 3)
        n.gauge("g", 0.5)
    n.span_at("q", 0.0, 1.0)
    with n:
        pass
    n.close()
    assert list(tmp_path.iterdir()) == []  # nothing ever touches disk


def test_tracer_emits_nested_spans_and_counters(tmp_path):
    p = tmp_path / "t.jsonl"
    with Tracer(p, proc="main") as tr:
        with tr.span("outer", cell="x"):
            with tr.span("inner"):
                tr.count("hits", 2)
                tr.count("hits", 3)
            tr.gauge("load", 0.5)
    evs = load_events(p)
    assert validate_events(evs) == []
    assert all(e["schema"] == EVENTS_SCHEMA_VERSION for e in evs)
    by_name = {e["name"]: e for e in evs if e["kind"] == "span"}
    # inner closes first, at depth 1; outer wraps it at depth 0
    assert by_name["inner"]["depth"] == 1
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["dur"] >= by_name["inner"]["dur"]
    assert by_name["outer"]["ts"] <= by_name["inner"]["ts"]
    assert by_name["outer"]["attrs"] == {"cell": "x"}
    assert counter_totals(evs) == {"hits": 5}
    assert tr.counters == {"hits": 5}
    # per-process seq is a total order
    seqs = [e["seq"] for e in evs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_span_survives_exception(tmp_path):
    p = tmp_path / "t.jsonl"
    tr = Tracer(p)
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    tr.close()
    evs = load_events(p)
    assert [e["name"] for e in evs] == ["boom"]


def test_merge_events_is_deterministic(tmp_path):
    d = tmp_path / "ev"
    with Tracer(d / "main.jsonl", proc="main") as tr:
        tr.count("a")
    with worker_tracer(d, proc="worker-7") as tr:
        with tr.span("w"):
            pass
    m1 = merge_events(d, tmp_path / "m1.jsonl")
    m2 = merge_events(d, tmp_path / "m2.jsonl")
    assert m1 == m2
    assert (tmp_path / "m1.jsonl").read_text() == \
        (tmp_path / "m2.jsonl").read_text()
    # merged order is the canonical (ts, proc, seq) sort
    keys = [(e["ts"], e["proc"], e["seq"]) for e in m1]
    assert keys == sorted(keys)
    assert {e["proc"] for e in m1} == {"main", "worker-7"}
    # undecodable sidecar junk is skipped (with a warning), not fatal
    (d / "junk.jsonl").write_text("{not json\n\n")
    with pytest.warns(UserWarning, match="skipped 1 undecodable"):
        assert merge_events(d) == m1


def test_merge_events_tolerates_truncated_sidecar(tmp_path):
    """A worker killed mid-write (crash fault, SIGKILL) leaves a torn
    final line in its sidecar; the merge must keep every intact event
    and surface the loss instead of raising."""
    d = tmp_path / "ev"
    with worker_tracer(d, proc="worker-9") as tr:
        tr.count("a")
        tr.count("b")
    sidecar = next(d.glob("*.jsonl"))
    whole = sidecar.read_text().splitlines()
    torn = whole[0] + "\n" + whole[1][: len(whole[1]) // 2]
    sidecar.write_text(torn)                       # no trailing newline
    stats: dict = {}
    assert [e["name"] for e in load_events(sidecar, stats)] == ["a"]
    assert stats == {"skipped_lines": 1}
    with pytest.warns(UserWarning, match="skipped 1 undecodable"):
        merged = merge_events(d, tmp_path / "m.jsonl")
    assert [e["name"] for e in merged] == ["a"]


def test_validate_events_flags_bad_shapes():
    good = fixture_events()
    assert validate_events(good) == []
    bad = [dict(good[0], schema=99),
           dict(good[0], kind="nope"),
           {k: v for k, v in good[1].items() if k != "ts"},
           dict(good[0], dur="fast")]
    problems = validate_events(bad)
    assert len(problems) == 4


def test_aggregations_on_fixture_events():
    evs = fixture_events()
    assert campaign_wall(evs) == pytest.approx(6.65)
    totals = span_totals(evs)
    assert totals["cell.eval"].count == 2
    assert totals["cell.eval"].max_s == pytest.approx(5.8)
    util = worker_utilization(evs)
    assert set(util) == {"worker-1", "worker-2"}
    assert util["worker-2"]["util"] == pytest.approx(5.8 / 6.65)
    slow = slowest_spans(evs, k=1)
    assert len(slow) == 1 and "zcu102" in slow[0]["attrs"]["cell"]


def test_chrome_trace_structure():
    evs = fixture_events()
    doc = chrome_trace(evs)
    json.dumps(doc)  # exportable
    tes = doc["traceEvents"]
    names = {e["args"]["name"] for e in tes if e["ph"] == "M"}
    assert names == {"main", "worker-1", "worker-2"}
    xs = [e for e in tes if e["ph"] == "X"]
    assert len(xs) == len([e for e in evs if e["kind"] == "span"])
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    # counter samples accumulate; gauges sample raw values
    cs = [e for e in tes if e["ph"] == "C"]
    done = [e["args"]["cells.done"] for e in cs
            if e["name"] == "cells.done"]
    assert done == [1, 2]
    assert chrome_trace([]) == {"traceEvents": [],
                                "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# campaign integration (spawn pool)
# ---------------------------------------------------------------------------


def test_traced_campaign_spawn_pool(tmp_path):
    be, cells = _tpu_cells()
    store = tmp_path / "t.jsonl"
    rep = run_campaign(cells, str(store), backend=be, workers=2, trace=True)
    assert rep.events_path == events_path_for(store)
    assert rep.events_path.exists() and rep.trace_path.exists()
    evs = load_events(rep.events_path)
    assert validate_events(evs) == []
    # span nesting survived pickling into spawn workers: every cell got
    # a queue.wait + cell.run(depth 0) wrapping cell.eval(depth 1), all
    # attributed to a worker proc, not main
    for name, depth in (("queue.wait", 0), ("cell.run", 0),
                        ("cell.eval", 1)):
        got = [e for e in evs if e.get("name") == name]
        assert len(got) == len(cells)
        assert all(e["depth"] == depth for e in got)
        assert all(e["proc"].startswith("worker-") for e in got)
    appends = [e for e in evs if e.get("name") == "store.append"]
    assert len(appends) == len(cells)
    assert all(e["proc"] == "main" for e in appends)
    assert counter_totals(evs)["cells.done"] == len(cells)
    assert max(e["value"] for e in evs
               if e.get("name") == "pool.inflight") <= len(cells)
    json.loads(rep.trace_path.read_text())  # chrome export parses
    # the obs CLI reads the same store
    assert events_for_store(str(store)) == evs
    rc = obs_main([str(store), "--validate",
                   "--chrome", str(tmp_path / "c.json")])
    assert rc == 0
    json.loads((tmp_path / "c.json").read_text())


def test_untraced_campaign_emits_zero_telemetry_files(tmp_path):
    be, cells = _tpu_cells()
    store = tmp_path / "t.jsonl"
    rep = run_campaign(cells, str(store), backend=be, workers=2)
    assert rep.events_path is None and rep.trace_path is None
    assert not events_dir_for(store).exists()
    assert not events_path_for(store).exists()
    assert not chrome_path_for(store).exists()
    assert sorted(x.name for x in tmp_path.iterdir()) == ["t.jsonl"]
    assert events_for_store(str(store)) == []


def test_trace_field_roundtrips_resume(tmp_path):
    store = tmp_path / "c.jsonl"
    cells = expand_cells(["vgg16"], [(64, 64)], ["zc706"], [16], [1])
    r1 = run_campaign(cells, str(store), trace=True, **_FAST)
    t = r1.records[0]["trace"]
    assert t["schema"] == 1 and t["engine"] == "pso"
    assert t["stop_reason"] in ("converged", "iteration_cap")
    assert t["iterations"] <= _FAST["iterations"]
    assert len(t["history"]) == t["iterations"] + 1  # init + per-iteration
    assert t["best_fitness"] == pytest.approx(max(t["history"]))
    # a traced store resumes cleanly in an untraced re-run (and vice
    # versa): the trace field is additive and search-config matching
    # does not see it
    r2 = run_campaign(cells, str(store), **_FAST)
    assert r2.new_cells == 0 and r2.reused_cells == len(cells)
    assert r2.records[0]["trace"] == t
    # and the reloaded record round-trips through JSONL byte-identically
    assert ResultStore(store).get(cells[0].key)["trace"] == t


def test_enumeration_trace_on_tpu_records(tmp_path):
    be, cells = _tpu_cells()
    rep = run_campaign(cells, str(tmp_path / "t.jsonl"), backend=be)
    for rec in rep.records:
        t = rec["trace"]
        assert t["engine"] == "enumeration"
        assert t["stop_reason"] == "exhaustive"
        assert t["iterations"] == t["evaluations"] > 0


# ---------------------------------------------------------------------------
# store corruption accounting
# ---------------------------------------------------------------------------


def test_store_torn_final_line_is_benign(tmp_path):
    p = tmp_path / "s.jsonl"
    s = ResultStore(p)
    s.put({"cell_key": "a", "x": 1})
    with p.open("a") as f:
        f.write('{"cell_key": "b", "x":')
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any warning -> failure
        s2 = ResultStore(p)
    assert s2.skipped_lines == 1
    assert s2.corrupt_lines == 0


def test_store_mid_file_corruption_warns_and_counts(tmp_path):
    p = tmp_path / "s.jsonl"
    s = ResultStore(p)
    s.put({"cell_key": "a", "x": 1})
    s.put({"cell_key": "b", "x": 2})
    lines = p.read_text().splitlines()
    lines[0] = lines[0][:10]  # damage a NON-final line
    p.write_text("\n".join(lines) + "\n")
    tr = Tracer(tmp_path / "ev" / "main.jsonl")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        s2 = ResultStore(p, tracer=tr)
    tr.close()
    assert s2.skipped_lines == 1
    assert s2.corrupt_lines == 1
    assert "a" not in s2 and s2.get("b")["x"] == 2
    evs = load_events(tmp_path / "ev" / "main.jsonl")
    assert counter_totals(evs)["store.corrupt_lines"] == 1


# ---------------------------------------------------------------------------
# health report + committed example drift check
# ---------------------------------------------------------------------------


def test_health_section_flags_iteration_capped_cells():
    md = "\n".join(health_section(fixture_records(), fixture_events()))
    assert "Wall-time breakdown" in md
    assert "Worker utilization" in md
    assert "Slowest cells" in md
    assert "Convergence diagnostics" in md
    assert "**iteration_cap**" in md
    assert "net=vgg16|in=224x224|fpga=ku115|prec=16|bmax=1" in md


def test_health_section_without_any_telemetry():
    recs = [{"cell_key": "x", "objectives": {"feasible": True}}]
    md = "\n".join(health_section(recs))
    assert "No telemetry" in md


def test_render_report_includes_health_only_when_telemetry():
    fix = fixture_records()
    assert "Campaign health" in render_report(fix)  # traces present
    ok = [r for r in fix if r.get("status", "ok") == "ok"]
    failed = [r for r in fix if r.get("status") == "failed"]
    bare = [dict(r) for r in ok]
    for r in bare:
        r.pop("trace", None)
        r.pop("resilience", None)
    assert "Campaign health" not in render_report(bare)
    assert "Campaign health" in render_report(bare,
                                              events=fixture_events())
    # a quarantined record alone is telemetry enough — failures must
    # never drop out of the report silently
    md = render_report(bare + failed)
    assert "Campaign health" in md and "Failures & retries" in md


def test_committed_example_health_report_is_current():
    committed = Path(__file__).resolve().parent.parent / \
        "docs" / "reports" / "example_health.md"
    assert committed.exists(), \
        "regenerate with: python -m repro.dse.obs --fixture --out " \
        "docs/reports/example_health.md"
    assert committed.read_text() == example_health_md(), \
        "docs/reports/example_health.md is stale — regenerate with: " \
        "python -m repro.dse.obs --fixture --out " \
        "docs/reports/example_health.md"


def test_obs_cli_fixture_mode(tmp_path, capsys):
    out = tmp_path / "ex.md"
    assert obs_main(["--fixture", "--out", str(out)]) == 0
    assert out.read_text() == example_health_md()
    assert obs_main(["--fixture"]) == 0
    assert "Campaign health" in capsys.readouterr().out
