"""Distributed-runtime behaviour tests: data determinism, checkpoint
atomicity + resume + elastic reshard, failure injection, straggler monitor,
pipeline parallelism equivalence, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_stateless():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=8, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    a = p1.make(step=17, shard=2, n_shards=4)
    b = p2.make(step=17, shard=2, n_shards=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_shards_partition_global_batch():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=8, seed=0)
    p = TokenPipeline(cfg)
    full = [p.make(5, shard=i, n_shards=4)["tokens"] for i in range(4)]
    assert all(f.shape == (2, 32) for f in full)
    # different shards differ
    assert not np.array_equal(full[0], full[1])


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    t = _tree()
    store.save(d, 3, t, meta={"k": "v"})
    assert store.latest_step(d) == 3
    like = jax.tree.map(jnp.zeros_like, t)
    out = store.restore(d, 3, like)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert store.meta(d, 3)["meta"]["k"] == "v"


def test_checkpoint_latest_survives_torn_write(tmp_path):
    d = str(tmp_path)
    store.save(d, 1, _tree())
    store.save(d, 2, _tree())
    # simulate a torn step_3: directory without manifest + stale LATEST
    os.makedirs(os.path.join(d, "step_00000003"))
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("step_00000003")
    assert store.latest_step(d) == 2  # falls back to newest complete


def test_checkpoint_reshard_on_restore(tmp_path):
    d = str(tmp_path)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    store.save(d, 1, t)
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    out = store.restore(d, 1, t, sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


# ---------------------------------------------------------------------------
# trainer: loss goes down, resume, failure injection
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("starcoder2-3b").reduced()
    shape = ShapeSpec("t", "train", 64, 4)
    return cfg, shape


def test_trainer_loss_decreases(tiny_setup, tmp_path):
    cfg, shape = tiny_setup
    tr = Trainer(cfg, shape, TrainConfig(steps=12, ckpt_every=100,
                                         ckpt_dir=str(tmp_path),
                                         log_every=100))
    tr.run()
    first = np.mean([s["loss"] for s in tr.stats[:3]])
    last = np.mean([s["loss"] for s in tr.stats[-3:]])
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_trainer_failure_injection_recovers(tiny_setup, tmp_path):
    cfg, shape = tiny_setup
    tr = Trainer(cfg, shape, TrainConfig(steps=8, ckpt_every=2,
                                         ckpt_dir=str(tmp_path),
                                         log_every=100))
    tr.fail_at(5)
    tr.run()
    assert tr.step == 8
    assert tr._restarts == 1
    # steps replayed from the last checkpoint: all 8 steps were executed
    assert {s["step"] for s in tr.stats} == set(range(8))


def test_trainer_resume_from_checkpoint(tiny_setup, tmp_path):
    cfg, shape = tiny_setup
    t1 = Trainer(cfg, shape, TrainConfig(steps=4, ckpt_every=4,
                                         ckpt_dir=str(tmp_path), log_every=100))
    t1.run()
    t2 = Trainer(cfg, shape, TrainConfig(steps=8, ckpt_every=4,
                                         ckpt_dir=str(tmp_path), log_every=100))
    t2.run()
    # t2 resumed at 4, only ran 4..7
    assert min(s["step"] for s in t2.stats) == 4


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100)
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw.apply(grads, state, params, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.5


def test_adamw_clips_gradients():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    state = adamw.init(params)
    _, _, stats = adamw.apply({"x": jnp.full((3,), 100.0)}, state, params, cfg)
    assert float(stats["grad_norm"]) > 100  # raw norm reported


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compression_error_feedback_unbiased():
    from repro.parallel.collectives import compress_grads, init_error_feedback
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
    err = init_error_feedback(g)
    total_q = np.zeros(1000)
    for _ in range(50):
        q, err = compress_grads(g, err)
        total_q += np.asarray(q["w"])
    # long-run average of compressed grads converges to the true gradient
    np.testing.assert_allclose(total_q / 50, np.asarray(g["w"]), atol=2e-3)


# ---------------------------------------------------------------------------
# pipeline parallelism (uses >1 host device only if available)
# ---------------------------------------------------------------------------


def test_pipeline_apply_matches_sequential():
    from repro.parallel.pipeline import pipeline_apply, split_microbatches
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >=2 devices for a pipeline mesh (see "
                    "tests/test_pipeline_multidev.py run via subprocess)")
    mesh = jax.make_mesh((n_dev,), ("stage",))
    d = 16
    ws = jnp.asarray(np.random.default_rng(0).standard_normal((n_dev, d, d))
                     * 0.3, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((8, d)),
                    jnp.float32)

    def stage(w, h):
        return jnp.tanh(h @ w)

    seq = x
    for i in range(n_dev):
        seq = stage(ws[i], seq)
    mbs = split_microbatches(x, 4)
    out = pipeline_apply(stage, ws, mbs, mesh)
    np.testing.assert_allclose(np.asarray(out.reshape(8, d)),
                               np.asarray(seq), atol=1e-5)
