"""MoE expert-parallel shard_map dispatch must match the dense dispatch
numerically (subprocess: needs a multi-device mesh)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import moe
from repro.parallel import act

cfg = get_config("kimi-k2-1t-a32b").reduced()   # 4 experts, top-2
params = moe.init_moe_mlp(jax.random.key(0), cfg)
x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, cfg.d_model)),
                jnp.float32)

y_dense, aux_dense = moe.moe_mlp(x, params, cfg)

mesh = jax.make_mesh((1, 4), ("data", "model"))
specs = act.default_specs(mesh)
specs["_ep_mesh"] = (mesh, "model")
with mesh, act.activation_specs(specs):
    y_ep, aux_ep = jax.jit(lambda x, p: moe.moe_mlp(x, p, cfg))(x, params)

np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                           atol=2e-5, rtol=2e-5)
np.testing.assert_allclose(float(aux_ep), float(aux_dense), atol=1e-5)
print("MOE_EP_OK")
"""


def test_moe_ep_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MOE_EP_OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
