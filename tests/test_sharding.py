"""Sharding-rule unit/property tests (fit_spec, param rules, batch specs)."""
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.specs import input_specs, make_batch
from repro.models import api
from repro.parallel import sharding as shd

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


@pytest.fixture(scope="module")
def mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_fit_spec_drops_nondivisible_axes(mesh22):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # mesh sizes are 1 -> everything divides; use a fake wider mesh below
    spec = shd.fit_spec(P("data", "model"), (7, 5), mesh)
    assert spec == P("data", "model")  # 1-way always divides


def test_fit_spec_wide_mesh_subprocess():
    """fit_spec with a 16-way mesh must drop axes on 51865-sized dims."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax
from jax.sharding import PartitionSpec as P
from repro.parallel import sharding as shd
mesh = jax.make_mesh((4, 4), ("data", "model"))
assert shd.fit_spec(P("data", "model"), (51865, 512), mesh) == P(None, "model")
assert shd.fit_spec(P("data", "model"), (512, 51865), mesh) == P("data", None)
assert shd.fit_spec(P(("data", "model"),), (4,), mesh) == P("data",)  # partial
assert shd.fit_spec(P("data"), (1,), mesh) == P(None)
print("FIT_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "FIT_OK" in r.stdout, r.stderr[-1500:]


@pytest.mark.parametrize("arch", ["starcoder2-3b", "kimi-k2-1t-a32b",
                                  "zamba2-2.7b", "whisper-base", "xlstm-350m"])
def test_param_pspecs_cover_all_leaves(arch, mesh22):
    cfg = get_config(arch).reduced()
    shapes = jax.eval_shape(lambda: api.init_params(jax.random.key(0), cfg))
    specs = shd.param_pspecs(shapes, mesh22)
    leaves_s = jax.tree.leaves(shapes)
    leaves_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(leaves_p)
    for sh, sp in zip(leaves_s, leaves_p):
        assert isinstance(sp, P)
        assert len(sp) <= len(sh.shape)


def test_big_2d_weights_are_sharded(mesh22):
    cfg = get_config("starcoder2-3b")
    shapes = jax.eval_shape(lambda: api.init_params(jax.random.key(0), cfg))
    specs = shd.param_pspecs(shapes, mesh22)
    # embed (V, D) must carry both axes on the 1x1 mesh (everything divides)
    assert specs["embed"] == P("data", "model")
    # stacked block weights get leading None for the layer axis
    assert specs["blocks"]["attn"]["wq"][0] is None


def test_batch_pspecs_match_input_specs(mesh22):
    cfg = get_config("h2o-danube-3-4b")
    for shape_name in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_name]
        specs = input_specs(cfg, shape)
        b = shd.batch_pspecs(cfg, shape, specs, mesh22)
        assert set(b) == set(specs)


def test_make_batch_matches_specs():
    cfg = get_config("whisper-base").reduced()
    from repro.configs.base import ShapeSpec
    shape = ShapeSpec("t", "train", 32, 2)
    specs = input_specs(cfg, shape)
    batch = make_batch(cfg, shape)
    for k, v in specs.items():
        got = jax.tree.map(lambda a: (a.shape, a.dtype), batch[k])
        want = jax.tree.map(lambda s: (s.shape, s.dtype), v)
        assert jax.tree.all(jax.tree.map(lambda a, b: a == b, got, want)), k


if HAVE_HYP:

    @given(st.integers(1, 64), st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def test_fit_spec_never_violates_divisibility(a, b):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = shd.fit_spec(P("data", "model"), (a, b), mesh)
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for ax in axes:
                total *= mesh.shape[ax]
            assert (a, b)[d] % total == 0
