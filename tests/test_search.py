"""Searcher-protocol tests: golden PSO trajectories (bit-identity with
the pre-refactor implementation), cross-engine conformance over every
registered searcher, and the registry's config plumbing.

The golden fixture (``tests/data/pso_golden.json``) was captured from
the monolithic ``pso.optimize`` BEFORE the ask/tell refactor; the
refactored ``PSOSearcher`` + ``run_search`` must reproduce it exactly —
discrete fields bit-identical, floats to 1e-9 relative. Regenerate the
fixture only on an intentional algorithm change.
"""
import json
import math
from pathlib import Path

import pytest

from repro.core import PSOConfig, explore
from repro.core.hw_specs import FPGAS
from repro.core.netinfo import vgg16
from repro.core.search import (SEARCHERS, SearchSpace, make_searcher,
                               searcher_names)
from repro.dse.campaign import expand_cells, run_cell
from repro.dse.store import ResultStore

GOLDEN = Path(__file__).parent / "data" / "pso_golden.json"


def _golden_cases():
    with GOLDEN.open() as f:
        return json.load(f)["cases"]


@pytest.mark.parametrize("case", _golden_cases(),
                         ids=lambda c: f"seed{c['seed']}_{c['fpga']}")
def test_pso_golden_trajectory(case):
    """The refactored PSOSearcher replays pre-refactor trajectories
    bit-for-bit: same RNG draw order, same dedup/memo behavior, same
    stop reason."""
    net = vgg16(case["input"])
    cfg = PSOConfig(population=case["population"],
                    iterations=case["iterations"],
                    patience=case["patience"], seed=case["seed"])
    res = explore(net, FPGAS[case["fpga"]], dw=case["dw"], ww=case["ww"],
                  batch_max=case["batch_max"], cfg=cfg).pso

    assert res.best_rav.sp == case["best_rav"][0]
    assert res.best_rav.batch == case["best_rav"][1]
    for got, want in zip((res.best_rav.dsp_frac, res.best_rav.bram_frac,
                          res.best_rav.bw_frac), case["best_rav"][2:]):
        assert math.isclose(got, want, rel_tol=1e-9), (got, want)
    assert math.isclose(res.best_fitness, case["best_fitness"],
                        rel_tol=1e-9)
    assert res.iterations_run == case["iterations_run"]
    assert res.evaluations == case["evaluations"]
    assert res.cache_hits == case["cache_hits"]
    assert res.stop_reason == case["stop_reason"]
    assert len(res.history) == len(case["history"])
    for got, want in zip(res.history, case["history"]):
        assert math.isclose(got, want, rel_tol=1e-9), (got, want)


# ---------------------------------------------------------------------------
# cross-searcher conformance: every registered engine honors the protocol
# ---------------------------------------------------------------------------


_NET = vgg16(64)
_FPGA = FPGAS["zc706"]
_BMAX = 2
# tiny budgets so hyperband's screen rung stays cheap under pytest
_OVERRIDES = {"hyperband": {"screen": 256, "survivors": 4}}


def _run(name, seed=5):
    cfg = PSOConfig(population=6, iterations=5, patience=2, seed=seed)
    return explore(_NET, _FPGA, batch_max=_BMAX, cfg=cfg, searcher=name,
                   searcher_config=_OVERRIDES.get(name))


@pytest.mark.parametrize("name", searcher_names())
def test_searcher_conformance(name):
    """Every engine returns a bounds-valid best RAV, stays within its own
    declared evaluation cap, reports a stop reason, and is deterministic
    under a fixed seed."""
    res = _run(name)
    p = res.pso
    sp_max = len(_NET.major_layers)

    r = res.design.rav
    assert 0 <= r.sp <= sp_max
    assert 1 <= r.batch <= _BMAX
    for frac in (r.dsp_frac, r.bram_frac, r.bw_frac):
        assert 0.05 <= frac <= 0.95

    space = SearchSpace(sp_max=sp_max, batch_max=_BMAX)
    engine = make_searcher(
        name, space,
        base=dict(population=6, iterations=5, patience=2, seed=5),
        overrides=_OVERRIDES.get(name))
    assert p.evaluations <= engine.eval_cap(), \
        f"{name}: {p.evaluations} full evals > declared cap"

    assert p.engine == name
    assert p.stop_reason in ("converged", "iteration_cap")
    assert p.iterations_run >= 0
    assert len(p.history) >= 1
    assert math.isclose(p.history[-1], p.best_fitness, rel_tol=1e-9)
    # histories are monotone: each entry is the best-so-far
    assert all(b >= a - 1e-12 for a, b in zip(p.history, p.history[1:]))

    again = _run(name).pso
    assert again.best_fitness == p.best_fitness
    assert again.history == p.history
    assert again.evaluations == p.evaluations


@pytest.mark.parametrize("name", searcher_names())
def test_searcher_store_roundtrip(name, tmp_path):
    """A campaign record produced under any engine survives the JSONL
    store round trip with its convergence trace intact."""
    cell = expand_cells(["vgg16"], [(64, 64)], ["zc706"], [16], [_BMAX])[0]
    rec = run_cell(cell, base_seed=5, population=6, iterations=5,
                   searcher=name, searcher_config=_OVERRIDES.get(name))
    assert rec["trace"]["engine"] == name
    if name == "hyperband":
        assert rec["trace"]["screened"] > 0

    store = ResultStore(tmp_path / "s.jsonl")
    store.put(rec)
    back = ResultStore(tmp_path / "s.jsonl").get(cell.key)
    assert back is not None
    assert back["trace"] == rec["trace"]
    assert back["search"] == rec["search"]
    # engine identity is part of the resume-match config exactly when
    # it differs from the default paper flow
    if name == "pso":
        assert "searcher" not in back["search"]
    else:
        assert back["search"]["searcher"] == name


def test_registry_and_config_plumbing():
    names = searcher_names()
    for expected in ("pso", "random", "anneal", "hyperband"):
        assert expected in names
    assert set(names) == set(SEARCHERS)

    space = SearchSpace(sp_max=10, batch_max=2)
    with pytest.raises(ValueError):
        make_searcher("no_such_engine", space)
    with pytest.raises(ValueError):
        make_searcher("pso", space, overrides={"bogus_field": 1})
    # base keys an engine doesn't have are dropped; overrides coerce to
    # the config field's type
    eng = make_searcher("anneal", space,
                        base=dict(population=4, inertia=0.7),
                        overrides={"t0": "0.1"})
    assert eng.cfg.population == 4
    assert eng.cfg.t0 == 0.1
