"""Pipeline-parallel equivalence on a real multi-device mesh, via a
subprocess with XLA_FLAGS host-device virtualization (the main test
process is locked to 1 CPU device)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply, split_microbatches

mesh = jax.make_mesh((4,), ("stage",))
d = 16
ws = jnp.asarray(np.random.default_rng(0).standard_normal((4, d, d)) * 0.3,
                 jnp.float32)
x = jnp.asarray(np.random.default_rng(1).standard_normal((8, d)), jnp.float32)

def stage(w, h):
    return jnp.tanh(h @ w)

seq = x
for i in range(4):
    seq = stage(ws[i], seq)
mbs = split_microbatches(x, 4)
out = pipeline_apply(stage, ws, mbs, mesh)
np.testing.assert_allclose(np.asarray(out.reshape(8, d)), np.asarray(seq),
                           atol=1e-5)

# differentiability: grads through the pipeline match sequential grads
def loss_pipe(ws):
    return pipeline_apply(stage, ws, mbs, mesh).sum()

def loss_seq(ws):
    h = x
    for i in range(4):
        h = stage(ws[i], h)
    return h.sum()

gp = jax.grad(loss_pipe)(ws)
gs = jax.grad(loss_seq)(ws)
np.testing.assert_allclose(np.asarray(gp), np.asarray(gs), atol=1e-4)
print("PIPELINE_OK")
"""


def test_pipeline_parallel_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr}"
