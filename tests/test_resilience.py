"""Resilience-layer tests: retry policy, fault-injection harness,
quarantine semantics, pool crash/timeout recovery, signal-driven partial
campaigns, and the explicit non-ok filtering every downstream consumer
(report, frontier, placement) must apply.

Everything nondeterministic about real failures (which cell, which
attempt, how long) is pinned by :mod:`repro.testing.faults`, so these
tests never rely on races or wall-clock flakiness. The pool tests spawn
real worker processes — they are the point — but keep the grids tiny.
"""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.dse.backends import run_cell_by_backend
from repro.dse.campaign import expand_cells, run_campaign
from repro.dse.cli import exit_code
from repro.dse.placement import candidates_by_workload, pooled_records
from repro.dse.report import render_report
from repro.dse.resilience import (CellTimeout, CorruptRecord, RetryPolicy,
                                  WorkerCrash, attempt_outcome, execute_cell,
                                  quarantine_record, validate_record)
from repro.dse.store import is_ok, open_store, record_status
from repro.testing.faults import (ENV_VAR, Fault, FaultPlan,
                                  InjectedPermanentError,
                                  InjectedTransientError, load_plan)

FAST = dict(population=4, iterations=2, progress=None)
CELLS2 = expand_cells(["alexnet"], [(224, 224)], ["ku115", "zcu102"],
                      [16], [1])
KU115_KEY = "net=alexnet|in=native|fpga=ku115|prec=16|bmax=1"


def scrub(rec):
    """Volatile fields removed: timing and retry metadata — everything
    else must be bit-identical between faulted and fault-free runs."""
    return {k: v for k, v in rec.items()
            if k not in ("search_time_s", "resilience")}


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------


def test_backoff_is_deterministic_exponential_and_jittered():
    p = RetryPolicy(backoff_s=0.1, backoff_factor=2.0, jitter_frac=0.1)
    d1 = p.backoff("cell-a", 1)
    assert d1 == p.backoff("cell-a", 1)              # reproducible
    assert d1 != p.backoff("cell-b", 1)              # de-synchronized
    assert d1 != RetryPolicy(backoff_s=0.1, seed=7).backoff("cell-a", 1)
    for attempt in (1, 2, 3):
        base = 0.1 * 2.0 ** (attempt - 1)
        d = p.backoff("cell-a", attempt)
        assert base * 0.9 <= d <= base * 1.1         # jitter bounded


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(cell_timeout_s=0.0)


def test_failure_taxonomy():
    p = RetryPolicy()
    assert p.retryable(RuntimeError("flaky"))
    assert p.retryable(CellTimeout())
    assert p.retryable(WorkerCrash())
    assert p.retryable(CorruptRecord("torn"))
    assert p.retryable(InjectedTransientError("x"))
    for exc in (ValueError("bad"), KeyError("k"), TypeError("t"),
                ZeroDivisionError(), InjectedPermanentError("x")):
        assert not p.retryable(exc)
    assert attempt_outcome(CellTimeout()) == "timeout"
    assert attempt_outcome(WorkerCrash()) == "crash"
    assert attempt_outcome(CorruptRecord("x")) == "corrupt"
    assert attempt_outcome(RuntimeError()) == "error"


def test_validate_record_rejects_garbage():
    cell = CELLS2[0]
    with pytest.raises(CorruptRecord):
        validate_record(cell, None)
    with pytest.raises(CorruptRecord):
        validate_record(cell, {"cell_key": "someone-else"})
    with pytest.raises(CorruptRecord):
        validate_record(cell, {"cell_key": cell.key})   # no objectives
    validate_record(cell, {"cell_key": cell.key,
                           "objectives": {"feasible": True}})


# ---------------------------------------------------------------------------
# execute_cell (the shared single-worker primitive)
# ---------------------------------------------------------------------------


def _flaky_fn(fail_attempts, exc_type=RuntimeError):
    def attempt_fn(cell, attempt):
        if attempt in fail_attempts:
            raise exc_type(f"boom on {attempt}")
        return {"cell_key": cell.key, "objectives": {"feasible": True},
                "evaluations": 1}
    return attempt_fn


def test_execute_cell_retries_transient_then_stamps():
    out = execute_cell(CELLS2[0], _flaky_fn({1}),
                       RetryPolicy(backoff_s=0.0), sleep=lambda s: None)
    assert out.ok and out.retried and not out.failed
    res = out.record["resilience"]
    assert res["attempts"] == 2 and res["retries"] == 1
    assert [a["outcome"] for a in res["attempt_log"]] == ["error", "ok"]


def test_execute_cell_first_attempt_success_is_unstamped():
    out = execute_cell(CELLS2[0], _flaky_fn(set()))
    assert out.ok and not out.retried
    assert "resilience" not in out.record


def test_execute_cell_permanent_failure_never_retries():
    calls = []

    def attempt_fn(cell, attempt):
        calls.append(attempt)
        raise InjectedPermanentError("deterministic model bug")

    out = execute_cell(CELLS2[0], attempt_fn,
                       RetryPolicy(max_attempts=5, backoff_s=0.0),
                       search={"base_seed": 0})
    assert calls == [1]                       # one attempt, no retry
    assert out.failed
    rec = out.record
    assert record_status(rec) == "failed" and not is_ok(rec)
    assert rec["error_type"] == "InjectedPermanentError"
    assert rec["attempts"] == 1 and rec["evaluations"] == 0
    assert "deterministic model bug" in rec["error"]
    assert "backend" not in rec               # fpga convention


def test_execute_cell_exhausts_budget_then_quarantines():
    out = execute_cell(CELLS2[0], _flaky_fn({1, 2, 3}),
                       RetryPolicy(max_attempts=3, backoff_s=0.0),
                       sleep=lambda s: None)
    assert out.failed and out.record["attempts"] == 3
    assert [a["outcome"] for a in out.record["attempt_log"]] \
        == ["error"] * 3


def test_quarantine_record_backend_field_convention():
    err = RuntimeError("x")
    log = [{"attempt": 1, "outcome": "error", "duration_s": 0.1,
            "error_type": "RuntimeError"}]
    assert "backend" not in quarantine_record(
        CELLS2[0], search=None, error=err, attempt_log=log)
    assert quarantine_record(CELLS2[0], search=None, error=err,
                             attempt_log=log, backend="tpu")["backend"] \
        == "tpu"


# ---------------------------------------------------------------------------
# fault-injection harness
# ---------------------------------------------------------------------------


def test_fault_plan_round_trip(tmp_path):
    plan = FaultPlan({"a": Fault("raise-transient", (1, 2)),
                      "b": Fault("hang-for", (), hang_s=1.5)})
    p = plan.save(tmp_path / "plan.json")
    loaded = load_plan(p)
    assert loaded == plan
    assert load_plan(plan.as_dict()) == plan
    assert load_plan(plan) is plan


def test_fault_fires_on_listed_attempts_only():
    f = Fault("raise-transient", (2,))
    assert not f.fires_on(1) and f.fires_on(2) and not f.fires_on(3)
    assert Fault("raise-transient", ()).fires_on(99)   # empty = always
    with pytest.raises(ValueError):
        Fault("set-on-fire")


def test_seeded_plan_is_deterministic():
    keys = [f"cell-{i}" for i in range(64)]
    a = FaultPlan.seeded(keys, seed=3, rate=0.25)
    b = FaultPlan.seeded(list(reversed(keys)), seed=3, rate=0.25)
    assert a == b                              # order-independent
    assert 0 < len(a.faults) < len(keys)       # rate actually selects
    assert FaultPlan.seeded(keys, seed=4, rate=0.25) != a


def test_mangle_after_strips_objectives():
    plan = FaultPlan({"k": Fault("corrupt-record")})
    rec = {"cell_key": "k", "objectives": {"feasible": True}}
    bad = plan.mangle_after("k", 1, rec)
    assert "objectives" not in bad and bad["injected_corruption"]
    assert plan.mangle_after("k", 2, rec) is rec        # attempt 2 clean
    assert plan.mangle_after("other", 1, rec) is rec


def test_harness_env_var_arms_run_cell_by_backend(tmp_path, monkeypatch):
    plan = FaultPlan({CELLS2[0].key: Fault("raise-permanent")})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    with pytest.raises(InjectedPermanentError):
        run_cell_by_backend("fpga", CELLS2[0], 0, 4, 2, None, None)
    # attempt 2 is past the fault's window: evaluation goes through
    rec = run_cell_by_backend("fpga", CELLS2[0], 0, 4, 2, None, None,
                              attempt=2)
    assert rec["cell_key"] == CELLS2[0].key
    # unarmed: same call, no fault module in the loop
    monkeypatch.delenv(ENV_VAR)
    assert run_cell_by_backend("fpga", CELLS2[0], 0, 4, 2, None,
                               None)["cell_key"] == CELLS2[0].key


# ---------------------------------------------------------------------------
# serial campaigns under faults
# ---------------------------------------------------------------------------


def test_transient_fault_retries_to_byte_identical_record(tmp_path,
                                                          monkeypatch):
    clean = run_campaign(CELLS2, str(tmp_path / "clean.jsonl"), **FAST)
    plan = FaultPlan({KU115_KEY: Fault("raise-transient", (1,))})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    faulted = run_campaign(CELLS2, str(tmp_path / "faulted.jsonl"),
                           policy=RetryPolicy(backoff_s=0.001), **FAST)
    assert not faulted.partial and exit_code(faulted) == 0
    by_key = {r["cell_key"]: r for r in faulted.records}
    assert by_key[KU115_KEY]["resilience"]["retries"] == 1
    for cr, fr in zip(clean.records, faulted.records):
        assert scrub(cr) == scrub(fr)   # retry converged to same answer


def test_corrupt_record_fault_is_caught_and_retried(tmp_path, monkeypatch):
    plan = FaultPlan({KU115_KEY: Fault("corrupt-record", (1,))})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    report = run_campaign(CELLS2, str(tmp_path / "s.jsonl"),
                          policy=RetryPolicy(backoff_s=0.001), **FAST)
    assert not report.partial
    rec = {r["cell_key"]: r for r in report.records}[KU115_KEY]
    assert rec["resilience"]["attempt_log"][0]["outcome"] == "corrupt"
    assert "injected_corruption" not in rec


def test_permanent_fault_quarantines_without_aborting_others(tmp_path,
                                                             monkeypatch):
    store = tmp_path / "s.jsonl"
    plan = FaultPlan({KU115_KEY: Fault("raise-permanent")})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    report = run_campaign(CELLS2, str(store), **FAST)
    assert report.partial and report.failed_cells == 1
    assert exit_code(report) == 3
    assert len(report.records) == 2           # other cell completed
    assert len(report.failures()) == 1
    assert len(report.feasible()) == 1        # failed record filtered
    fkeys = {json.loads(line)["cell_key"] for line in store.open()
             if json.loads(line).get("status") == "failed"}
    assert fkeys == {KU115_KEY}

    # resume WITHOUT --retry-failed: quarantine is sticky, fault or not
    monkeypatch.delenv(ENV_VAR)
    r2 = run_campaign(CELLS2, str(store), **FAST)
    assert r2.new_cells == 0 and r2.failed_cells == 1

    # resume WITH retry_failed and the fault gone: cell goes green
    r3 = run_campaign(CELLS2, str(store), retry_failed=True, **FAST)
    assert r3.new_cells == 1 and r3.failed_cells == 0
    assert not r3.partial and exit_code(r3) == 0
    # last-wins: the success superseded the quarantine record
    assert is_ok(open_store(str(store)).get(KU115_KEY))


def test_deeper_search_config_rerun_retries_quarantined_cell(
        tmp_path, monkeypatch):
    store = tmp_path / "s.jsonl"
    plan = FaultPlan({KU115_KEY: Fault("raise-permanent")})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    run_campaign(CELLS2, str(store), **FAST)
    monkeypatch.delenv(ENV_VAR)
    # a different search config is a different experiment: the failed
    # record no longer resume-matches, so the cell re-runs even without
    # retry_failed
    r = run_campaign(CELLS2, str(store), population=6, iterations=3,
                     progress=None)
    assert r.failed_cells == 0 and not r.partial


# ---------------------------------------------------------------------------
# non-ok records never leak into report / frontier / placement
# ---------------------------------------------------------------------------


def _quarantined(key=KU115_KEY):
    return {
        "schema": 1, "status": "failed", "quarantine_schema": 1,
        "cell_key": key,
        "cell": {"net": "alexnet", "h": 0, "w": 0, "fpga": "ku115",
                 "precision": 16, "batch_max": 1},
        "search": None, "error_type": "RuntimeError", "error": "boom",
        "attempts": 3,
        "attempt_log": [{"attempt": a, "outcome": "error",
                         "duration_s": 0.01, "error_type": "RuntimeError"}
                        for a in (1, 2, 3)],
        "evaluations": 0,
    }


def test_failed_records_excluded_from_every_consumer(tmp_path):
    report = run_campaign([CELLS2[1]], str(tmp_path / "s.jsonl"), **FAST)
    records = report.records + [_quarantined()]

    assert all(r["cell_key"] != KU115_KEY
               for r in candidates_by_workload(records, "tflops").get(
                   "alexnet", []))
    md = render_report(records, title="t")
    assert "Failures & retries (1 quarantined" in md
    assert "`RuntimeError` | 1" in md
    # pooled_records keeps last-wins semantics across failure/success
    later_ok = dict(records[0], cell_key=KU115_KEY)
    assert is_ok(pooled_records([[_quarantined(), later_ok]])[0])
    assert not is_ok(pooled_records([[later_ok, _quarantined()]])[0])


def test_report_tail_skips_quarantined_from_frontier(tmp_path,
                                                     monkeypatch):
    plan = FaultPlan({KU115_KEY: Fault("raise-permanent")})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    report = run_campaign(CELLS2, str(tmp_path / "s.jsonl"), **FAST)
    fi = report.frontier_index()
    assert all(fi.payload(k)["cell_key"] != KU115_KEY
               for k in fi.front_keys())
    assert all(r["cell_key"] != KU115_KEY for r in report.ranked())


# ---------------------------------------------------------------------------
# pool campaigns: crash recovery, timeouts
# ---------------------------------------------------------------------------


def test_pool_worker_crash_rebuilds_and_loses_no_cell(tmp_path,
                                                      monkeypatch):
    plan = FaultPlan({KU115_KEY: Fault("crash-process", (1,))})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    report = run_campaign(CELLS2, str(tmp_path / "s.jsonl"), workers=2,
                          policy=RetryPolicy(backoff_s=0.001), **FAST)
    assert not report.partial and exit_code(report) == 0
    assert report.pool_rebuilds >= 1
    assert len(report.records) == len(CELLS2)       # nothing lost
    assert all(is_ok(r) for r in report.records)
    crashed = {r["cell_key"]: r for r in report.records}[KU115_KEY]
    outcomes = [a["outcome"]
                for a in crashed["resilience"]["attempt_log"]]
    assert outcomes[0] == "crash" and outcomes[-1] == "ok"


def test_pool_cell_timeout_quarantines_hung_cell(tmp_path, monkeypatch):
    plan = FaultPlan({KU115_KEY: Fault("hang-for", (), hang_s=60.0)})
    monkeypatch.setenv(ENV_VAR, str(plan.save(tmp_path / "p.json")))
    report = run_campaign(
        CELLS2, str(tmp_path / "s.jsonl"), workers=2,
        policy=RetryPolicy(max_attempts=1, cell_timeout_s=1.5), **FAST)
    assert report.partial and exit_code(report) == 3
    failed = {r["cell_key"]: r for r in report.failures()}
    assert failed[KU115_KEY]["error_type"] == "CellTimeout"
    ok = [r for r in report.records if is_ok(r)]
    assert {r["cell_key"] for r in ok} \
        == {c.key for c in CELLS2 if c.key != KU115_KEY}


# ---------------------------------------------------------------------------
# signal-driven shutdown (subprocess: signal handlers are main-thread)
# ---------------------------------------------------------------------------


def test_sigint_flushes_store_and_exits_3(tmp_path):
    store = tmp_path / "s.jsonl"
    plan = FaultPlan({KU115_KEY: Fault("hang-for", (), hang_s=120.0)})
    env = dict(os.environ, REPRO_FAULTS=str(plan.save(tmp_path / "p.json")),
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    cmd = [sys.executable, "-m", "repro.dse.campaign",
           "--nets", "alexnet", "--fpgas", "ku115,zcu102",
           "--precisions", "16,8", "--batch-caps", "1",
           "--population", "4", "--iterations", "2",
           "--workers", "2", "--store", str(store)]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    try:
        deadline = time.time() + 60
        # wait for a non-hung cell to land, proving work-before-signal
        while time.time() < deadline:
            if store.exists() and store.stat().st_size > 0:
                break
            time.sleep(0.1)
        else:
            pytest.fail("no record appeared before the signal")
        time.sleep(0.5)
        proc.send_signal(signal.SIGINT)
        out, _ = proc.communicate(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert proc.returncode == 3, out
    assert "partial campaign" in out and "exit code 3" in out
    assert "resume: re-run the same command" in out
    # the flushed store resumes cleanly: every stored record is intact
    recs = list(open_store(str(store)).iter_records())
    assert recs and all(is_ok(r) for r in recs)
    assert all(r["cell_key"] != KU115_KEY for r in recs)   # hung cell
