"""Tests for the cost-aware multi-family placement engine
(repro.dse.placement): workload parsing, candidate costing/pruning,
solver correctness + determinism, budget/coverage diagnostics,
mixed-store pooling and resume safety, and the committed docs example."""
import copy
from pathlib import Path

import pytest

from repro.core.hw_specs import FPGAS, GPUS, TPU_V5E, CostEnvelope, pod_cost
from repro.dse import run_campaign
from repro.dse.backends import get_backend, workload_families
from repro.dse.placement import (BudgetInfeasibleError, CoverageError,
                                 candidates_by_workload, ensure_coverage,
                                 normalize_workload, parse_workloads, place,
                                 pooled_records, prune_candidates)
from repro.dse.placement import main as placement_main
from repro.dse.report import fixture_records, render_placement
from repro.dse.store import ResultStore

ROOT = Path(__file__).resolve().parents[1]

WORKLOADS = ["starcoder2-3b/train_4k", "xlstm-350m/decode_32k",
             "vgg16@224x224"]
BUDGET = CostEnvelope(usd_per_hour=60.0, watts=25000.0)


# ---------------------------------------------------------------------------
# workload keys
# ---------------------------------------------------------------------------


def test_normalize_workload_forms():
    assert normalize_workload("starcoder2-3b/train_4k") == \
        "starcoder2-3b/train_4k"
    assert normalize_workload("vgg16@224") == "vgg16@224x224"
    assert normalize_workload("vgg16@320x480") == "vgg16@320x480"
    assert normalize_workload("alexnet") == "alexnet@native"
    assert normalize_workload("alexnet@native") == "alexnet@native"


def test_normalize_workload_rejects_unknown():
    with pytest.raises(KeyError):
        normalize_workload("nonexistent-net@224")
    with pytest.raises(KeyError):
        normalize_workload("starcoder2-3b/not_a_shape")
    with pytest.raises(KeyError):
        normalize_workload("vgg16@huge")


def test_normalize_workload_rejects_sized_fixed_net():
    """Fixed-topology nets record as @native; an explicit size would
    build a key no record can ever match, so reject it loudly."""
    with pytest.raises(KeyError, match="fixed input topology"):
        normalize_workload("alexnet@224")
    with pytest.raises(KeyError, match="fixed input topology"):
        normalize_workload("alexnet@224x224")


def test_parse_workloads_dedupes_in_order():
    keys = parse_workloads("vgg16@224, vgg16@224x224, alexnet")
    assert keys == ["vgg16@224x224", "alexnet@native"]
    with pytest.raises(KeyError):
        parse_workloads(" , ")


def test_workload_families_overlap_is_the_point():
    assert workload_families("starcoder2-3b/train_4k") == ("tpu", "cuda")
    assert workload_families("vgg16@224x224") == ("fpga",)
    assert workload_families("no-such-thing") == ()


# ---------------------------------------------------------------------------
# candidates: costing and pruning
# ---------------------------------------------------------------------------


def test_candidate_costs_follow_hw_tables():
    cands = candidates_by_workload(fixture_records(), "tflops")
    by_key = {c.cell_key: c for cs in cands.values() for c in cs}
    tpu16 = by_key["arch=starcoder2-3b|shape=train_4k|chips=16"
                   "|remat=full|mb=2"]
    assert (tpu16.watts, tpu16.usd_per_hour) == pod_cost(TPU_V5E, 16)
    h100 = by_key["arch=starcoder2-3b|shape=train_4k|gpu=h100|gpus=8"
                  "|remat=full|mb=2"]
    assert (h100.watts, h100.usd_per_hour) == pod_cost(GPUS["h100"], 8)
    ku = by_key["net=vgg16|in=224x224|fpga=ku115|prec=16|bmax=1"]
    assert (ku.watts, ku.usd_per_hour) == pod_cost(FPGAS["ku115"])
    assert ku.count == 1 and h100.count == 8 and tpu16.count == 16


def test_infeasible_records_are_not_candidates():
    cands = candidates_by_workload(fixture_records(), "tflops")
    keys = {c.cell_key for cs in cands.values() for c in cs}
    # the fixture marks this tpu cell infeasible (HBM blowout)
    assert "arch=starcoder2-3b|shape=train_4k|chips=16|remat=none|mb=2" \
        not in keys


def test_prune_drops_cost_dominated_designs():
    cands = candidates_by_workload(fixture_records(), "tflops")
    sc2 = cands["starcoder2-3b/train_4k"]
    kept = prune_candidates(sc2, BUDGET)
    kept_keys = {c.cell_key for c in kept}
    # the a100-80g design is beaten on value by the cheaper tpu16 cell
    assert "arch=starcoder2-3b|shape=train_4k|gpu=a100-80g|gpus=8" \
        "|remat=full|mb=2" not in kept_keys
    assert len(kept) < len(sc2)
    # with no caps, only the best-value design survives
    best_only = prune_candidates(sc2, CostEnvelope())
    assert len(best_only) == 1
    assert best_only[0].value == max(c.value for c in sc2)


# ---------------------------------------------------------------------------
# solving: optimality, determinism, tie-breaking
# ---------------------------------------------------------------------------


def _picks(result):
    return [(a.workload, a.candidate.cell_key) for a in result.assignments]


def test_exact_respects_budget_and_beats_nothing_greedy_found():
    exact = place(WORKLOADS, fixture_records(), BUDGET, solver="exact")
    greedy = place(WORKLOADS, fixture_records(), BUDGET, solver="greedy")
    assert BUDGET.admits(exact.total_usd, exact.total_watts)
    assert BUDGET.admits(greedy.total_usd, greedy.total_watts)
    assert exact.total_value >= greedy.total_value - 1e-12
    # on the fixture the greedy heuristic finds the optimum
    assert _picks(exact) == _picks(greedy)
    # the $60 cap forces the tpu pod over the h100 pod for starcoder2
    assert _picks(exact)[0] == (
        "starcoder2-3b/train_4k",
        "arch=starcoder2-3b|shape=train_4k|chips=16|remat=full|mb=2")


def test_loose_budget_takes_the_best_designs():
    loose = place(WORKLOADS, fixture_records(), CostEnvelope())
    by_wl = dict(_picks(loose))
    assert by_wl["starcoder2-3b/train_4k"] == \
        "arch=starcoder2-3b|shape=train_4k|gpu=h100|gpus=8|remat=full|mb=2"


def test_placement_is_deterministic_across_runs_and_orders():
    a = place(WORKLOADS, fixture_records(), BUDGET)
    b = place(WORKLOADS, fixture_records(), BUDGET)
    assert _picks(a) == _picks(b)
    assert [(s.workload, s.candidate.cell_key, s.blocked_by)
            for s in a.suggestions] == \
        [(s.workload, s.candidate.cell_key, s.blocked_by)
         for s in b.suggestions]
    # record order must not matter
    c = place(WORKLOADS, list(reversed(fixture_records())), BUDGET)
    assert _picks(a) == _picks(c)


def _tpu_rec(cell_key_suffix, mfu, chips=8):
    return {
        "schema": 1, "backend": "tpu",
        "cell_key": f"arch=xlstm-350m|shape=train_4k|{cell_key_suffix}",
        "cell": {"arch": "xlstm-350m", "shape": "train_4k", "chips": chips,
                 "remat": "full", "microbatches": 1},
        "plan": {"dp": chips, "tp": 1, "bound": "compute"},
        "objectives": {"step_time_s": 1.0, "mfu": mfu, "hbm_gib": 1.0,
                       "chips": float(chips), "feasible": True},
        "search": {"weights": None}, "evaluations": 1,
    }


def test_exact_ties_break_to_smaller_cell_key():
    # two candidates with IDENTICAL value and cost: the lexicographically
    # smaller cell key must win, for both solvers, in either input order
    recs = [_tpu_rec("chips=8|remat=full|mb=9", 0.5),
            _tpu_rec("chips=8|remat=full|mb=1", 0.5)]
    want = recs[1]["cell_key"]
    for solver in ("exact", "greedy"):
        for order in (recs, list(reversed(recs))):
            res = place(["xlstm-350m/train_4k"], order,
                        CostEnvelope(usd_per_hour=100.0), solver=solver)
            assert res.assignments[0].candidate.cell_key == want, solver


def _cuda_rec(gpu, gpus, mfu):
    return {
        "schema": 1, "backend": "cuda",
        "cell_key": (f"arch=xlstm-350m|shape=train_4k|gpu={gpu}"
                     f"|gpus={gpus}|remat=full|mb=1"),
        "cell": {"arch": "xlstm-350m", "shape": "train_4k", "gpu": gpu,
                 "gpus": gpus, "remat": "full", "microbatches": 1},
        "plan": {"dp": gpus, "tp": 1, "bound": "compute"},
        "objectives": {"step_time_s": 1.0, "mfu": mfu, "hbm_gib": 1.0,
                       "gpus": float(gpus),
                       "watts": gpus * GPUS[gpu].tdp_watts,
                       "feasible": True},
        "search": {"weights": None}, "evaluations": 1,
    }


def test_greedy_start_is_not_lexicographic_on_costs():
    """When the two caps pull different ways — one candidate cheaper in
    dollars but hotter in watts ($38.4/6400W tpu32 vs $55.84/5600W
    h100x8) — greedy must start from the least budget-STRAIN candidate,
    not the lexicographically cheapest, or it falsely reports a feasible
    budget as infeasible."""
    recs = [_tpu_rec("chips=32|remat=full|mb=1", 0.5, chips=32),
            _cuda_rec("h100", 8, 0.5)]
    budget = CostEnvelope(usd_per_hour=60.0, watts=6000.0)
    for solver in ("greedy", "exact"):
        res = place(["xlstm-350m/train_4k"], recs, budget, solver=solver)
        assert res.assignments[0].candidate.part == "h100", solver
        assert budget.admits(res.total_usd, res.total_watts)


def test_value_ties_break_to_cheaper_cost():
    recs = [_tpu_rec("chips=16|remat=full|mb=1", 0.25, chips=16),
            _tpu_rec("chips=8|remat=full|mb=1", 0.5, chips=8)]
    # same delivered tflops (mfu x chips x peak), different cost
    res = place(["xlstm-350m/train_4k"], recs,
                CostEnvelope(usd_per_hour=100.0), solver="exact")
    assert res.assignments[0].candidate.count == 8


# ---------------------------------------------------------------------------
# diagnostics: infeasible budgets and missing coverage
# ---------------------------------------------------------------------------


def test_budget_infeasible_raises_with_floor_costs():
    with pytest.raises(BudgetInfeasibleError) as e:
        place(WORKLOADS, fixture_records(), CostEnvelope(usd_per_hour=1.0))
    msg = str(e.value)
    assert "infeasible" in msg and "cheapest" in msg
    for w in WORKLOADS:
        assert w in msg


def test_uncovered_workload_raises_coverage_error():
    with pytest.raises(CoverageError) as e:
        place(["whisper-base/decode_32k"], fixture_records(), BUDGET)
    assert "whisper-base/decode_32k" in str(e.value)
    assert "--evaluate-missing" in str(e.value)


def test_cli_exit_codes_and_diagnostics(capsys):
    argv = ["--fixture", "--workloads", "vgg16@224x224"]
    assert placement_main(argv + ["--budget-usd", "0.1"]) == 2
    err = capsys.readouterr().err
    assert "placement failed" in err and "infeasible" in err
    assert placement_main(
        ["--fixture", "--workloads", "whisper-base/decode_32k"]) == 2
    err = capsys.readouterr().err
    assert "no store coverage" in err
    assert placement_main(argv) == 0


def test_cli_selftest():
    assert placement_main(["--selftest"]) == 0


# ---------------------------------------------------------------------------
# stores: pooling, last-wins, resume safety, coverage fallback
# ---------------------------------------------------------------------------


def test_pooled_records_later_stores_win():
    first = fixture_records()
    dup = copy.deepcopy(
        [r for r in first if r["cell_key"].startswith(
            "arch=starcoder2-3b|shape=train_4k|chips=16|remat=full")])
    assert len(dup) == 1
    dup[0]["objectives"]["mfu"] = 0.99  # "newer" evidence in a later store
    pooled = pooled_records([first, dup])
    assert len(pooled) == len(first)
    winner = [r for r in pooled if r["cell_key"] == dup[0]["cell_key"]]
    assert winner[0]["objectives"]["mfu"] == 0.99


def test_mixed_store_resume_is_placement_stable(tmp_path):
    """Re-running campaigns into the same stores (pure resume, zero new
    evaluations) must not change a placement drawn from them."""
    tpu_store = tmp_path / "tpu.jsonl"
    cuda_store = tmp_path / "cuda.jsonl"
    be = get_backend("tpu")
    cells = be.expand_cells(archs=["xlstm-350m"], shapes=["train_4k"],
                            chips=[8, 16], remats=("full",),
                            microbatches=(1,))
    run_campaign(cells, tpu_store, backend="tpu")
    gc = get_backend("cuda").expand_cells(
        archs=["xlstm-350m"], shapes=["train_4k"], gpus=[8],
        gpu_types=("a100-80g",), remats=("full",), microbatches=(1,))
    run_campaign(gc, cuda_store, backend="cuda")

    budget = CostEnvelope(usd_per_hour=25.0)
    recs = pooled_records([ResultStore(tpu_store), ResultStore(cuda_store)])
    before = place(["xlstm-350m/train_4k"], recs, budget)

    rerun = run_campaign(cells, tpu_store, backend="tpu")
    assert rerun.new_evaluations == 0  # pure resume
    recs2 = pooled_records([ResultStore(tpu_store), ResultStore(cuda_store)])
    after = place(["xlstm-350m/train_4k"], recs2, budget)
    assert _picks(before) == _picks(after)
    assert before.total_value == after.total_value


def test_ensure_coverage_fills_only_the_gap(tmp_path):
    store = ResultStore(tmp_path / "cov.jsonl")
    known = candidates_by_workload(store.iter_records(), "tflops")
    filled = ensure_coverage(["xlstm-350m/decode_32k"], store, known)
    assert filled == ["xlstm-350m/decode_32k"]
    recs = list(store.iter_records())
    assert recs and all(
        get_backend(r["backend"]).group_key(r) == "xlstm-350m/decode_32k"
        for r in recs)
    assert {r["backend"] for r in recs} == {"tpu", "cuda"}
    # now covered: a second pass evaluates nothing
    known = candidates_by_workload(store.iter_records(), "tflops")
    assert ensure_coverage(["xlstm-350m/decode_32k"], store, known) == []
    res = place(["xlstm-350m/decode_32k"], list(store.iter_records()),
                CostEnvelope(watts=30000.0))
    assert res.assignments[0].candidate.workload == "xlstm-350m/decode_32k"


# ---------------------------------------------------------------------------
# report + the committed docs example
# ---------------------------------------------------------------------------


def test_render_placement_sections_and_totals():
    res = place(WORKLOADS, fixture_records(), BUDGET, solver="exact")
    md = render_placement(res)
    for must in ("## Assignment", "## Budget utilization",
                 "## Marginal upgrades", "**total**", "blocked by"):
        assert must in md
    assert f"{res.total_usd:.4g}" in md


def test_committed_example_placement_is_current(tmp_path):
    """docs/placement.md's worked example command must reproduce the
    committed docs/reports/example_placement.md byte-for-byte."""
    out = tmp_path / "example_placement.md"
    rc = placement_main([
        "--fixture",
        "--workloads",
        "starcoder2-3b/train_4k,xlstm-350m/decode_32k,vgg16@224x224",
        "--budget-usd", "60", "--budget-watts", "25000",
        "--solver", "exact", "--out", str(out)])
    assert rc == 0
    committed = ROOT / "docs" / "reports" / "example_placement.md"
    assert committed.exists(), "docs/reports/example_placement.md missing"
    assert out.read_text() == committed.read_text(), (
        "docs/reports/example_placement.md has drifted from what the "
        "worked example in docs/placement.md generates; regenerate it "
        "with the command in that doc")
