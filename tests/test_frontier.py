"""FrontierIndex property tests: the incremental insert-time dominance
archive must agree with the :mod:`repro.dse.pareto` oracle — same front
members, same order, same diversity read-off — under seeded random
insert streams, duplicate vectors, duplicate keys (last-wins
replacement), and mixed dimensions.

The vectors are drawn from a SMALL integer lattice on purpose: that
forces exact duplicates, dominance ties, and deep fronts — the cases a
naive archive gets wrong — far more often than uniform floats would.
"""
import numpy as np
import pytest

from repro.dse.frontier import FrontierIndex
from repro.dse.pareto import (diverse_front, dominance_split, non_dominated,
                              nondominated_sort)


def lattice_vecs(rng, n, d, side=5):
    return [tuple(float(x) for x in row)
            for row in rng.integers(0, side, size=(n, d))]


# ---------------------------------------------------------------------------
# property sweep vs the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(25))
def test_front_matches_oracle_under_random_stream(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 80))
    d = int(rng.integers(2, 5))
    vecs = lattice_vecs(rng, n, d)
    fi = FrontierIndex()
    for i, v in enumerate(vecs):
        on = fi.insert(i, v)
        assert on == fi.on_front(i)
    expect = non_dominated(vecs)
    assert fi.front_keys() == expect
    assert fi.front_vectors() == [vecs[i] for i in expect]
    assert fi.front_size() == len(expect)
    assert len(fi) == n
    # front 0 of the full NSGA-II sort is the same set (sanity on the
    # oracle itself)
    assert nondominated_sort(vecs)[0] == expect


@pytest.mark.parametrize("seed", range(15))
def test_duplicate_keys_last_wins_matches_oracle(seed):
    rng = np.random.default_rng(1000 + seed)
    n_keys = int(rng.integers(2, 25))
    stream = int(rng.integers(n_keys, 120))
    d = int(rng.integers(2, 4))
    fi = FrontierIndex()
    current: dict[int, tuple] = {}
    for v in lattice_vecs(rng, stream, d):
        key = int(rng.integers(0, n_keys))
        fi.insert(key, v)
        current[key] = v
        # invariant holds after EVERY insert, not just at the end:
        # current points in first-appearance key order vs the oracle
        keys = list(current)
        vecs = [current[k] for k in keys]
        assert fi.front_keys() == [keys[i] for i in non_dominated(vecs)]
    assert len(fi) == len(current)
    assert fi.inserts == stream


@pytest.mark.parametrize("seed", range(10))
def test_diverse_matches_diverse_front(seed):
    rng = np.random.default_rng(2000 + seed)
    vecs = lattice_vecs(rng, int(rng.integers(1, 60)), 3)
    fi = FrontierIndex()
    for i, v in enumerate(vecs):
        fi.insert(i, v)
    assert fi.diverse() == diverse_front(vecs)
    for k in (1, 2, 5):
        assert fi.diverse(k) == diverse_front(vecs, k)


def test_dominance_split_matches_scalar_oracle():
    rng = np.random.default_rng(7)
    for _ in range(50):
        mat = rng.integers(0, 4, size=(int(rng.integers(0, 12)), 3)) \
            .astype(float)
        v = rng.integers(0, 4, size=3).astype(float)
        dominated, kills = dominance_split(mat, v)
        from repro.dse.pareto import dominates
        assert dominated == any(dominates(row, v) for row in mat)
        assert list(kills) == [dominates(v, row) for row in mat]


# ---------------------------------------------------------------------------
# edge semantics
# ---------------------------------------------------------------------------


def test_duplicate_vectors_coexist_on_front():
    fi = FrontierIndex()
    fi.insert("a", (1.0, 2.0))
    fi.insert("b", (1.0, 2.0))
    assert fi.front_keys() == ["a", "b"]


def test_replacement_resurrects_shadowed_points():
    fi = FrontierIndex()
    fi.insert("edge", (3.0, 0.0), payload={"who": "edge"})
    fi.insert("lo", (1.0, 1.0), payload={"who": "lo"})
    fi.insert("hi", (2.0, 2.0), payload={"who": "hi"})
    assert fi.front_keys() == ["edge", "hi"]
    # last-wins: hi's re-run got worse; lo must come back
    fi.insert("hi", (0.5, 0.5))
    assert fi.front_keys() == ["edge", "lo"]
    assert fi.rebuilds == 1
    # edge never left the front: its payload survives the rebuild; lo
    # was shadowed away (payload dropped, O(front) memory) and comes
    # back payloadless — consumers re-fetch from the store by key
    assert fi.payload("edge") == {"who": "edge"}
    assert fi.payload("lo") is None


def test_resurrected_member_payload_may_be_none():
    fi = FrontierIndex()
    fi.insert("lo", (1.0, 1.0), payload={"who": "lo"})
    fi.insert("hi", (2.0, 2.0), payload={"who": "hi"})
    # lo was dominated away -> its payload was dropped (O(front) memory);
    # after hi degrades, lo is back on the front but payloadless
    fi.insert("hi", (0.0, 0.0), payload={"who": "hi2"})
    assert fi.front_keys() == ["lo"]
    assert fi.payload("lo") is None
    assert fi.payload("hi") is None  # off-front members never keep one


def test_same_key_same_vector_is_geometry_noop():
    fi = FrontierIndex()
    fi.insert("a", (1.0, 1.0), payload=1)
    assert fi.insert("a", (1.0, 1.0), payload=2) is True
    assert fi.rebuilds == 0
    assert fi.payload("a") == 2  # live member's payload refreshes


def test_dim_mismatch_raises():
    fi = FrontierIndex()
    fi.insert("a", (1.0, 2.0))
    with pytest.raises(ValueError, match="arity mismatch"):
        fi.insert("b", (1.0, 2.0, 3.0))


def test_payloads_only_for_front_members():
    rng = np.random.default_rng(3)
    fi = FrontierIndex()
    for i, v in enumerate(lattice_vecs(rng, 60, 3)):
        fi.insert(i, v, payload={"i": i})
    assert set(fi._payloads) == set(fi.front_keys())
    for key, vec, payload in fi.front():
        assert payload == {"i": key}
