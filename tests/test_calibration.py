"""Tests for :mod:`repro.calib` — the model-vs-measured calibration loop.

Three layers of guarantees:

* **Unit**: the geomean fit recovers known skews exactly, merges
  provenance, guarantees calibrated error <= raw error per part, and
  round-trips through JSON with fingerprint stability.
* **Byte-identity** (the PR's acceptance lock): with no calibration —
  or the explicit ``IDENTITY`` — every backend evaluation and the
  rendered fixture report are byte-identical to the pre-calibration
  goldens committed in ``tests/data/``.
* **End-to-end round trip**: a tiny campaign evaluated against
  synthetic measurements with a known skew; the fit shrinks the error
  table, the calibration fingerprint keys the store's resume match so
  calibrated and uncalibrated results never mix, and the per-record
  provenance stamp survives a store reopen.
"""
import json
import math
import random
from pathlib import Path

import pytest

from repro.calib import (IDENTITY, Calibration, Correction, Measurement,
                         Provenance, error_rows, fit_corrections,
                         fixture_measurements, published_measurements,
                         validate_calibration)
from repro.core.hw_specs import KU115, TPU_V5E

REPO = Path(__file__).resolve().parent.parent

_PROV = Provenance(source="test", date="2026-08-01", kind="synthetic")


def _meas(part, axis, pred, meas, workload="w"):
    return Measurement(part=part, axis=axis, workload=workload,
                       predicted_s=pred, measured_s=meas, provenance=_PROV)


# ---------------------------------------------------------------------------
# unit: fit math
# ---------------------------------------------------------------------------


def test_fit_recovers_exact_skew():
    # hardware delivers 80% of datasheet compute -> measured = pred / 0.8
    ms = [_meas("tpu_v5e", "compute", p, p / 0.8) for p in (0.1, 1.0, 7.5)]
    cal = fit_corrections(ms)
    c = cal.correction("tpu_v5e")
    assert c.compute_scale == pytest.approx(0.8, rel=1e-12)
    assert c.bw_scale == 1.0 and c.n_bandwidth == 0
    assert c.cal_err_pct == pytest.approx(0.0, abs=1e-9)
    assert c.raw_err_pct == pytest.approx(25.0, rel=1e-9)  # 1/0.8 - 1


def test_fit_is_geomean_of_ratios():
    ms = [_meas("ku115", "compute", 1.0, 2.0),
          _meas("ku115", "compute", 1.0, 0.5)]
    cal = fit_corrections(ms)
    # geomean(1/2, 1/0.5) = 1 -> identity on that axis
    assert cal.correction("ku115").compute_scale == pytest.approx(1.0)


def test_fit_handles_both_axes_independently():
    ms = [_meas("h100", "compute", 1.0, 2.0),
          _meas("h100", "bandwidth", 1.0, 1.25)]
    c = fit_corrections(ms).correction("h100")
    assert c.compute_scale == pytest.approx(0.5)
    assert c.bw_scale == pytest.approx(0.8)
    assert (c.n_compute, c.n_bandwidth) == (1, 1)


def test_fit_merges_provenance():
    p1 = Provenance("src-a", "2026-01-01", "microbench")
    p2 = Provenance("src-b", "2026-03-01", "published")
    ms = [Measurement("ku115", "compute", "w1", 1.0, 2.0, p1),
          Measurement("ku115", "compute", "w2", 1.0, 2.0, p2)]
    prov = fit_corrections(ms).correction("ku115").provenance
    assert "src-a" in prov.source and "src-b" in prov.source
    assert prov.date == "2026-03-01"          # newest measurement wins
    assert prov.kind == "microbench+published"  # sorted, joined


@pytest.mark.parametrize("seed", range(8))
def test_property_cal_err_never_exceeds_raw_err(seed):
    """The geomean minimizes RMS log error, so the calibrated error can
    never exceed the raw error — on any noisy measurement set."""
    rng = random.Random(seed)
    ms = []
    for part in ("ku115", "tpu_v5e", "h100"):
        skew = rng.uniform(0.3, 3.0)
        for i in range(rng.randint(1, 6)):
            p = rng.uniform(0.01, 10.0)
            noise = math.exp(rng.gauss(0.0, 0.2))
            axis = rng.choice(("compute", "bandwidth"))
            ms.append(_meas(part, axis, p, p / skew * noise, f"w{i}"))
    cal = fit_corrections(ms)
    for row in error_rows(cal):
        assert row["cal_err_pct"] <= row["raw_err_pct"] + 1e-9, \
            f"seed={seed} part={row['part']}"
    assert validate_calibration(cal, ms) == []


def test_fixture_fit_error_table_improves_every_row():
    cal = fit_corrections(fixture_measurements())
    rows = error_rows(cal)
    assert len(rows) == len(cal.parts()) > 0
    for row in rows:
        assert row["cal_err_pct"] <= row["raw_err_pct"] + 1e-9
        assert row["kind"] and row["source"] and row["date"]


def test_published_table_fits_delivered_fractions():
    cal = fit_corrections(published_measurements())
    # MLPerf-style delivered fractions land well below datasheet peaks
    for part in cal.parts():
        assert 0.3 <= cal.correction(part).compute_scale <= 0.9


def test_measurement_validates_inputs():
    with pytest.raises(ValueError):
        _meas("x", "latency", 1.0, 1.0)
    with pytest.raises(ValueError):
        _meas("x", "compute", 0.0, 1.0)
    with pytest.raises(ValueError):
        _meas("x", "compute", 1.0, -2.0)


# ---------------------------------------------------------------------------
# unit: Calibration container
# ---------------------------------------------------------------------------


def test_identity_filtering_and_fingerprint():
    assert IDENTITY.is_identity()
    assert Calibration({"ku115": Correction()}).is_identity()
    # fingerprint of the empty fit is a stable golden (sha256 of "{}")
    assert IDENTITY.fingerprint() == "44136fa355b3"
    cal = fit_corrections(fixture_measurements())
    assert not cal.is_identity()
    assert cal.fingerprint() != IDENTITY.fingerprint()


def test_for_spec_identity_returns_same_object():
    assert IDENTITY.for_spec(KU115) is KU115
    assert IDENTITY.for_spec(TPU_V5E) is TPU_V5E
    cal = Calibration({"h100": Correction(compute_scale=0.5)})
    assert cal.for_spec(KU115) is KU115  # uncorrected part untouched


def test_for_spec_scales_the_right_family_fields():
    cal = Calibration({
        "ku115": Correction(compute_scale=0.9, bw_scale=0.8),
        "tpu_v5e": Correction(compute_scale=0.75, bw_scale=0.85)})
    f = cal.for_spec(KU115)
    assert f.freq_mhz == pytest.approx(KU115.freq_mhz * 0.9)
    assert f.bw_gbps == pytest.approx(KU115.bw_gbps * 0.8)
    assert f.dsp == KU115.dsp  # resources are physical, never scaled
    t = cal.for_spec(TPU_V5E)
    assert t.peak_flops == pytest.approx(TPU_V5E.peak_flops * 0.75)
    assert t.hbm_bw == pytest.approx(TPU_V5E.hbm_bw * 0.85)
    assert t.hbm_bytes == TPU_V5E.hbm_bytes


def test_save_load_round_trip_preserves_everything(tmp_path):
    cal = fit_corrections(fixture_measurements())
    path = cal.save(tmp_path / "cal.json")
    back = Calibration.load(path)
    assert back == cal
    assert back.fingerprint() == cal.fingerprint()
    for part in cal.parts():
        assert back.correction(part).provenance == \
            cal.correction(part).provenance


def test_record_info_identity_none_else_stamped():
    assert IDENTITY.record_info("ku115") is None
    cal = fit_corrections(fixture_measurements())
    assert cal.record_info("no-such-part") is None
    info = cal.record_info("tpu_v5e")
    assert info["fingerprint"] == cal.fingerprint()
    assert info["part"] == "tpu_v5e"
    assert info["provenance"]["date"]


def test_validate_flags_bad_calibrations():
    bad = Calibration({"ku115": Correction(compute_scale=100.0,
                                           provenance=_PROV)})
    assert any("plausible" in p or "scale" in p
               for p in validate_calibration(bad))
    worse = Calibration({"ku115": Correction(
        compute_scale=0.9, provenance=_PROV,
        raw_err_pct=1.0, cal_err_pct=5.0)})
    assert validate_calibration(worse) != []
    no_prov = Calibration({"ku115": Correction(compute_scale=0.9)})
    assert validate_calibration(no_prov) != []


# ---------------------------------------------------------------------------
# byte-identity against the pre-calibration goldens
# ---------------------------------------------------------------------------


def _fresh_records(calibration):
    from repro.dse.backends import BACKENDS, CUDACell, TPUCell
    from repro.dse.campaign import CampaignCell, run_cell
    kw = {} if calibration is None else {"calibration": calibration}
    out = {
        "fpga": run_cell(CampaignCell("vgg16", 64, 64, "zc706", 16, 1),
                         0, 6, 4, **kw),
        "tpu": BACKENDS["tpu"].run_cell(
            TPUCell("xlstm-350m", "train_4k", 8, "full", 1), **kw),
        "cuda": BACKENDS["cuda"].run_cell(
            CUDACell("xlstm-350m", "train_4k", "a100-80g", 8, "full", 1),
            **kw),
    }
    for rec in out.values():
        rec.pop("search_time_s", None)
    return out


@pytest.mark.parametrize("calibration", [None, IDENTITY],
                         ids=["none", "identity"])
def test_uncalibrated_backends_byte_identical_to_golden(calibration):
    golden = json.loads((REPO / "tests/data/golden_uncalibrated.json")
                        .read_text())
    fresh = _fresh_records(calibration)
    for backend in golden:
        assert json.dumps(fresh[backend], sort_keys=True) == \
            json.dumps(golden[backend], sort_keys=True), backend


def test_uncalibrated_fixture_report_byte_identical_to_golden():
    from repro.dse.report import (fixture_events, fixture_records,
                                  render_report)
    md = render_report(fixture_records(), title="golden fixture report",
                       events=fixture_events())
    assert md == (REPO / "tests/data/golden_fixture_report.md").read_text()
    assert "Calibration" not in md


# ---------------------------------------------------------------------------
# report + committed example
# ---------------------------------------------------------------------------


def test_report_renders_error_table_with_provenance():
    from repro.dse.report import fixture_records, render_report
    cal = fit_corrections(fixture_measurements())
    md = render_report(fixture_records(), title="calibrated fixture",
                       calibration=cal)
    assert "## Calibration (predicted vs measured)" in md
    assert cal.fingerprint() in md
    for part in cal.parts():
        assert f"`{part}`" in md
    assert "raw err %" in md and "cal err %" in md


def test_committed_example_calibration_doc_is_current():
    from repro.calib.__main__ import example_markdown
    committed = (REPO / "docs/reports/example_calibration.md").read_text()
    assert example_markdown() == committed, \
        "regenerate with: python -m repro.calib example --out " \
        "docs/reports/example_calibration.md"


def test_calib_cli_fit_show_validate(tmp_path, capsys):
    from repro.calib.__main__ import main
    out = str(tmp_path / "cal.json")
    assert main(["fit", "--fixture", "--out", out]) == 0
    assert main(["show", out]) == 0
    assert main(["validate", out, "--fixture"]) == 0
    text = capsys.readouterr().out
    assert "fingerprint" in text and "raw err %" in text


# ---------------------------------------------------------------------------
# end-to-end: seeded campaign round trip (the tentpole's closing loop)
# ---------------------------------------------------------------------------


def _skewed_tpu_measurements(seed=7, compute_skew=0.8, bw_skew=0.9):
    """Synthetic measured numbers for tpu_v5e with a known skew: the
    'hardware' delivers ``skew`` of datasheet, plus small seeded noise."""
    rng = random.Random(seed)
    ms = []
    for i in range(5):
        p = rng.uniform(0.05, 2.0)
        noise = math.exp(rng.gauss(0.0, 0.03))
        ms.append(_meas("tpu_v5e", "compute", p, p / compute_skew * noise,
                        workload=f"synthetic/{i}"))
    for i in range(3):
        p = rng.uniform(0.05, 2.0)
        noise = math.exp(rng.gauss(0.0, 0.03))
        ms.append(_meas("tpu_v5e", "bandwidth", p, p / bw_skew * noise,
                        workload=f"synthetic/bw{i}"))
    return ms


def test_e2e_fit_shrinks_error_and_scales_predictions():
    ms = _skewed_tpu_measurements()
    cal = fit_corrections(ms)
    c = cal.correction("tpu_v5e")
    assert c.compute_scale == pytest.approx(0.8, rel=0.05)
    assert c.bw_scale == pytest.approx(0.9, rel=0.05)
    (row,) = error_rows(cal)
    assert row["cal_err_pct"] < row["raw_err_pct"]
    assert row["cal_err_pct"] < 5.0 < row["raw_err_pct"]
    # applying the correction slows the modeled step time: delivered
    # compute dropped to ~80% of the datasheet the raw model assumed
    from repro.dse.backends import BACKENDS, TPUCell
    cell = TPUCell("xlstm-350m", "train_4k", 8, "full", 1)
    raw = BACKENDS["tpu"].run_cell(cell)
    corrected = BACKENDS["tpu"].run_cell(cell, calibration=cal)
    assert corrected["objectives"]["step_time_s"] > \
        raw["objectives"]["step_time_s"]
    assert corrected["calibration"]["fingerprint"] == cal.fingerprint()
    assert "calibration" not in raw


def test_e2e_store_round_trip_provenance_and_resume(tmp_path):
    from repro.dse import run_campaign
    from repro.dse.backends import get_backend
    from repro.dse.store import open_store

    cal = fit_corrections(_skewed_tpu_measurements())
    cells = get_backend("tpu").expand_cells(
        archs=["xlstm-350m"], shapes=["train_4k"], chips=[8],
        remats=("full",), microbatches=(1,))
    store = str(tmp_path / "calibrated.jsonl")

    first = run_campaign(cells, store, backend="tpu", calibration=cal)
    assert first.new_cells == 1

    # provenance stamp survives the store reopen
    (rec,) = list(open_store(store).iter_records())
    stamp = rec["calibration"]
    assert stamp["fingerprint"] == cal.fingerprint()
    assert stamp["compute_scale"] == \
        pytest.approx(cal.correction("tpu_v5e").compute_scale)
    assert stamp["provenance"]["kind"] == "synthetic"
    assert rec["search"]["calibration"] == cal.fingerprint()

    # same calibration -> memoized resume, nothing re-evaluated
    again = run_campaign(cells, store, backend="tpu", calibration=cal)
    assert again.reused_cells == 1 and again.new_evaluations == 0

    # dropping (or changing) the calibration invalidates the resume
    # match: uncalibrated results never silently mix with corrected ones
    uncal = run_campaign(cells, store, backend="tpu")
    assert uncal.new_cells == 1 and uncal.new_evaluations > 0
    (rec2,) = list(open_store(store).iter_records())
    assert "calibration" not in rec2
