"""Property-based invariant tests for :mod:`repro.dse.pareto`, using
seeded randomized sweeps (no extra dependencies): every property is
checked against many generated vector sets, including degenerate axes,
duplicates, and single-point fronts, with the failing seed in the
assertion message so any counterexample replays deterministically.

The front oracle is an independent re-implementation (set semantics over
pairwise tuple comparison) so the test does not share code — or bugs —
with ``non_dominated``.
"""
import math

import numpy as np
import pytest

from repro.dse.pareto import (crowding_distance, diverse_front, dominates,
                              non_dominated, nondominated_sort, pareto_front,
                              select_diverse)

SEEDS = range(12)


def _vectors(seed: int) -> list[tuple[float, ...]]:
    """A randomized objective set: dimension 2-4, size 1-60, values
    quantized so duplicates and ties actually occur, occasionally with a
    degenerate (constant) axis."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 61))
    dim = int(rng.integers(2, 5))
    vals = rng.integers(0, 8, size=(n, dim)).astype(float)
    if rng.random() < 0.3:
        vals[:, int(rng.integers(0, dim))] = 3.0  # degenerate objective
    if n > 3:  # force exact duplicates
        vals[1] = vals[0]
    return [tuple(row) for row in vals]


def _oracle_front(vectors) -> set:
    """Brute-force O(n^2) oracle, written independently: i is on the
    front iff no j is >= everywhere and > somewhere."""
    out = set()
    for i, v in enumerate(vectors):
        dominated = False
        for j, u in enumerate(vectors):
            if j == i:
                continue
            if all(uk >= vk for uk, vk in zip(u, v)) and tuple(u) != tuple(v):
                dominated = True
                break
        if not dominated:
            out.add(i)
    return out


@pytest.mark.parametrize("seed", SEEDS)
def test_front_matches_bruteforce_oracle(seed):
    vecs = _vectors(seed)
    got = non_dominated(vecs)
    assert got == sorted(got), f"seed={seed}: front not in input order"
    assert set(got) == _oracle_front(vecs), f"seed={seed}"
    assert [vecs[i] for i in got] == pareto_front(vecs, vecs), f"seed={seed}"


@pytest.mark.parametrize("seed", SEEDS)
def test_dominance_axioms(seed):
    """Irreflexivity, antisymmetry, and transitivity on sampled pairs and
    triples."""
    vecs = _vectors(seed)
    rng = np.random.default_rng(1000 + seed)
    idx = rng.integers(0, len(vecs), size=(60, 3))
    for a, b, c in idx:
        va, vb, vc = vecs[a], vecs[b], vecs[c]
        assert not dominates(va, va), f"seed={seed}: reflexive {va}"
        if dominates(va, vb):
            assert not dominates(vb, va), \
                f"seed={seed}: antisymmetry {va} {vb}"
            if dominates(vb, vc):
                assert dominates(va, vc), \
                    f"seed={seed}: transitivity {va} {vb} {vc}"


@pytest.mark.parametrize("seed", SEEDS)
def test_nondominated_sort_partitions(seed):
    """Every index lands in exactly one front, front 0 is THE front, and
    each later front is non-dominated once earlier fronts are removed."""
    vecs = _vectors(seed)
    fronts = nondominated_sort(vecs)
    flat = [i for f in fronts for i in f]
    assert sorted(flat) == list(range(len(vecs))), f"seed={seed}"
    assert set(fronts[0]) == _oracle_front(vecs), f"seed={seed}"
    remaining = list(range(len(vecs)))
    for front in fronts:
        sub = [vecs[i] for i in remaining]
        want = {remaining[j] for j in _oracle_front(sub)}
        assert set(front) == want, f"seed={seed}"
        remaining = [i for i in remaining if i not in want]


@pytest.mark.parametrize("seed", SEEDS)
def test_crowding_boundary_points_infinite(seed):
    """Vectors extreme in any non-degenerate objective get inf distance;
    everyone else gets a finite non-negative credit."""
    vecs = _vectors(seed)
    dist = crowding_distance(vecs)
    assert len(dist) == len(vecs), f"seed={seed}"
    if len(vecs) == 1:
        assert dist == [math.inf]
        return
    for d in range(len(vecs[0])):
        col = [v[d] for v in vecs]
        lo, hi = min(col), max(col)
        if lo == hi:
            continue  # degenerate axis contributes nothing
        # sorted() is stable, so among ties for the minimum the FIRST
        # input index sorts to position 0, and among ties for the
        # maximum the LAST input index sorts to position -1 — those are
        # the boundary slots credited inf
        first_lo = min(i for i in range(len(col)) if col[i] == lo)
        last_hi = max(i for i in range(len(col)) if col[i] == hi)
        assert dist[first_lo] == math.inf, f"seed={seed} d={d}"
        assert dist[last_hi] == math.inf, f"seed={seed} d={d}"
    assert all(x >= 0.0 for x in dist), f"seed={seed}"


@pytest.mark.parametrize("seed", SEEDS)
def test_select_diverse_subset_and_deterministic(seed):
    """select_diverse(k): exactly min(k, n) distinct indices; with k no
    larger than the first front it returns ONLY first-front members; and
    it is a pure deterministic function of its input."""
    vecs = _vectors(seed)
    front = set(non_dominated(vecs))
    for k in (0, 1, len(front), len(vecs), len(vecs) + 5):
        sel = select_diverse(vecs, k)
        assert len(sel) == min(k, len(vecs)), f"seed={seed} k={k}"
        assert len(set(sel)) == len(sel), f"seed={seed} k={k}: dupes"
        if 0 < k <= len(front):
            assert set(sel) <= front, f"seed={seed} k={k}"
        assert sel == select_diverse(list(vecs), k), \
            f"seed={seed} k={k}: non-deterministic"
    # full selection is a permutation, whole fronts in rank order
    sel = select_diverse(vecs, len(vecs))
    assert sorted(sel) == list(range(len(vecs))), f"seed={seed}"
    rank = {}
    for ri, f in enumerate(nondominated_sort(vecs)):
        for i in f:
            rank[i] = ri
    assert [rank[i] for i in sel] == sorted(rank[i] for i in sel), \
        f"seed={seed}: fronts interleaved"


@pytest.mark.parametrize("seed", SEEDS)
def test_diverse_front_is_crowding_ordered_first_front(seed):
    """diverse_front == the first front reordered (extremes first), never
    reaching into later fronts even when truncated."""
    vecs = _vectors(seed)
    front = set(non_dominated(vecs))
    full = diverse_front(vecs)
    assert set(full) == front, f"seed={seed}"
    for k in (1, 2, len(front)):
        cut = diverse_front(vecs, k)
        assert len(cut) == min(k, len(front)), f"seed={seed} k={k}"
        assert set(cut) <= front, f"seed={seed} k={k}"
        assert cut == full[:len(cut)], f"seed={seed} k={k}: order drifts"
