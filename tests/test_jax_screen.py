"""Cross-cell jax screening: BIT-equivalence against the NumPy
reference (:func:`repro.core.batch_eval.screen_rav_batch`) and campaign
parity with ``jax_screen=True``.

Exact equality (``np.array_equal``, not allclose) is the contract: the
jax kernel mirrors the reference operation-for-operation in
float64/int64, so any drift means a real divergence in the port, and
the ``screen_fits`` handoff into the hyperband searcher would silently
change search trajectories. Skips wholesale when jax is absent (the CI
bench runner) — the NumPy path is the fallback there by design.
"""
import numpy as np
import pytest

from repro.core import screen_jax
from repro.core.batch_eval import screen_rav_batch
from repro.core.hw_specs import FPGAS
from repro.core.search import (SearchSpace, hyperband_rung0,
                               searcher_config_for)
from repro.dse.campaign import (build_net, cell_seed, expand_cells,
                                prescreen_cells_jax, run_campaign)

pytestmark = pytest.mark.skipif(not screen_jax.available(),
                                reason="jax not installed")

# A deliberately heterogeneous cell mix: different table lengths
# (vgg16 vs alexnet vs vgg19), precisions (alpha 2 vs 4), and boards —
# so the padded stacking is actually exercised.
CASES = [("vgg16", 224, 224, "ku115", 16),
         ("alexnet", 0, 0, "zcu102", 8),
         ("vgg19", 320, 320, "vu9p", 16),
         ("vgg16", 128, 128, "zc706", 8)]


def _spaces_and_tables():
    tables, spaces = [], []
    for net_name, h, w, fp, prec in CASES:
        net = build_net(net_name, h, w)
        spaces.append(SearchSpace(sp_max=len(net.major_layers), batch_max=8))
        tables.append(screen_jax.cell_tables(net, FPGAS[fp], prec, prec))
    return spaces, tables


def test_bit_equivalence_vs_numpy_reference():
    spaces, tables = _spaces_and_tables()
    rng = np.random.default_rng(11)
    blocks = [rng.uniform(sp.lo(), sp.hi(), size=(311, 5)) for sp in spaces]
    out = screen_jax.screen_cells(screen_jax.stack_cells(tables),
                                  np.stack(blocks))
    assert out.shape == (len(CASES), 311)
    for i, (net_name, h, w, fp, prec) in enumerate(CASES):
        ref = screen_rav_batch(build_net(net_name, h, w), FPGAS[fp],
                               blocks[i], prec, prec)
        assert np.array_equal(out[i], ref), f"cell {i} diverged"


def test_boundary_positions_bit_equal():
    """Degenerate candidates — sp=0 (no pipeline), full split, zero-ish
    bandwidth fractions — hit every where-guard in the kernel."""
    spaces, tables = _spaces_and_tables()
    blocks = []
    for sp in spaces:
        lo, hi = sp.lo(), sp.hi()
        blocks.append(np.stack([lo, hi, sp.canonical()[1],
                                [0.4, 1.0, 0.05, 0.05, 0.05],
                                [hi[0], hi[1], 0.95, 0.95, 0.05]]))
    out = screen_jax.screen_cells(screen_jax.stack_cells(tables),
                                  np.stack(blocks))
    for i, (net_name, h, w, fp, prec) in enumerate(CASES):
        ref = screen_rav_batch(build_net(net_name, h, w), FPGAS[fp],
                               blocks[i], prec, prec)
        assert np.array_equal(out[i], ref)


def test_prescreen_matches_searcher_rung0():
    """prescreen_cells_jax must score the EXACT block the hyperband
    searcher will ask for — same config construction, same rng draws."""
    cells = expand_cells(["vgg16"], [(224, 224)], ["ku115"], [16, 8], [1])
    overrides = {"screen": 256, "survivors": 4}
    fits = prescreen_cells_jax(cells, base_seed=3, population=6,
                               iterations=3, searcher_config=overrides)
    assert set(fits) == {c.key for c in cells}
    for c in cells:
        net = build_net(c.net, c.h, c.w)
        cfg = searcher_config_for(
            "hyperband",
            base=dict(population=6, iterations=3, patience=2,
                      seed=cell_seed(3, c)),
            overrides=overrides)
        space = SearchSpace(sp_max=len(net.major_layers),
                            batch_max=c.batch_max)
        block = hyperband_rung0(space, cfg)
        ref = screen_rav_batch(net, FPGAS[c.fpga], block,
                               c.precision, c.precision)
        assert np.array_equal(fits[c.key], ref)


def test_campaign_jax_screen_record_parity(tmp_path):
    cells = expand_cells(["vgg16"], [(224, 224)], ["ku115", "zcu102"],
                         [16], [1])
    kw = dict(searcher="hyperband",
              searcher_config={"screen": 256, "survivors": 4},
              population=6, iterations=3)
    plain = run_campaign(cells, str(tmp_path / "np.jsonl"), **kw)
    jaxed = run_campaign(cells, str(tmp_path / "jx.jsonl"),
                         jax_screen=True, **kw)
    for a, b in zip(plain.records, jaxed.records):
        sa = {k: v for k, v in a.items() if k != "search_time_s"}
        sb = {k: v for k, v in b.items() if k != "search_time_s"}
        assert sa == sb
    # and the two stores resume each other: same search config
    resumed = run_campaign(cells, str(tmp_path / "jx.jsonl"), **kw)
    assert resumed.reused_cells == len(cells)


def test_jax_screen_rejected_off_hyperband(tmp_path):
    cells = expand_cells(["vgg16"], [(224, 224)], ["ku115"], [16], [1])
    with pytest.raises(ValueError, match="hyperband"):
        run_campaign(cells, str(tmp_path / "x.jsonl"), jax_screen=True)


def test_screen_cells_shape_validation():
    _, tables = _spaces_and_tables()
    stacked = screen_jax.stack_cells(tables)
    with pytest.raises(ValueError, match=r"\(cells, n, 5\)"):
        screen_jax.screen_cells(stacked, np.zeros((2, 7)))
    with pytest.raises(ValueError, match="stacked cells"):
        screen_jax.screen_cells(stacked, np.zeros((1, 7, 5)))
