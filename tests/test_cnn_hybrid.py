"""VGG-in-JAX + the hybrid (pipeline-head/generic-tail) execution plan."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.netinfo import _B, vgg16
from repro.models.cnn import HybridPlan, forward, hybrid_forward, init_vgg


def _tiny_net():
    b = _B("tiny", 16, 16, 8)
    b.conv(8, 3).conv(8, 3).pool(2).conv(16, 3)
    return b.done()


def test_vgg_forward_shapes():
    net = _tiny_net()
    params = init_vgg(jax.random.key(0), net)
    x = jnp.zeros((2, 8, 16, 16))
    y = forward(params, net, x)
    assert y.shape == (2, 16, 8, 8)


def test_vgg_pallas_conv_path_matches_lax():
    net = _tiny_net()
    params = init_vgg(jax.random.key(0), net)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 8, 16, 16)),
                    jnp.float32)
    y_lax = forward(params, net, x, use_pallas=False)
    y_pl = forward(params, net, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_lax),
                               atol=1e-4, rtol=1e-4)


def test_hybrid_sequential_fallback_matches_forward():
    net = vgg16(32)
    params = init_vgg(jax.random.key(1), net)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 3, 32, 32)),
                    jnp.float32)
    ref = forward(params, net, x)
    out = hybrid_forward(params, net, x, HybridPlan(sp=4, n_micro=2), mesh=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_hybrid_pipelined_subprocess():
    """The real pipelined head (4 stages) must match sequential execution —
    the examples script asserts this internally."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    script = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                          "examples", "hybrid_vgg_pipeline.py"))
    r = subprocess.run([sys.executable, script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
