"""Serving subsystem: continuous batcher, int8 weight quantization, and
the hybrid LM execution plan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api, transformer
from repro.serve.quant import dequantize_params, quantize_params, storage_bytes
from repro.serve.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("starcoder2-3b").reduced()
    params = api.init_params(jax.random.key(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def test_batcher_completes_all_requests(tiny_lm):
    cfg, params = tiny_lm
    b = ContinuousBatcher(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab, 5 + i)),
                    max_new=4) for i in range(5)]
    for r in reqs:
        b.submit(r)
    done = b.run()
    assert sorted(c.rid for c in done) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 4 for c in done)
    # continuous batching must overlap requests: 5 requests on 2 slots
    # cannot take 5x a single request's steps
    assert b.utilization > 0.5, f"utilization {b.utilization}"


def test_batcher_matches_single_request_decode(tiny_lm):
    """Tokens produced in a shared-slot run must equal an isolated run
    (slot reuse must not leak KV state between requests)."""
    cfg, params = tiny_lm
    prompt = [5, 7, 11, 13]

    solo = ContinuousBatcher(cfg, params, slots=1, max_seq=32)
    solo.submit(Request(rid=0, prompt=prompt, max_new=6))
    ref = solo.run()[0].tokens

    crowded = ContinuousBatcher(cfg, params, slots=2, max_seq=32)
    rng = np.random.default_rng(1)
    crowded.submit(Request(rid=9, prompt=list(rng.integers(0, cfg.vocab, 9)),
                           max_new=3))
    crowded.submit(Request(rid=0, prompt=prompt, max_new=6))
    crowded.submit(Request(rid=8, prompt=list(rng.integers(0, cfg.vocab, 3)),
                           max_new=3))
    out = {c.rid: c.tokens for c in crowded.run()}
    assert out[0] == ref


def test_batcher_eos_stops_early(tiny_lm):
    cfg, params = tiny_lm
    b = ContinuousBatcher(cfg, params, slots=1, max_seq=64)
    # figure out the first greedy token, then use it as EOS
    probe = ContinuousBatcher(cfg, params, slots=1, max_seq=64)
    probe.submit(Request(rid=0, prompt=[1, 2, 3], max_new=1))
    first = probe.run()[0].tokens[0]
    b.submit(Request(rid=0, prompt=[1, 2, 3], max_new=10, eos=first))
    done = b.run()
    assert done[0].tokens == [first]


# ---------------------------------------------------------------------------
# int8 weight-only quantization
# ---------------------------------------------------------------------------


def test_quantized_params_are_4x_smaller(tiny_lm):
    cfg, params = tiny_lm
    q = quantize_params(params)
    # 2-D+ weights dominate: expect close to 4x (fp32 -> int8 + small scales)
    ratio = storage_bytes(params) / storage_bytes(q)
    assert ratio > 3.0, f"only {ratio:.2f}x smaller"


def test_quantized_logits_close_and_top1_stable(tiny_lm):
    cfg, params = tiny_lm
    toks = jax.random.randint(jax.random.key(2), (2, 16), 0, cfg.vocab)
    full = transformer.forward(params, cfg, toks, compute_dtype=jnp.float32)
    deq = dequantize_params(quantize_params(params), dtype=jnp.float32)
    qlog = transformer.forward(deq, cfg, toks, compute_dtype=jnp.float32)
    # top-1 agreement on most positions (weight-only int8 is near-lossless)
    agree = (jnp.argmax(full, -1) == jnp.argmax(qlog, -1)).mean()
    assert agree > 0.9, f"top-1 agreement {agree}"


def test_quantize_preserves_norm_scales(tiny_lm):
    cfg, params = tiny_lm
    q = quantize_params(params)
    assert q["ln_f"]["scale"].dtype == params["ln_f"]["scale"].dtype


# ---------------------------------------------------------------------------
# hybrid LM plan
# ---------------------------------------------------------------------------


def test_hybrid_lm_matches_plain_forward(tiny_lm):
    from repro.train.hybrid import HybridLMPlan, hybrid_lm_forward
    cfg, params = tiny_lm
    toks = jax.random.randint(jax.random.key(3), (4, 16), 0, cfg.vocab)
    ref = transformer.forward(params, cfg, toks, compute_dtype=jnp.float32,
                              remat="none")
    plan = HybridLMPlan(sp=2, n_stages=2, n_micro=2)
    out = hybrid_lm_forward(params, cfg, toks, plan, mesh=None,
                            compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_hybrid_lm_pipelined_subprocess():
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import api, transformer
from repro.train.hybrid import HybridLMPlan, hybrid_lm_forward

cfg = get_config("starcoder2-3b").reduced()
params = api.init_params(jax.random.key(0), cfg)
toks = jax.random.randint(jax.random.key(3), (4, 16), 0, cfg.vocab)
ref = transformer.forward(params, cfg, toks, compute_dtype=jnp.float32,
                          remat="none")
mesh = jax.make_mesh((2,), ("stage",))
plan = HybridLMPlan(sp=2, n_stages=2, n_micro=2)
out = hybrid_lm_forward(params, cfg, toks, plan, mesh=mesh,
                        compute_dtype=jnp.float32)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                           rtol=1e-4)
# gradients flow through the pipelined head
from repro.train.hybrid import hybrid_lm_loss
g = jax.grad(lambda p: hybrid_lm_loss(p, cfg, toks, toks, plan, mesh,
                                      compute_dtype=jnp.float32))(params)
assert all(np.isfinite(x).all() for x in jax.tree.leaves(g))
print("HYBRID_LM_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "HYBRID_LM_OK" in r.stdout, f"{r.stdout}\n{r.stderr[-2000:]}"


# ---------------------------------------------------------------------------
# rmsnorm kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 16, 64), (1, 100, 128), (4, 7, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_matches_ref(shape, dtype):
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    s = jnp.asarray(rng.standard_normal(shape[-1]), dtype)
    out = rmsnorm(x, s, bm=32)
    ref = rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)
