"""Guard: the tier-1 campaign -> store -> report path emits no
DeprecationWarnings from repro code.

``ResultStore.records()`` is deprecated in favor of the streaming
``iter_records()``; every in-repo caller has been migrated (the one
remaining ``.records()`` call lives in ``test_store_v2.py``, which
asserts the warning *does* fire). This test keeps the main paths clean
so the deprecation stays actionable instead of drowning in noise."""
import warnings


def _repro_deprecations(caught):
    return [w for w in caught
            if issubclass(w.category, DeprecationWarning)
            and "repro" in (w.filename or "")]


def test_campaign_store_report_path_is_deprecation_free(tmp_path):
    from repro.dse import run_campaign
    from repro.dse.backends import get_backend
    from repro.dse.report import render_report
    from repro.dse.store import open_store

    cells = get_backend("tpu").expand_cells(
        archs=["xlstm-350m"], shapes=["train_4k"], chips=[8, 16],
        remats=("full",), microbatches=(1,))
    store = str(tmp_path / "nd.jsonl")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rep = run_campaign(cells, store, backend="tpu")
        rep.frontier()
        rep.ranked(None)
        s = open_store(store)
        recs = list(s.iter_records())
        md = render_report(recs, title="no-deprecation smoke")
    assert len(recs) == 2 and "Pareto frontier" in md
    assert _repro_deprecations(caught) == [], \
        [str(w.message) for w in _repro_deprecations(caught)]


def test_fixture_report_and_calibration_paths_are_deprecation_free():
    from repro.calib import fit_corrections, fixture_measurements
    from repro.dse.report import fixture_events, fixture_records, \
        render_report

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cal = fit_corrections(fixture_measurements())
        render_report(fixture_records(), title="t", events=fixture_events(),
                      calibration=cal)
    assert _repro_deprecations(caught) == [], \
        [str(w.message) for w in _repro_deprecations(caught)]
