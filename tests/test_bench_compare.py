"""Tests for the CI benchmark regression gate (benchmarks/compare.py)."""
import json

from benchmarks.compare import _rows, compare, main


def _dump(rows):
    return {"benchmarks": {"b": [{"name": n, "us_per_call": us,
                                  "derived": ""} for n, us in rows]}}


def test_rows_flatten():
    assert _rows(_dump([("x", 10.0), ("y", 20.0)])) == \
        {"b/x": 10.0, "b/y": 20.0}
    assert _rows({}) == {}


def test_no_regression_when_identical():
    d = _dump([("x", 1000.0), ("y", 2000.0)])
    res = compare(d, d)
    assert res["regressions"] == [] and res["improvements"] == []
    assert res["scale"] == 1.0
    assert res["checked"] == 2


def test_detects_single_row_regression():
    base = _dump([("x", 1000.0), ("y", 1000.0), ("z", 1000.0)])
    new = _dump([("x", 1000.0), ("y", 1000.0), ("z", 2000.0)])
    res = compare(new, base)
    assert [e["row"] for e in res["regressions"]] == ["b/z"]
    assert res["regressions"][0]["relative"] > 1.3


def test_calibration_forgives_uniformly_slow_machines():
    """A 2x slower runner (every row 2x the baseline) is machine speed,
    not a regression; a single hot row on top of that still trips."""
    base = _dump([("x", 1000.0), ("y", 1000.0), ("z", 1000.0)])
    slow = _dump([("x", 2000.0), ("y", 2000.0), ("z", 2000.0)])
    assert compare(slow, base)["regressions"] == []
    hot = _dump([("x", 2000.0), ("y", 2000.0), ("z", 5000.0)])
    res = compare(hot, base)
    assert [e["row"] for e in res["regressions"]] == ["b/z"]
    # without calibration everything trips
    raw = compare(slow, base, calibrate=False)
    assert len(raw["regressions"]) == 3


def test_min_us_floor_skips_noise_rows():
    base = _dump([("noisy", 50.0), ("real", 10000.0)])
    new = _dump([("noisy", 500.0), ("real", 10000.0)])
    res = compare(new, base, min_us=200.0)
    assert res["regressions"] == []
    assert "b/noisy" in res["skipped"]


def test_new_and_missing_rows_are_reported_not_fatal():
    base = _dump([("x", 1000.0), ("gone", 1000.0)])
    new = _dump([("x", 1000.0), ("fresh", 1000.0)])
    res = compare(new, base)
    assert res["only_new"] == ["b/fresh"]
    assert res["only_baseline"] == ["b/gone"]
    assert res["regressions"] == []


def test_main_gate_and_update_baseline(tmp_path, capsys):
    base_p = tmp_path / "baseline.json"
    new_p = tmp_path / "bench.json"
    new_p.write_text(json.dumps(_dump([("x", 1000.0), ("y", 1000.0)])))

    # no baseline yet -> exit 2 with a hint
    assert main([str(new_p), "--baseline", str(base_p)]) == 2
    # record it
    assert main([str(new_p), "--baseline", str(base_p),
                 "--update-baseline"]) == 0
    assert json.loads(base_p.read_text()) == json.loads(new_p.read_text())
    # identical run passes
    assert main([str(new_p), "--baseline", str(base_p)]) == 0
    out = capsys.readouterr().out
    assert "OK" in out
    # regressing one of two rows fails (too few anchors for calibration,
    # so the raw 3x ratio trips the gate directly)
    new_p.write_text(json.dumps(_dump([("x", 1000.0), ("y", 3000.0)])))
    assert main([str(new_p), "--baseline", str(base_p)]) == 1
    assert "REGRESSED" in capsys.readouterr().out


def test_gated_rows_cannot_self_mask_via_calibration():
    """The CI shape: many sub-min_us anchor rows + few gated rows. A
    uniform slowdown of ONLY the gated rows must still trip — the
    anchors, not the gated rows, set the machine-speed scale."""
    micro = [(f"m{i}", 100.0) for i in range(10)]
    base = _dump(micro + [("camp_a", 100000.0), ("camp_b", 200000.0)])
    both_slow = _dump(micro + [("camp_a", 200000.0), ("camp_b", 400000.0)])
    res = compare(both_slow, base, min_us=5000.0)
    assert {e["row"] for e in res["regressions"]} == \
        {"b/camp_a", "b/camp_b"}
    # and a genuinely 2x-slower machine (everything doubles) still passes
    all_slow = _dump([(n, 2 * us) for n, us in
                      micro + [("camp_a", 100000.0), ("camp_b", 200000.0)]])
    assert compare(all_slow, base, min_us=5000.0)["regressions"] == []
    # too few anchors -> raw comparison, never a silent scale of 2
    two_rows = _dump([("camp_a", 200000.0), ("camp_b", 400000.0)])
    two_base = _dump([("camp_a", 100000.0), ("camp_b", 200000.0)])
    assert len(compare(two_rows, two_base, min_us=5000.0)["regressions"]) == 2
