"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeSpec
from repro.launch.specs import make_batch
from repro.models import api

TRAIN = ShapeSpec("smoke_train", "train", 64, 2)
DECODE = ShapeSpec("smoke_decode", "decode", 64, 2)


@pytest.fixture(scope="module")
def reduced_params():
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = get_config(arch_id).reduced()
            cache[arch_id] = (cfg, api.init_params(jax.random.key(0), cfg))
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id, reduced_params):
    cfg, params = reduced_params(arch_id)
    batch = make_batch(cfg, TRAIN)
    loss, grads = jax.value_and_grad(api.loss_fn)(params, cfg, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch_id}: loss={loss}"
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert jnp.all(jnp.isfinite(g)), f"{arch_id}: non-finite grad"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_smoke(arch_id, reduced_params):
    cfg, params = reduced_params(arch_id)
    batch = make_batch(cfg, TRAIN)
    logits = api.prefill_logits(params, cfg, batch)
    b = TRAIN.global_batch
    assert logits.shape[0] == b
    assert logits.shape[-1] == cfg.vocab
    assert jnp.all(jnp.isfinite(logits)), f"{arch_id}: non-finite logits"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step_smoke(arch_id, reduced_params):
    cfg, params = reduced_params(arch_id)
    batch = make_batch(cfg, DECODE)
    logits, new_cache = api.decode_step(params, cfg, batch["cache"],
                                        batch["tokens"], batch["pos"])
    assert logits.shape == (DECODE.global_batch, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits)), f"{arch_id}: non-finite decode logits"
    # cache must be structurally unchanged
    assert jax.tree.structure(new_cache) == jax.tree.structure(batch["cache"])


@pytest.mark.parametrize("arch_id", ["starcoder2-3b", "h2o-danube-3-4b",
                                     "xlstm-350m", "zamba2-2.7b",
                                     "kimi-k2-1t-a32b"])
def test_decode_matches_prefill_last_token(arch_id, reduced_params):
    """Feeding tokens one-by-one through decode must reproduce the prefill
    logits of the final position (numerical consistency of the two paths).

    MoE: capacity-based token dropping legitimately differs between a
    batched prefill and per-token decode, so the MoE case runs with a
    drop-free capacity factor — the consistency claim is about the
    routing/attention/cache math, not the drop policy."""
    cfg, params = reduced_params(arch_id)
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    seq = 8
    toks = jax.random.randint(jax.random.key(1), (1, seq), 0, cfg.vocab)
    full = api.prefill_logits(params, cfg, {"tokens": toks},
                              compute_dtype=jnp.float32)

    cache = api.init_cache(cfg, 1, seq, dtype=jnp.float32)
    logits = None
    for t in range(seq):
        logits, cache = api.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                        jnp.array([t], jnp.int32),
                                        compute_dtype=jnp.float32)
    assert jnp.allclose(logits, full[:, -1], atol=2e-2, rtol=2e-2), (
        f"{arch_id}: decode/prefill mismatch "
        f"max={jnp.abs(logits - full[:, -1]).max()}")
