"""Whisper enc-dec consistency: the incremental decode path (self KV cache
+ precomputed cross K/V) must reproduce the teacher-forced decoder."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import encdec


def test_whisper_decode_matches_teacher_forcing():
    cfg = get_config("whisper-base").reduced()
    params = encdec.init_encdec(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    b, s = 1, 8
    frames = jnp.asarray(rng.standard_normal((b, cfg.n_audio_frames,
                                              cfg.d_model)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    memory = encdec.encode(params, cfg, frames, compute_dtype=jnp.float32)
    full = encdec.decode_train(params, cfg, toks, memory,
                               compute_dtype=jnp.float32, remat="none")

    cache = encdec.init_cache(cfg, b, s, cfg.n_audio_frames, dtype=jnp.float32)
    cache = encdec.prefill_cross(params, cfg, memory, cache)
    logits = None
    for t in range(s):
        logits, cache = encdec.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                           jnp.array([t], jnp.int32),
                                           compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=2e-2, rtol=2e-2)


def test_whisper_encoder_is_order_sensitive():
    """Sanity: the (bidirectional) encoder attends across frames — permuting
    frames must change the memory (catches accidental causal masking)."""
    cfg = get_config("whisper-base").reduced()
    params = encdec.init_encdec(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    frames = jnp.asarray(rng.standard_normal((1, cfg.n_audio_frames,
                                              cfg.d_model)), jnp.float32)
    m1 = encdec.encode(params, cfg, frames, compute_dtype=jnp.float32)
    m2 = encdec.encode(params, cfg, frames[:, ::-1], compute_dtype=jnp.float32)
    assert not np.allclose(np.asarray(m1), np.asarray(m2))
