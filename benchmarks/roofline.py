"""§Roofline: per-(arch x shape x mesh) roofline terms from the dry-run
artifacts + the analytic model, dominant-bottleneck identification, and
the MODEL_FLOPS / HLO_FLOPs usefulness ratio.

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
    (writes the markdown table printed on stdout; EXPERIMENTS.md embeds it)
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.core.hw_specs import TPU_V5E
from repro.core.tpu_model import (MeshDesc, analytic_roofline, hlo_roofline,
                                  model_flops)


def load_cells(d: str) -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def roofline_rows(cells: list[dict]) -> list[dict]:
    rows = []
    for c in cells:
        if c.get("status") != "ok" or "single" not in c.get("mesh", ""):
            continue  # roofline table is single-pod per spec
        cfg = get_config(c["arch"])
        shape = SHAPES[c["shape"]]
        mesh = MeshDesc.single_pod()
        hlo = hlo_roofline(c["exact"])
        ana = analytic_roofline(cfg, shape, mesh)
        # memory term: the analytic model (HLO operand bytes on the CPU
        # backend are inflated by unfused materialization; TPU fuses);
        # compute/collective terms: measured from the compiled HLO.
        from repro.core.tpu_model import Roofline
        mixed = Roofline(hlo.t_compute, ana.t_memory, hlo.t_collective)
        mf = model_flops(cfg, shape)
        hlo_flops_total = c["exact"]["flops"] * mesh.n_chips
        useful = mf / hlo_flops_total if hlo_flops_total else 0.0
        # roofline fraction: useful-compute time over the binding term
        t_useful = mf / mesh.n_chips / TPU_V5E.peak_flops
        frac = t_useful / mixed.step_time if mixed.step_time else 0.0
        frac = min(frac, 1.0)
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "t_compute": mixed.t_compute, "t_memory": mixed.t_memory,
            "t_collective": mixed.t_collective, "bound": mixed.bound,
            "ana_compute": ana.t_compute, "ana_memory": ana.t_memory,
            "ana_collective": ana.t_collective, "ana_bound": ana.bound,
            "model_flops": mf, "hlo_flops_per_dev": c["exact"]["flops"],
            "useful_ratio": useful, "roofline_frac": frac,
            "mem_gib_per_dev": c["memory"]["total_per_device"] / 2 ** 30,
            "compile_s": c.get("compile_s", 0.0),
        })
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | bound | "
           "useful=MODEL/HLO | roofline-frac | mem/dev (GiB) |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3g} | "
            f"{r['t_memory']:.3g} | {r['t_collective']:.3g} | {r['bound']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['mem_gib_per_dev']:.1f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = roofline_rows(load_cells(args.dir))
    print(markdown_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
