"""Paper-reported numbers (digitized from DNNExplorer, ICCAD'20) used as
comparison targets by the benchmark harness. Values from Tables 1/3/4 are
exact; figure-only series are digitized approximations, flagged as such."""

# Table 3: batch=1 accelerators on KU115 (input -> (GOP/s, img/s, SP, DSP, eff, BRAM))
TABLE3 = {
    (32, 32): (368.5, 588.9, 4, 2268, 0.423, 2326),
    (64, 64): (890.8, 339.1, 5, 2730, 0.779, 2560),
    (128, 128): (1453.7, 169.5, 9, 4686, 0.908, 3589),
    (224, 224): (1702.3, 55.4, 12, 4444, 0.958, 3296),
    (320, 320): (1702.4, 27.1, 13, 4450, 0.957, 3224),
    (384, 384): (1702.4, 18.8, 14, 4452, 0.956, 3436),
    (320, 480): (1702.4, 18.1, 14, 4452, 0.956, 3296),
    (448, 448): (1702.4, 13.8, 13, 4450, 0.956, 3552),
    (512, 512): (1702.4, 10.6, 13, 4450, 0.956, 3678),
    (480, 800): (1702.4, 7.2, 13, 4450, 0.956, 3678),
    (512, 1382): (1702.5, 3.9, 14, 4452, 0.956, 3792),
    (720, 1280): (1702.5, 3.0, 13, 4450, 0.956, 4186),
}

# Table 4: batch explored (input -> (batch, GOP/s))
TABLE4 = {
    (32, 32): (8, 1698.1),
    (64, 64): (8, 1701.5),
    (128, 128): (4, 1702.4),
    (224, 224): (2, 1702.3),
}

# Table 1: V1/V2 CTC variance ratios
TABLE1 = {
    "alexnet": 185.8, "googlenet": 3622.8, "inceptionv3": 6210.6,
    "vgg16": 489.8, "vgg19": 552.6, "resnet18": 1607.3, "resnet50": 998.7,
    "squeezenet": 238.9, "mobilenet": 3904.2, "mobilenetv2": 251.5,
}

# Fig. 11 (digitized, normalized to the 13-layer case): measured DNNBuilder
# collapses 77.8% at 38 layers; DNNExplorer holds ~1.0.
FIG11_DNNBUILDER_REL = {13: 1.00, 18: 0.81, 28: 0.52, 38: 0.222}
FIG11_CLAIM_RATIO_38L = 4.2

# Fig. 9 peak claims
FIG9_DPU_PEAK_RATIO = 4.4       # case 1 vs Xilinx DPU (ZCU102)
FIG9_HYBRIDDNN_PEAK_RATIO = 2.0  # case 1 vs HybridDNN (KU115)
