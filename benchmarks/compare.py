"""Benchmark regression gate: compare a fresh ``benchmarks/run.py --json``
dump against the committed ``benchmarks/baseline.json``.

A row regresses when its ``us_per_call`` exceeds the baseline by more
than ``--threshold`` (default 30%) AFTER machine-speed calibration: the
median new/baseline ratio estimates how much faster or slower this
machine is than the one that wrote the baseline, and each row is judged
against that calibrated expectation. That keeps the gate meaningful on
CI runners whose absolute speed differs from the baseline machine while
still catching the thing that matters — one benchmark slowing down
relative to the rest.

Calibration and gating use DIFFERENT row sets on purpose: the median is
anchored by every shared row above a small noise floor
(``CAL_MIN_US``), while only rows above ``--min-us`` can fail the gate.
Gated rows therefore cannot mask their own regression by dragging the
median with them (with few gated rows and self-calibration, a uniform
slowdown of exactly the gated set would read as "machine speed").
Calibration also needs at least ``MIN_CAL_ROWS`` anchor rows — below
that the scale is forced to 1.0 (raw comparison). ``--no-calibrate``
compares raw ratios always.

Rows faster than ``--min-us`` in the baseline are not gated (pure
timing noise), and rows only one side has are reported, never fatal —
adding a benchmark must not break CI until ``--update-baseline``
records it.

    python -m benchmarks.run --only fig1,table1,campaign_fpga,campaign_tpu \\
        --json bench.json
    python -m benchmarks.compare bench.json            # gate (exit 1 on fail)
    python -m benchmarks.compare bench.json --update-baseline
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).parent / "baseline.json"

#: Rows above this baseline time anchor the machine-speed median (even
#: when they are too noisy to gate on).
CAL_MIN_US = 50.0
#: Fewer anchor rows than this and calibration is meaningless — compare
#: raw ratios instead of letting one or two rows set the scale.
MIN_CAL_ROWS = 3


def _rows(dump: dict) -> dict[str, float]:
    """``{bench/row-name: us_per_call}`` flattened from a --json dump."""
    out = {}
    for bench, rows in dump.get("benchmarks", {}).items():
        for r in rows:
            out[f"{bench}/{r['name']}"] = float(r["us_per_call"])
    return out


def compare(new: dict, baseline: dict, *, threshold: float = 0.30,
            min_us: float = 200.0, calibrate: bool = True) -> dict:
    """Pure comparison -> {scale, regressions, improvements, skipped,
    only_new, only_baseline}; ``regressions`` non-empty == gate fails."""
    new_rows, base_rows = _rows(new), _rows(baseline)
    shared = sorted(set(new_rows) & set(base_rows))
    anchors = [k for k in shared
               if base_rows[k] >= CAL_MIN_US and new_rows[k] > 0]
    timed = [k for k in shared if base_rows[k] >= min_us and new_rows[k] > 0]
    cal_ratios = [new_rows[k] / base_rows[k] for k in anchors]
    scale = statistics.median(cal_ratios) \
        if calibrate and len(cal_ratios) >= MIN_CAL_ROWS else 1.0
    regressions, improvements = [], []
    for k in timed:
        rel = (new_rows[k] / base_rows[k]) / scale
        entry = {"row": k, "base_us": base_rows[k], "new_us": new_rows[k],
                 "ratio": new_rows[k] / base_rows[k], "relative": rel}
        if rel > 1.0 + threshold:
            regressions.append(entry)
        elif rel < 1.0 - threshold:
            improvements.append(entry)
    timed_set = set(timed)
    return {
        "scale": scale,
        "checked": len(timed),
        "regressions": regressions,
        "improvements": improvements,
        "skipped": [k for k in shared if k not in timed_set],
        "only_new": sorted(set(new_rows) - set(base_rows)),
        "only_baseline": sorted(set(base_rows) - set(new_rows)),
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.compare",
        description="Gate benchmarks/run.py --json output against the "
                    "committed baseline (exit 1 on any >threshold "
                    "per-row regression).")
    ap.add_argument("new", help="fresh benchmarks/run.py --json output")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="committed baseline JSON (default: %(default)s)")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="allowed relative slowdown per row "
                         "(default: %(default)s)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="ignore rows whose baseline is faster than this "
                         "(timing noise; default: %(default)s)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip machine-speed calibration; compare raw "
                         "us_per_call ratios")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the new dump and "
                         "exit 0 (commit the result)")
    args = ap.parse_args(argv)

    with open(args.new) as f:
        new = json.load(f)
    if args.update_baseline:
        Path(args.baseline).write_text(
            json.dumps(new, indent=2, sort_keys=True) + "\n")
        print(f"baseline updated <- {args.new} "
              f"({len(_rows(new))} rows) -> {args.baseline}")
        return 0
    if not Path(args.baseline).exists():
        print(f"no baseline at {args.baseline}; run with --update-baseline "
              f"first", file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)

    res = compare(new, baseline, threshold=args.threshold,
                  min_us=args.min_us, calibrate=not args.no_calibrate)
    print(f"machine-speed calibration: x{res['scale']:.2f} "
          f"(median new/baseline over timed rows)")
    for k in res["only_new"]:
        print(f"  new row (no baseline yet): {k}")
    for k in res["only_baseline"]:
        print(f"  baseline row missing from this run: {k}")
    for e in res["improvements"]:
        print(f"  improved: {e['row']} {e['base_us']:.0f}us -> "
              f"{e['new_us']:.0f}us ({e['relative']:.2f}x calibrated)")
    for e in res["regressions"]:
        print(f"  REGRESSED: {e['row']} {e['base_us']:.0f}us -> "
              f"{e['new_us']:.0f}us ({e['relative']:.2f}x calibrated, "
              f"limit {1.0 + args.threshold:.2f}x)")
    n = len(res["regressions"])
    if n:
        print(f"FAIL: {n} row(s) regressed beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    print(f"OK: no regression beyond {args.threshold:.0%} across "
          f"{res['checked']} timed rows")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
