"""Benchmark harness: one function per paper table/figure.

Each benchmark returns rows ``{name, us_per_call, derived}`` where
``derived`` holds the headline metric(s) the paper's table/figure reports;
``main`` prints one CSV line per row:  name,us_per_call,derived.
``--json out.json`` additionally dumps the rows as structured JSON so
campaign/bench results can feed the ``BENCH_*.json`` perf trajectory.

    PYTHONPATH=src python -m benchmarks.run [--only fig11,table3] \\
        [--json out.json]

CI runs the cheap analytic subset and gates on ``benchmarks/compare.py``
against the committed ``benchmarks/baseline.json`` (see that module).
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

from repro.core import (KU115, RAV, ZCU102, PSOConfig, dnnbuilder_design,
                        explore, generic_only_design)
from repro.core.local_opt import dpu_proxy_design
from repro.core.netinfo import INPUT_CASES, TABLE1_NETS, vgg16

from . import paper_data as paper

_CFG = PSOConfig(population=20, iterations=30, seed=1)


def _timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def bench_fig1_ctc() -> list[dict]:
    """Fig. 1: CTC medians of VGG16 over the 12 input sizes."""
    rows = []
    for h, w in INPUT_CASES:
        net, us = _timed(vgg16, h, w)
        med = statistics.median(net.ctc_list())
        rows.append({"name": f"fig1_ctc_{h}x{w}", "us_per_call": us,
                     "derived": f"median_ctc={med:.0f}"})
    m32 = statistics.median(vgg16(32).ctc_list())
    m512 = statistics.median(vgg16(512).ctc_list())
    rows.append({"name": "fig1_ctc_scaling_32_to_512", "us_per_call": 0.0,
                 "derived": f"ratio={m512 / m32:.1f}x(paper~256x)"})
    return rows


def bench_table1_variance() -> list[dict]:
    """Table 1: V1/V2 CTC variance ratio per network."""
    rows = []
    for name, fn in TABLE1_NETS.items():
        net, us = _timed(fn)
        r = net.half_variance_ratio()
        rows.append({"name": f"table1_{name}", "us_per_call": us,
                     "derived": f"v1_over_v2={r:.1f}"
                                f"(paper={paper.TABLE1[name]})"})
    return rows


def bench_fig9_dsp_efficiency() -> list[dict]:
    """Fig. 9: DSP efficiency across the input cases; DNNExplorer vs the
    analytical paradigm-A baselines (HybridDNN / DPU proxies)."""
    rows = []
    for h, w in INPUT_CASES[:9]:
        net = vgg16(h, w)
        res, us = _timed(explore, net, KU115, cfg=_CFG)
        gen = generic_only_design(net, KU115)
        dpu = dpu_proxy_design(net, ZCU102)
        rows.append({
            "name": f"fig9_eff_{h}x{w}", "us_per_call": us,
            "derived": (f"explorer={res.design.dsp_eff:.3f};"
                        f"hybriddnn_proxy={gen.dsp_eff:.3f};"
                        f"dpu_proxy={dpu.dsp_eff:.3f}")})
    return rows


def bench_fig10_throughput() -> list[dict]:
    """Fig. 10 / Table 3: GOP/s on KU115 across the 12 input sizes."""
    rows = []
    for h, w in INPUT_CASES:
        net = vgg16(h, w)
        res, us = _timed(explore, net, KU115, cfg=_CFG)
        d = res.design
        pgops = paper.TABLE3[(h, w)][0]
        rows.append({
            "name": f"fig10_gops_{h}x{w}", "us_per_call": us,
            "derived": (f"gops={d.gops:.1f}(paper={pgops});"
                        f"sp={d.rav.sp};eff={d.dsp_eff:.3f};"
                        f"search_s={res.search_time_s:.2f}")})
    return rows


def bench_fig11_deeper() -> list[dict]:
    """Fig. 11: throughput vs depth (13/18/28/38-layer VGG-like, 224x224).
    Reports our DSE result, our analytical DNNBuilder baseline, and the
    ratio against the paper's *measured* DNNBuilder curve."""
    rows = []
    base = None
    for extra, layers in [(0, 13), (1, 18), (3, 28), (5, 38)]:
        net = vgg16(224, extra_per_group=extra)
        res, us = _timed(explore, net, KU115, cfg=_CFG)
        ours = res.design.gops
        builder_model = dnnbuilder_design(net, KU115).gops
        if base is None:
            base = ours
        builder_paper = base * paper.FIG11_DNNBUILDER_REL[layers]
        rows.append({
            "name": f"fig11_{layers}layers", "us_per_call": us,
            "derived": (f"explorer={ours:.1f};builder_model={builder_model:.1f};"
                        f"builder_paper={builder_paper:.1f};"
                        f"ratio_vs_paper_builder={ours / builder_paper:.2f}x")})
    return rows


def bench_table3_rav() -> list[dict]:
    """Table 3: full RAV + search-time reproduction at batch=1."""
    rows = []
    for h, w in INPUT_CASES:
        net = vgg16(h, w)
        res, us = _timed(explore, net, KU115, cfg=_CFG)
        d = res.design
        p_gops, p_ips, p_sp, p_dsp, p_eff, _ = paper.TABLE3[(h, w)]
        rows.append({
            "name": f"table3_{h}x{w}", "us_per_call": us,
            "derived": (f"gops={d.gops:.1f}/{p_gops};"
                        f"img_s={d.throughput_ips:.1f}/{p_ips};"
                        f"sp={d.rav.sp}/{p_sp};dsp={d.dsp_used}/{p_dsp};"
                        f"eff={d.dsp_eff:.3f}/{p_eff};"
                        f"evals={res.pso.evaluations}")})
    return rows


def bench_table4_batch() -> list[dict]:
    """Table 4: batch-size exploration for the small-input cases."""
    rows = []
    for (h, w), (p_batch, p_gops) in paper.TABLE4.items():
        net = vgg16(h, w)
        res, us = _timed(explore, net, KU115, batch_max=16,
                         cfg=PSOConfig(population=24, iterations=40, seed=1))
        d = res.design
        rows.append({
            "name": f"table4_{h}x{w}", "us_per_call": us,
            "derived": (f"gops={d.gops:.1f}(paper={p_gops});"
                        f"batch={d.rav.batch}(paper={p_batch})")})
    return rows


def bench_roofline() -> list[dict]:
    """§Roofline: summarized per-cell terms from the dry-run artifacts
    (full table in EXPERIMENTS.md; see benchmarks/roofline.py)."""
    from .roofline import load_cells, roofline_rows
    cells = load_cells("results/dryrun")
    rows = []
    for r in roofline_rows(cells):
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            "us_per_call": 0.0,
            "derived": (f"t_comp={r['t_compute']:.2e};t_mem={r['t_memory']:.2e};"
                        f"t_coll={r['t_collective']:.2e};bound={r['bound']};"
                        f"mfu_frac={r['roofline_frac']:.3f}")})
    if not rows:
        rows.append({"name": "roofline", "us_per_call": 0.0,
                     "derived": "no dryrun artifacts (run repro.launch.dryrun)"})
    return rows


def bench_dse_campaign() -> list[dict]:
    """repro.dse: a small (net x fpga x precision) campaign — wall time,
    memoized re-run time, and frontier size."""
    import tempfile

    from repro.dse import run_campaign
    from repro.dse.campaign import expand_cells

    cells = expand_cells(["vgg16"], [(64, 64), (224, 224)],
                         ["ku115", "zcu102"], [16, 8], [1])
    with tempfile.TemporaryDirectory() as td:
        store = f"{td}/bench.jsonl"
        rep, us = _timed(run_campaign, cells, store, population=20,
                         iterations=30)
        rerun, us2 = _timed(run_campaign, cells, store, population=20,
                            iterations=30)
    return [{
        "name": f"dse_campaign_{len(cells)}cells", "us_per_call": us,
        "derived": (f"evals={rep.new_evaluations};"
                    f"frontier={len(rep.frontier())};"
                    f"resume_us={us2:.0f};"
                    f"resume_evals={rerun.new_evaluations}")}]


def bench_fpga_campaign() -> list[dict]:
    """repro.dse fpga backend hot path: one campaign cell's PSO through the
    batched array-kernel engine vs the scalar reference path, same seed and
    trajectory, measured in the same run — plus an ``evaluate_rav_batch``
    microbench over a fixed random population."""
    import numpy as np

    from repro.core.batch_eval import evaluate_rav_batch
    from repro.core.local_opt import evaluate_rav
    from repro.core.pso import optimize

    net = vgg16(224)
    sp_max = len(net.major_layers)

    def batched_hook(ravs):
        return [d.fitness for d in evaluate_rav_batch(net, KU115, ravs)]

    def scalar_hook(ravs):
        return [evaluate_rav(net, KU115, r).fitness for r in ravs]

    # Warm both paths to campaign steady state (numpy.random import, packed
    # layer tables, per-split cycle caches) before timing anything.
    optimize(sp_max=sp_max, batch_max=1,
             cfg=PSOConfig(population=6, iterations=2, seed=0),
             batch_fitness_fn=batched_hook)
    scalar_hook([RAV(sp_max // 2, 1, 0.5, 0.5, 0.5)])

    res_b, us_b = _timed(optimize, sp_max=sp_max, batch_max=1, cfg=_CFG,
                         batch_fitness_fn=batched_hook)
    res_s, us_s = _timed(optimize, sp_max=sp_max, batch_max=1, cfg=_CFG,
                         batch_fitness_fn=scalar_hook)
    rows = [{
        "name": "campaign_fpga_vgg16_224_ku115", "us_per_call": us_b,
        "derived": (f"scalar_us={us_s:.0f};speedup={us_s / us_b:.1f}x;"
                    f"evals={res_b.evaluations};"
                    f"same_best={res_b.best_rav == res_s.best_rav};"
                    f"gops_fitness={res_b.best_fitness:.1f}")}]

    rng = np.random.default_rng(0)
    ravs = [RAV(int(rng.integers(0, sp_max + 1)), int(rng.integers(1, 5)),
                float(rng.uniform(0.05, 0.95)), float(rng.uniform(0.05, 0.95)),
                float(rng.uniform(0.05, 0.95))) for _ in range(128)]
    out_b, us_bt = _timed(evaluate_rav_batch, net, KU115, ravs)
    out_s, us_sc = _timed(lambda: [evaluate_rav(net, KU115, r) for r in ravs])
    agree = all(a.dsp_used == b.dsp_used and a.feasible == b.feasible
                for a, b in zip(out_s, out_b))
    rows.append({
        "name": "evaluate_rav_batch_128", "us_per_call": us_bt,
        "derived": (f"scalar_us={us_sc:.0f};speedup={us_sc / us_bt:.1f}x;"
                    f"n=128;agree={agree}")})

    # telemetry overhead: the same tiny campaign untraced vs --trace
    # (spans + sidecar merge + chrome export); untraced is the gated
    # configuration, traced shows what --trace costs on top
    import tempfile

    from repro.dse import run_campaign
    from repro.dse.campaign import expand_cells
    from repro.obs import load_events

    cells = expand_cells(["vgg16"], [(64, 64)], ["zc706"], [16, 8], [1])
    with tempfile.TemporaryDirectory() as td:
        _, us_plain = _timed(run_campaign, cells, f"{td}/plain.jsonl",
                             population=6, iterations=4)
        traced, us_tr = _timed(run_campaign, cells, f"{td}/traced.jsonl",
                               population=6, iterations=4, trace=True)
        n_events = len(load_events(traced.events_path))
    rows.append({
        "name": "campaign_fpga_traced", "us_per_call": us_tr,
        "derived": (f"untraced_us={us_plain:.0f};"
                    f"overhead={us_tr / us_plain:.2f}x;"
                    f"events={n_events}")})

    # fault-path overhead: the same cell evaluations through the
    # resilience layer (execute_cell retry accounting + the UNARMED
    # injection harness, i.e. the shipped configuration) vs a bare
    # run_cell loop — gates the claim that an idle harness + retry
    # bookkeeping costs ~nothing
    from repro.dse.backends import run_cell_by_backend
    from repro.dse.resilience import RetryPolicy, execute_cell

    def attempt_fn(cell, attempt):
        return run_cell_by_backend("fpga", cell, 0, 6, 4, None, None,
                                   attempt=attempt)

    def bare_loop():
        return [run_cell_by_backend("fpga", c, 0, 6, 4, None, None)
                for c in cells]

    def resilient_loop():
        policy = RetryPolicy()
        return [execute_cell(c, attempt_fn, policy) for c in cells]

    resilient_loop()                       # warm both paths identically
    bare_loop()
    _, us_res = _timed(resilient_loop)
    _, us_bare = _timed(bare_loop)
    rows.append({
        "name": "campaign_fpga_faultpath", "us_per_call": us_res,
        "derived": (f"bare_us={us_bare:.0f};"
                    f"overhead={us_res / us_bare:.2f}x;"
                    f"harness=inert")})
    return rows


def bench_searcher_engines() -> list[dict]:
    """repro.core.search: every registered engine on the Table-3 flagship
    cell (vgg16/224/ku115), same population/iteration budget. Headline:
    hyperband must reach best-fitness parity with pure PSO at equal or
    lower wall-clock while triaging a ~100x larger candidate pool through
    the screening relaxation (``screened`` counts those candidates)."""
    from repro.core.search import searcher_names

    net = vgg16(224)
    # warm the packed-table / per-split cycle caches once so engine rows
    # measure search, not first-touch model building
    explore(net, KU115, cfg=PSOConfig(population=6, iterations=2, seed=1))

    rows, by_engine = [], {}
    for name in searcher_names():
        res, us = _timed(explore, net, KU115, cfg=_CFG, searcher=name)
        by_engine[name] = (res, us)
        p = res.pso
        rows.append({
            "name": f"searcher_{name}_vgg16_224_ku115", "us_per_call": us,
            "derived": (f"fitness={p.best_fitness:.3f};"
                        f"evals={p.evaluations};screened={p.screened};"
                        f"stop={p.stop_reason}")})

    (res_h, us_h), (res_p, us_p) = by_engine["hyperband"], by_engine["pso"]
    pool = res_h.pso.screened + res_h.pso.evaluations
    rows.append({
        "name": "campaign_fpga_hyperband", "us_per_call": us_h,
        "derived": (f"pso_us={us_p:.0f};wall_ratio={us_h / us_p:.2f}x;"
                    f"fitness={res_h.pso.best_fitness:.3f};"
                    f"pso_fitness={res_p.pso.best_fitness:.3f};"
                    f"parity={res_h.pso.best_fitness >= res_p.pso.best_fitness};"
                    f"screened={res_h.pso.screened};"
                    f"space_x={pool / max(1, res_p.pso.evaluations):.0f}x")})
    return rows


def bench_tpu_campaign() -> list[dict]:
    """repro.dse tpu backend: a small (arch x shape x chips x remat x mb)
    campaign — wall time, memoized re-run time, and frontier size/spread."""
    import tempfile

    from repro.dse import run_campaign
    from repro.dse.backends import get_backend

    be = get_backend("tpu")
    cells = be.expand_cells(archs=["starcoder2-3b", "xlstm-350m"],
                            shapes=["train_4k", "decode_32k"],
                            chips=[8, 16, 32], remats=("full", "none"),
                            microbatches=(1, 2))
    with tempfile.TemporaryDirectory() as td:
        store = f"{td}/bench_tpu.jsonl"
        rep, us = _timed(run_campaign, cells, store, backend="tpu")
        rerun, us2 = _timed(run_campaign, cells, store, backend="tpu")
    return [{
        "name": f"dse_campaign_tpu_{len(cells)}cells", "us_per_call": us,
        "derived": (f"evals={rep.new_evaluations};"
                    f"frontier={len(rep.frontier())};"
                    f"frontier_k4={len(rep.frontier(k=4))};"
                    f"resume_us={us2:.0f};"
                    f"resume_evals={rerun.new_evaluations}")}]


def bench_cuda_campaign() -> list[dict]:
    """repro.dse cuda backend: a small (arch x shape x GPU part x count)
    campaign — wall time, memoized re-run time, and frontier size/spread."""
    import tempfile

    from repro.dse import run_campaign
    from repro.dse.backends import get_backend

    be = get_backend("cuda")
    cells = be.expand_cells(archs=["starcoder2-3b", "xlstm-350m"],
                            shapes=["train_4k", "decode_32k"],
                            gpus=[8, 16, 32],
                            gpu_types=("a100-80g", "h100"),
                            remats=("full", "none"), microbatches=(1, 2))
    with tempfile.TemporaryDirectory() as td:
        store = f"{td}/bench_cuda.jsonl"
        rep, us = _timed(run_campaign, cells, store, backend="cuda")
        rerun, us2 = _timed(run_campaign, cells, store, backend="cuda")
    return [{
        "name": f"dse_campaign_cuda_{len(cells)}cells", "us_per_call": us,
        "derived": (f"evals={rep.new_evaluations};"
                    f"frontier={len(rep.frontier())};"
                    f"frontier_k4={len(rep.frontier(k=4))};"
                    f"resume_us={us2:.0f};"
                    f"resume_evals={rerun.new_evaluations}")}]


def bench_placement() -> list[dict]:
    """repro.dse.placement: tpu+cuda campaigns pooled into one store, then
    a budgeted multi-workload placement — campaign wall time, solve time
    for both solvers, and whether greedy matched the exact optimum."""
    import tempfile

    from repro.core.hw_specs import CostEnvelope
    from repro.dse import run_campaign
    from repro.dse.backends import get_backend
    from repro.dse.placement import place, pooled_records
    from repro.dse.store import open_store

    archs = ["starcoder2-3b", "xlstm-350m"]
    shapes = ["train_4k", "decode_32k"]
    with tempfile.TemporaryDirectory() as td:
        store = f"{td}/bench_place.jsonl"
        tpu_cells = get_backend("tpu").expand_cells(
            archs=archs, shapes=shapes, chips=[8, 16],
            remats=("full",), microbatches=(1,))
        cuda_cells = get_backend("cuda").expand_cells(
            archs=archs, shapes=shapes, gpus=[8, 16],
            gpu_types=("a100-80g", "h100"), remats=("full",),
            microbatches=(1,))
        _, us_tpu = _timed(run_campaign, tpu_cells, store, backend="tpu")
        _, us_cuda = _timed(run_campaign, cuda_cells, store, backend="cuda")
        records = pooled_records([open_store(store)])
        workloads = [f"{a}/{s}" for a in archs for s in shapes]
        budget = CostEnvelope(usd_per_hour=150.0, watts=40000.0)
        exact, us_exact = _timed(place, workloads, records, budget,
                                 solver="exact")
        greedy, us_greedy = _timed(place, workloads, records, budget,
                                   solver="greedy")
        agree = [a.candidate.cell_key for a in exact.assignments] == \
            [a.candidate.cell_key for a in greedy.assignments]
    return [{
        "name": f"dse_placement_{len(workloads)}workloads",
        "us_per_call": us_tpu + us_cuda + us_exact,
        "derived": (f"cells={len(tpu_cells) + len(cuda_cells)};"
                    f"value={exact.total_value:.1f};"
                    f"usd={exact.total_usd:.2f};"
                    f"exact_nodes={exact.explored};"
                    f"solve_us={us_exact:.0f};"
                    f"greedy_us={us_greedy:.0f};"
                    f"greedy_matches_exact={agree}")}]


def bench_campaign_100k() -> list[dict]:
    """Store v2 + FrontierIndex at report scale: 100k synthetic records
    bulk-written to a sharded store, then ONE streaming pass (offset
    index + iter_records + incremental frontier) timed against the full
    non-dominated re-sort the report historically ran per render. The
    re-sort is O(n^2) python — measured on a subsample and extrapolated
    quadratically (running it straight at 100k would take hours)."""
    import tempfile

    import numpy as np

    from repro.dse.frontier import FrontierIndex
    from repro.dse.pareto import non_dominated
    from repro.dse.store import open_store, shard_name, sharded_dir_for

    n, sub = 100_000, 800
    rng = np.random.default_rng(0)
    vals = rng.random((n, 3))
    with tempfile.TemporaryDirectory() as td:
        store_path = f"{td}/bench100k.d"
        d = sharded_dir_for(store_path)
        d.mkdir(parents=True)
        (d / "manifest.json").write_text(
            json.dumps({"store_format": 2}) + "\n")
        # bulk append, the shape a campaign worker's shard ends up in
        # (puts go through the same append path, plus fsync per record)
        with open(d / shard_name(0), "w") as f:
            for i in range(n):
                f.write(json.dumps(
                    {"cell_key": f"c{i}",
                     "objectives": {"a": vals[i, 0], "b": vals[i, 1],
                                    "c": vals[i, 2], "feasible": True}},
                    sort_keys=True) + "\n")

        def streaming_pass():
            s = open_store(store_path)
            fi = FrontierIndex()
            for rec in s.iter_records():
                o = rec["objectives"]
                fi.insert(rec["cell_key"], (o["a"], o["b"], o["c"]))
            return fi

        fi, us_stream = _timed(streaming_pass)
    sub_vecs = [tuple(v) for v in vals[:sub]]
    _, us_sub = _timed(non_dominated, sub_vecs)
    us_resort_est = us_sub * (n / sub) ** 2
    speedup = us_resort_est / us_stream
    return [{
        "name": "campaign_100k_synthetic",
        "us_per_call": us_stream,
        "derived": (f"records={len(fi)};front={fi.front_size()};"
                    f"stream_us={us_stream:.0f};"
                    f"resort_est_us={us_resort_est:.0f};"
                    f"speedup={speedup:.0f}x;ge5x={speedup >= 5.0}")}]


def bench_screen_cells_jax() -> list[dict]:
    """Cross-cell jax screening vs the per-cell NumPy reference: one
    jitted (cells x n) call against a python loop of screen_rav_batch.
    Emits a skip row when jax is absent (the CI bench runner) — the
    row is one-sided there and never gates."""
    from repro.core import screen_jax

    if not screen_jax.available():
        return [{"name": "screen_cells_jax", "us_per_call": 0.0,
                 "derived": "skipped=jax_unavailable"}]
    import numpy as np

    from repro.core.batch_eval import screen_rav_batch
    from repro.core.hw_specs import FPGAS
    from repro.core.search import SearchSpace
    from repro.dse.campaign import build_net

    cases = [("vgg16", h, w, fp, prec)
             for h, w in ((128, 128), (224, 224), (320, 320))
             for fp in ("ku115", "zcu102", "vu9p", "zc706")
             for prec in (16, 8)]
    n = 4096
    rng = np.random.default_rng(0)
    nets = [build_net(c[0], c[1], c[2]) for c in cases]
    tables = [screen_jax.cell_tables(net, FPGAS[c[3]], c[4], c[4])
              for net, c in zip(nets, cases)]
    blocks = np.stack([
        rng.uniform(sp.lo(), sp.hi(), size=(n, 5))
        for sp in (SearchSpace(sp_max=len(net.major_layers), batch_max=8)
                   for net in nets)])
    stacked = screen_jax.stack_cells(tables)

    def numpy_loop():
        return [screen_rav_batch(net, FPGAS[c[3]], blk, c[4], c[4])
                for net, c, blk in zip(nets, cases, blocks)]

    ref, us_np = _timed(numpy_loop)
    screen_jax.screen_cells(stacked, blocks)       # compile warmup
    out, us_jax = _timed(screen_jax.screen_cells, stacked, blocks)
    exact = all(np.array_equal(out[i], r) for i, r in enumerate(ref))
    return [{
        "name": f"screen_cells_jax_{len(cases)}x{n}",
        "us_per_call": us_jax,
        "derived": (f"cells={len(cases)};n={n};numpy_us={us_np:.0f};"
                    f"jax_us={us_jax:.0f};"
                    f"speedup={us_np / us_jax:.1f}x;bit_equal={exact}")}]


def bench_calib_fit() -> list[dict]:
    """repro.calib: fit per-part corrections on the committed fixture
    measurement set and render the error table — the docs-job smoke path.
    Headline: every part's calibrated error must come in at or under its
    raw error (the geomean fit guarantees it; ``all_improved`` gates)."""
    from repro.calib import error_rows, fit_corrections, fixture_measurements

    ms = fixture_measurements()
    cal, us = _timed(fit_corrections, ms)
    rows = error_rows(cal)
    improved = all(r["cal_err_pct"] <= r["raw_err_pct"] + 1e-9 for r in rows)
    worst = max((r["cal_err_pct"] for r in rows), default=0.0)
    return [{
        "name": "calib_fit", "us_per_call": us,
        "derived": (f"parts={len(cal.parts())};meas={len(ms)};"
                    f"fingerprint={cal.fingerprint()};"
                    f"worst_cal_err_pct={worst:.2f};"
                    f"all_improved={improved}")}]


BENCHES = {
    "fig1": bench_fig1_ctc,
    "table1": bench_table1_variance,
    "fig9": bench_fig9_dsp_efficiency,
    "fig10": bench_fig10_throughput,
    "fig11": bench_fig11_deeper,
    "table3": bench_table3_rav,
    "table4": bench_table4_batch,
    "campaign": bench_dse_campaign,
    "campaign_fpga": bench_fpga_campaign,
    "campaign_fpga_hyperband": bench_searcher_engines,
    "campaign_tpu": bench_tpu_campaign,
    "campaign_cuda": bench_cuda_campaign,
    "campaign_placement": bench_placement,
    "campaign_100k": bench_campaign_100k,
    "screen_jax": bench_screen_cells_jax,
    "calib_fit": bench_calib_fit,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, metavar="NAMES",
                    help="comma list of benchmarks to run, from: "
                         + ",".join(BENCHES))
    ap.add_argument("--json", dest="json_path", default=None, metavar="OUT",
                    help="also write rows (grouped by benchmark) as JSON")
    args = ap.parse_args()
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in BENCHES]
        if unknown:
            ap.error(f"unknown benchmarks {unknown}; "
                     f"choose from {list(BENCHES)}")
    else:
        names = list(BENCHES)
    results: dict[str, list[dict]] = {}
    print("name,us_per_call,derived")
    for n in names:
        results[n] = BENCHES[n]()
        for row in results[n]:
            print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump({"benchmarks": results}, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
