"""Relative-link checker for the docs set (the CI docs job runs this).

Walks every Markdown file under ``docs/`` (plus the top-level README)
and verifies that each relative link target exists on disk. External
(``http``/``mailto``) links and intra-page ``#fragment`` links are out
of scope — this guards the cheap, common breakage: a renamed file or a
report that was never regenerated.

    python docs/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

#: inline links ``[text](target)``; images share the syntax.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files() -> list[Path]:
    return sorted((ROOT / "docs").rglob("*.md")) + [ROOT / "README.md"]


def broken_links(path: Path) -> list[tuple[int, str]]:
    out = []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for target in _LINK.findall(line):
            if target.startswith(_SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]  # file part only
            if not rel:
                continue
            if not (path.parent / rel).exists():
                out.append((lineno, target))
    return out


def main() -> int:
    bad = 0
    files = doc_files()
    for path in files:
        for lineno, target in broken_links(path):
            print(f"{path.relative_to(ROOT)}:{lineno}: broken relative "
                  f"link -> {target}", file=sys.stderr)
            bad += 1
    if bad:
        print(f"FAIL: {bad} broken link(s)", file=sys.stderr)
        return 1
    print(f"OK: all relative links resolve across {len(files)} file(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
