"""End-to-end training driver: train a ~10M-param StarCoder2-family model
for a few hundred steps on CPU with checkpointing, auto-resume, and a
mid-run injected node failure — the full fault-tolerance path.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import logging
import tempfile

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    # ~10M params: the reduced starcoder2 family scaled up a notch
    cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                              n_layers=4, d_model=256, d_ff=1024, vocab=2048)
    shape = ShapeSpec("example", "train", args.seq, args.batch)
    with tempfile.TemporaryDirectory() as ckpt:
        trainer = Trainer(cfg, shape, TrainConfig(
            steps=args.steps, ckpt_every=50, ckpt_dir=ckpt, log_every=25))
        trainer.fail_at(args.steps // 2)  # exercise failover mid-run
        trainer.run()
        first = sum(s["loss"] for s in trainer.stats[:10]) / 10
        last = sum(s["loss"] for s in trainer.stats[-10:]) / 10
        print(f"\nloss: {first:.3f} -> {last:.3f} over {args.steps} steps "
              f"({'improved' if last < first else 'NO IMPROVEMENT'})")
        print(f"restarts: {trainer._restarts} (1 injected), "
              f"stragglers flagged: {len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
