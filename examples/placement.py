"""Walkthrough: cost-aware multi-family placement with repro.dse.placement.

Runs two small campaigns (TPU and CUDA) for the same two workloads into
ONE store, then answers the end-to-end question the campaigns alone
don't: *which family, part, and count should each workload run on,
under a joint dollar/watt budget?*

1. build campaign evidence: tpu + cuda sweeps of two workloads,
2. place the mix under a loose budget (the best designs win outright),
3. tighten the budget and watch the assignment trade down — and the
   marginal "next dollar / next watt" table say exactly what a budget
   raise would buy,
4. demonstrate the coverage fallback: a workload no store covers gets
   fresh default-campaign evaluations before placing,
5. write the Markdown placement report.

    PYTHONPATH=src python examples/placement.py
"""
from repro.core.hw_specs import CostEnvelope
from repro.dse import run_campaign
from repro.dse.backends import get_backend
from repro.dse.placement import (candidates_by_workload, ensure_coverage,
                                 place, pooled_records)
from repro.dse.report import render_placement
from repro.dse.store import open_store


def show(result):
    unit = "TFLOP/s" if result.objective.startswith("tflops") else ""
    for a in result.assignments:
        c = a.candidate
        print(f"  {a.workload:<28} -> {c.backend}:{c.part} x{c.count} "
              f"[{c.point}]  {c.value:.4g} {unit} "
              f"(${c.usd_per_hour:g}/h, {c.watts:g} W)")
    print(f"  total {result.total_value:.4g} {unit} for "
          f"${result.total_usd:g}/h, {result.total_watts:g} W")
    for s in result.suggestions[:2]:
        print(f"  next: {s.workload} could gain +{s.gain:.4g} {unit} for "
              f"+${s.d_usd:g}/h / +{s.d_watts:g} W "
              f"(blocked by {', '.join(s.blocked_by)})")


def main():
    store_path = "results/placement_example.jsonl"
    archs, shapes = ["starcoder2-3b", "xlstm-350m"], ["train_4k"]
    workloads = [f"{a}/{s}" for a in archs for s in shapes]

    # 1. campaign evidence: both families sweep the same workloads.
    tpu, cuda = get_backend("tpu"), get_backend("cuda")
    run_campaign(tpu.expand_cells(archs=archs, shapes=shapes, chips=[8, 16],
                                  remats=("full",), microbatches=(1,)),
                 store_path, backend="tpu")
    run_campaign(cuda.expand_cells(archs=archs, shapes=shapes, gpus=[8, 16],
                                   gpu_types=("a100-80g", "h100"),
                                   remats=("full",), microbatches=(1,)),
                 store_path, backend="cuda")
    records = pooled_records([open_store(store_path)])
    print(f"== store: {len(records)} cells across tpu+cuda ==")

    # 2. loose budget: every workload gets its best design.
    loose = place(workloads, records, CostEnvelope(usd_per_hour=200.0))
    print(f"\n== placement under $200/h ({loose.solver}) ==")
    show(loose)

    # 3. tight budget: the solver trades down, and the marginal table
    #    quantifies what the next dollar would buy.
    tight = place(workloads, records,
                  CostEnvelope(usd_per_hour=60.0, watts=8000.0))
    print(f"\n== placement under $60/h and 8 kW ({tight.solver}) ==")
    show(tight)

    # 4. coverage fallback: decode_32k was never swept — fill it with the
    #    backends' default coverage cells, then place the widened mix.
    wider = workloads + ["xlstm-350m/decode_32k"]
    store = open_store(store_path)
    known = candidates_by_workload(store.iter_records(), "tflops")
    filled = ensure_coverage(wider, store, known)
    print(f"\n== coverage fallback evaluated: {filled} ==")
    full = place(wider, pooled_records([store]),
                 CostEnvelope(usd_per_hour=250.0))
    show(full)

    # 5. the Markdown report (assignment, utilization, marginal upgrades).
    out = "results/placement_example_report.md"
    with open(out, "w") as f:
        f.write(render_placement(tight, title="placement.py example"))
    print(f"\nreport -> {out}")
    print("OK")


if __name__ == "__main__":
    main()
