"""Serving example: continuous batching + int8 weight-only quantization.

A ragged stream of requests (prompt lengths 3..24, varying max_new) served
through the fixed-slot continuous batcher; compares slot utilization vs a
naive static batch and shows the int8 storage win.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.quant import dequantize_params, quantize_params, storage_bytes
from repro.serve.scheduler import ContinuousBatcher, Request


def main():
    cfg = get_config("starcoder2-3b").reduced()
    params = api.init_params(jax.random.key(0), cfg)

    q = quantize_params(params)
    print(f"int8 weight-only quantization: {storage_bytes(params) / 2**20:.1f} "
          f"MiB -> {storage_bytes(q) / 2**20:.1f} MiB "
          f"({storage_bytes(params) / storage_bytes(q):.1f}x)")
    params = dequantize_params(q)  # serve from the quantized store

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab,
                                                    int(rng.integers(3, 24)))),
                    max_new=int(rng.integers(4, 12)))
            for i in range(12)]

    b = ContinuousBatcher(cfg, params, slots=4, max_seq=64)
    for r in reqs:
        b.submit(r)
    t0 = time.perf_counter()
    done = b.run()
    dt = time.perf_counter() - t0

    total_tokens = sum(len(c.tokens) + c.prompt_len for c in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s), slot utilization "
          f"{b.utilization:.0%} over {b.steps} ticks")
    naive_ticks = sum(len(r.prompt) + r.max_new for r in reqs)  # 1 slot
    static_ticks = 0  # static batching: batch of 4, each round as long as
    for i in range(0, len(reqs), 4):  # its longest member
        static_ticks += max(len(r.prompt) + r.max_new for r in reqs[i:i + 4])
    print(f"vs sequential: {naive_ticks} ticks; vs static batch-of-4: "
          f"{static_ticks} ticks; continuous: {b.steps} ticks")


if __name__ == "__main__":
    main()
