"""Quickstart: a multi-objective DSE campaign with repro.dse.

Sweeps VGG-16 at two input sizes across two FPGAs and two precisions
(8 cells), persists every cell to a JSONL store, then shows the three
things the campaign engine adds over the single-pair ``explore()``:

1. ranked results under a custom scalarization (throughput + efficiency),
2. the 5-objective Pareto frontier across all designs, and
3. free re-runs — the second campaign reuses the store, zero PSO evals.

    PYTHONPATH=src python examples/dse_campaign.py
"""
from repro.dse import Objectives, run_campaign
from repro.dse.campaign import expand_cells


def main():
    cells = expand_cells(nets=["vgg16"], inputs=[(64, 64), (224, 224)],
                         fpgas=["ku115", "zcu102"], precisions=[16, 8],
                         batch_caps=[4])
    store = "results/dse_quickstart.jsonl"
    print(f"== campaign: {len(cells)} cells -> {store} ==")
    report = run_campaign(cells, store, workers=2, progress=print)

    weights = {"throughput_ips": 1.0, "dsp_eff": 100.0}
    print(f"\n== ranked by {weights} ==")
    for rec in report.ranked(weights)[:4]:
        o = rec["objectives"]
        print(f"  {rec['cell_key']}: {o['throughput_ips']:.1f} img/s, "
              f"{o['gops']:.1f} GOP/s, eff {o['dsp_eff']:.1%}")

    print("\n== Pareto frontier (throughput, GOP/s, latency, eff, BRAM) ==")
    for rec in report.frontier():
        o = Objectives.from_dict(rec["objectives"])
        print(f"  {rec['cell_key']}: {o.throughput_ips:.1f} img/s, "
              f"{o.latency_s * 1e3:.2f} ms, {int(o.bram_used)} BRAM")

    rerun = run_campaign(cells, store)
    print(f"\n== resume: {rerun.reused_cells}/{len(cells)} cells reused, "
          f"{rerun.new_evaluations} new evaluations ==")
    print("OK")


if __name__ == "__main__":
    main()
