"""Quickstart: multi-objective DSE campaigns with repro.dse.

Sweeps VGG-16 at two input sizes across two FPGAs and two precisions
(8 cells), persists every cell to a JSONL store, then shows what the
campaign engine adds over the single-pair ``explore()``:

1. ranked results under a custom scalarization (throughput + efficiency),
2. the 5-objective Pareto frontier across all designs,
3. free re-runs — the second campaign reuses the store, zero PSO evals,
4. the same engine pointed at a different device family (`tpu` backend),
   and a Markdown report rendered from the combined store.

    PYTHONPATH=src python examples/dse_campaign.py
"""
from repro.dse import Objectives, render_report, run_campaign
from repro.dse.backends import get_backend
from repro.dse.campaign import expand_cells
from repro.dse.store import ResultStore


def main():
    cells = expand_cells(nets=["vgg16"], inputs=[(64, 64), (224, 224)],
                         fpgas=["ku115", "zcu102"], precisions=[16, 8],
                         batch_caps=[4])
    store = "results/dse_quickstart.jsonl"
    print(f"== campaign: {len(cells)} cells -> {store} ==")
    report = run_campaign(cells, store, workers=2, progress=print)

    weights = {"throughput_ips": 1.0, "dsp_eff": 100.0}
    print(f"\n== ranked by {weights} ==")
    for rec in report.ranked(weights)[:4]:
        o = rec["objectives"]
        print(f"  {rec['cell_key']}: {o['throughput_ips']:.1f} img/s, "
              f"{o['gops']:.1f} GOP/s, eff {o['dsp_eff']:.1%}")

    print("\n== Pareto frontier (throughput, GOP/s, latency, eff, BRAM) ==")
    for rec in report.frontier():
        o = Objectives.from_dict(rec["objectives"])
        print(f"  {rec['cell_key']}: {o.throughput_ips:.1f} img/s, "
              f"{o.latency_s * 1e3:.2f} ms, {int(o.bram_used)} BRAM")

    rerun = run_campaign(cells, store)
    print(f"\n== resume: {rerun.reused_cells}/{len(cells)} cells reused, "
          f"{rerun.new_evaluations} new evaluations ==")

    # Same engine, different device family: sweep the TPU planner's axes
    # into the SAME store (records are tagged per backend).
    tpu = get_backend("tpu")
    tpu_cells = tpu.expand_cells(archs=["starcoder2-3b", "xlstm-350m"],
                                 shapes=["train_4k", "decode_32k"],
                                 chips=[8, 16, 32])
    tpu_report = run_campaign(tpu_cells, store, backend="tpu")
    print(f"\n== tpu campaign: {len(tpu_cells)} cells, frontier of "
          f"{len(tpu_report.frontier())}; 4 most-spread designs: ==")
    for rec in tpu_report.frontier(k=4):
        o = rec["objectives"]
        print(f"  {rec['cell_key']}: step {o['step_time_s']:.3g}s, "
              f"mfu {o['mfu']:.2f}, {o['hbm_gib']:.1f} GiB/chip")

    out = "results/dse_quickstart_report.md"
    md = render_report(ResultStore(store).records(),
                       title="dse_campaign.py example")
    with open(out, "w") as f:
        f.write(md)
    print(f"\nreport -> {out} ({len(md)} chars)")
    print("OK")


if __name__ == "__main__":
    main()
