"""Quickstart: multi-objective DSE campaigns with repro.dse.

Sweeps VGG-16 at two input sizes across two FPGAs and two precisions
(8 cells), persists every cell to a JSONL store, then shows what the
campaign engine adds over the single-pair ``explore()``:

1. ranked results under a custom scalarization (throughput + efficiency),
2. the 5-objective Pareto frontier across all designs,
3. free re-runs — the second campaign reuses the store, zero PSO evals,
4. the same engine pointed at two more device families (`tpu` and `cuda`
   backends) into the SAME store,
5. a cross-backend comparison: every record normalized to (TFLOP/s, per
   watt, per dollar, per peak) so one frontier ranks all three families,
   and a Markdown report (with cross-backend section) from the mix.

    PYTHONPATH=src python examples/dse_campaign.py
"""
from repro.dse import (NORMALIZED_OBJECTIVES, Objectives, canonical_vector,
                       diverse_front, render_report, run_campaign)
from repro.dse.backends import get_backend
from repro.dse.campaign import expand_cells
from repro.dse.store import open_store


def main():
    cells = expand_cells(nets=["vgg16"], inputs=[(64, 64), (224, 224)],
                         fpgas=["ku115", "zcu102"], precisions=[16, 8],
                         batch_caps=[4])
    store = "results/dse_quickstart.jsonl"
    print(f"== campaign: {len(cells)} cells -> {store} ==")
    report = run_campaign(cells, store, workers=2, progress=print)

    weights = {"throughput_ips": 1.0, "dsp_eff": 100.0}
    print(f"\n== ranked by {weights} ==")
    for rec in report.ranked(weights)[:4]:
        o = rec["objectives"]
        print(f"  {rec['cell_key']}: {o['throughput_ips']:.1f} img/s, "
              f"{o['gops']:.1f} GOP/s, eff {o['dsp_eff']:.1%}")

    print("\n== Pareto frontier (throughput, GOP/s, latency, eff, BRAM) ==")
    for rec in report.frontier():
        o = Objectives.from_dict(rec["objectives"])
        print(f"  {rec['cell_key']}: {o.throughput_ips:.1f} img/s, "
              f"{o.latency_s * 1e3:.2f} ms, {int(o.bram_used)} BRAM")

    rerun = run_campaign(cells, store)
    print(f"\n== resume: {rerun.reused_cells}/{len(cells)} cells reused, "
          f"{rerun.new_evaluations} new evaluations ==")

    # Same engine, different device family: sweep the TPU planner's axes
    # into the SAME store (records are tagged per backend).
    tpu = get_backend("tpu")
    tpu_cells = tpu.expand_cells(archs=["starcoder2-3b", "xlstm-350m"],
                                 shapes=["train_4k", "decode_32k"],
                                 chips=[8, 16, 32])
    tpu_report = run_campaign(tpu_cells, store, backend="tpu")
    print(f"\n== tpu campaign: {len(tpu_cells)} cells, frontier of "
          f"{len(tpu_report.frontier())}; 4 most-spread designs: ==")
    for rec in tpu_report.frontier(k=4):
        o = rec["objectives"]
        print(f"  {rec['cell_key']}: step {o['step_time_s']:.3g}s, "
              f"mfu {o['mfu']:.2f}, {o['hbm_gib']:.1f} GiB/chip")

    # Third family: CUDA GPUs over the SM/HBM/NVLink roofline, with the
    # GPU part itself as a campaign axis (A100-80G vs H100).
    cuda = get_backend("cuda")
    cuda_cells = cuda.expand_cells(archs=["starcoder2-3b", "xlstm-350m"],
                                   shapes=["train_4k", "decode_32k"],
                                   gpus=[8, 16, 32],
                                   gpu_types=("a100-80g", "h100"))
    cuda_report = run_campaign(cuda_cells, store, backend="cuda")
    print(f"\n== cuda campaign: {len(cuda_cells)} cells, frontier of "
          f"{len(cuda_report.frontier())}; 4 most-spread designs: ==")
    for rec in cuda_report.frontier(k=4):
        o = rec["objectives"]
        print(f"  {rec['cell_key']}: step {o['step_time_s']:.3g}s, "
              f"mfu {o['mfu']:.2f}, {int(o['watts'])} W")

    # Cross-backend frontier: every record normalized to the shared
    # (tflops, /W, /$, /peak) schema, one dominance sort over all of it.
    records = list(open_store(store).iter_records())
    norm = [(r, get_backend(r.get("backend", "fpga")).normalized(r))
            for r in records]
    norm = [(r, n) for r, n in norm if n["feasible"]]
    vecs = [canonical_vector(n, NORMALIZED_OBJECTIVES) for _, n in norm]
    print("\n== cross-backend frontier (normalized, most-spread first) ==")
    for i in diverse_front(vecs)[:6]:
        r, n = norm[i]
        print(f"  [{r.get('backend', 'fpga')}] {r['cell_key']}: "
              f"{n['tflops']:.1f} TFLOP/s, {n['tflops_per_watt']:.3f}/W, "
              f"{n['tflops_per_dollar']:.1f}/$, {n['tflops_per_peak']:.2f} "
              f"of peak")

    out = "results/dse_quickstart_report.md"
    md = render_report(records, title="dse_campaign.py example")
    with open(out, "w") as f:
        f.write(md)
    print(f"\nreport -> {out} ({len(md)} chars, incl. cross-backend "
          f"frontier section)")
    print("OK")


if __name__ == "__main__":
    main()
