"""The paper's paradigm, executed: a VGG-like conv group where the DSE's
split-point sends the first SP layers through a REAL pipeline (shard_map +
ppermute over a `stage` mesh axis) and the rest through the generic
(reusable) apply — then verifies the hybrid output matches the plain
sequential forward bit-for-bit.

Run with multiple virtual devices to see actual pipelining:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/hybrid_vgg_pipeline.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.netinfo import _B
from repro.models.cnn import HybridPlan, forward, hybrid_forward, init_vgg


def main():
    # A homogeneous conv group (the paper's deepened-VGG structure): 4
    # identical 32-ch 3x3 layers (the pipelined head) + pool + 2 more
    # (the generic tail).
    b = _B("vgg_group", 32, 32, 32)
    for _ in range(4):
        b.conv(32, 3)
    b.pool(2)
    b.conv(64, 3).conv(64, 3)
    net = b.done()

    params = init_vgg(jax.random.key(0), net)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 32, 32, 32)),
                    jnp.float32)

    ref = forward(params, net, x)

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("stage",)) if n_dev == 4 else None
    plan = HybridPlan(sp=4, n_micro=4)
    out = hybrid_forward(params, net, x, plan, mesh=mesh)

    err = float(jnp.abs(out - ref).max())
    mode = f"pipelined over {n_dev} stages" if mesh is not None else "sequential"
    print(f"hybrid ({mode}, SP={plan.sp}, {plan.n_micro} microbatches) vs "
          f"sequential: max |diff| = {err:.2e}")
    assert err < 1e-4
    print("OK — the paper's pipeline-head + generic-tail paradigm runs as a "
          "real JAX execution plan.")


if __name__ == "__main__":
    main()
