"""DNNExplorer-for-TPU: run the retargeted two-level DSE for every
assigned architecture x workload and print the chosen plan — the TPU
analogue of the paper's Table 3 (RAV per case).

    PYTHONPATH=src python examples/plan_tpu.py [--shape train_4k]
"""
import argparse

from repro.configs import ARCH_IDS, SHAPES, cell_enabled, get_config
from repro.core.tpu_planner import best_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--max-chips", type=int, default=256)
    args = ap.parse_args()
    shapes = [args.shape] if args.shape else list(SHAPES)

    for shape_name in shapes:
        shape = SHAPES[shape_name]
        print(f"== {shape_name} (seq={shape.seq_len}, "
              f"batch={shape.global_batch}) ==")
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            ok, why = cell_enabled(cfg, shape)
            if not ok:
                print(f"  {arch}: skipped ({why})")
                continue
            p = best_plan(cfg, shape, max_chips=args.max_chips)
            print("  " + p.pretty())
        print()


if __name__ == "__main__":
    main()
