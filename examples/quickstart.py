"""Quickstart: DNNExplorer's three-step flow on the paper's own workload.

Runs Model/HW Analysis -> Accelerator Modeling -> Architecture Exploration
for VGG-16 at 224x224 on a Xilinx KU115, then compares the discovered
hybrid design against the two pure paradigms (Fig. 9 / Table 3 setting).

    PYTHONPATH=src python examples/quickstart.py
"""
import statistics

from repro.core import (KU115, PSOConfig, dnnbuilder_design, explore,
                        generic_only_design)
from repro.core.netinfo import vgg16


def main():
    net = vgg16(224)
    print(f"== Model analysis: {net.name} ==")
    print(f"  {len(net.major_layers)} CONV layers, "
          f"{net.total_ops / 1e9:.1f} GOP/frame")
    ctcs = net.ctc_list()
    print(f"  CTC range {min(ctcs):.0f}..{max(ctcs):.0f} "
          f"(median {statistics.median(ctcs):.0f}) -> strong early-layer "
          f"heterogeneity, the paper's motivation")
    print(f"  V1/V2 variance ratio: {net.half_variance_ratio():.0f}")

    print("\n== Architecture exploration (two-level DSE) ==")
    res = explore(net, KU115, cfg=PSOConfig(population=20, iterations=30,
                                            seed=1))
    d = res.design
    print(f"  best RAV: {res.rav_pretty}")
    print(f"  throughput: {d.gops:.1f} GOP/s ({d.throughput_ips:.1f} img/s)"
          f"  [paper Table 3: 1702.3 GOP/s, 55.4 img/s]")
    print(f"  DSP efficiency: {d.dsp_eff:.1%}  [paper: 95.8%]")
    print(f"  search: {res.search_time_s:.2f}s, "
          f"{res.pso.evaluations} design points")

    print("\n== The two pure paradigms (what the paper improves on) ==")
    b = dnnbuilder_design(net, KU115)
    g = generic_only_design(net, KU115)
    print(f"  paradigm B (pure pipeline, DNNBuilder-like): {b.gops:.1f} GOP/s "
          f"eff {b.dsp_eff:.1%}")
    print(f"  paradigm A (pure generic, HybridDNN-like):  {g.gops:.1f} GOP/s "
          f"eff {g.dsp_eff:.1%}")
    print(f"  DNNExplorer hybrid:                          {d.gops:.1f} GOP/s "
          f"eff {d.dsp_eff:.1%}")


if __name__ == "__main__":
    main()
