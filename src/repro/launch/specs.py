"""Input specifications for every (arch x shape) cell.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (no allocation) for
the dry-run; ``make_batch`` materializes small concrete batches for smoke
tests and the CPU examples. Both share one shape source so the dry-run and
the tests can never drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import api


def _shapes_for(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """name -> (shape tuple, dtype) for the given workload."""
    b, s = shape.global_batch, shape.seq_len
    out: dict[str, tuple[tuple, np.dtype]] = {}
    if shape.kind in ("train", "prefill"):
        s_text = s
        if cfg.family == "vlm":
            s_text = s - cfg.n_patches
            out["patch_embeds"] = ((b, cfg.n_patches, cfg.vision_embed_dim),
                                   jnp.bfloat16)
        if cfg.family == "audio":
            out["frames"] = ((b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
        out["tokens"] = ((b, s_text), jnp.int32)
        if shape.kind == "train":
            out["labels"] = ((b, s_text), jnp.int32)
    else:  # decode: one new token against a cache of length s
        out["tokens"] = ((b, 1), jnp.int32)
        out["pos"] = ((b,), jnp.int32)
    return out


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct tree for jit(...).lower(**input_specs(...))."""
    specs = {k: jax.ShapeDtypeStruct(sh, dt)
             for k, (sh, dt) in _shapes_for(cfg, shape).items()}
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
        specs["cache"] = cache
    return specs


def make_batch(cfg: ArchConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    """Concrete (small!) batch for CPU tests/examples."""
    rng = np.random.default_rng(seed)
    batch = {}
    for k, (sh, dt) in _shapes_for(cfg, shape).items():
        if dt == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels") else shape.seq_len
            batch[k] = jnp.asarray(rng.integers(0, hi, size=sh), jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.standard_normal(sh), dt)
    if shape.kind == "decode":
        batch["pos"] = jnp.full((shape.global_batch,), shape.seq_len - 1,
                                jnp.int32)
        batch["cache"] = api.init_cache(cfg, shape.global_batch, shape.seq_len)
    return batch
