"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--reduced`` runs the CPU-sized config (smoke/demo); without it the full
config is used (requires a real TPU slice; the multi-pod dry-run proves
the sharded program compiles for the production mesh).
"""
from __future__ import annotations

import argparse
import logging

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeSpec
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli_train", "train", args.seq, args.batch)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir,
                       grad_compression=args.grad_compression)
    trainer = Trainer(cfg, shape, tcfg)
    trainer.run()
    losses = [s["loss"] for s in trainer.stats]
    print(f"done: {len(losses)} steps, loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"stragglers={len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
