"""Extract roofline inputs from compiled XLA artifacts.

``cost_analysis()`` gives HLO FLOPs and bytes; collective traffic is NOT in
cost_analysis, so we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.:  %x = f32[8,128]{1,0} all-gather(f32[1,128]{1,0} %y), ...
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*("
    + "|".join(_COLLECTIVES) + r")(-start|-done)?\(", re.M)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum *output* operand sizes of every collective op in the HLO text.

    Uses the op's result shape (the tuple/array on the lhs), which for
    all-gather is the gathered size — a conservative upper bound on the
    per-device link traffic; `-start/-done` async pairs are counted once
    (on the -start)."""
    by_bytes: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    by_count: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        result_shapes, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        by_bytes[kind] += _shape_bytes(result_shapes)
        by_count[kind] += 1
    return CollectiveStats(by_bytes, by_count)


def cost_summary(compiled) -> dict:
    """Normalize compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0))
    out["total_per_device"] = (out["argument_size_in_bytes"]
                               + out["output_size_in_bytes"]
                               + out["temp_size_in_bytes"]
                               - out["alias_size_in_bytes"])
    return out
