"""Exact cost accounting from optimized HLO text.

XLA's ``compiled.cost_analysis()`` prices while-loop bodies ONCE regardless
of trip count, so scanned-layer models undercount FLOPs by ~n_layers, and
collectives inside scans are likewise invisible. This module parses
``compiled.as_text()`` into its computation graph, multiplies while bodies
by their ``known_trip_count`` backend config, and descends into fusions —
yielding exact per-device dot/conv FLOPs and collective traffic for
scan-based graphs (validated against unrolled lowerings in tests).

The parser is HLO-print-version-aware: older XLA prints operands as bare
``%name`` references (resolved through the computation's symbol table),
newer XLA (jax >= 0.4.3x) inlines each operand's full shape
(``dot(f32[4,32,64]{2,1,0} %a, ...)``), whose dims/layouts contain commas
and parens. Operand lists are therefore split at top-level commas only,
and shapes come from the operand text itself when present.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_SHAPE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"\b([a-z][\w\-]*)\(")
_PARAM = re.compile(r"%?([\w.\-]+):\s*([^,)]+(?:\([^)]*\))?)")
_CALLED = re.compile(
    r"(to_apply|condition|body|calls)=%?([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'\\?"known_trip_count\\?":\s*\{\s*\\?"n\\?":\s*\\?"?(\d+)')
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shapes_in(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _split_operands(text: str) -> list[str]:
    """Split an operand list on top-level commas only. Newer XLA prints
    typed operands (``f32[4,32,64]{2,1,0} %x``) whose dims/layouts contain
    commas, so a plain ``split(",")`` tears shapes apart."""
    parts, cur, depth = [], [], 0
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _operand_body(rest: str, start: int) -> str:
    """The text between the op's ``(`` (at ``start``) and its matching
    ``)``. Typed operands can nest parens (tuple shapes), so track depth
    instead of cutting at the first ``)``."""
    depth = 1
    for i in range(start, len(rest)):
        ch = rest[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[start:i]
    return rest[start:]


def _operand_shape(token: str, symtab: dict[str, str]) -> str:
    """Shape text of one operand token, HLO-version-aware: newer XLA
    inlines the shape in the operand itself; older XLA prints bare
    ``%name`` references that resolve through the symbol table."""
    if _SHAPE.search(token):
        return token
    return symtab.get(token.strip().lstrip("%"), "")


def _numel_bytes(text: str) -> tuple[int, int]:
    n_tot = b_tot = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        n_tot += n
        b_tot += n * _DTYPE_BYTES[dt]
    return n_tot, b_tot


_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "broadcast", "reshape"}


@dataclasses.dataclass
class _Comp:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    calls: list = dataclasses.field(default_factory=list)


def _parse_computation(header_args: str, lines: list[str],
                       fusion_body: bool = False) -> _Comp:
    comp = _Comp()
    symtab: dict[str, str] = {}  # op name -> result shape text
    for m in _PARAM.finditer(header_args):
        symtab[m.group(1)] = m.group(2)
    parsed = []
    for line in lines:
        d = _DEF.match(line)
        if not d:
            continue
        name, rest = d.groups()
        om = _OPNAME.search(rest)
        if not om:
            continue
        result = rest[:om.start()]
        op = om.group(1)
        operands = _split_operands(_operand_body(rest, om.end()))
        symtab[name] = result
        parsed.append((line, result, op, operands))

    for line, result, op, operands in parsed:
        # HBM traffic at fusion granularity: result + operand bytes of every
        # materializing op. Fusion *bodies* stream through VMEM -> skipped.
        if not fusion_body and op not in _FREE_OPS:
            _, rb = _numel_bytes(result)
            ob = 0
            for tok in operands:
                _, b_ = _numel_bytes(_operand_shape(tok, symtab))
                ob += b_
            comp.mem_bytes += rb + ob

        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            _, b = _numel_bytes(result)
            if op.endswith("-start"):
                b //= 2  # -start result tuple = (operand, result)
            comp.coll_bytes[base] += b

        if op == "dot":
            numel, _ = _numel_bytes(result)
            lhs_shape_text = _operand_shape(operands[0], symtab) if operands else ""
            shapes = _shapes_in(lhs_shape_text)
            cm = _LHS_CONTRACT.search(line)
            kprod = 1
            if cm and shapes:
                dims = shapes[0][1]
                for ci in (int(c) for c in cm.group(1).split(",") if c):
                    if ci < len(dims):
                        kprod *= dims[ci]
            comp.flops += 2.0 * numel * kprod
        elif op == "convolution":
            numel, _ = _numel_bytes(result)
            wm = re.search(r"window=\{size=([\dx]+)", line)
            k = 1
            if wm:
                for d in wm.group(1).split("x"):
                    k *= int(d)
            cin = 1
            if len(operands) > 1:
                rshapes = _shapes_in(_operand_shape(operands[1], symtab))
                fm = re.search(r"dim_labels=[^,]*?_([\w?]+?)->", line)
                if rshapes and fm and "i" in fm.group(1):
                    cin = rshapes[0][1][fm.group(1).index("i")]
                elif rshapes:
                    cin = rshapes[0][1][0]
            comp.flops += 2.0 * numel * k * cin

        trip = 1
        if op == "while":
            tm = _TRIP.search(line)
            trip = int(tm.group(1)) if tm else 1
        for cm_ in _CALLED.finditer(line):
            if cm_.group(2):
                kw = cm_.group(1)
                mult = trip if kw in ("body", "condition") else 1
                comp.calls.append((cm_.group(2), mult))
            elif cm_.group(3):
                for b in cm_.group(3).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        comp.calls.append((b, 1))
    return comp


@dataclasses.dataclass
class ExactCost:
    flops: float
    coll_bytes: dict[str, float]
    mem_bytes: float = 0.0

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "coll_bytes": self.coll_bytes,
                "coll_total": self.coll_total, "mem_bytes": self.mem_bytes}


def exact_cost(hlo_text: str) -> ExactCost:
    comps: dict[str, _Comp] = {}
    entry = None

    def is_fusion_body(name: str) -> bool:
        return "fused_computation" in name or name.startswith("wrapped_")

    cur_name, cur_args, cur_lines = None, "", []
    for line in hlo_text.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            if cur_name is not None:
                comps[cur_name] = _parse_computation(
                    cur_args, cur_lines, is_fusion_body(cur_name))
            cur_name, cur_args, cur_lines = h.group(2), h.group(3), []
            if h.group(1):
                entry = cur_name
            continue
        if cur_name is not None:
            if line.startswith("}"):
                comps[cur_name] = _parse_computation(
                    cur_args, cur_lines, is_fusion_body(cur_name))
                cur_name, cur_lines = None, []
            else:
                cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = _parse_computation(cur_args, cur_lines,
                                             is_fusion_body(cur_name))

    memo: dict[str, tuple[float, float, dict]] = {}

    def total(name: str, stack=()) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}
        c = comps[name]
        f, mb = c.flops, c.mem_bytes
        cb = dict(c.coll_bytes)
        for callee, mult in c.calls:
            cf, cmb, ccb = total(callee, stack + (name,))
            f += mult * cf
            mb += mult * cmb
            for k in cb:
                cb[k] += mult * ccb[k]
        memo[name] = (f, mb, cb)
        return memo[name]

    root = entry if entry else (next(iter(comps)) if comps else "")
    f, mb, cb = total(root)
    return ExactCost(f, cb, mb)
