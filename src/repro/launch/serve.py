"""Batched-decoding server demo: prefill a prompt batch, then decode
tokens with the KV-cache serve step.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --reduced --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init_params(jax.random.key(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    s_max = args.prompt_len + args.gen

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    cache = api.init_cache(cfg, args.batch, s_max)

    decode = jax.jit(lambda p, c, t, pos: api.decode_step(p, cfg, c, t, pos))

    # prefill by teacher-forcing the prompt through the decode step (keeps
    # one compiled program; a production server would batch-prefill).
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, t:t + 1],
                               jnp.full((args.batch,), t, jnp.int32))
    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    for t in range(args.prompt_len, s_max):
        logits, cache = decode(params, cache, toks,
                               jnp.full((args.batch,), t, jnp.int32))
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    total_tokens = args.batch * s_max
    print(f"{args.arch}: served {args.batch} seqs x ({args.prompt_len} prompt "
          f"+ {args.gen} generated) = {total_tokens} steps in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {np.asarray(gen[b, :16])}")


if __name__ == "__main__":
    main()
