import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell this lowers + compiles the
appropriate step function (train_step / prefill_step / serve_step) against
the production mesh — 16x16 single-pod and 2x16x16 multi-pod — using
ShapeDtypeStruct inputs (no allocation), then records
``memory_analysis()`` / ``cost_analysis()`` / collective traffic for the
roofline analysis.

Usage:
    python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback


from repro.configs import ARCH_IDS, SHAPES, cell_enabled, get_config
from repro.launch.hlo_cost import exact_cost
from repro.launch.hlo_stats import (collective_stats, cost_summary,
                                    memory_summary)
from repro.train.steps import BASELINE, OPTIMIZED, build_step
from repro.launch.mesh import make_production_mesh
from repro.parallel import act


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             unroll: bool = False, optimized: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    ok, why = cell_enabled(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    t0 = time.perf_counter()
    rec["unroll"] = unroll
    rec["optimized"] = optimized
    opts = OPTIMIZED if optimized else BASELINE
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh, act.activation_specs(act.default_specs(mesh)):
        fn, args = build_step(cfg, shape, mesh, unroll=unroll, opts=opts)
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    rec.update(status="ok", n_devices=mesh.devices.size,
               lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
               cost=cost_summary(compiled), memory=memory_summary(compiled))
    hlo = compiled.as_text()
    st = collective_stats(hlo)
    rec["collectives"] = {"bytes_by_kind": st.bytes_by_kind,
                          "count_by_kind": st.count_by_kind,
                          "total_bytes": st.total_bytes,
                          "total_count": st.total_count}
    # exact per-device dot/conv FLOPs + loop-aware collective traffic
    # (XLA cost_analysis prices while bodies once; see hlo_cost.py)
    rec["exact"] = exact_cost(hlo).as_dict()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact cost_analysis")
    ap.add_argument("--opt", action="store_true",
                    help="use the adopted §Perf optimizations (remat=dots, "
                         "bf16 cast, grad constraints, MoE dispatch specs)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else ([args.shape] if args.shape
                                            else list(SHAPES))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.unroll:
                    tag += "__unroll"
                if args.opt:
                    tag += "__opt"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, unroll=args.unroll,
                                   optimized=args.opt)
                except Exception as e:  # record the failure, keep going
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    m = rec["memory"]
                    print(f"  ok: flops={rec['cost']['flops']:.3e} "
                          f"bytes={rec['cost']['bytes_accessed']:.3e} "
                          f"coll={rec['collectives']['total_bytes']:.3e} "
                          f"mem/dev={m['total_per_device']/2**30:.2f}GiB "
                          f"(compile {rec['compile_s']}s)", flush=True)
                else:
                    print(f"  {rec['status']}: {rec.get('reason') or rec.get('error')}",
                          flush=True)
    print(f"done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
