import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower+compile one (arch x shape) cell under a
named optimization variant and record the roofline terms, so EXPERIMENTS.md
§Perf can show hypothesis -> change -> before/after.

    python -m repro.launch.hillclimb --cell kimi-k2-1t-a32b:train_4k \
        --variant v2_bf16_rs --out results/perf
"""
import argparse
import json
import time

from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.hlo_cost import exact_cost
from repro.launch.hlo_stats import memory_summary
from repro.launch.mesh import make_production_mesh
from repro.parallel import act
from repro.train.steps import BASELINE, StepOptions, build_step


def _specs_baseline(mesh):
    """The act-spec table the 80-cell baseline sweep ran with (before the
    MoE dispatch constraints were added)."""
    s = act.default_specs(mesh)
    s.pop("experts_flat", None)
    s.pop("tokens_flat", None)
    return s


def _specs_seqpar(mesh):
    s = act.default_specs(mesh)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dpa = dp if len(dp) > 1 else dp[0]
    # sequence-parallel residual stream: shard S over `model` between blocks
    s["act"] = P(dpa, "model", None)
    return s


def _specs_ep_shardmap(mesh):
    s = act.default_specs(mesh)
    s["_ep_mesh"] = (mesh, "model")  # manual EP dispatch inside shard_map
    return s


VARIANTS: dict[str, tuple[StepOptions, callable]] = {
    "v0_baseline": (BASELINE, _specs_baseline),
    "v1_moe_dispatch": (BASELINE, act.default_specs),
    "v2_bf16_cast": (StepOptions(cast_params=True), act.default_specs),
    "v3_rs_grads": (StepOptions(cast_params=True, constrain_grads=True),
                    act.default_specs),
    "v4_remat_dots": (StepOptions(cast_params=True, constrain_grads=True,
                                  remat="dots"), act.default_specs),
    "v5_seqpar": (StepOptions(cast_params=True, constrain_grads=True),
                  _specs_seqpar),
    "v6_moe_ep_shardmap": (BASELINE, _specs_ep_shardmap),
}


def run_variant(arch: str, shape_name: str, variant: str,
                multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    opts, spec_fn = VARIANTS[variant]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    with mesh, act.activation_specs(spec_fn(mesh)):
        fn, args = build_step(cfg, shape, mesh, opts=opts)
        compiled = fn.lower(*args).compile()
    hlo = compiled.as_text()
    ec = exact_cost(hlo)
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "opts": vars(opts) if not hasattr(opts, "__dataclass_fields__")
        else {f: getattr(opts, f) for f in opts.__dataclass_fields__},
        "exact": ec.as_dict(),
        "memory": memory_summary(compiled),
        "compile_s": round(time.perf_counter() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", choices=list(VARIANTS), required=True)
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    os.makedirs(args.out, exist_ok=True)
    tag = f"{arch}__{shape}__{args.variant}"
    print(f"[hillclimb] {tag}", flush=True)
    rec = run_variant(arch, shape, args.variant)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    e = rec["exact"]
    print(f"  flops={e['flops']:.3e} coll={e['coll_total']:.3e} "
          f"mem_hlo={e['mem_bytes']:.3e} "
          f"temp/dev={rec['memory']['temp_size_in_bytes'] / 2**30:.1f}GiB "
          f"({rec['compile_s']}s)")


if __name__ == "__main__":
    main()
