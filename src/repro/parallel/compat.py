"""JAX version compatibility for ``shard_map``.

The ``shard_map`` entry point and its keyword surface have churned across
JAX releases: it moved from ``jax.experimental.shard_map`` to ``jax``
(>= 0.8), the replication check was renamed ``check_rep`` -> ``check_vma``,
and ``axis_names`` appeared late. This wrapper feature-detects the installed
signature once (via :func:`inspect.signature`) and translates/drops keywords
so call sites can use the modern spelling on any supported JAX.
"""
from __future__ import annotations

import inspect

try:
    from jax import shard_map as _shard_map  # jax >= 0.8
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

# Renamed keywords, modern -> legacy. Applied only when the modern name is
# missing from the installed signature but the legacy one is present.
_RENAMES = {"check_vma": "check_rep"}

try:
    _PARAMS = inspect.signature(_shard_map).parameters
    _ACCEPTED = set(_PARAMS)
    _HAS_VARKW = any(p.kind is inspect.Parameter.VAR_KEYWORD
                     for p in _PARAMS.values())
except (TypeError, ValueError):  # pragma: no cover - C-level callables
    _ACCEPTED, _HAS_VARKW = set(), True


def _translate(kwargs: dict) -> dict:
    if _HAS_VARKW:
        return kwargs
    out = {}
    for k, v in kwargs.items():
        if k not in _ACCEPTED and _RENAMES.get(k) in _ACCEPTED:
            k = _RENAMES[k]
        if k in _ACCEPTED:
            out[k] = v
    return out


def shard_map(f, **kwargs):
    """``shard_map(f, mesh=..., in_specs=..., out_specs=..., ...)`` with
    unsupported keywords renamed or dropped for the installed JAX."""
    try:
        return _shard_map(f, **_translate(kwargs))
    except TypeError as e:  # signature detection failed us: retry minimal
        if "unexpected keyword argument" not in str(e):
            raise
        core = {k: kwargs[k] for k in ("mesh", "in_specs", "out_specs")
                if k in kwargs}
        return _shard_map(f, **core)
