"""Distributed-optimization helpers: int8 gradient compression with error
feedback, and hierarchical-reduction description helpers.

Compression halves (fp32->int8: quarters) the DP all-reduce volume — the
dominant collective for FSDP training — at the cost of quantization noise
that the error-feedback accumulator re-injects next step (Seide et al.;
1-bit SGD lineage), keeping convergence unbiased in practice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def compress_grads(grads, err):
    """grads+err -> (quantized grads (dequantized form), new err).

    The returned grads are already dequantized so the caller's psum /
    optimizer path is unchanged; on a real fabric the int8 payload is what
    crosses the links (jax lowers the int8 psum when you reduce ``q``
    directly — see ``compressed_psum`` below for that variant).
    """
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        q, scale = quantize_int8(acc)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), acc - deq

    flat = jax.tree.map(one, grads, err)
    new_grads = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err


def compressed_psum(grads, axis: str, err):
    """shard_map-context variant: int8 payload actually crosses the links.
    all-reduce of int8 with per-shard scales = all-gather scales (tiny) +
    psum of the int8 tensor in int32 accumulation."""
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        q, scale = quantize_int8(acc)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        scale_max = jax.lax.pmax(scale, axis)
        deq = total.astype(jnp.float32) * scale_max
        return deq.astype(g.dtype), acc - dequantize_int8(q, scale)

    pairs = jax.tree.map(one, grads, err)
    return (jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple)),
            jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple)))
