"""Activation sharding constraints.

GSPMD's sharding propagation does not reliably push input shardings
through scanned (while-loop) bodies — without explicit constraints the
partitioner replicates activations per device (observed: full-batch
f32[1048576, ...] dots and 221 GiB/device temps on the 256-chip mesh).
Models therefore call :func:`constrain` at well-known points; the launcher
installs a spec table for the active mesh via :func:`use_activation_specs`,
and with no table installed the calls are no-ops (CPU tests, examples).

The table is also the main §Perf lever: changing e.g. ``act`` from
P(dp, None, None) to P(dp, "model", None) flips the model into sequence-
parallel mode without touching model code.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, PartitionSpec as P

_STATE = threading.local()


def default_specs(mesh: Mesh) -> dict[str, P]:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dpa = dp if len(dp) > 1 else dp[0]
    return {
        # (B, S, D) residual-stream activations
        "act": P(dpa, None, None),
        # (B, S, F) ffn hidden — TP-sharded (Megatron column output)
        "ffn": P(dpa, None, "model"),
        # (B, S, 2*d_inner) mamba in_proj output
        "ffn2": P(dpa, None, "model"),
        # (B, S, H*hd) attention output before the row-parallel wo
        "attn_out": P(dpa, None, "model"),
        # (B, S, H, hd) attention heads — TP over heads
        "heads": P(dpa, None, "model", None),
        # (B, S, V) logits — TP over vocab
        "logits": P(dpa, None, "model"),
        # (E, C, D/F) MoE expert buffers — EP over experts
        "experts": P("model", None, None),
        # (E*C, D) flat expert buffers around the dispatch scatter/gather
        "experts_flat": P("model", None),
        # (k*T, D) flattened token stream entering/leaving dispatch
        "tokens_flat": P(dpa, None),
        # (B, 1, D) decode activations
        "dec": P(dpa, None, None),
    }


def use_activation_specs(specs: dict | None):
    """Install (or clear, with None) the activation spec table."""
    _STATE.specs = specs


@contextlib.contextmanager
def activation_specs(specs: dict | None):
    prev = getattr(_STATE, "specs", None)
    _STATE.specs = specs
    try:
        yield
    finally:
        _STATE.specs = prev


def ep_mesh():
    """(mesh, axis) for shard_map expert parallelism, if the active spec
    table advertises one (key ``_ep_mesh``); None otherwise."""
    specs = getattr(_STATE, "specs", None)
    if not specs:
        return None
    return specs.get("_ep_mesh")


def constrain(x, name: str):
    specs = getattr(_STATE, "specs", None)
    if not specs or name not in specs or specs[name] is None:
        return x
    spec = specs[name]
    if len(spec) > x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
