"""Microbatch pipeline parallelism over a mesh axis (shard_map + ppermute).

This is the TPU instantiation of the paper's *pipeline structure*: each
stage (a submesh slice along the ``stage`` axis) owns the dedicated
parameters of its layer range, and activations stream stage-to-stage the
way DNNBuilder's column buffers stream between RTL stages — the "column"
is a microbatch, the column buffer is the ppermute edge, and the
fine-grained launch-as-soon-as-first-column-arrives behavior is the
pipeline fill phase (GPipe fill/drain schedule).

``pipeline_apply`` is differentiable (ppermute transposes to the reverse
permutation), so it composes with jax.grad for training.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map


def pipeline_apply(stage_fn, stage_params, x_microbatches, mesh: Mesh,
                   axis: str = "stage"):
    """Run ``stage_fn(params_i, x)`` over pipeline stages.

    stage_params: pytree stacked on a leading stage axis (sharded over
    ``axis``); x_microbatches: (n_micro, mb, ...) activations entering
    stage 0. Returns (n_micro, mb, ...) outputs of the last stage,
    replicated across stages for downstream use.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_microbatches.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params, mbs):
        # params: this stage's slice (leading axis 1); mbs: full microbatch
        # stack (replicated input; only stage 0 consumes it).
        params = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        mb_shape = mbs.shape[1:]
        carry_in = jnp.zeros(mb_shape, mbs.dtype)
        outs = jnp.zeros((n_micro,) + mb_shape, mbs.dtype)

        def tick(state, t):
            carry, outs = state
            # stage 0 ingests microbatch t (during fill+steady phase)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inj = jax.lax.dynamic_index_in_dim(mbs, mb_idx, 0, keepdims=False)
            x = jnp.where(idx == 0, inj, carry)
            y = stage_fn(params, x)
            # the last stage commits microbatch t - (n_stages - 1)
            out_idx = t - (n_stages - 1)
            valid = jnp.logical_and(idx == n_stages - 1, out_idx >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, n_micro - 1), 0),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(y, axis, perm) if perm else y
            return (nxt, outs), None

        (carry, outs), _ = jax.lax.scan(tick, (carry_in, outs),
                                        jnp.arange(ticks))
        # replicate the last stage's outputs to every stage
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, x_microbatches)


def split_microbatches(x, n_micro: int):
    """(B, ...) -> (n_micro, B // n_micro, ...)"""
    b = x.shape[0]
    assert b % n_micro == 0, f"batch {b} % microbatches {n_micro}"
    return x.reshape((n_micro, b // n_micro) + x.shape[1:])
