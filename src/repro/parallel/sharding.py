"""Sharding rules: param + activation PartitionSpecs per arch family.

Layout (GSPMD/pjit, MaxText-style):
* ``model`` mesh axis — tensor parallel (Megatron column/row) + expert
  parallel for MoE + sequence-parallel KV cache for decode.
* ``data`` (and ``pod`` when present) — data parallel AND fully-sharded
  params/optimizer (FSDP/ZeRO-3: weights sharded along their large
  non-TP dim; XLA inserts the per-layer all-gathers).

Rules are looked up by the *name* of each leaf (the last dict key on its
path), with context checks for MoE expert tensors; leading stack dims
(scan layers, zamba groups) are padded with ``None``.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec

FSDP = "__fsdp__"  # placeholder resolved to ("pod","data") or ("data",)


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return fsdp_axes(mesh)


# name -> spec template (trailing dims; leading stack dims padded with None)
_RULES: dict[str, tuple] = {
    # embeddings / heads
    "embed": (FSDP, "model"),
    "lm_head": (FSDP, "model"),
    "pos_dec": (None, "model"),
    "projector": (None, "model"),
    # attention
    "wq": (FSDP, "model"), "wk": (FSDP, "model"), "wv": (FSDP, "model"),
    "wo": ("model", FSDP),
    # dense mlp
    "w_up": (FSDP, "model"), "w_gate": (FSDP, "model"),
    "w_down": ("model", FSDP),
    # moe
    "router": (FSDP, None),
    # mamba2
    "in_proj": (FSDP, "model"), "bc_proj": (FSDP, None),
    "dt_proj": (FSDP, None), "out_proj": ("model", FSDP),
    "conv_w": (None, "model"),
    # xlstm gates
    "wi": (FSDP, None), "wf": (FSDP, None),
    "w_gates": (FSDP, "model"), "r_gates": (FSDP, "model"),
}

# MoE expert tensors (rank 3 before stacking): EP over `model`, FSDP inside.
_MOE_RULES: dict[str, tuple] = {
    "w_up": ("model", FSDP, None),
    "w_gate": ("model", FSDP, None),
    "w_down": ("model", FSDP, None),
}


def _path_names(path) -> list[str]:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            names.append(f"[{e.idx}]")
        else:
            names.append(str(e))
    return names


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """pjit in_shardings demand exact divisibility (GSPMD does not pad
    explicit argument shardings). Drop trailing mesh axes from any dim
    that does not divide — e.g. whisper's vocab 51865 cannot take the
    16-way 'model' axis, and batch-1 decode cannot take the DP axes."""
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(None if d >= len(shape) else entry)
            continue
        axes = tuple(entry) if isinstance(entry, tuple) else (entry,)
        while axes and shape[d] % _prod(mesh.shape[a] for a in axes) != 0:
            axes = axes[:-1]
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def _resolve(template: tuple, mesh: Mesh) -> tuple:
    fs = fsdp_axes(mesh)
    out = []
    for t in template:
        if t == FSDP:
            out.append(fs if len(fs) > 1 else fs[0])
        else:
            out.append(t)
    return tuple(out)


def param_pspecs(params_or_shapes: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching the params pytree."""

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        rank = len(leaf.shape)
        is_moe_expert = ("moe" in names and "shared" not in names
                         and name in _MOE_RULES)
        rule = _MOE_RULES[name] if is_moe_expert else _RULES.get(name)
        if rule is None or rank < len(rule):
            return P()  # scales, biases, scalars -> replicated
        pad = (None,) * (rank - len(rule))
        return fit_spec(P(*pad, *_resolve(rule, mesh)), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_or_shapes)


def cache_pspecs(cfg: ArchConfig, cache_shapes: Any, mesh: Mesh) -> Any:
    """KV/state cache specs. Dense KV caches are *sequence-sharded* along
    `model` (distributed decode attention: partial scores + collective
    softmax), batch along the DP axes. Recurrent states shard heads."""
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        rank = len(leaf.shape)
        if cfg.family == "ssm":
            # per-layer list caches: c (B,H,hd,hd) / n (B,H,hd) / m (B,H) /
            # h (B,D) / c_slstm (B,D)
            return P(*((dpa,) + (None,) * (rank - 1)))
        if cfg.family == "hybrid":
            if name in ("k", "v"):   # (G, B, S, kv, hd)
                return P(None, dpa, "model", None, None)
            if name == "conv":       # (G, per, B, W-1, d_in)
                return P(None, None, dpa, None, "model")
            if name == "ssm":        # (G, per, B, n_h, hd, N)
                return P(None, None, dpa, "model", None, None)
        if name in ("k", "v", "xk", "xv"):  # (L, B, S, kv, hd)
            return P(None, dpa, "model", None, None)
        return P()

    def fitted(path, leaf):
        return fit_spec(leaf_spec(path, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(fitted, cache_shapes)


def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec, specs: dict,
                 mesh: Mesh) -> dict:
    """Input shardings matching launch.specs.input_specs output."""
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_pspecs(cfg, v, mesh)
        elif k == "pos":
            out[k] = fit_spec(P(dpa), v.shape, mesh)
        else:
            out[k] = fit_spec(P(*((dpa,) + (None,) * (len(v.shape) - 1))),
                              v.shape, mesh)
    return out


def named(tree, mesh: Mesh):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), tree,
                        is_leaf=lambda x: isinstance(x, P))
