"""Deterministic fault injection for campaign resilience tests.

A :class:`FaultPlan` maps cell keys to :class:`Fault` specs — what goes
wrong, and on which attempt numbers. The plan is JSON round-trippable so
spawn-based pool workers can load it from a file named by the
``REPRO_FAULTS`` env var (env vars are inherited across ``spawn``, open
objects are not). Five fault kinds cover the failure taxonomy the
resilience layer (:mod:`repro.dse.resilience`) must survive:

``raise-transient``
    Raise :class:`InjectedTransientError` (a ``RuntimeError``) — the
    retryable class: flaky I/O, OOM-adjacent allocation failures.
``raise-permanent``
    Raise :class:`InjectedPermanentError` (a ``ValueError``) — the
    deterministic-model-bug class that retrying cannot fix.
``hang-for``
    Sleep ``hang_s`` seconds before evaluating — exercises the per-cell
    wall-clock timeout (the parent kills and rebuilds the pool).
``crash-process``
    ``os._exit(17)`` — an un-catchable worker death (SIGKILL/OOM
    stand-in); the parent sees ``BrokenProcessPool`` and must rebuild.
``corrupt-record``
    Let the evaluation finish, then return a mangled record (no
    ``objectives``) — exercises the parent-side record validation.

The hook site is :func:`repro.dse.backends.run_cell_by_backend`, which
checks the env var with a single dict lookup and imports this module
only when a plan is armed — disabled, the hot path pays nothing.

Injection is deterministic two ways: explicitly (hand-written
``{cell_key: Fault}`` maps, the usual test style) or seeded
(:meth:`FaultPlan.seeded` hashes ``(seed, cell_key)`` to pick victims at
a given rate — same seed, same victims, independent of iteration order).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from pathlib import Path
from typing import Mapping, Sequence

#: Env var naming a saved plan file; read (one dict lookup) per cell
#: evaluation, so arming a plan needs no plumbing through the pool.
ENV_VAR = "REPRO_FAULTS"

FAULT_KINDS = ("raise-transient", "raise-permanent", "hang-for",
               "crash-process", "corrupt-record")


class InjectedTransientError(RuntimeError):
    """An injected retryable failure (the resilience layer retries it)."""


class InjectedPermanentError(ValueError):
    """An injected permanent failure (quarantined without retry)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One cell's injected failure. ``attempts`` lists the attempt numbers
    (1-based) the fault fires on; empty means EVERY attempt — a fault
    that never goes away."""

    kind: str
    attempts: tuple[int, ...] = (1,)
    hang_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        object.__setattr__(self, "attempts",
                           tuple(int(a) for a in self.attempts))

    def fires_on(self, attempt: int) -> bool:
        return not self.attempts or attempt in self.attempts


@dataclasses.dataclass
class FaultPlan:
    """Cell key -> :class:`Fault`; the unit the harness loads and fires."""

    faults: dict[str, Fault] = dataclasses.field(default_factory=dict)

    @classmethod
    def seeded(cls, cell_keys: Sequence[str], *, seed: int = 0,
               rate: float = 0.25,
               kind: str = "raise-transient",
               attempts: Sequence[int] = (1,),
               hang_s: float = 0.0) -> "FaultPlan":
        """A deterministic plan: each cell key is a victim iff
        ``sha256(seed|key)`` maps below ``rate`` — stable across runs,
        orderings, and worker counts."""
        faults = {}
        for key in cell_keys:
            digest = hashlib.sha256(f"{seed}|{key}".encode()).digest()
            if int.from_bytes(digest[:8], "big") / 2 ** 64 < rate:
                faults[key] = Fault(kind, tuple(attempts), hang_s)
        return cls(faults)

    def fault_for(self, cell_key: str, attempt: int) -> Fault | None:
        f = self.faults.get(cell_key)
        return f if f is not None and f.fires_on(attempt) else None

    def fire_before(self, cell_key: str, attempt: int) -> None:
        """The pre-evaluation fault site: raise / hang / die. A no-op for
        cells without an armed fault (and for ``corrupt-record``, which
        fires after the evaluation)."""
        f = self.fault_for(cell_key, attempt)
        if f is None:
            return
        tag = f"injected[{f.kind}] {cell_key} (attempt {attempt})"
        if f.kind == "raise-transient":
            raise InjectedTransientError(tag)
        if f.kind == "raise-permanent":
            raise InjectedPermanentError(tag)
        if f.kind == "hang-for":
            time.sleep(f.hang_s)
        elif f.kind == "crash-process":
            # sys.stderr may be a worker pipe; nothing to say anyway —
            # the point is dying without cleanup, like SIGKILL/OOM
            os._exit(17)

    def mangle_after(self, cell_key: str, attempt: int, rec: dict) -> dict:
        """The post-evaluation fault site: ``corrupt-record`` returns the
        record without its ``objectives`` (what a half-pickled or
        truncated worker return looks like); everything else passes the
        record through untouched."""
        f = self.fault_for(cell_key, attempt)
        if f is None or f.kind != "corrupt-record":
            return rec
        bad = {k: v for k, v in rec.items() if k != "objectives"}
        bad["injected_corruption"] = True
        return bad

    # -- persistence (spawn workers re-load the plan from disk) -----------

    def as_dict(self) -> dict:
        return {"schema": 1,
                "faults": {k: dataclasses.asdict(f)
                           for k, f in sorted(self.faults.items())}}

    def save(self, path: str | os.PathLike) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.as_dict(), indent=2, sort_keys=True)
                     + "\n")
        return p

    @classmethod
    def from_dict(cls, d: Mapping) -> "FaultPlan":
        return cls({k: Fault(f["kind"], tuple(f.get("attempts", ())),
                             float(f.get("hang_s", 0.0)))
                    for k, f in d.get("faults", {}).items()})

    @classmethod
    def load(cls, path: str | os.PathLike) -> "FaultPlan":
        return cls.from_dict(json.loads(Path(path).read_text()))


def load_plan(src: "str | os.PathLike | Mapping | FaultPlan") -> FaultPlan:
    """Resolve any armed-plan reference — a :class:`FaultPlan`, a plan
    dict, or a path to a saved plan (what the env var carries)."""
    if isinstance(src, FaultPlan):
        return src
    if isinstance(src, Mapping):
        return FaultPlan.from_dict(src)
    return FaultPlan.load(src)
