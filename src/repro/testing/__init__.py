"""Test-only harnesses: deterministic fault injection for campaign
resilience tests (:mod:`repro.testing.faults`).

Nothing in here is imported by production code paths unless explicitly
armed (the ``REPRO_FAULTS`` env var / ``faults=`` kwarg), so shipping
this package costs the hot path nothing.
"""
from .faults import (ENV_VAR, FAULT_KINDS, Fault, FaultPlan,
                     InjectedPermanentError, InjectedTransientError,
                     load_plan)

__all__ = ["ENV_VAR", "FAULT_KINDS", "Fault", "FaultPlan",
           "InjectedPermanentError", "InjectedTransientError", "load_plan"]
