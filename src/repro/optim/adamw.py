"""AdamW with gradient clipping, warmup-cosine schedule, and optional
gradient compression — states are plain pytrees mirroring the params, so
they inherit the params' shardings (ZeRO-style fully-sharded states come
for free from the FSDP param specs).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                    count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)
    lr = schedule(cfg, count.astype(jnp.float32))

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(mu, nu, count), {"grad_norm": gnorm, "lr": lr}
