"""``python -m repro.dse`` == ``python -m repro.dse.campaign``."""
from .cli import main

main()
