"""``python -m repro.dse`` == ``python -m repro.dse.campaign``."""
from .cli import run

raise SystemExit(run())
