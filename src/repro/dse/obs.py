"""Telemetry inspector CLI: summarize, validate, and export the
``repro.obs`` events a ``--trace`` campaign leaves next to its store.

    python -m repro.dse.obs results/dse.jsonl            # text summary
    python -m repro.dse.obs results/dse.jsonl --validate # schema check
    python -m repro.dse.obs results/dse.jsonl --chrome   # trace export
    python -m repro.dse.obs --fixture --out docs/reports/example_health.md

The summary is the plain-text twin of the report's campaign-health
section: per-backend store stats (cells, feasible, incremental-frontier
size — streamed off ``CampaignStore.iter_records``, never materialized),
wall-time breakdown by span, worker utilization, slowest cells, and
counter totals. ``--validate`` checks every event against
the v1 schema and exits non-zero on any problem (the CI docs job runs
it on a freshly traced smoke campaign). ``--chrome`` writes the
Chrome trace-event export (load in Perfetto / ``chrome://tracing``).
``--fixture`` renders the deterministic example health report that is
committed at ``docs/reports/example_health.md`` and drift-checked by
the test suite.

Events are looked up as the merged ``<store>.events.jsonl`` first,
falling back to re-merging the ``<store>.events/`` sidecar directory —
so the inspector also works on a campaign that was killed before its
parent merged the sidecars.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.obs import (campaign_wall, chrome_path_for, chrome_trace,
                       counter_totals, events_dir_for, events_path_for,
                       load_events, merge_events, slowest_spans, span_totals,
                       validate_events, worker_utilization)


def events_for_store(store_path: str) -> list[dict]:
    """Merged events for a store: the ``.events.jsonl`` file if the
    campaign parent wrote it, else a fresh merge of the sidecar dir
    (covers runs killed before the final merge). Empty list if the
    campaign was never traced."""
    merged = events_path_for(store_path)
    if merged.exists():
        return load_events(merged)
    d = events_dir_for(store_path)
    if d.is_dir():
        return merge_events(d)
    return []


def example_health_md() -> str:
    """The deterministic example health report (fixture records +
    fixture events through the real renderer). Committed at
    ``docs/reports/example_health.md``; a test re-renders and diffs it,
    so the committed doc can never drift from the code."""
    from .report import fixture_events, fixture_records, health_section
    lines = [
        "# Example campaign health report",
        "",
        "Deterministic output of `python -m repro.dse.obs --fixture`: the",
        "campaign-health section a traced run (`--trace`) adds to",
        "`python -m repro.dse.report <store>`, rendered from the built-in",
        "fixture store and a hand-written event stream. Regenerate with:",
        "",
        "    python -m repro.dse.obs --fixture --out "
        "docs/reports/example_health.md",
        "",
    ] + health_section(fixture_records(), fixture_events())
    return "\n".join(lines).rstrip() + "\n"


def print_store_stats(store) -> None:
    """Per-backend store stats in one streaming pass per backend: cell
    and feasible counts plus the incremental Pareto frontier size —
    ``iter_records()`` + :class:`repro.dse.frontier.FrontierIndex`, so a
    100k-record store summarizes without a record list in memory."""
    from .backends import BACKENDS, get_backend
    from .frontier import FrontierIndex
    layout = "sharded" if store.sharded else "v1"
    print(f"\n-- store ({layout}, {len(store)} cells) --")
    for bk in store.backends():
        n = feas = 0
        be = get_backend(bk) if bk in BACKENDS else None
        fi = FrontierIndex()
        for rec in store.iter_records(bk):
            n += 1
            if rec.get("objectives", {}).get("feasible"):
                feas += 1
                if be is not None:
                    fi.insert(rec["cell_key"],
                              be.canonical(rec["objectives"]))
        front = fi.front_size() if be is not None else "?"
        print(f"{bk:<8} {n:>6} cells  {feas:>6} feasible  "
              f"frontier {front}")


def print_summary(events: list[dict], top: int) -> None:
    wall = campaign_wall(events)
    print(f"{len(events)} events, campaign wall {wall:.2f}s")

    print("\n-- wall-time breakdown --")
    print(f"{'span':<16} {'count':>5} {'total s':>9} {'max s':>9} "
          f"{'% wall':>7}")
    for name, st in sorted(span_totals(events).items(),
                           key=lambda kv: -kv[1].total_s):
        pct = f"{st.total_s / wall:.0%}" if wall > 0 else "—"
        print(f"{name:<16} {st.count:>5} {st.total_s:>9.3f} "
              f"{st.max_s:>9.3f} {pct:>7}")

    util = worker_utilization(events)
    if util:
        print("\n-- worker utilization (cell.eval busy / campaign wall) --")
        for proc, row in sorted(util.items()):
            print(f"{proc:<16} {row['cells']:>3} cells "
                  f"{row['busy_s']:>9.3f}s busy  {row['util']:>5.0%}")

    slow = slowest_spans(events, k=top)
    if slow:
        print(f"\n-- slowest cells (top {len(slow)} by cell.eval) --")
        for e in slow:
            print(f"{e.get('dur', 0.0):>9.3f}s  "
                  f"{e.get('attrs', {}).get('cell', '?')}  "
                  f"[{e.get('proc', '?')}]")

    counts = counter_totals(events)
    if counts:
        print("\n-- counters --")
        for name, v in sorted(counts.items()):
            print(f"{name:<24} {v:g}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.obs",
        description="Inspect the telemetry of a traced DSE campaign: "
                    "summarize spans/counters, validate events against "
                    "the schema, export a Chrome trace.")
    ap.add_argument("store", nargs="?", default=None,
                    help="campaign JSONL store whose telemetry to read "
                         "(<store>.events.jsonl or <store>.events/)")
    ap.add_argument("--validate", action="store_true",
                    help="check every event against the v1 schema; "
                         "non-zero exit on any problem")
    ap.add_argument("--chrome", nargs="?", const="", default=None,
                    metavar="JSON",
                    help="write the Chrome trace-event export (default "
                         "path: <store>.trace.json)")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the slowest-cell table")
    ap.add_argument("--fixture", action="store_true",
                    help="render the deterministic example health report "
                         "instead of reading a store")
    ap.add_argument("--out", default=None, metavar="MD",
                    help="with --fixture: write the Markdown here instead "
                         "of stdout")
    args = ap.parse_args(argv)

    if args.fixture:
        md = example_health_md()
        if args.out:
            out = Path(args.out)
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(md)
            print(f"example health report -> {out} ({len(md)} chars)")
        else:
            print(md, end="")
        return 0

    if not args.store:
        ap.error("a store path is required (or use --fixture)")
    events = events_for_store(args.store)
    if not events:
        ap.error(f"no telemetry for {args.store}: neither "
                 f"{events_path_for(args.store)} nor a "
                 f"{events_dir_for(args.store)}/ sidecar dir — run the "
                 f"campaign with --trace")

    rc = 0
    if args.validate:
        problems = validate_events(events)
        for p in problems:
            print(f"INVALID: {p}")
        print(f"validate: {len(events)} events, {len(problems)} problem(s)")
        rc = 1 if problems else 0

    from .store import open_store, sharded_dir_for
    store_p = Path(args.store)
    if store_p.exists() or sharded_dir_for(store_p).is_dir():
        print_store_stats(open_store(args.store))

    print_summary(events, args.top)

    if args.chrome is not None:
        out = Path(args.chrome) if args.chrome else \
            chrome_path_for(args.store)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(chrome_trace(events)))
        print(f"\nchrome trace -> {out}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
