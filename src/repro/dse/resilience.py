"""Fault-tolerant campaign execution: retries, timeouts, quarantine.

:func:`repro.dse.campaign.run_campaign` used to call ``fut.result()``
bare — one bad cell threw away every in-flight cell and left no
diagnosis behind. This module is the execution layer that makes partial
progress plus an honest failure report the worst case:

* :class:`RetryPolicy` — max attempts, deterministic seeded exponential
  backoff + jitter (``backoff(cell_key, attempt)`` hashes the cell key,
  so delays are reproducible across runs and worker counts), a per-cell
  wall-clock timeout, and the transient/permanent failure taxonomy
  (:meth:`RetryPolicy.retryable`).
* :func:`execute_cell` — one cell through the policy: retry transient
  failures with backoff, validate the returned record
  (:func:`validate_record`), stamp retried successes with a
  ``resilience`` block, and quarantine a cell that exhausts its attempts
  as a schema-versioned ``status: "failed"`` record
  (:func:`quarantine_record`) that flows through the normal store path.
  This is the single-worker execution primitive; the pool runner applies
  the same accounting future-by-future.
* :func:`run_resilient_pool` — the process-pool loop: deadline-tracked
  futures, ``BrokenProcessPool`` detection with automatic pool rebuild
  and resubmission of the lost in-flight cells, per-cell timeouts
  enforced by killing the (unkillable-from-the-API) running worker and
  rebuilding, and a cooperative stop flag for signal-driven shutdown.
* :func:`interrupt_scope` — SIGINT/SIGTERM set a stop flag (second
  signal raises ``KeyboardInterrupt``); the campaign drains, flushes
  the store and telemetry sidecars, and returns a partial report.

Quarantine semantics: a failed record carries the exception type, a
traceback tail, the attempt count, and per-attempt durations; it resumes
as "done" (same search config) so a restarted campaign does not bang its
head on a permanent failure — ``retry_failed=True`` (CLI
``--retry-failed``) opts quarantined cells back in. Failed records are
never silently mixed into frontiers, reports, or placement: every
consumer checks :func:`repro.dse.store.record_status`.

Obs counters: ``cells.retried`` (one per retry), ``cells.failed`` (one
per quarantine), ``pool.rebuilds`` (one per pool replacement) — the
report's "Failures & retries" table reads them back.

Everything here is deterministic-testable without flaky sleeps via the
fault-injection harness in :mod:`repro.testing.faults`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import heapq
import itertools
import signal
import threading
import time
import traceback
from concurrent.futures import FIRST_COMPLETED, wait
from concurrent.futures.process import BrokenProcessPool
from typing import Callable, Mapping, Sequence

from repro.obs import NULL

from .store import SCHEMA_VERSION, record_status

#: Version of the quarantine-record layout (the ``quarantine_schema``
#: field on ``status: "failed"`` records).
QUARANTINE_SCHEMA_VERSION = 1

#: Exception classes retrying cannot fix: the models are deterministic,
#: so a bad-input/bad-config error reproduces identically on attempt 2.
PERMANENT_ERRORS = (ValueError, KeyError, TypeError, IndexError,
                    AttributeError, ZeroDivisionError, AssertionError)

#: Characters of formatted traceback kept on a quarantine record — the
#: tail is where the raising frame and message live.
TRACEBACK_TAIL_CHARS = 2000


class CellTimeout(Exception):
    """A cell exceeded the policy's per-attempt wall-clock deadline
    (always retryable: stragglers are load, not logic)."""


class WorkerCrash(Exception):
    """A pool worker died mid-cell (``BrokenProcessPool``: SIGKILL, OOM,
    ``os._exit``). The executor API cannot name the culprit cell, so
    every in-flight cell is charged one crash attempt and resubmitted —
    with ``max_attempts >= 2`` no cell is lost to a single crash."""


class CorruptRecord(RuntimeError):
    """A worker returned something that is not a plausible record for
    the submitted cell (retryable — transport/serialization damage)."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How hard a campaign fights for each cell.

    ``backoff(cell_key, attempt)`` is exponential
    (``backoff_s * backoff_factor**(attempt-1)``) with a deterministic
    jitter in ``±jitter_frac`` derived from
    ``sha256(seed|cell_key|attempt)`` — reproducible, yet de-synchronized
    across cells so retry herds do not stampede together.

    ``cell_timeout_s`` is the per-attempt wall-clock deadline, enforced
    on the pool path by killing the worker processes and rebuilding the
    pool (``concurrent.futures`` cannot cancel running work); the
    single-worker path runs attempts inline and cannot preempt them, so
    the timeout applies to pool campaigns only.
    """

    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    jitter_frac: float = 0.1
    cell_timeout_s: float | None = None
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.cell_timeout_s is not None and self.cell_timeout_s <= 0:
            raise ValueError(f"cell_timeout_s must be positive or None, "
                             f"got {self.cell_timeout_s}")

    def backoff(self, cell_key: str, attempt: int) -> float:
        """Seconds to wait before re-running ``cell_key`` after failed
        attempt number ``attempt`` (1-based). Deterministic."""
        base = self.backoff_s * self.backoff_factor ** (attempt - 1)
        digest = hashlib.sha256(
            f"{self.seed}|{cell_key}|{attempt}".encode()).digest()
        unit = int.from_bytes(digest[:8], "big") / 2 ** 64   # [0, 1)
        return base * (1.0 + self.jitter_frac * (2.0 * unit - 1.0))

    def retryable(self, exc: BaseException) -> bool:
        """The failure taxonomy: timeouts, crashes, corrupt returns, and
        generic runtime errors are transient (retry); the deterministic
        model-error classes (:data:`PERMANENT_ERRORS`) are permanent —
        the same inputs will fail the same way."""
        if isinstance(exc, (CellTimeout, WorkerCrash, CorruptRecord,
                            BrokenProcessPool)):
            return True
        return not isinstance(exc, PERMANENT_ERRORS)


def attempt_outcome(exc: BaseException) -> str:
    """Attempt-log label for a failure: ``timeout`` / ``crash`` /
    ``corrupt`` / ``error``."""
    if isinstance(exc, CellTimeout):
        return "timeout"
    if isinstance(exc, (WorkerCrash, BrokenProcessPool)):
        return "crash"
    if isinstance(exc, CorruptRecord):
        return "corrupt"
    return "error"


def validate_record(cell, rec) -> None:
    """Raise :class:`CorruptRecord` unless ``rec`` is a plausible store
    record for ``cell`` — the parent-side guard between a worker's
    return value and ``store.put`` (a crashed serializer or an injected
    ``corrupt-record`` fault fails here and is retried)."""
    if not isinstance(rec, dict):
        raise CorruptRecord(f"cell {cell.key}: worker returned "
                            f"{type(rec).__name__}, not a record dict")
    if rec.get("cell_key") != cell.key:
        raise CorruptRecord(f"cell {cell.key}: worker returned a record "
                            f"for {rec.get('cell_key')!r}")
    if not isinstance(rec.get("objectives"), Mapping):
        raise CorruptRecord(f"cell {cell.key}: record has no objectives "
                            f"dict (corrupt worker return)")


def _tb_tail(exc: BaseException, limit: int = TRACEBACK_TAIL_CHARS) -> str:
    text = "".join(traceback.format_exception(type(exc), exc,
                                              exc.__traceback__))
    return text[-limit:]


def quarantine_record(cell, *, search: Mapping | None,
                      error: BaseException,
                      attempt_log: Sequence[Mapping],
                      backend: str = "fpga") -> dict:
    """The schema-versioned ``status: "failed"`` store record for a cell
    that exhausted its attempts. Carries enough to diagnose without the
    original logs (exception type, traceback tail, per-attempt outcomes
    and durations) and the search config, so resume treats it as "done
    under these settings" until ``--retry-failed`` or a config change.
    ``evaluations: 0`` keeps campaign accounting uniform. The ``backend``
    field follows the success-record convention (absent for fpga)."""
    rec = {
        "schema": SCHEMA_VERSION,
        "status": "failed",
        "quarantine_schema": QUARANTINE_SCHEMA_VERSION,
        "cell_key": cell.key,
        "cell": dataclasses.asdict(cell),
        "search": dict(search) if search is not None else None,
        "error_type": type(error).__name__,
        "error": _tb_tail(error),
        "attempts": len(attempt_log),
        "attempt_log": [dict(a) for a in attempt_log],
        "evaluations": 0,
    }
    if backend != "fpga":
        rec["backend"] = backend
    return rec


def stamp_resilience(rec: dict, attempt_log: Sequence[Mapping]) -> dict:
    """Attach retry metadata to a success record that needed more than
    one attempt. First-attempt successes are NOT stamped — fault-free
    campaigns stay byte-identical to pre-resilience stores."""
    out = dict(rec)
    out["resilience"] = {
        "attempts": len(attempt_log),
        "retries": sum(1 for a in attempt_log if a["outcome"] != "ok"),
        "attempt_log": [dict(a) for a in attempt_log],
    }
    return out


@dataclasses.dataclass
class CellOutcome:
    """What happened to one cell: a record to store (success or
    quarantine), or nothing (``interrupted`` — the cell stays absent
    from the store and a resumed campaign re-runs it)."""

    cell: object
    record: dict | None
    attempt_log: list[dict]
    error: BaseException | None = None
    interrupted: bool = False

    @property
    def ok(self) -> bool:
        return self.record is not None and record_status(self.record) == "ok"

    @property
    def failed(self) -> bool:
        return (self.record is not None
                and record_status(self.record) != "ok")

    @property
    def retried(self) -> bool:
        return any(a["outcome"] != "ok" for a in self.attempt_log)


def _interruptible_sleep(delay: float, stop: threading.Event | None,
                         sleep: Callable[[float], None]) -> None:
    if stop is None:
        if delay > 0:
            sleep(delay)
        return
    # stop.wait returns early when the flag is set — backoff never
    # delays a requested shutdown
    if delay > 0:
        stop.wait(delay)


def execute_cell(cell, attempt_fn: Callable[[object, int], dict],
                 policy: RetryPolicy | None = None, *,
                 search: Mapping | None = None, backend: str = "fpga",
                 stop: threading.Event | None = None, tracer=NULL,
                 sleep: Callable[[float], None] = time.sleep) -> CellOutcome:
    """Run one cell under the policy, inline (the single-worker path).

    ``attempt_fn(cell, attempt)`` performs attempt number ``attempt``
    (1-based) and returns a store record. Transient failures retry with
    deterministic backoff; permanent failures and exhausted budgets
    quarantine. ``stop`` aborts between attempts (the cell is then
    ``interrupted`` — nothing is stored, resume re-runs it).

    The per-attempt wall-clock timeout is a pool-path feature (workers
    can be killed); inline attempts cannot be preempted, so
    ``policy.cell_timeout_s`` is not enforced here.
    """
    policy = policy or RetryPolicy()
    attempt_log: list[dict] = []
    last_exc: BaseException | None = None
    for attempt in range(1, policy.max_attempts + 1):
        if stop is not None and stop.is_set():
            return CellOutcome(cell, None, attempt_log, error=last_exc,
                               interrupted=True)
        t0 = time.perf_counter()
        try:
            rec = attempt_fn(cell, attempt)
            validate_record(cell, rec)
        except Exception as exc:
            dur = time.perf_counter() - t0
            attempt_log.append({"attempt": attempt,
                                "outcome": attempt_outcome(exc),
                                "duration_s": round(dur, 4),
                                "error_type": type(exc).__name__})
            last_exc = exc
            if not policy.retryable(exc) or attempt == policy.max_attempts:
                break
            tracer.count("cells.retried", cell=cell.key,
                         error=type(exc).__name__)
            _interruptible_sleep(policy.backoff(cell.key, attempt), stop,
                                 sleep)
        else:
            dur = time.perf_counter() - t0
            attempt_log.append({"attempt": attempt, "outcome": "ok",
                                "duration_s": round(dur, 4),
                                "error_type": None})
            if attempt > 1:
                rec = stamp_resilience(rec, attempt_log)
            return CellOutcome(cell, rec, attempt_log)
    tracer.count("cells.failed", cell=cell.key,
                 error=type(last_exc).__name__)
    qrec = quarantine_record(cell, search=search, error=last_exc,
                             attempt_log=attempt_log, backend=backend)
    return CellOutcome(cell, qrec, attempt_log, error=last_exc)


# ---------------------------------------------------------------------------
# the resilient pool loop
# ---------------------------------------------------------------------------

#: Ceiling on one ``wait()`` tick: keeps the loop responsive to the stop
#: flag and to newly-eligible (backed-off) resubmissions.
_TICK_S = 0.2


@dataclasses.dataclass
class PoolStats:
    rebuilds: int = 0
    interrupted: bool = False


def _kill_pool(pool) -> None:
    """Tear a pool down NOW: cancel queued work, terminate workers
    (running cells cannot be cancelled through the API — killing the
    process is the only preemption there is)."""
    procs = list((getattr(pool, "_processes", None) or {}).values())
    pool.shutdown(wait=False, cancel_futures=True)
    for p in procs:  # snapshot first: shutdown may null _processes
        with contextlib.suppress(Exception):
            p.terminate()


def run_resilient_pool(todo: Sequence, *,
                       make_pool: Callable[[], object],
                       submit: Callable[[object, object, int], object],
                       on_outcome: Callable[[CellOutcome], None],
                       policy: RetryPolicy | None = None,
                       search: Mapping | None = None,
                       backend: str = "fpga",
                       stop: threading.Event | None = None,
                       tracer=NULL, workers: int | None = None,
                       clock: Callable[[], float] = time.monotonic,
                       ) -> PoolStats:
    """Fan ``todo`` over a process pool with retries, timeouts, crash
    recovery, and cooperative shutdown.

    ``submit(pool, cell, attempt)`` submits one attempt and returns its
    future; ``on_outcome`` receives each cell's :class:`CellOutcome` in
    completion order (success or quarantine — interrupted cells are not
    reported, they simply stay absent from the store).

    Failure handling, per future:

    * exception -> one failed attempt; transient + budget left -> the
      cell re-enters the submit queue after its deterministic backoff.
    * ``BrokenProcessPool`` -> EVERY in-flight cell is charged one
      ``crash`` attempt (the executor cannot name the culprit), the pool
      is rebuilt (``pool.rebuilds`` counter), and survivors resubmit.
    * deadline exceeded (``policy.cell_timeout_s``) -> the overdue cells
      are charged a ``timeout`` attempt; the pool is killed and rebuilt
      (running work cannot be cancelled), and the innocent in-flight
      cells resubmit WITHOUT being charged an attempt.
    * ``stop`` set -> pending futures are cancelled, workers killed,
      and the loop returns with ``interrupted=True``.
    """
    policy = policy or RetryPolicy()
    stats = PoolStats()
    tie = itertools.count()
    # (eligible-time, tiebreak, cell) — cells waiting to be (re)submitted
    ready: list[tuple[float, int, object]] = [(0.0, next(tie), c)
                                              for c in todo]
    heapq.heapify(ready)
    state = {c.key: {"attempt": 0, "log": [], "t0": 0.0} for c in todo}
    inflight: dict[object, object] = {}       # future -> cell
    deadlines: dict[object, float] = {}       # future -> monotonic deadline
    remaining = len(todo)
    pool = make_pool()

    def fail_attempt(cell, exc: BaseException, dur: float) -> None:
        nonlocal remaining
        st = state[cell.key]
        st["log"].append({"attempt": st["attempt"],
                          "outcome": attempt_outcome(exc),
                          "duration_s": round(dur, 4),
                          "error_type": type(exc).__name__})
        if policy.retryable(exc) and st["attempt"] < policy.max_attempts:
            tracer.count("cells.retried", cell=cell.key,
                         error=type(exc).__name__)
            eligible = clock() + policy.backoff(cell.key, st["attempt"])
            heapq.heappush(ready, (eligible, next(tie), cell))
            return
        tracer.count("cells.failed", cell=cell.key,
                     error=type(exc).__name__)
        qrec = quarantine_record(cell, search=search, error=exc,
                                 attempt_log=st["log"], backend=backend)
        remaining -= 1
        on_outcome(CellOutcome(cell, qrec, st["log"], error=exc))

    def settle(fut, cell, *, now: float) -> bool:
        """Resolve one completed future; True when the pool broke."""
        nonlocal remaining
        st = state[cell.key]
        dur = now - st["t0"]
        exc = fut.exception()
        if isinstance(exc, BrokenProcessPool):
            fail_attempt(cell, WorkerCrash(
                f"worker died while {len(inflight) + 1} cell(s) were "
                f"in flight ({exc})"), dur)
            return True
        if exc is not None:
            fail_attempt(cell, exc, dur)
            return False
        rec = fut.result()
        try:
            validate_record(cell, rec)
        except CorruptRecord as bad:
            fail_attempt(cell, bad, dur)
            return False
        st["log"].append({"attempt": st["attempt"], "outcome": "ok",
                          "duration_s": round(dur, 4), "error_type": None})
        if st["attempt"] > 1:
            rec = stamp_resilience(rec, st["log"])
        remaining -= 1
        on_outcome(CellOutcome(cell, rec, st["log"]))
        return False

    def rebuild() -> None:
        nonlocal pool
        _kill_pool(pool)
        inflight.clear()
        deadlines.clear()
        pool = make_pool()
        stats.rebuilds += 1
        tracer.count("pool.rebuilds")

    try:
        while remaining > 0:
            if stop is not None and stop.is_set():
                stats.interrupted = True
                return stats
            now = clock()
            submitted = False
            while ready and ready[0][0] <= now:
                _, _, cell = heapq.heappop(ready)
                st = state[cell.key]
                st["attempt"] += 1
                st["t0"] = clock()
                fut = submit(pool, cell, st["attempt"])
                inflight[fut] = cell
                submitted = True
                if policy.cell_timeout_s is not None:
                    deadlines[fut] = st["t0"] + policy.cell_timeout_s
            if submitted:
                tracer.gauge("pool.inflight", len(inflight),
                             workers=workers)

            if not inflight:
                # everything is backing off; sleep toward the nearest
                # eligible time (capped for stop responsiveness)
                _interruptible_sleep(
                    min(_TICK_S, max(0.0, ready[0][0] - clock()))
                    if ready else _TICK_S, stop, time.sleep)
                continue

            tick = _TICK_S
            if deadlines:
                tick = min(tick, max(0.0, min(deadlines.values()) - now))
            if ready:
                tick = min(tick, max(0.0, ready[0][0] - now))
            done, _ = wait(list(inflight), timeout=tick,
                           return_when=FIRST_COMPLETED)

            now = clock()
            broken = False
            for fut in done:
                cell = inflight.pop(fut)
                deadlines.pop(fut, None)
                broken = settle(fut, cell, now=now) or broken
            if done:
                tracer.gauge("pool.inflight", len(inflight),
                             workers=workers)
            if broken:
                # the pool is dead: every still-inflight future is (or is
                # about to be) BrokenProcessPool — drain them all as
                # crashes, then rebuild once
                settled, _ = wait(list(inflight), timeout=5.0)
                for fut in settled:
                    cell = inflight.pop(fut)
                    deadlines.pop(fut, None)
                    settle(fut, cell, now=now)
                for fut, cell in list(inflight.items()):
                    st = state[cell.key]
                    fail_attempt(cell, WorkerCrash(
                        "worker died; future never settled"),
                        now - st["t0"])
                rebuild()
                continue

            overdue = [f for f, dl in deadlines.items() if dl <= now]
            if overdue:
                # kill-and-rebuild is the only preemption; the innocent
                # in-flight cells are requeued without an attempt charge
                for fut, cell in list(inflight.items()):
                    st = state[cell.key]
                    if fut in overdue:
                        fail_attempt(cell, CellTimeout(
                            f"cell exceeded --cell-timeout "
                            f"{policy.cell_timeout_s}s "
                            f"(attempt {st['attempt']})"), now - st["t0"])
                    else:
                        st["attempt"] -= 1
                        heapq.heappush(ready, (now, next(tie), cell))
                rebuild()
    finally:
        if stats.interrupted or remaining > 0:
            _kill_pool(pool)
        else:
            pool.shutdown(wait=True)
    return stats


# ---------------------------------------------------------------------------
# signal handling
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def interrupt_scope(install: bool = True):
    """Yield a ``threading.Event`` that SIGINT/SIGTERM set.

    The first signal requests a graceful stop (drain in-flight work,
    flush the store, return a partial report); a second SIGINT raises
    ``KeyboardInterrupt`` — the user insists. Previous handlers are
    restored on exit. Outside the main thread (or with
    ``install=False``) no handlers are touched and the event is simply
    never signal-set."""
    stop = threading.Event()
    if not install or threading.current_thread() is not \
            threading.main_thread():
        yield stop
        return
    previous = {}

    def _handler(signum, frame):
        if stop.is_set() and signum == signal.SIGINT:
            raise KeyboardInterrupt
        stop.set()

    for sig in (signal.SIGINT, signal.SIGTERM):
        with contextlib.suppress(ValueError, OSError):
            previous[sig] = signal.signal(sig, _handler)
    try:
        yield stop
    finally:
        for sig, handler in previous.items():
            with contextlib.suppress(ValueError, OSError):
                signal.signal(sig, handler)
