"""Pareto dominance and non-dominated sorting (NSGA-II style, O(n^2)).

All functions take vectors in *canonical maximization form* (see
:meth:`repro.dse.objectives.Objectives.canonical`): every component is
better when larger. Campaign sizes are hundreds to a few thousand designs,
so the simple fast-non-dominated-sort is plenty.
"""
from __future__ import annotations

from typing import Sequence, TypeVar

T = TypeVar("T")

Vector = Sequence[float]


def dominates(a: Vector, b: Vector) -> bool:
    """True iff ``a`` is >= ``b`` everywhere and > somewhere."""
    if len(a) != len(b):
        raise ValueError(f"objective arity mismatch: {len(a)} vs {len(b)}")
    better = False
    for ai, bi in zip(a, b):
        if ai < bi:
            return False
        if ai > bi:
            better = True
    return better


def non_dominated(vectors: Sequence[Vector]) -> list[int]:
    """Indices of the first Pareto front, in input order. Duplicate vectors
    all survive (none strictly dominates its copies)."""
    out = []
    for i, v in enumerate(vectors):
        if not any(dominates(u, v) for j, u in enumerate(vectors) if j != i):
            out.append(i)
    return out


def nondominated_sort(vectors: Sequence[Vector]) -> list[list[int]]:
    """Successive Pareto fronts: front 0 is non-dominated, front k is
    non-dominated once fronts < k are removed. Every index appears in
    exactly one front."""
    remaining = list(range(len(vectors)))
    fronts: list[list[int]] = []
    while remaining:
        sub = [vectors[i] for i in remaining]
        keep = set(non_dominated(sub))
        front = [remaining[j] for j in range(len(remaining)) if j in keep]
        fronts.append(front)
        remaining = [remaining[j] for j in range(len(remaining))
                     if j not in keep]
    return fronts


def pareto_front(items: Sequence[T], vectors: Sequence[Vector]) -> list[T]:
    """The items whose vectors sit on the first front."""
    if len(items) != len(vectors):
        raise ValueError("items/vectors length mismatch")
    return [items[i] for i in non_dominated(vectors)]
