"""Pareto dominance, non-dominated sorting, and crowding-distance
diversity (NSGA-II style, O(n^2)).

All functions take vectors in *canonical maximization form* (see
:meth:`repro.dse.objectives.Objectives.canonical`): every component is
better when larger. Campaign sizes are hundreds to a few thousand designs,
so the simple fast-non-dominated-sort is plenty.

:func:`crowding_distance` and :func:`select_diverse` implement NSGA-II's
diversity preservation (Deb et al., 2002): when a frontier must be
truncated to *k* designs, keep the ones whose objective-space neighbors
are farthest apart, so the survivors SPREAD across the trade-off surface
instead of clumping around one region of it.
"""
from __future__ import annotations

import math
from typing import Sequence, TypeVar

import numpy as np

T = TypeVar("T")

Vector = Sequence[float]


def dominates(a: Vector, b: Vector) -> bool:
    """True iff ``a`` is >= ``b`` everywhere and > somewhere."""
    if len(a) != len(b):
        raise ValueError(f"objective arity mismatch: {len(a)} vs {len(b)}")
    better = False
    for ai, bi in zip(a, b):
        if ai < bi:
            return False
        if ai > bi:
            better = True
    return better


def dominance_split(mat: np.ndarray, v: np.ndarray,
                    ) -> tuple[bool, np.ndarray]:
    """One vector against a set, vectorized: ``(dominated, dominates)``
    where ``dominated`` says some row of ``mat`` strictly dominates ``v``
    and ``dominates`` masks the rows ``v`` strictly dominates. The
    incremental frontier (:mod:`repro.dse.frontier`) calls this once per
    insert, so it is the O(front) inner loop of million-record streaming
    — numpy, not the scalar :func:`dominates`."""
    if mat.size == 0:
        return False, np.zeros(0, dtype=bool)
    ge = mat >= v
    gt = mat > v
    dominated = bool((ge.all(axis=1) & gt.any(axis=1)).any())
    dominates_mask = (~gt).all(axis=1) & (~ge).any(axis=1)
    return dominated, dominates_mask


def non_dominated(vectors: Sequence[Vector]) -> list[int]:
    """Indices of the first Pareto front, in input order. Duplicate vectors
    all survive (none strictly dominates its copies)."""
    out = []
    for i, v in enumerate(vectors):
        if not any(dominates(u, v) for j, u in enumerate(vectors) if j != i):
            out.append(i)
    return out


def nondominated_sort(vectors: Sequence[Vector]) -> list[list[int]]:
    """Successive Pareto fronts: front 0 is non-dominated, front k is
    non-dominated once fronts < k are removed. Every index appears in
    exactly one front."""
    remaining = list(range(len(vectors)))
    fronts: list[list[int]] = []
    while remaining:
        sub = [vectors[i] for i in remaining]
        keep = set(non_dominated(sub))
        front = [remaining[j] for j in range(len(remaining)) if j in keep]
        fronts.append(front)
        remaining = [remaining[j] for j in range(len(remaining))
                     if j not in keep]
    return fronts


def pareto_front(items: Sequence[T], vectors: Sequence[Vector]) -> list[T]:
    """The items whose vectors sit on the first front."""
    if len(items) != len(vectors):
        raise ValueError("items/vectors length mismatch")
    return [items[i] for i in non_dominated(vectors)]


def crowding_distance(vectors: Sequence[Vector]) -> list[float]:
    """NSGA-II crowding distance of each vector within its set.

    Per objective, vectors are sorted and each interior one is credited
    the (normalized) gap between its two neighbors; boundary vectors get
    ``inf`` so extremes always survive truncation. Larger distance ==
    lonelier == more diverse. Degenerate objectives (all values equal)
    contribute nothing.
    """
    n = len(vectors)
    if n == 0:
        return []
    if n == 1:
        return [math.inf]
    dist = [0.0] * n
    for d in range(len(vectors[0])):
        order = sorted(range(n), key=lambda i: vectors[i][d])
        lo, hi = vectors[order[0]][d], vectors[order[-1]][d]
        if hi == lo:
            continue  # degenerate objective: no extremes, no gaps
        dist[order[0]] = dist[order[-1]] = math.inf
        for j in range(1, n - 1):
            if dist[order[j]] != math.inf:
                dist[order[j]] += ((vectors[order[j + 1]][d]
                                    - vectors[order[j - 1]][d]) / (hi - lo))
    return dist


def diverse_front(vectors: Sequence[Vector],
                  k: int | None = None) -> list[int]:
    """Indices of the FIRST front only, ordered by crowding distance
    (extremes first, clumps thinned), optionally truncated to ``k``.

    This is the one frontier read-off every consumer wants — per-backend
    report tables, the CLI dump, and the cross-backend frontier over the
    normalized objective schema — as opposed to :func:`select_diverse`,
    which tops up from later fronts to fill ``k``.
    """
    idx = non_dominated(vectors)
    sub = [vectors[i] for i in idx]
    order = select_diverse(sub, len(sub) if k is None or k <= 0 else k)
    return [idx[j] for j in order]


def select_diverse(vectors: Sequence[Vector], k: int) -> list[int]:
    """Up to ``k`` indices by NSGA-II ranking: whole fronts in order, the
    last partially-admitted front truncated to its most-spread members
    (rank ties broken by crowding distance, then by input order for
    determinism). With ``k >= len(vectors)`` this is a diversity-sorted
    permutation of everything."""
    if k <= 0:
        return []
    out: list[int] = []
    for front in nondominated_sort(vectors):
        cd = crowding_distance([vectors[i] for i in front])
        by_spread = sorted(range(len(front)), key=lambda j: (-cd[j], front[j]))
        for j in by_spread:
            if len(out) >= k:
                return out
            out.append(front[j])
    return out
