"""Markdown campaign reports from JSONL stores (+ optional bench JSON).

The ROADMAP's "perf-trajectory dashboard": turn any
:class:`repro.dse.store.CampaignStore` — FPGA, TPU, or a mixed store —
into a human-readable Markdown report under ``docs/reports/``.

Rendering is *streaming*: every section is built by per-backend /
cross-backend accumulators (record counts, running per-workload winners,
and an incremental Pareto archive —
:class:`repro.dse.frontier.FrontierIndex`) fed one record at a time, so
a 100k-record store renders in ONE pass over ``iter_records()`` with
O(frontier) memory instead of materializing and re-sorting the full
record list. Sections:

* per-backend **Pareto frontier tables**, ordered by NSGA-II rank +
  crowding distance so a truncated read-off still spreads across the
  trade-off surface (extremes first, clumps thinned);
* **per-workload winners** (best scalarized design per net@input / per
  arch/shape), following HybridDNN's practice of reporting the
  efficiency/latency trade-off per workload rather than a single scalar;
* **objective trade-off summaries** — for each objective, the frontier
  design that is best at it and what that choice costs on the others;
* a **cross-backend frontier** whenever a store mixes device families:
  every record re-expressed in the normalized objective schema
  (delivered TFLOP/s, per watt, per dollar-proxy, per peak TFLOP) and
  Pareto-sorted into ONE frontier, plus per-backend champions;
* an optional **benchmark appendix** from ``benchmarks/run.py --json``
  output, so paper-figure reproductions land in the same document.

A second mode, ``--compare``, takes TWO OR MORE stores (e.g. the same
campaign re-run over time, or sibling backends' campaigns) and renders the
*trajectory* between them: per-workload winner deltas, best-normalized-
objective trajectories across the store sequence, and a pooled
cross-backend frontier annotated with which store each design came from.

CLI (also ``python -m repro.dse.report``)::

    python -m repro.dse.report results/dse.jsonl --out docs/reports/fpga.md
    python -m repro.dse.report results/dse_tpu.jsonl --bench bench.json
    python -m repro.dse.report --compare results/dse_tpu.jsonl \\
        results/dse_cuda.jsonl --out docs/reports/tpu_vs_cuda.md
    python -m repro.dse.report --selftest   # render the built-in fixture

``--selftest`` renders a small built-in fixture store (all three
backends) through the full pipeline — including the cross-backend and
compare paths — and fails loudly if anything regresses; CI runs it as
the docs check.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Iterable, Mapping, Sequence

from repro.obs import (campaign_wall, counter_totals, events_path_for,
                       load_events, slowest_spans, span_totals,
                       worker_utilization)

from .backends import BACKENDS, get_backend, record_backend
from .frontier import FrontierIndex
from .objectives import (NORMALIZED_DEFAULT_WEIGHTS, NORMALIZED_OBJECTIVES,
                         canonical_vector, scalarize_values)
from .store import open_store, record_status

#: Where reports land unless --out says otherwise.
DEFAULT_REPORT_DIR = Path("docs/reports")


# ---------------------------------------------------------------------------
# markdown helpers
# ---------------------------------------------------------------------------


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(headers: Sequence[str], rows: Sequence[Sequence]) -> list[str]:
    # cell keys contain "|" (the axis separator) — escape so Markdown
    # doesn't read them as column breaks
    esc = lambda v: _fmt(v).replace("|", "\\|")
    out = ["| " + " | ".join(esc(h) for h in headers) + " |",
           "|" + "|".join(" --- " for _ in headers) + "|"]
    for row in rows:
        out.append("| " + " | ".join(esc(v) for v in row) + " |")
    return out


def _objective_columns(be) -> list[str]:
    return [f"{s.name} ({'max' if s.maximize else 'min'}, {s.units})"
            for s in be.objectives]


def _objective_values(be, rec: Mapping) -> list:
    return [rec["objectives"][s.name] for s in be.objectives]


# ---------------------------------------------------------------------------
# report body
# ---------------------------------------------------------------------------


class _BackendAcc:
    """Streaming per-backend report state: record/feasible counts, the
    incremental Pareto archive (integer keys in feasible-arrival order,
    records as payloads), and running per-workload winners — one
    :meth:`add` per record, no record list retained."""

    def __init__(self, name: str):
        self.name = name
        self.known = name in BACKENDS
        self.be = get_backend(name) if self.known else None
        self.count = 0
        self.feasible = 0
        self.failed = 0
        self.fi = FrontierIndex()
        self.winners: dict[str, tuple[float, dict]] = {}

    def add(self, rec: Mapping) -> None:
        self.count += 1
        if record_status(rec) != "ok":
            # quarantined cell: counted, never ranked/frontiered
            self.failed += 1
            return
        if not self.known or not rec.get("objectives", {}).get("feasible"):
            return
        be = self.be
        self.fi.insert(self.feasible, be.canonical(rec["objectives"]),
                       payload=rec)
        self.feasible += 1
        g = be.group_key(rec)
        score = be.scalarize(rec["objectives"])
        best = self.winners.get(g)
        # strict > keeps the FIRST maximum, matching max() over a list
        if best is None or score > best[0]:
            self.winners[g] = (score, rec)

    def section(self, k: int) -> list[str]:
        be = self.be
        lines = [f"## Backend `{self.name}` — {self.count} cells, "
                 f"{self.feasible} feasible"
                 + (f", {self.failed} quarantined" if self.failed else ""),
                 ""]
        lines += ["Objectives: " + ", ".join(
            f"`{s.name}` ({'max' if s.maximize else 'min'}, {s.units})"
            for s in be.objectives), ""]
        if not self.feasible:
            lines += ["_No feasible designs in this store._", ""]
            return lines

        # diversity order: whole front sorted by crowding so the top rows
        # are the spread, not a clump around one region
        entries = {key: (vec, rec) for key, vec, rec in self.fi.front()}
        order = self.fi.diverse()
        front = [entries[key][1] for key in order]
        fvecs = [entries[key][0] for key in order]

        lines += [f"### Pareto frontier ({len(front)} of {self.feasible} "
                  f"feasible, crowding-distance order)", ""]
        cols = ["cell"] + _objective_columns(be)
        rows = [[f"`{r['cell_key']}`"] + _objective_values(be, r)
                for r in front[:len(front) if k <= 0 else k]]
        shown = len(rows)
        lines += _table(cols, rows)
        if shown < len(front):
            lines += ["", f"_{len(front) - shown} more frontier designs in "
                          f"the store (rerun with `--top {len(front)}`)._"]
        lines += [""]

        lines += [f"### Per-workload winners "
                  f"(best by default weights {dict(be.default_weights)})", ""]
        rows = []
        for g in sorted(self.winners):
            win = self.winners[g][1]
            rows.append([g, f"`{win['cell_key']}`"]
                        + _objective_values(be, win))
        lines += _table(["workload", "cell"] + _objective_columns(be), rows)
        lines += [""]

        # trade-off summary: the frontier specialist per objective
        lines += ["### Objective trade-offs (frontier specialist per "
                  "objective)", ""]
        rows = []
        for j, spec in enumerate(be.objectives):
            best_i = max(range(len(front)), key=lambda i: fvecs[i][j])
            rows.append([f"`{spec.name}`", f"`{front[best_i]['cell_key']}`"]
                        + _objective_values(be, front[best_i]))
        lines += _table(["best at", "cell"] + _objective_columns(be), rows)
        lines += [""]
        return lines


def _norm_row(r: Mapping, label: str | None = None) -> dict | None:
    """One record -> its cross-backend normalized row
    (``{rec, backend, norm, label}``), or ``None`` when the record is
    from an unknown backend, not normalizable, or infeasible."""
    if record_status(r) != "ok":
        return None  # quarantined (status: failed) — never ranked/pooled
    name = record_backend(r)
    if name not in BACKENDS:
        return None
    be = get_backend(name)
    try:
        norm = be.normalized(r)
    except (KeyError, TypeError):
        return None  # foreign/truncated record: not normalizable
    if not norm["feasible"]:
        return None
    return {"rec": r, "backend": name, "norm": norm, "label": label}


def _normalized_rows(records: Sequence[Mapping],
                     label: str | None = None) -> list[dict]:
    """Feasible records of known backends, re-expressed in the
    cross-backend normalized schema: ``{rec, backend, norm, label}``."""
    return [row for r in records
            if (row := _norm_row(r, label)) is not None]


def _norm_score(row: Mapping) -> float:
    return scalarize_values(row["norm"], NORMALIZED_OBJECTIVES, None,
                            NORMALIZED_DEFAULT_WEIGHTS)


def _normalized_columns() -> list[str]:
    return [f"{s.name} (max, {s.units})" for s in NORMALIZED_OBJECTIVES]


def _normalized_values(norm: Mapping) -> list:
    return [norm[s.name] for s in NORMALIZED_OBJECTIVES]


class _NormAcc:
    """Streaming cross-backend state over normalized rows: the pooled
    incremental frontier (unique integer keys in arrival order, rows as
    payloads), running per-backend champions, and the best overall
    score — shared by the single-store cross-backend section and the
    ``--compare`` pooled frontier, so neither materializes the pooled
    record list."""

    def __init__(self):
        self.n = 0
        self.names: set[str] = set()
        self.fi = FrontierIndex()
        self.champs: dict[str, tuple[float, dict]] = {}
        self.best: float | None = None

    def add_record(self, r: Mapping,
                   label: str | None = None) -> dict | None:
        """Feed one raw record; returns its normalized row (or ``None``
        when it does not participate)."""
        row = _norm_row(r, label)
        if row is not None:
            self.add_row(row)
        return row

    def add_row(self, row: dict) -> None:
        self.fi.insert(self.n, canonical_vector(row["norm"],
                                                NORMALIZED_OBJECTIVES),
                       payload=row)
        self.n += 1
        self.names.add(row["backend"])
        s = _norm_score(row)
        champ = self.champs.get(row["backend"])
        if champ is None or s > champ[0]:
            self.champs[row["backend"]] = (s, row)
        if self.best is None or s > self.best:
            self.best = s

    def section(self, k: int, labeled: bool = False) -> list[str]:
        """One frontier across device families: every feasible record
        mapped to the normalized objective schema, Pareto-sorted
        together."""
        lines = ["## Cross-backend frontier (normalized objectives)", ""]
        if not self.n:
            lines += ["_No normalizable feasible designs._", ""]
            return lines
        names = sorted(self.names)
        lines += [f"{self.n} feasible cells from backend(s) "
                  + ", ".join(f"`{n}`" for n in names)
                  + ", compared in normalized units: "
                  + ", ".join(f"`{s.name}` ({s.units})"
                              for s in NORMALIZED_OBJECTIVES)
                  + ". Hardware watt/dollar/peak terms come from the spec "
                    "tables in `repro.core.hw_specs`.", ""]

        payloads = {key: row for key, _, row in self.fi.front()}
        order = self.fi.diverse()
        shown = order[:len(order) if k <= 0 else k]
        cols = ((["store"] if labeled else []) + ["backend", "cell"]
                + _normalized_columns())
        rows = []
        for key in shown:
            x = payloads[key]
            rows.append(([x["label"]] if labeled else [])
                        + [f"`{x['backend']}`", f"`{x['rec']['cell_key']}`"]
                        + _normalized_values(x["norm"]))
        lines += [f"### Frontier ({len(order)} of {self.n} designs, "
                  f"crowding-distance order)", ""]
        lines += _table(cols, rows)
        if len(shown) < len(order):
            lines += ["", f"_{len(order) - len(shown)} more frontier "
                          f"designs (rerun with `--top {len(order)}`)._"]
        lines += [""]

        # per-backend champions under the default normalized scalarization
        lines += [f"### Backend champions (best by "
                  f"{dict(NORMALIZED_DEFAULT_WEIGHTS)})", ""]
        best_overall = self.best
        rows = []
        for n in names:
            score, champ = self.champs[n]
            ratio = (score / best_overall) if best_overall else 0.0
            rows.append([f"`{n}`", f"`{champ['rec']['cell_key']}`"]
                        + _normalized_values(champ["norm"])
                        + [f"{ratio:.2f}x"])
        lines += _table(["backend", "cell"] + _normalized_columns()
                        + ["vs best"], rows)
        lines += [""]
        return lines


# ---------------------------------------------------------------------------
# store comparison (--compare): winner deltas + objective trajectories
# ---------------------------------------------------------------------------


def _pct(new: float, old: float) -> str:
    if not old:
        return "n/a"
    return f"{(new - old) / old * 100:+.1f}%"


def render_compare(stores: Sequence[tuple[str, Iterable[Mapping]]], *,
                   title: str | None = None, k: int = 12) -> str:
    """Two or more (label, records) stores -> a Markdown comparison.

    The store ORDER is the trajectory: deltas are last-vs-first, so
    passing two snapshots of the same campaign shows perf drift over
    time, and passing sibling backends' stores shows which family wins
    each workload and by how much.

    Each store's records may be any iterable (e.g. a streaming
    ``iter_records()``) and is consumed exactly once: summary counts,
    trajectories, winner groups, and the pooled frontier all accumulate
    in that single pass, sharing one incremental frontier index.
    """
    stores = list(stores)
    if len(stores) < 2:
        raise ValueError("compare needs at least two stores")
    labels = [lab for lab, _ in stores]
    title = title or ("DSE store comparison — " + " vs ".join(labels))

    pooled = _NormAcc()
    summaries = []       # (label, cells, backend names, normalizable, best)
    traj: list[dict[str, float | None]] = []  # per-store objective maxima
    groups: dict[str, dict[str, dict]] = {}   # workload -> label -> best row
    for lab, recs in stores:
        n, n_norm, best = 0, 0, None
        names: set[str] = set()
        bests: dict[str, float | None] = {s.name: None
                                          for s in NORMALIZED_OBJECTIVES}
        for r in recs:
            n += 1
            names.add(record_backend(r))
            row = pooled.add_record(r, label=lab)
            if row is None:
                continue
            n_norm += 1
            s = _norm_score(row)
            if best is None or s > best:
                best = s
            for spec in NORMALIZED_OBJECTIVES:
                v = row["norm"][spec.name]
                if bests[spec.name] is None or v > bests[spec.name]:
                    bests[spec.name] = v
            g = get_backend(row["backend"]).group_key(row["rec"])
            cur = groups.setdefault(g, {})
            if lab not in cur or s > _norm_score(cur[lab]):
                cur[lab] = row
        summaries.append((lab, n, names, n_norm,
                          best if best is not None else 0.0))
        traj.append(bests)

    lines = [f"# {title}", ""]
    rows = [[lab, n, ", ".join(f"`{b}`" for b in sorted(names)), n_norm,
             best] for lab, n, names, n_norm, best in summaries]
    lines += _table(["store", "cells", "backends", "feasible (normalizable)",
                     f"best {dict(NORMALIZED_DEFAULT_WEIGHTS)}"], rows)
    lines += [""]

    # objective trajectories: best normalized value per store, in order
    lines += ["## Objective trajectories (best per store, in store order)",
              ""]
    rows = []
    for spec in NORMALIZED_OBJECTIVES:
        bests = [(t[spec.name] if t[spec.name] is not None else 0.0)
                 for t in traj]
        rows.append([f"`{spec.name}` ({spec.units})"] + bests
                    + [_pct(bests[-1], bests[0])])
    lines += _table(["objective"] + labels + ["last vs first"], rows)
    lines += [""]

    # per-workload winner deltas
    lines += ["## Per-workload winner deltas", "",
              "Best design per workload per store under the default "
              f"normalized scalarization {dict(NORMALIZED_DEFAULT_WEIGHTS)}; "
              "delta compares the LAST store against the FIRST.", ""]
    rows = []
    for g in sorted(groups):
        per_lab = groups[g]
        scores = [(_norm_score(per_lab[lab]) if lab in per_lab else None)
                  for lab in labels]
        present = [s for s in scores if s is not None]
        win_i = scores.index(max(present))
        winner = per_lab[labels[win_i]]
        delta = (_pct(scores[-1], scores[0])
                 if scores[0] is not None and scores[-1] is not None
                 else "n/a")
        rows.append([g]
                    + [f"{s:.4g}" if s is not None else "—" for s in scores]
                    + [delta, labels[win_i],
                       f"`{winner['rec']['cell_key']}`"])
    lines += _table(["workload"] + [f"{lab} tflops" for lab in labels]
                    + ["Δ last vs first", "winner", "winning cell"], rows)
    lines += [""]

    # pooled cross-backend frontier, annotated with source store
    lines += pooled.section(k, labeled=True)
    return "\n".join(lines).rstrip() + "\n"


# ---------------------------------------------------------------------------
# placement report (repro.dse.placement results)
# ---------------------------------------------------------------------------


def placement_section(result) -> list[str]:
    """Markdown section for a :class:`repro.dse.placement.PlacementResult`:
    the per-workload assignment table ({family, part, count, design
    point}), budget utilization per capped axis, and the marginal
    "next dollar / next watt" upgrade table."""
    unit = {s.name: s.units for s in NORMALIZED_OBJECTIVES}[result.objective]
    lines = [
        f"{len(result.assignments)} workload(s) placed by the "
        f"`{result.solver}` solver ({result.explored} points examined), "
        f"maximizing `{result.objective}` ({unit}) under a budget of "
        f"{result.budget.describe()}. Candidate designs per workload "
        f"(raw -> cost-dominance-pruned): "
        + ", ".join(f"{w} {raw}->{kept}"
                    for w, (raw, kept) in sorted(result.options.items()))
        + ".", ""]

    lines += ["## Assignment", ""]
    cols = ["workload", "family", "part", "count", "design point", "cell",
            f"{result.objective} ({unit})", "$/h", "W"]
    rows = []
    for a in result.assignments:
        c = a.candidate
        rows.append([a.workload, f"`{c.backend}`", c.part, c.count,
                     f"`{c.point}`", f"`{c.cell_key}`", c.value,
                     c.usd_per_hour, c.watts])
    rows.append(["**total**", "", "", "", "", "", result.total_value,
                 result.total_usd, result.total_watts])
    lines += _table(cols, rows)
    lines += [""]

    lines += ["## Budget utilization", ""]
    rows = []
    for axis, label in (("usd_per_hour", "dollars ($/h)"),
                        ("watts", "power (W)")):
        used, cap = result.utilization()[axis]
        rows.append([label, used, f"{cap:g}" if cap is not None else "—",
                     f"{used / cap:.0%}" if cap else "—"])
    lines += _table(["axis", "used", "cap", "utilization"], rows)
    lines += [""]

    lines += ["## Marginal upgrades (next dollar / next watt)", ""]
    if not result.suggestions:
        lines += ["_Every value-raising upgrade already fits in the "
                  "budget — raising it would not change this "
                  "assignment._", ""]
        return lines
    lines += ["Best rejected upgrade per workload — the cheapest budget "
              "raise that would change the answer:", ""]
    rows = []
    for s in result.suggestions:
        rows.append([s.workload, f"`{s.candidate.cell_key}`",
                     f"+{_fmt(s.gain)}", f"{s.d_usd:+.4g}",
                     f"{s.d_watts:+.4g}",
                     ", ".join(s.blocked_by) or "budget"])
    lines += _table(["workload", "upgrade to", f"+{result.objective}",
                     "+$/h", "+W", "blocked by"], rows)
    lines += [""]
    return lines


def render_placement(result, *, title: str | None = None) -> str:
    """A full Markdown placement report (one section per concern)."""
    title = title or (f"Placement — {len(result.assignments)} workload(s) "
                      f"under {result.budget.describe()}")
    lines = [f"# {title}", ""] + placement_section(result)
    return "\n".join(lines).rstrip() + "\n"


def calibration_section(calibration, stamped: int = 0,
                        stamp_fps: set[str] | None = None) -> list[str]:
    """The predicted-vs-measured error table for a fitted calibration
    (:mod:`repro.calib`): per corrected part, the fitted compute/bandwidth
    multipliers, measurement count, raw vs calibrated geometric-RMS error,
    and provenance — the error bars behind every corrected frontier claim.
    ``stamped``/``stamp_fps`` describe the store's per-record calibration
    stamps, so the section also says how many records actually carried
    corrections (and flags stamps from a DIFFERENT fit)."""
    from repro.calib.fit import error_rows
    lines = ["## Calibration (predicted vs measured)", ""]
    if calibration.is_identity():
        lines += ["_Identity calibration: no corrections applied; every "
                  "evaluation used datasheet specs._", ""]
        return lines
    fp = calibration.fingerprint()
    lines += [f"{len(calibration.parts())} corrected part(s), calibration "
              f"fingerprint `{fp}`. `compute ×` / `bandwidth ×` multiply "
              f"the part's delivered rate; errors are geometric-RMS "
              f"relative error of the model against the fitted "
              f"measurements, before (`raw`) and after (`cal`) the "
              f"correction — the fit guarantees cal ≤ raw per part.", ""]
    rows = []
    for r in error_rows(calibration):
        src = r["source"] + (f" ({r['date']})" if r["date"] else "")
        rows.append([f"`{r['part']}`", r["compute_scale"], r["bw_scale"],
                     r["n"], f"{r['raw_err_pct']:.2f}",
                     f"{r['cal_err_pct']:.2f}", r["kind"], src])
    lines += _table(["part", "compute ×", "bandwidth ×", "n", "raw err %",
                     "cal err %", "kind", "source (date)"], rows)
    lines += [""]
    if stamped:
        fps = sorted(f for f in (stamp_fps or set()) if f)
        note = (f"{stamped} store record(s) were evaluated under "
                f"calibration stamp(s) "
                + ", ".join(f"`{f}`" for f in fps) + ".")
        if any(f != fp for f in fps):
            note += (" ⚠ Some stamps differ from the calibration shown "
                     "above — those records were corrected by a different "
                     "fit.")
        lines += [note, ""]
    return lines


def _bench_section(bench: Mapping) -> list[str]:
    lines = ["## Benchmark appendix (`benchmarks/run.py --json`)", ""]
    for name in sorted(bench.get("benchmarks", {})):
        rows = bench["benchmarks"][name]
        lines += [f"### `{name}`", ""]
        lines += _table(["row", "us/call", "derived"],
                        [[r["name"], f"{r['us_per_call']:.1f}",
                          f"`{r['derived']}`"] for r in rows])
        lines += [""]
    return lines


# ---------------------------------------------------------------------------
# campaign health (repro.obs events + per-record convergence traces)
# ---------------------------------------------------------------------------


def _pct_of(part: float, whole: float) -> str:
    return f"{part / whole:.0%}" if whole > 0 else "—"


def health_section(records: Sequence[Mapping],
                   events: Sequence[Mapping] | None = None,
                   k: int = 10, *, total: int | None = None) -> list[str]:
    """The campaign-health section: where the wall time went (spans),
    which workers sat idle (utilization), which cells dominated the run
    (slowest-cell table), and per-cell convergence diagnostics from the
    ``trace`` field — flagging cells that were still improving when the
    iteration cap hit, i.e. cells whose budget was too small.

    ``records`` may mix normal and quarantined (``status: "failed"``)
    records: failures feed the "Failures & retries" table (exception
    histogram, per-cell attempt counts, slowest attempts — alongside the
    ``cells.failed`` / ``cells.retried`` / ``pool.rebuilds`` counters
    when events are present) and are excluded from every other table."""
    lines = ["## Campaign health", ""]
    events = list(events or [])
    failed = [r for r in records if record_status(r) != "ok"]
    records = [r for r in records if record_status(r) == "ok"]

    if events:
        wall = campaign_wall(events)
        totals = span_totals(events)
        lines += [f"### Wall-time breakdown ({wall:.2f}s campaign wall, "
                  f"{len(events)} events)", ""]
        rows = [[f"`{name}`", st.count, f"{st.total_s:.3f}",
                 f"{st.max_s:.3f}", _pct_of(st.total_s, wall)]
                for name, st in sorted(totals.items(),
                                       key=lambda kv: -kv[1].total_s)]
        lines += _table(["span", "count", "total s", "max s",
                         "% of wall"], rows)
        lines += [""]

        util = worker_utilization(events)
        if util:
            mean = sum(r["util"] for r in util.values()) / len(util)
            lines += [f"### Worker utilization (mean {mean:.0%} over "
                      f"{len(util)} process(es))", ""]
            rows = [[f"`{proc}`", r["cells"], f"{r['busy_s']:.3f}",
                     f"{r['util']:.0%}"]
                    for proc, r in sorted(util.items())]
            lines += _table(["process", "cells", "busy s", "utilization"],
                            rows)
            lines += ["", "_Utilization is `cell.eval` busy time over the "
                          "campaign wall; low values mean workers idled "
                          "(too few cells, or one straggler cell)._", ""]

        slow = slowest_spans(events, k=k)
        if slow:
            lines += [f"### Slowest cells (top {len(slow)} by `cell.eval` "
                      f"time)", ""]
            rows = [[f"`{e.get('attrs', {}).get('cell', '?')}`",
                     f"{e.get('dur', 0.0):.3f}",
                     _pct_of(e.get("dur", 0.0), wall),
                     f"`{e.get('proc', '?')}`"] for e in slow]
            lines += _table(["cell", "eval s", "% of wall", "process"], rows)
            lines += [""]

        counts = counter_totals(events)
        if counts:
            lines += ["### Counters", ""]
            lines += _table(["counter", "total"],
                            [[f"`{n}`", f"{v:g}"]
                             for n, v in sorted(counts.items())])
            lines += [""]

    retried = [r for r in records
               if isinstance(r.get("resilience"), Mapping)]
    if failed or retried:
        lines += [f"### Failures & retries ({len(failed)} quarantined, "
                  f"{len(retried)} retried-then-ok cell(s))", ""]
        if failed:
            hist: dict[str, int] = {}
            for r in failed:
                et = str(r.get("error_type", "?"))
                hist[et] = hist.get(et, 0) + 1
            lines += _table(["exception", "quarantined cells"],
                            [[f"`{et}`", n]
                             for et, n in sorted(hist.items())])
            lines += [""]
            rows = []
            for r in sorted(failed, key=lambda r: r.get("cell_key", "")):
                log = r.get("attempt_log") or []
                outcomes = ",".join(str(a.get("outcome", "?"))
                                    for a in log) or "—"
                last = (r.get("error") or "").strip().splitlines()
                rows.append([f"`{r.get('cell_key', '?')}`",
                             f"`{r.get('error_type', '?')}`",
                             r.get("attempts", len(log)), outcomes,
                             last[-1][:80] if last else "—"])
            lines += _table(["cell", "exception", "attempts", "outcomes",
                             "last error"], rows)
            lines += [""]
        attempts = []
        for r in failed + retried:
            log = (r.get("attempt_log")
                   or r.get("resilience", {}).get("attempt_log") or [])
            for a in log:
                attempts.append((float(a.get("duration_s", 0.0)),
                                 r.get("cell_key", "?"),
                                 a.get("attempt", "?"),
                                 a.get("outcome", "?")))
        attempts.sort(key=lambda t: (-t[0], t[1]))
        if attempts:
            lines += [f"### Slowest attempts (top {min(k, len(attempts))} "
                      f"across failed/retried cells)", ""]
            lines += _table(["cell", "attempt", "outcome", "duration s"],
                            [[f"`{c}`", n, o, f"{d:.3f}"]
                             for d, c, n, o in attempts[:k]])
            lines += [""]

    traced = [r for r in records if isinstance(r.get("trace"), Mapping)]
    if traced:
        n_all = total if total is not None else len(records)
        lines += [f"### Convergence diagnostics ({len(traced)} of "
                  f"{n_all} cells carry a `trace`)", ""]
        rows = []
        capped = []
        for r in sorted(traced, key=lambda r: r["cell_key"]):
            t = r["trace"]
            stop = t.get("stop_reason", "?")
            if stop == "iteration_cap":
                capped.append(r["cell_key"])
                stop = "**iteration_cap**"
            rows.append([f"`{r['cell_key']}`", t.get("engine", "?"), stop,
                         t.get("iterations", "?"), t.get("evaluations", "?"),
                         t.get("cache_hits", "?"),
                         _fmt(t.get("final_delta", 0.0))])
        lines += _table(["cell", "engine", "stop", "iters", "evals",
                         "cache hits", "final Δ"], rows)
        lines += [""]
        if capped:
            lines += [f"⚠ {len(capped)} cell(s) hit the iteration cap while "
                      f"still within the improvement patience — the search "
                      f"was still moving when it was cut off. Consider "
                      f"rerunning with a higher `--iterations`: "
                      + ", ".join(f"`{c}`" for c in capped) + ".", ""]
        else:
            lines += ["All traced searches stopped on their own terms "
                      "(converged or exhaustive) — the iteration budget "
                      "was sufficient.", ""]

        by_engine: dict[str, list[Mapping]] = {}
        for r in traced:
            t = r["trace"]
            by_engine.setdefault(str(t.get("engine", "?")), []).append(t)
        lines += [f"### Per-engine convergence ({len(by_engine)} engine(s) "
                  f"across the traced cells)", ""]
        rows = []
        for eng in sorted(by_engine):
            ts = by_engine[eng]
            stops: dict[str, int] = {}
            for t in ts:
                s = str(t.get("stop_reason", "?"))
                stops[s] = stops.get(s, 0) + 1
            stop_s = ", ".join(f"{s}×{n}" for s, n in sorted(stops.items()))
            evals = sum(int(t.get("evaluations", 0)) for t in ts)
            screened = sum(int(t.get("screened", 0)) for t in ts)
            iters = sum(int(t.get("iterations", 0)) for t in ts) / len(ts)
            fits = [t["best_fitness"] for t in ts if "best_fitness" in t]
            rows.append([f"`{eng}`", len(ts), stop_s, f"{iters:.1f}",
                         evals, screened if screened else "—",
                         _fmt(max(fits)) if fits else "—"])
        lines += _table(["engine", "cells", "stop reasons", "mean iters",
                         "evals", "screened", "best fitness"], rows)
        lines += ["", "_`screened` counts candidates a multi-fidelity "
                      "engine triaged through the cheap vectorized "
                      "relaxation; they never touch the full analytical "
                      "models and are not part of `evals`._", ""]

    if not events and not traced and not failed:
        lines += ["_No telemetry: the store records carry no `trace` field "
                  "and no events file was found. Re-run the campaign with "
                  "`--trace` to populate both._", ""]
    return lines


def render_report(records: Iterable[Mapping], *,
                  title: str = "DSE campaign report",
                  bench: Mapping | None = None, k: int = 12,
                  events: Sequence[Mapping] | None = None,
                  calibration=None) -> str:
    """Records (any mix of backends) -> a Markdown report string.

    ``records`` may be any iterable — typically a streaming
    ``CampaignStore.iter_records()`` — and is consumed in ONE pass: every
    section reads off the per-backend / cross-backend accumulators, so
    memory stays O(frontier + winners), not O(records). Only records
    carrying a convergence ``trace`` are retained (for the health
    tables).

    ``k`` caps each frontier table at the k most-spread designs
    (NSGA-II rank + crowding order); ``k <= 0`` means no cap.
    ``events`` (merged ``repro.obs`` events, e.g. from
    ``<store>.events.jsonl``) adds the campaign-health section; records
    with a ``trace`` field add convergence diagnostics even without
    events.

    ``calibration`` (a :class:`repro.calib.Calibration`) appends the
    predicted-vs-measured error table (:func:`calibration_section`), so
    the report's frontier claims carry the model's measured error bars;
    per-record calibration stamps are counted either way.
    """
    accs: dict[str, _BackendAcc] = {}
    norm = _NormAcc()
    traced: list[Mapping] = []
    failures: list[Mapping] = []
    total = 0
    stamped, stamp_fps = 0, set()
    for r in records:
        total += 1
        name = record_backend(r)
        acc = accs.get(name)
        if acc is None:
            acc = accs[name] = _BackendAcc(name)
        acc.add(r)
        if record_status(r) != "ok":
            # quarantined: counted by the accumulator, retained only for
            # the health section's failure tables
            failures.append(r)
            continue
        norm.add_record(r)
        if isinstance(r.get("trace"), Mapping):
            traced.append(r)
        info = r.get("calibration")
        if isinstance(info, Mapping):
            stamped += 1
            stamp_fps.add(str(info.get("fingerprint", "")))

    lines = [f"# {title}", "",
             f"{total} campaign cells across "
             f"{len(accs)} backend(s): "
             + ", ".join(f"`{n}`" for n in sorted(accs)) + ".", ""]
    for name in sorted(accs):
        acc = accs[name]
        if not acc.known:
            lines += [f"## Backend `{name}` — {acc.count} cells "
                      f"(unknown backend; skipped)", ""]
            continue
        lines += acc.section(k)
    if len([n for n in accs if accs[n].known]) > 1:
        lines += norm.section(k)
    if calibration is not None:
        lines += calibration_section(calibration, stamped, stamp_fps)
    elif stamped:
        fps = sorted(f for f in stamp_fps if f)
        lines += ["## Calibration (predicted vs measured)", "",
                  f"{stamped} record(s) carry calibration stamp(s) "
                  + ", ".join(f"`{f}`" for f in fps)
                  + " but no calibration file was supplied — rerun with "
                    "`--calibration <file>` to render the error table.", ""]
    if events or traced or failures:
        lines += health_section(traced + failures, events,
                                k=min(k, 10) if k > 0 else 10, total=total)
    if bench:
        lines += _bench_section(bench)
    return "\n".join(lines).rstrip() + "\n"


# ---------------------------------------------------------------------------
# selftest fixture
# ---------------------------------------------------------------------------


def fixture_records() -> list[dict]:
    """A tiny deterministic three-backend store: enough shape variety to
    exercise frontier extraction, crowding order, winner grouping,
    trade-off tables, and the cross-backend normalized frontier without
    running any search."""
    recs = []
    fpga_pts = [  # (net, h, fpga, ips, gops, lat_ms, eff, bram, feasible)
        ("vgg16", 224, "ku115", 145.0, 4220.0, 6.9, 0.764, 1800, True),
        ("vgg16", 224, "zcu102", 66.0, 1930.0, 15.2, 0.771, 1100, True),
        ("vgg16", 64, "ku115", 1630.0, 3950.0, 0.61, 0.716, 1350, True),
        ("vgg16", 64, "zcu102", 760.0, 1840.0, 1.31, 0.733, 960, True),
        ("alexnet", 0, "ku115", 2250.0, 3280.0, 0.44, 0.594, 820, True),
        ("alexnet", 0, "zcu102", 990.0, 1450.0, 1.01, 0.577, 640, False),
    ]
    for i, (net, h, fpga, ips, gops, lat, eff, bram, ok) \
            in enumerate(fpga_pts):
        size = f"{h}x{h}" if h else "native"
        # one deliberately iteration-capped cell (index 0) so health
        # reports exercise the "still improving at the cap" flag; one
        # multi-fidelity cell (index 2) so the per-engine table shows a
        # `screened` count alongside the paper's PSO
        capped = i == 0
        hyperband = i == 2
        recs.append({
            "schema": 1,
            "cell_key": f"net={net}|in={size}|fpga={fpga}|prec=16|bmax=1",
            "cell": {"net": net, "h": h, "w": h, "fpga": fpga,
                     "precision": 16, "batch_max": 1},
            "rav": {"sp": 4, "batch": 1, "f_dsp": 0.9, "f_bram": 0.8,
                    "f_bw": 0.7},
            "objectives": {"throughput_ips": ips, "gops": gops,
                           "latency_s": lat / 1e3, "dsp_eff": eff,
                           "bram_used": float(bram), "feasible": ok},
            "search": {"base_seed": 0, "population": 20, "iterations": 30,
                       "weights": None},
            "evaluations": 600,
            "trace": {
                "schema": 1,
                "engine": "hyperband" if hyperband else "pso",
                "stop_reason": "iteration_cap" if capped else "converged",
                "iterations": 30 if capped else 10 + i,
                "evaluations": 130 if hyperband else 600,
                "cache_hits": 40 + 7 * i,
                "best_fitness": ips,
                "final_delta": 1.25 if capped else 0.0,
                "history": [round(ips * f, 6)
                            for f in (0.82, 0.97, 1.0)],
                **({"screened": 4096} if hyperband else {}),
            },
        })
    # one retried-then-ok cell (index 3) and one quarantined cell so the
    # health report's "Failures & retries" section renders byte-stably
    # from the fixture alone — same hand-written-durations discipline as
    # fixture_events()
    recs[3]["resilience"] = {
        "attempts": 2,
        "retries": 1,
        "attempt_log": [
            {"attempt": 1, "outcome": "error", "duration_s": 0.021,
             "error_type": "RuntimeError"},
            {"attempt": 2, "outcome": "ok", "duration_s": 0.34,
             "error_type": None},
        ],
    }
    recs.append({
        "schema": 1,
        "status": "failed",
        "quarantine_schema": 1,
        "cell_key": "net=alexnet|in=native|fpga=ku115|prec=8|bmax=1",
        "cell": {"net": "alexnet", "h": 0, "w": 0, "fpga": "ku115",
                 "precision": 8, "batch_max": 1},
        "search": {"base_seed": 0, "population": 20, "iterations": 30,
                   "weights": None},
        "error_type": "ValueError",
        "error": "Traceback (most recent call last):\n"
                 "  ...\n"
                 "ValueError: injected[raise-permanent] "
                 "net=alexnet|in=native|fpga=ku115|prec=8|bmax=1 "
                 "(attempt 1)",
        "attempts": 1,
        "attempt_log": [
            {"attempt": 1, "outcome": "error", "duration_s": 0.012,
             "error_type": "ValueError"},
        ],
        "evaluations": 0,
    })
    tpu_pts = [  # (arch, shape, chips, remat, mb, dp, tp, step, mfu, hbm, ok)
        ("starcoder2-3b", "train_4k", 8, "full", 2, 8, 1, 18.1, 0.52,
         10.4, True),
        ("starcoder2-3b", "train_4k", 16, "full", 2, 16, 1, 9.1, 0.51,
         5.2, True),
        ("starcoder2-3b", "train_4k", 16, "none", 2, 16, 1, 6.8, 0.58,
         24.7, False),
        ("starcoder2-3b", "decode_32k", 8, "none", 1, 8, 1, 0.021, 0.03,
         15.7, True),
        ("xlstm-350m", "train_4k", 8, "full", 1, 8, 1, 1.28, 0.47,
         2.4, True),
        ("xlstm-350m", "decode_32k", 8, "none", 1, 8, 1, 0.00064, 0.06,
         0.4, True),
    ]
    for arch, shape, chips, remat, mb, dp, tp, step, mfu, hbm, ok in tpu_pts:
        recs.append({
            "schema": 1,
            "backend": "tpu",
            "cell_key": (f"arch={arch}|shape={shape}|chips={chips}"
                         f"|remat={remat}|mb={mb}"),
            "cell": {"arch": arch, "shape": shape, "chips": chips,
                     "remat": remat, "microbatches": mb},
            "plan": {"dp": dp, "tp": tp, "bound": "compute"},
            "objectives": {"step_time_s": step, "mfu": mfu, "hbm_gib": hbm,
                           "chips": float(chips), "feasible": ok},
            "search": {"weights": None},
            "evaluations": 4,
            "trace": {"schema": 1, "engine": "enumeration",
                      "stop_reason": "exhaustive", "iterations": 4,
                      "evaluations": 4, "cache_hits": 0},
        })
    cuda_pts = [  # (arch, shape, gpu, n, remat, mb, dp, tp,
                  #  step, mfu, hbm, watts, ok)
        ("starcoder2-3b", "train_4k", "a100-80g", 8, "full", 2, 8, 1,
         11.5, 0.62, 10.4, 3200.0, True),
        ("starcoder2-3b", "train_4k", "h100", 8, "full", 2, 8, 1,
         3.7, 0.55, 10.4, 5600.0, True),
        ("starcoder2-3b", "train_4k", "a100-40g", 8, "none", 1, 8, 1,
         2.9, 0.71, 41.0, 3200.0, False),
        ("starcoder2-3b", "decode_32k", "h100", 8, "none", 1, 8, 1,
         0.009, 0.04, 14.9, 5600.0, True),
        ("xlstm-350m", "train_4k", "a100-40g", 8, "full", 1, 8, 1,
         0.92, 0.49, 2.4, 3200.0, True),
    ]
    for arch, shape, gpu, n, remat, mb, dp, tp, step, mfu, hbm, w, ok \
            in cuda_pts:
        recs.append({
            "schema": 1,
            "backend": "cuda",
            "cell_key": (f"arch={arch}|shape={shape}|gpu={gpu}|gpus={n}"
                         f"|remat={remat}|mb={mb}"),
            "cell": {"arch": arch, "shape": shape, "gpu": gpu, "gpus": n,
                     "remat": remat, "microbatches": mb},
            "plan": {"dp": dp, "tp": tp, "bound": "compute"},
            "objectives": {"step_time_s": step, "mfu": mfu, "hbm_gib": hbm,
                           "gpus": float(n), "watts": w, "feasible": ok},
            "search": {"weights": None},
            "evaluations": 4,
            "trace": {"schema": 1, "engine": "enumeration",
                      "stop_reason": "exhaustive", "iterations": 4,
                      "evaluations": 4, "cache_hits": 0},
        })
    return recs


def fixture_events() -> list[dict]:
    """A tiny deterministic merged-events stream matching two of the
    fixture FPGA cells: a campaign span over two spawn workers, each
    with queue-wait / cell.run / cell.eval spans, store appends, pool
    gauges, and counters. Hand-written timestamps (no clocks), so the
    rendered health report is byte-stable — the committed
    ``docs/reports/example_health.md`` drift test depends on that."""
    a = "net=vgg16|in=64x64|fpga=ku115|prec=16|bmax=1"
    b = "net=vgg16|in=64x64|fpga=zcu102|prec=16|bmax=1"

    def ev(kind, name, proc, ts, seq, **fields):
        attrs = fields.pop("attrs", {})
        return {"schema": 1, "kind": kind, "name": name, "proc": proc,
                "ts": ts, "seq": seq, **fields, "attrs": attrs}

    return sorted([
        ev("gauge", "pool.inflight", "main", 100.05, 0, value=2.0),
        ev("span", "queue.wait", "worker-1", 100.4, 0, dur=0.35, depth=0,
           attrs={"cell": a}),
        ev("span", "cell.eval", "worker-1", 100.45, 1, dur=3.6, depth=1,
           attrs={"cell": a}),
        ev("span", "cell.run", "worker-1", 100.4, 2, dur=3.7, depth=0,
           attrs={"cell": a, "backend": "fpga"}),
        ev("span", "queue.wait", "worker-2", 100.5, 0, dur=0.45, depth=0,
           attrs={"cell": b}),
        ev("span", "cell.eval", "worker-2", 100.55, 1, dur=5.8, depth=1,
           attrs={"cell": b}),
        ev("span", "cell.run", "worker-2", 100.5, 2, dur=5.9, depth=0,
           attrs={"cell": b, "backend": "fpga"}),
        ev("span", "store.append", "main", 104.2, 1, dur=0.012, depth=1,
           attrs={"cell": a}),
        ev("counter", "cells.done", "main", 104.25, 2, value=1),
        ev("gauge", "pool.inflight", "main", 104.3, 3, value=1.0),
        ev("span", "store.append", "main", 106.5, 4, dur=0.011, depth=1,
           attrs={"cell": b}),
        ev("counter", "cells.done", "main", 106.55, 5, value=1),
        ev("gauge", "pool.inflight", "main", 106.6, 6, value=0.0),
        ev("span", "campaign", "main", 100.0, 7, dur=6.65, depth=0,
           attrs={"backend": "fpga", "cells": 2, "todo": 2, "workers": 2}),
    ], key=lambda e: (e["ts"], e["proc"], e["seq"]))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.report",
        description="Render a Markdown campaign report from a JSONL store "
                    "(plus optional benchmarks/run.py --json output).")
    ap.add_argument("store", nargs="?", default=None,
                    help="campaign JSONL store (any backend or a mix)")
    ap.add_argument("--compare", nargs="+", default=None, metavar="STORE",
                    help="compare mode: two or more stores, in trajectory "
                         "order — renders per-workload winner deltas, "
                         "normalized objective trajectories, and a pooled "
                         "cross-backend frontier")
    ap.add_argument("--bench", default=None, metavar="JSON",
                    help="benchmarks/run.py --json output to append")
    ap.add_argument("--calibration", default=None, metavar="JSON",
                    help="fitted calibration (python -m repro.calib fit) — "
                         "appends the predicted-vs-measured error table "
                         "so frontier claims carry error bars")
    ap.add_argument("--out", default=None, metavar="MD",
                    help="output path (default: docs/reports/<store-stem>.md)")
    ap.add_argument("--title", default=None)
    ap.add_argument("--top", type=int, default=12,
                    help="frontier rows per backend, crowding-ordered "
                         "(<= 0: all)")
    ap.add_argument("--selftest", action="store_true",
                    help="render the built-in fixture store and exit "
                         "(the CI docs check); writes nothing")
    args = ap.parse_args(argv)

    if args.selftest:
        fix = fixture_records()
        md = render_report(fix, title="selftest campaign", k=args.top,
                           events=fixture_events())
        half = [r for r in fix if r.get("backend") != "cuda"]
        cmp_md = render_compare([("tpu+fpga", half),
                                 ("all", fix)], k=args.top)
        for must in ("Pareto frontier", "Backend `fpga`", "Backend `tpu`",
                     "Backend `cuda`", "Per-workload winners",
                     "Objective trade-offs", "Cross-backend frontier",
                     "Backend champions", "Campaign health",
                     "Wall-time breakdown", "Worker utilization",
                     "Slowest cells", "Convergence diagnostics",
                     "Per-engine convergence", "iteration cap",
                     "Failures & retries", "Slowest attempts"):
            if must not in md:
                raise SystemExit(f"selftest: section {must!r} missing "
                                 f"from rendered report")
        if "Calibration" in md:
            raise SystemExit("selftest: uncalibrated fixture report must "
                             "not contain a Calibration section")
        for must in ("Per-workload winner deltas", "Objective trajectories",
                     "Cross-backend frontier"):
            if must not in cmp_md:
                raise SystemExit(f"selftest: section {must!r} missing "
                                 f"from compare report")
        from repro.calib import fit_corrections, fixture_measurements
        cal = fit_corrections(fixture_measurements())
        cal_md = render_report(fix, title="selftest calibrated campaign",
                               k=args.top, calibration=cal)
        if "## Calibration (predicted vs measured)" not in cal_md:
            raise SystemExit("selftest: calibration error table missing "
                             "from calibrated report")
        for part in cal.parts():
            c = cal.correction(part)
            if f"`{part}`" not in cal_md:
                raise SystemExit(f"selftest: part {part!r} missing from "
                                 f"calibration error table")
            if c.cal_err_pct > c.raw_err_pct + 1e-9:
                raise SystemExit(f"selftest: calibrated error exceeds raw "
                                 f"for {part!r}")
        print(f"selftest OK: rendered {len(md)} + {len(cmp_md)} + "
              f"{len(cal_md)} chars, all sections present")
        return 0

    if args.compare:
        if args.bench:
            ap.error("--bench only applies to single-store reports, "
                     "not --compare")
        if args.store:
            args.compare = [args.store] + args.compare
        if len(args.compare) < 2:
            ap.error("--compare needs at least two stores")
        stores, labels = [], []
        for path in args.compare:
            s = open_store(path)
            if not len(s):
                ap.error(f"store {path} is empty or missing")
            stem = Path(path).stem
            n_seen = sum(1 for l in labels if l.split("#")[0] == stem)
            lab = stem if not n_seen else f"{stem}#{n_seen + 1}"
            labels.append(lab)
            stores.append((lab, s.iter_records()))
        md = render_compare(stores, title=args.title, k=args.top)
        out = Path(args.out) if args.out else \
            DEFAULT_REPORT_DIR / ("compare_" + "_vs_".join(
                Path(p).stem for p in args.compare[:2]) + ".md")
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(md)
        print(f"compare report -> {out} ({len(md)} chars, "
              f"{len(stores)} stores)")
        return 0

    if not args.store:
        ap.error("a store path is required (or use --selftest / --compare)")
    store = open_store(args.store)
    if not len(store):
        ap.error(f"store {args.store} is empty or missing")
    bench = None
    if args.bench:
        with open(args.bench) as f:
            bench = json.load(f)
    calibration = None
    if args.calibration:
        from repro.calib import Calibration
        calibration = Calibration.load(args.calibration)
    # merged telemetry from a --trace run rides next to the store; pick
    # it up automatically so traced campaigns get the health section
    ev_path = events_path_for(args.store)
    events = load_events(ev_path) if ev_path.exists() else None
    title = args.title or f"DSE campaign report — {Path(args.store).name}"
    md = render_report(store.iter_records(), title=title, bench=bench,
                       k=args.top, events=events, calibration=calibration)
    out = Path(args.out) if args.out else \
        DEFAULT_REPORT_DIR / f"{Path(args.store).stem}.md"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(md)
    print(f"report -> {out} ({len(md)} chars, "
          f"{len(store)} cells, backends: {', '.join(store.backends())}"
          + (f", {len(events)} telemetry events" if events else "") + ")")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
