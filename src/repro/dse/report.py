"""Markdown campaign reports from JSONL stores (+ optional bench JSON).

The ROADMAP's "perf-trajectory dashboard": turn any
:class:`repro.dse.store.ResultStore` — FPGA, TPU, or a mixed store — into
a human-readable Markdown report under ``docs/reports/``:

* per-backend **Pareto frontier tables**, ordered by NSGA-II rank +
  crowding distance so a truncated read-off still spreads across the
  trade-off surface (extremes first, clumps thinned);
* **per-workload winners** (best scalarized design per net@input / per
  arch/shape), following HybridDNN's practice of reporting the
  efficiency/latency trade-off per workload rather than a single scalar;
* **objective trade-off summaries** — for each objective, the frontier
  design that is best at it and what that choice costs on the others;
* an optional **benchmark appendix** from ``benchmarks/run.py --json``
  output, so paper-figure reproductions land in the same document.

CLI (also ``python -m repro.dse.report``)::

    python -m repro.dse.report results/dse.jsonl --out docs/reports/fpga.md
    python -m repro.dse.report results/dse_tpu.jsonl --bench bench.json
    python -m repro.dse.report --selftest   # render the built-in fixture

``--selftest`` renders a small built-in fixture store through the full
pipeline and fails loudly if anything in the render path regresses — CI
runs it as the docs check.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Mapping, Sequence

from .backends import BACKENDS, get_backend, record_backend
from .pareto import non_dominated, select_diverse
from .store import ResultStore

#: Where reports land unless --out says otherwise.
DEFAULT_REPORT_DIR = Path("docs/reports")


# ---------------------------------------------------------------------------
# markdown helpers
# ---------------------------------------------------------------------------


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(headers: Sequence[str], rows: Sequence[Sequence]) -> list[str]:
    # cell keys contain "|" (the axis separator) — escape so Markdown
    # doesn't read them as column breaks
    esc = lambda v: _fmt(v).replace("|", "\\|")
    out = ["| " + " | ".join(esc(h) for h in headers) + " |",
           "|" + "|".join(" --- " for _ in headers) + "|"]
    for row in rows:
        out.append("| " + " | ".join(esc(v) for v in row) + " |")
    return out


def _objective_columns(be) -> list[str]:
    return [f"{s.name} ({'max' if s.maximize else 'min'}, {s.units})"
            for s in be.objectives]


def _objective_values(be, rec: Mapping) -> list:
    return [rec["objectives"][s.name] for s in be.objectives]


# ---------------------------------------------------------------------------
# report body
# ---------------------------------------------------------------------------


def _backend_section(name: str, recs: list[dict], k: int) -> list[str]:
    be = get_backend(name)
    feas = [r for r in recs if r["objectives"].get("feasible")]
    lines = [f"## Backend `{name}` — {len(recs)} cells, "
             f"{len(feas)} feasible", ""]
    lines += ["Objectives: " + ", ".join(
        f"`{s.name}` ({'max' if s.maximize else 'min'}, {s.units})"
        for s in be.objectives), ""]
    if not feas:
        lines += ["_No feasible designs in this store._", ""]
        return lines

    vecs = [be.canonical(r["objectives"]) for r in feas]
    front_idx = non_dominated(vecs)
    front = [feas[i] for i in front_idx]
    fvecs = [vecs[i] for i in front_idx]
    # diversity order: whole front sorted by crowding so the top rows
    # are the spread, not a clump around one region
    order = select_diverse(fvecs, len(fvecs))

    lines += [f"### Pareto frontier ({len(front)} of {len(feas)} feasible, "
              f"crowding-distance order)", ""]
    cols = ["cell"] + _objective_columns(be)
    rows = [[f"`{front[i]['cell_key']}`"] + _objective_values(be, front[i])
            for i in order[:len(front) if k <= 0 else k]]
    shown = len(rows)
    lines += _table(cols, rows)
    if shown < len(front):
        lines += ["", f"_{len(front) - shown} more frontier designs in the "
                      f"store (rerun with `--top {len(front)}`)._"]
    lines += [""]

    # per-workload winners under the backend's default scalarization
    groups: dict[str, list[dict]] = {}
    for r in feas:
        groups.setdefault(be.group_key(r), []).append(r)
    lines += [f"### Per-workload winners "
              f"(best by default weights {dict(be.default_weights)})", ""]
    rows = []
    for g in sorted(groups):
        win = max(groups[g], key=lambda r: be.scalarize(r["objectives"]))
        rows.append([g, f"`{win['cell_key']}`"]
                    + _objective_values(be, win))
    lines += _table(["workload", "cell"] + _objective_columns(be), rows)
    lines += [""]

    # trade-off summary: the frontier specialist per objective
    lines += ["### Objective trade-offs (frontier specialist per "
              "objective)", ""]
    rows = []
    for j, spec in enumerate(be.objectives):
        best_i = max(range(len(front)), key=lambda i: fvecs[i][j])
        rows.append([f"`{spec.name}`", f"`{front[best_i]['cell_key']}`"]
                    + _objective_values(be, front[best_i]))
    lines += _table(["best at", "cell"] + _objective_columns(be), rows)
    lines += [""]
    return lines


def _bench_section(bench: Mapping) -> list[str]:
    lines = ["## Benchmark appendix (`benchmarks/run.py --json`)", ""]
    for name in sorted(bench.get("benchmarks", {})):
        rows = bench["benchmarks"][name]
        lines += [f"### `{name}`", ""]
        lines += _table(["row", "us/call", "derived"],
                        [[r["name"], f"{r['us_per_call']:.1f}",
                          f"`{r['derived']}`"] for r in rows])
        lines += [""]
    return lines


def render_report(records: Sequence[Mapping], *,
                  title: str = "DSE campaign report",
                  bench: Mapping | None = None, k: int = 12) -> str:
    """Records (any mix of backends) -> a Markdown report string.

    ``k`` caps each frontier table at the k most-spread designs
    (NSGA-II rank + crowding order); ``k <= 0`` means no cap.
    """
    groups: dict[str, list[dict]] = {}
    for r in records:
        groups.setdefault(record_backend(r), []).append(r)
    lines = [f"# {title}", "",
             f"{len(records)} campaign cells across "
             f"{len(groups)} backend(s): "
             + ", ".join(f"`{n}`" for n in sorted(groups)) + ".", ""]
    for name in sorted(groups):
        if name not in BACKENDS:
            lines += [f"## Backend `{name}` — {len(groups[name])} cells "
                      f"(unknown backend; skipped)", ""]
            continue
        lines += _backend_section(name, groups[name], k)
    if bench:
        lines += _bench_section(bench)
    return "\n".join(lines).rstrip() + "\n"


# ---------------------------------------------------------------------------
# selftest fixture
# ---------------------------------------------------------------------------


def fixture_records() -> list[dict]:
    """A tiny deterministic two-backend store: enough shape variety to
    exercise frontier extraction, crowding order, winner grouping, and
    trade-off tables without running any search."""
    recs = []
    fpga_pts = [  # (net, h, fpga, ips, gops, lat_ms, eff, bram, feasible)
        ("vgg16", 224, "ku115", 145.0, 4220.0, 6.9, 0.764, 1800, True),
        ("vgg16", 224, "zcu102", 66.0, 1930.0, 15.2, 0.771, 1100, True),
        ("vgg16", 64, "ku115", 1630.0, 3950.0, 0.61, 0.716, 1350, True),
        ("vgg16", 64, "zcu102", 760.0, 1840.0, 1.31, 0.733, 960, True),
        ("alexnet", 0, "ku115", 2250.0, 3280.0, 0.44, 0.594, 820, True),
        ("alexnet", 0, "zcu102", 990.0, 1450.0, 1.01, 0.577, 640, False),
    ]
    for net, h, fpga, ips, gops, lat, eff, bram, ok in fpga_pts:
        size = f"{h}x{h}" if h else "native"
        recs.append({
            "schema": 1,
            "cell_key": f"net={net}|in={size}|fpga={fpga}|prec=16|bmax=1",
            "cell": {"net": net, "h": h, "w": h, "fpga": fpga,
                     "precision": 16, "batch_max": 1},
            "rav": {"sp": 4, "batch": 1, "f_dsp": 0.9, "f_bram": 0.8,
                    "f_bw": 0.7},
            "objectives": {"throughput_ips": ips, "gops": gops,
                           "latency_s": lat / 1e3, "dsp_eff": eff,
                           "bram_used": float(bram), "feasible": ok},
            "search": {"base_seed": 0, "population": 20, "iterations": 30,
                       "weights": None},
            "evaluations": 600,
        })
    tpu_pts = [  # (arch, shape, chips, remat, mb, dp, tp, step, mfu, hbm, ok)
        ("starcoder2-3b", "train_4k", 8, "full", 2, 8, 1, 18.1, 0.52,
         10.4, True),
        ("starcoder2-3b", "train_4k", 16, "full", 2, 16, 1, 9.1, 0.51,
         5.2, True),
        ("starcoder2-3b", "train_4k", 16, "none", 2, 16, 1, 6.8, 0.58,
         24.7, False),
        ("starcoder2-3b", "decode_32k", 8, "none", 1, 8, 1, 0.021, 0.03,
         15.7, True),
        ("xlstm-350m", "train_4k", 8, "full", 1, 8, 1, 1.28, 0.47,
         2.4, True),
        ("xlstm-350m", "decode_32k", 8, "none", 1, 8, 1, 0.00064, 0.06,
         0.4, True),
    ]
    for arch, shape, chips, remat, mb, dp, tp, step, mfu, hbm, ok in tpu_pts:
        recs.append({
            "schema": 1,
            "backend": "tpu",
            "cell_key": (f"arch={arch}|shape={shape}|chips={chips}"
                         f"|remat={remat}|mb={mb}"),
            "cell": {"arch": arch, "shape": shape, "chips": chips,
                     "remat": remat, "microbatches": mb},
            "plan": {"dp": dp, "tp": tp, "bound": "compute"},
            "objectives": {"step_time_s": step, "mfu": mfu, "hbm_gib": hbm,
                           "chips": float(chips), "feasible": ok},
            "search": {"weights": None},
            "evaluations": 4,
        })
    return recs


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.report",
        description="Render a Markdown campaign report from a JSONL store "
                    "(plus optional benchmarks/run.py --json output).")
    ap.add_argument("store", nargs="?", default=None,
                    help="campaign JSONL store (any backend or a mix)")
    ap.add_argument("--bench", default=None, metavar="JSON",
                    help="benchmarks/run.py --json output to append")
    ap.add_argument("--out", default=None, metavar="MD",
                    help="output path (default: docs/reports/<store-stem>.md)")
    ap.add_argument("--title", default=None)
    ap.add_argument("--top", type=int, default=12,
                    help="frontier rows per backend, crowding-ordered "
                         "(<= 0: all)")
    ap.add_argument("--selftest", action="store_true",
                    help="render the built-in fixture store and exit "
                         "(the CI docs check); writes nothing")
    args = ap.parse_args(argv)

    if args.selftest:
        md = render_report(fixture_records(), title="selftest campaign",
                           k=args.top)
        for must in ("Pareto frontier", "Backend `fpga`", "Backend `tpu`",
                     "Per-workload winners", "Objective trade-offs"):
            if must not in md:
                raise SystemExit(f"selftest: section {must!r} missing "
                                 f"from rendered report")
        print(f"selftest OK: rendered {len(md)} chars, "
              f"{md.count(chr(10))} lines, all sections present")
        return 0

    if not args.store:
        ap.error("a store path is required (or use --selftest)")
    store = ResultStore(args.store)
    if not len(store):
        ap.error(f"store {args.store} is empty or missing")
    bench = None
    if args.bench:
        with open(args.bench) as f:
            bench = json.load(f)
    title = args.title or f"DSE campaign report — {Path(args.store).name}"
    md = render_report(store.records(), title=title, bench=bench, k=args.top)
    out = Path(args.out) if args.out else \
        DEFAULT_REPORT_DIR / f"{Path(args.store).stem}.md"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(md)
    print(f"report -> {out} ({len(md)} chars, "
          f"{len(store)} cells, backends: {', '.join(store.backends())})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
