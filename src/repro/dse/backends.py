"""Campaign backends: one accelerator family == one backend.

DNNExplorer's claim is a *dynamic* design space that adapts to "different
combinations of DNN workloads and targeted FPGAs"; this module widens
"targeted FPGAs" to *targeted device families*. A :class:`Backend` bundles
everything :func:`repro.dse.campaign.run_campaign` needs to sweep one
family:

* an **objective schema** (:class:`repro.dse.objectives.ObjectiveSpec`
  tuple + default scalarization weights) — Pareto dominance, crowding
  diversity, ranking, and reports all derive from it generically;
* **cell expansion** — the cross product of that family's campaign axes
  into picklable cell dataclasses with stable ``.key`` strings;
* **cell evaluation** — ``run_cell(cell) -> store record``, the unit the
  process pool fans out and the JSONL store memoizes;
* a **search config** dict stored per record and compared on resume, so a
  store never silently serves results found under different settings;
* presentation/CLI hooks (table rows, progress headlines, axis flags).

Three backends ship:

``fpga``
    The paper's flow, byte-compatible with PR-1 stores: cells are
    (net x input x FPGA x precision x batch cap), each evaluated by a full
    PSO :func:`repro.core.explore`; records carry no ``backend`` field so
    existing stores resume unchanged.

``tpu``
    The beyond-paper retarget: cells are (arch x shape x chip count x
    remat x microbatches), each evaluated by enumerating the power-of-two
    (dp, tp) factorizations of the chip count through
    :func:`repro.core.tpu_planner.evaluate_point` and keeping the best
    mapping under the cell's scalarization. Objectives: step time, MFU,
    per-chip HBM (with the HBM-fit feasibility gate), chips used.

``cuda``
    The same retarget over the GPU roofline
    (:mod:`repro.core.gpu_model` / :mod:`repro.core.gpu_planner`): cells
    add a GPU-part axis (a100-40g / a100-80g / h100) on top of the TPU
    backend's workload axes, and the (dp, tp) search inside each cell is
    identical in shape. Objectives mirror the TPU vector plus board
    watts (GPU parts differ in TDP at the same count, so power is a real
    trade-off axis within the family).

Every backend can additionally express any of its records in the
*normalized* cross-backend schema
(:data:`repro.dse.objectives.NORMALIZED_OBJECTIVES` — delivered TFLOP/s,
per watt, per dollar-proxy, per peak TFLOP) via :meth:`Backend.normalized`
— computed from stored objectives at read time, so pre-existing stores
compare across device families without re-running anything.
"""
from __future__ import annotations

import abc
import argparse
import dataclasses
import os
import time
from typing import Mapping, Sequence

from repro.configs import ARCH_IDS, SHAPES, cell_enabled, get_config
from repro.core import gpu_planner
from repro.core.explorer import TRACE_SCHEMA_VERSION
from repro.core.hw_specs import FPGAS, GPUS, TPU_V5E, alpha_for, pod_cost
from repro.core.netinfo import TABLE1_NETS
from repro.core.tpu_planner import evaluate_point, factorizations

from .objectives import (DEFAULT_WEIGHTS, OBJECTIVES, ObjectiveSpec,
                         canonical_vector, normalized_throughput,
                         scalarize_values)
from .store import SCHEMA_VERSION

#: Kept as a local literal (matches :data:`repro.testing.faults.ENV_VAR`)
#: so the disabled-harness hot path never imports repro.testing.
_FAULTS_ENV = "REPRO_FAULTS"


# ---------------------------------------------------------------------------
# CLI axis parsing (shared by both backends; re-exported by repro.dse.cli)
# ---------------------------------------------------------------------------


def _csv(text: str) -> list[str]:
    return [t.strip() for t in text.split(",") if t.strip()]


def parse_inputs(text: str) -> list[tuple[int, int]]:
    """``"224,320x480"`` -> ``[(224, 224), (320, 480)]``."""
    out = []
    for tok in _csv(text):
        h, _, w = tok.partition("x")
        try:
            out.append((int(h), int(w or h)))
        except ValueError:
            raise ValueError(
                f"bad input size {tok!r}; expected H or HxW "
                f"(e.g. 224 or 320x480)") from None
    return out


def parse_searcher_config(text: str) -> dict | None:
    """``"screen=2048,survivors=8"`` -> engine-config override dict (None
    if empty). Values coerce to int, then float, else stay strings, so
    the stored search config is JSON-stable regardless of whether it
    came from the CLI or a programmatic call."""
    if not text:
        return None
    out: dict = {}
    for tok in _csv(text):
        name, sep, val = (part.strip() for part in tok.partition("="))
        if not name or not sep:
            raise ValueError(f"bad searcher-config token {tok!r}; "
                             f"expected name=value")
        try:
            out[name] = int(val)
        except ValueError:
            try:
                out[name] = float(val)
            except ValueError:
                out[name] = val
    return out


def parse_weights(text: str) -> dict[str, float] | None:
    """``"throughput_ips=1,dsp_eff=500"`` -> weight dict (None if empty).
    A bare ``name`` or ``name=`` means weight 1.0."""
    if not text:
        return None
    out = {}
    for tok in _csv(text):
        name, _, val = (part.strip() for part in tok.partition("="))
        if not name:
            raise ValueError(f"bad weight token {tok!r}; "
                             f"expected name=value")
        try:
            out[name] = float(val) if val else 1.0
        except ValueError:
            raise ValueError(f"bad weight value in {tok!r}; "
                             f"expected a number after '='") from None
    return out


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------


class Backend(abc.ABC):
    """One device family's campaign contract (see module docstring)."""

    name: str
    objectives: tuple[ObjectiveSpec, ...]
    default_weights: Mapping[str, float]
    default_store: str
    #: Whether ``--searcher`` applies: True only for backends whose cells
    #: run a pluggable search engine (fpga); exhaustive enumerators
    #: (tpu, cuda) reject any non-default engine up front.
    supports_searchers: bool = False

    # -- objective-vector helpers (schema-generic, shared) ------------------

    def objective_names(self) -> tuple[str, ...]:
        return tuple(s.name for s in self.objectives)

    def canonical(self, objectives: Mapping[str, float]) -> tuple[float, ...]:
        """A record's ``objectives`` dict -> maximization-form vector."""
        return canonical_vector(objectives, self.objectives)

    def scalarize(self, objectives: Mapping,
                  weights: Mapping[str, float] | None = None) -> float:
        """Weighted canonical sum; infeasible records score 0.0."""
        return scalarize_values(objectives, self.objectives, weights,
                                self.default_weights)

    @abc.abstractmethod
    def normalized(self, rec: Mapping) -> dict:
        """A store record's objectives re-expressed in the cross-backend
        :data:`~repro.dse.objectives.NORMALIZED_OBJECTIVES` schema
        (delivered TFLOP/s, per watt, per dollar-proxy, per peak TFLOP).
        Computed from the STORED objectives + the hardware tables, not
        re-evaluated — legacy stores normalize without re-running."""

    # -- campaign contract ---------------------------------------------------

    @abc.abstractmethod
    def expand_cells(self, **axes) -> list:
        """Cross product of this backend's campaign axes -> cell list."""

    @abc.abstractmethod
    def run_cell(self, cell, *, base_seed: int = 0, population: int = 20,
                 iterations: int = 30,
                 weights: Mapping[str, float] | None = None,
                 searcher: str = "pso",
                 searcher_config: Mapping | None = None,
                 calibration=None) -> dict:
        """Evaluate ONE cell -> a JSONL store record. ``searcher`` /
        ``searcher_config`` select the engine on backends that search
        (ignored by exhaustive enumerators, which accept only the
        default — :func:`repro.dse.campaign.run_campaign` rejects the
        rest up front). ``calibration`` (a
        :class:`repro.calib.Calibration`) rescales the cell's hardware
        spec to measured delivered rates before evaluation and stamps a
        ``calibration`` provenance block on the record; ``None`` / the
        identity calibration evaluate byte-identically to pre-calibration
        behavior."""

    @abc.abstractmethod
    def search_config(self, *, base_seed: int, population: int,
                      iterations: int,
                      weights: Mapping[str, float] | None,
                      searcher: str = "pso",
                      searcher_config: Mapping | None = None,
                      calibration=None) -> dict:
        """The settings a record was searched with (resume-match key).
        A non-identity ``calibration`` contributes its fingerprint, so a
        store searched under one set of correction factors never silently
        serves a campaign run under another; identity contributes nothing
        (legacy stores resume byte-for-byte)."""

    # -- presentation --------------------------------------------------------

    @abc.abstractmethod
    def headline(self, rec: dict) -> str:
        """One-line progress metric for a finished cell."""

    @abc.abstractmethod
    def group_key(self, rec: dict) -> str:
        """Workload grouping for per-cell-winner report tables. Also the
        *workload key* :mod:`repro.dse.placement` matches candidates on —
        the TPU and CUDA backends share the ``arch/shape`` key space on
        purpose, so one workload can be hosted by either family."""

    # -- placement hooks (repro.dse.placement) -------------------------------

    @abc.abstractmethod
    def record_cost(self, rec: Mapping) -> tuple[float, float]:
        """(watts, usd_per_hour) of the hardware a stored design occupies,
        from the ``hw_specs`` TDP/$ tables — the budget currency of
        :mod:`repro.dse.placement`."""

    @abc.abstractmethod
    def placement_point(self, rec: Mapping) -> dict:
        """``{part, count, point}`` describing the assigned hardware: the
        named part, how many of it, and the intra-cell design point the
        search picked (FPGA: the RAV split; TPU/CUDA: the dp x tp mesh)."""

    @abc.abstractmethod
    def coverage_cells(self, workload_key: str) -> list:
        """Default campaign cells for ONE workload key (the coverage-query
        hook): when a placement store has no candidates for a workload,
        these cells are what :mod:`repro.dse.placement` evaluates to fill
        the gap. Returns [] for keys this backend cannot host."""

    @abc.abstractmethod
    def table_header(self) -> str: ...

    @abc.abstractmethod
    def table_row(self, rec: dict) -> str: ...

    # -- CLI -----------------------------------------------------------------

    @abc.abstractmethod
    def add_axis_arguments(self, ap) -> None:
        """Register this backend's campaign-axis flags on the parser."""

    @abc.abstractmethod
    def cells_from_args(self, args) -> list:
        """Parsed argparse namespace -> expanded cell list."""


# ---------------------------------------------------------------------------
# fpga — the paper's flow (byte-compatible with PR-1 stores)
# ---------------------------------------------------------------------------


class FPGABackend(Backend):
    """DNNExplorer's own design space: one PSO search per campaign cell.

    Thin delegation onto :mod:`repro.dse.campaign`'s original module-level
    functions (imported lazily; campaign imports this module's registry).
    Records and search configs are IDENTICAL to what PR 1 wrote, so
    pre-existing stores resume with zero re-evaluation. Since PR 5 each
    cell's PSO population is evaluated through the batched array-kernel
    engine (:mod:`repro.core.batch_eval`, wired inside
    :func:`repro.core.explore`) — same designs, ~an order of magnitude
    less analytical-model time per cell (the ``campaign_fpga`` bench
    measures both paths in one run).

    The only backend with a pluggable per-cell search engine
    (``supports_searchers``): ``--searcher`` picks from the
    :data:`repro.core.search.SEARCHERS` registry (default: the paper's
    PSO) and ``--searcher-config`` overrides that engine's config —
    see ``docs/search.md``. The other backends enumerate their mapping
    spaces exhaustively and reject the flags.
    """

    name = "fpga"
    objectives = OBJECTIVES
    default_weights = DEFAULT_WEIGHTS
    default_store = "results/dse_campaign.jsonl"
    supports_searchers = True

    def expand_cells(self, *, nets: Sequence[str],
                     inputs: Sequence[tuple[int, int]],
                     fpgas: Sequence[str], precisions: Sequence[int],
                     batch_caps: Sequence[int]) -> list:
        from .campaign import expand_cells
        return expand_cells(nets, inputs, fpgas, precisions, batch_caps)

    def run_cell(self, cell, *, base_seed=0, population=20, iterations=30,
                 weights=None, searcher="pso", searcher_config=None,
                 screen_fits=None, calibration=None) -> dict:
        from .campaign import run_cell
        return run_cell(cell, base_seed, population, iterations, weights,
                        searcher, searcher_config, screen_fits,
                        calibration=calibration)

    def search_config(self, *, base_seed, population, iterations,
                      weights, searcher="pso", searcher_config=None,
                      calibration=None) -> dict:
        from .campaign import _search_config
        return _search_config(base_seed, population, iterations, weights,
                              searcher, searcher_config,
                              calibration=calibration)

    def normalized(self, rec: Mapping) -> dict:
        """GOP/s -> TFLOP/s against the board's power/price and the
        precision-dependent DSP peak (Eq. 1) — ``tflops_per_peak`` is
        exactly the paper's DSP efficiency."""
        hw = FPGAS[rec["cell"]["fpga"]]
        o = rec["objectives"]
        peak_tflops = hw.peak_gops(alpha_for(rec["cell"]["precision"])) / 1e3
        return normalized_throughput(o["gops"] / 1e3, hw.tdp_watts,
                                     hw.usd_per_hour, peak_tflops,
                                     feasible=o.get("feasible", True))

    def record_cost(self, rec: Mapping) -> tuple[float, float]:
        """One board per design — the paper's accelerators are single-FPGA."""
        return pod_cost(FPGAS[rec["cell"]["fpga"]])

    def placement_point(self, rec: Mapping) -> dict:
        r = rec["rav"]
        return {"part": rec["cell"]["fpga"], "count": 1,
                "point": f"sp={r['sp']},b={r['batch']}"}

    def coverage_cells(self, workload_key: str) -> list:
        """``net@HxW`` / ``net@native`` -> one cell per FPGA part at the
        paper's default precision and batch cap."""
        from .campaign import RESIZABLE_NETS
        net, _, size = workload_key.partition("@")
        if net not in RESIZABLE_NETS and net not in TABLE1_NETS:
            return []
        inputs = [(0, 0)] if size in ("native", "") else parse_inputs(size)
        return self.expand_cells(nets=[net], inputs=inputs,
                                 fpgas=sorted(FPGAS), precisions=[16],
                                 batch_caps=[1])

    def headline(self, rec: dict) -> str:
        return f"{rec['objectives']['gops']:.1f} GOP/s"

    def group_key(self, rec: dict) -> str:
        c = rec["cell"]
        size = f"{c['h']}x{c['w']}" if c.get("h") else "native"
        return f"{c['net']}@{size}"

    def table_header(self) -> str:
        return (f"{'cell':<48} {'rav':<10} {'img/s':>8} {'GOP/s':>8} "
                f"{'lat_ms':>8} {'eff':>6} {'bram':>6}")

    def table_row(self, rec: dict) -> str:
        o, r = rec["objectives"], rec["rav"]
        return (f"{rec['cell_key']:<48} sp={r['sp']:>2} b={r['batch']:>2} "
                f"{o['throughput_ips']:>8.1f} {o['gops']:>8.1f} "
                f"{o['latency_s'] * 1e3:>8.2f} {o['dsp_eff']:>6.3f} "
                f"{int(o['bram_used']):>6}")

    def add_axis_arguments(self, ap) -> None:
        from .campaign import RESIZABLE_NETS
        g = ap.add_argument_group("fpga campaign axes")
        g.add_argument("--nets", default="vgg16",
                       help="comma list; resizable: %s; fixed: %s" % (
                           ",".join(RESIZABLE_NETS),
                           ",".join(n for n in TABLE1_NETS
                                    if n not in RESIZABLE_NETS)))
        g.add_argument("--inputs", default="224",
                       help="comma list of H or HxW for resizable nets")
        g.add_argument("--fpgas", default="ku115",
                       help="comma list from: " + ",".join(sorted(FPGAS)))
        g.add_argument("--precisions", default="16",
                       help="comma list of bit-widths (data == weights)")
        g.add_argument("--batch-caps", default="1",
                       help="comma list of PSO batch upper bounds")

    def cells_from_args(self, args) -> list:
        return self.expand_cells(
            nets=_csv(args.nets), inputs=parse_inputs(args.inputs),
            fpgas=_csv(args.fpgas),
            precisions=[int(p) for p in _csv(args.precisions)],
            batch_caps=[int(b) for b in _csv(args.batch_caps)])


# ---------------------------------------------------------------------------
# shared workload axes (tpu + cuda both sweep arch x shape x remat x mb)
# ---------------------------------------------------------------------------


def _add_once(group, *args, **kw) -> None:
    try:
        group.add_argument(*args, **kw)
    except argparse.ArgumentError:
        pass  # a sibling backend already registered this flag


def add_workload_arguments(ap) -> None:
    """Register the workload axes the TPU and CUDA backends share
    (``--archs/--shapes/--remats/--microbatches``). One CLI registers
    every backend's flags, so double registration must be a no-op."""
    g = ap.add_argument_group("workload axes (tpu & cuda backends)")
    _add_once(g, "--archs", default="starcoder2-3b",
              help="comma list from: " + ",".join(ARCH_IDS))
    _add_once(g, "--shapes", default="train_4k,decode_32k",
              help="comma list from: " + ",".join(SHAPES))
    _add_once(g, "--remats", default="full,dots,none",
              help="comma list of remat policies (train shapes)")
    _add_once(g, "--microbatches", default="1,2,4",
              help="comma list of microbatch counts (train shapes)")


#: Device-count budgets swept when placement must fill store coverage for
#: a workload (the tpu/cuda ``coverage_cells`` default axis).
PLACEMENT_COUNTS: tuple[int, ...] = (8, 16, 32)


def enumeration_trace(evaluated: int) -> dict:
    """Per-cell ``trace`` dict for an exhaustively-enumerated search
    (tpu/cuda): the whole mapping space is always visited, so the stop
    reason is ``"exhaustive"`` — such cells are never iteration-capped
    and never "still improving". Shares the schema of the PSO trace
    (:meth:`repro.core.explorer.ExplorationResult.convergence_trace`),
    so health reports render both uniformly."""
    return {"schema": TRACE_SCHEMA_VERSION, "engine": "enumeration",
            "stop_reason": "exhaustive", "iterations": evaluated,
            "evaluations": evaluated, "cache_hits": 0}


def stamp_calibration(cfg: dict, calibration) -> dict:
    """Add a non-identity calibration's fingerprint to a search-config
    dict (the resume-match key). Identity / ``None`` add nothing, so
    uncalibrated search configs — and therefore every pre-calibration
    store — stay byte-identical."""
    if calibration is not None and not calibration.is_identity():
        cfg["calibration"] = calibration.fingerprint()
    return cfg


def _arch_shape(workload_key: str) -> tuple[str, str] | None:
    """``arch/shape`` workload key -> (arch, shape), or None if the key
    isn't in the tpu/cuda key space (both families share it by design)."""
    arch, sep, shape = workload_key.partition("/")
    if not sep or arch not in ARCH_IDS or shape not in SHAPES:
        return None
    return arch, shape


def workload_families(workload_key: str) -> tuple[str, ...]:
    """Which device families can host a workload key: ``arch/shape`` keys
    are shared by the tpu AND cuda backends (that overlap is what lets
    :mod:`repro.dse.placement` choose a family per workload); ``net@size``
    keys belong to the fpga backend. Unknown keys return ()."""
    if _arch_shape(workload_key) is not None:
        return ("tpu", "cuda")
    from .campaign import RESIZABLE_NETS
    net = workload_key.partition("@")[0]
    if net in RESIZABLE_NETS or net in TABLE1_NETS:
        return ("fpga",)
    return ()


# ---------------------------------------------------------------------------
# tpu — the beyond-paper retarget over repro.core.tpu_planner
# ---------------------------------------------------------------------------

#: TPU campaign objective vector, in report order. ``hbm_gib`` is the
#: per-chip HBM demand; the 90%-of-HBM fit check is the feasibility gate.
TPU_OBJECTIVES: tuple[ObjectiveSpec, ...] = (
    ObjectiveSpec("step_time_s", False, "s"),
    ObjectiveSpec("mfu", True, "frac"),
    ObjectiveSpec("hbm_gib", False, "GiB"),
    ObjectiveSpec("chips", False, "chips"),
)

#: Latency-first by default (the planner's own primary sort); campaigns
#: re-weight with e.g. ``mfu=1`` or ``chips=-...`` for efficiency sweeps.
TPU_DEFAULT_WEIGHTS: Mapping[str, float] = {"step_time_s": 1.0}


@dataclasses.dataclass(frozen=True)
class TPUCell:
    """One point of the TPU campaign grid: a (workload, mapping-budget)
    pair. The dp x tp factorization of ``chips`` is NOT an axis — it is
    searched inside the cell (the local step), mirroring how an FPGA cell
    searches its RAV inside :func:`repro.core.explore`."""

    arch: str
    shape: str
    chips: int
    remat: str
    microbatches: int

    @property
    def key(self) -> str:
        return (f"arch={self.arch}|shape={self.shape}|chips={self.chips}"
                f"|remat={self.remat}|mb={self.microbatches}")


class TPUBackend(Backend):
    """Sweep (arch x shape x chips x remat x microbatches) through the
    analytic TPU planner; per cell, keep the best (dp, tp) mapping under
    the cell's scalarization (feasible mappings first)."""

    name = "tpu"
    objectives = TPU_OBJECTIVES
    default_weights = TPU_DEFAULT_WEIGHTS
    default_store = "results/dse_campaign_tpu.jsonl"

    def expand_cells(self, *, archs: Sequence[str], shapes: Sequence[str],
                     chips: Sequence[int],
                     remats: Sequence[str] = ("full", "dots", "none"),
                     microbatches: Sequence[int] = (1, 2, 4)) -> list[TPUCell]:
        """The TPU campaign grid. Remat and microbatching only exist for
        training shapes: inference shapes collapse those axes to
        ``(none, 1)`` and contribute one row per remaining axis. Cells the
        spec disables (e.g. full attention at 500k context) are skipped."""
        for s in shapes:
            if s not in SHAPES:
                raise KeyError(f"unknown shape {s!r}; known: {sorted(SHAPES)}")
        for c in chips:
            if c <= 0 or c & (c - 1):
                raise ValueError(f"chips must be a positive power of two "
                                 f"(got {c}); the planner factorizes the "
                                 f"mesh into power-of-two dp x tp ways")
        for r in remats:
            if r not in ("full", "dots", "none"):
                raise ValueError(f"unknown remat policy {r!r}; "
                                 f"choose from full, dots, none")
        cells, seen = [], set()
        for arch in archs:
            cfg = get_config(arch)  # raises KeyError on unknown arch
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                enabled, _why = cell_enabled(cfg, shape)
                if not enabled:
                    continue
                train = shape.kind == "train"
                for n in chips:
                    for remat in (remats if train else ("none",)):
                        for mb in (microbatches if train else (1,)):
                            cell = TPUCell(arch, shape_name, n, remat, mb)
                            if cell.key not in seen:
                                seen.add(cell.key)
                                cells.append(cell)
        return cells

    def run_cell(self, cell: TPUCell, *, base_seed=0, population=20,
                 iterations=30, weights=None, searcher="pso",
                 searcher_config=None, calibration=None) -> dict:
        """Enumerate the (dp, tp) factorizations of the cell's chip count;
        keep the best mapping: feasible first, then highest scalarized
        objective (ties to the earlier factorization — smaller tp)."""
        t0 = time.perf_counter()
        cfg = get_config(cell.arch)
        shape = SHAPES[cell.shape]
        best, best_rank, evaluated = None, None, 0
        for dp, tp in factorizations(cell.chips):
            if shape.global_batch % dp:
                continue
            plan = evaluate_point(cfg, shape, cell.chips, dp, tp,
                                  cell.remat, cell.microbatches,
                                  calibration=calibration)
            evaluated += 1
            obj = self._plan_objectives(cell, plan)
            # rank ignoring the feasibility gate (an all-infeasible cell
            # still reports its least-bad mapping), feasible plans first
            raw = scalarize_values({**obj, "feasible": True},
                                   self.objectives, weights,
                                   self.default_weights)
            rank = (plan.fits, raw)
            if best_rank is None or rank > best_rank:
                best, best_rank = (plan, obj), rank
        if best is None:
            raise ValueError(f"no valid dp x tp factorization for {cell.key} "
                             f"(global_batch={shape.global_batch})")
        plan, obj = best
        rec = {
            "schema": SCHEMA_VERSION,
            "backend": self.name,
            "cell_key": cell.key,
            "cell": dataclasses.asdict(cell),
            "arch_name": cfg.name,
            "search": self.search_config(base_seed=base_seed,
                                         population=population,
                                         iterations=iterations,
                                         weights=weights,
                                         calibration=calibration),
            "plan": {"dp": plan.dp, "tp": plan.tp,
                     "bound": plan.roofline.bound},
            "objectives": obj,
            "fitness": self.scalarize(obj, weights),
            "evaluations": evaluated,
            "search_time_s": round(time.perf_counter() - t0, 4),
            "weights": dict(weights) if weights else None,
            "trace": enumeration_trace(evaluated),
        }
        info = calibration.record_info(TPU_V5E.name) if calibration else None
        if info:
            rec["calibration"] = info
        return rec

    @staticmethod
    def _plan_objectives(cell: TPUCell, plan) -> dict:
        return {
            "step_time_s": plan.predicted_step_s,
            "mfu": plan.mfu,
            "hbm_gib": plan.hbm_per_chip / 2**30,
            "chips": float(cell.chips),
            "feasible": bool(plan.fits),
        }

    def search_config(self, *, base_seed, population, iterations,
                      weights, searcher="pso", searcher_config=None,
                      calibration=None) -> dict:
        """The planner enumerates its space exhaustively and
        deterministically, so search-engine knobs and seeds are
        irrelevant here; only the scalarization (which picks the
        per-cell mapping) and a non-identity calibration (which moves
        every modeled time) invalidate stored cells."""
        return stamp_calibration(
            {"weights": {k: float(v) for k, v in weights.items()}
             if weights else None}, calibration)

    def normalized(self, rec: Mapping) -> dict:
        """Delivered TFLOP/s from the stored MFU (useful FLOPs / step over
        the pod) against the pod's power/price/peak —
        ``tflops_per_peak`` is exactly the stored MFU."""
        o = rec["objectives"]
        hw = TPU_V5E
        chips = float(o["chips"])
        peak_tflops = chips * hw.peak_flops / 1e12
        return normalized_throughput(o["mfu"] * peak_tflops,
                                     chips * hw.tdp_watts,
                                     chips * hw.usd_per_hour, peak_tflops,
                                     feasible=o.get("feasible", True))

    def record_cost(self, rec: Mapping) -> tuple[float, float]:
        return pod_cost(TPU_V5E, int(rec["objectives"]["chips"]))

    def placement_point(self, rec: Mapping) -> dict:
        p = rec["plan"]
        return {"part": TPU_V5E.name, "count": int(rec["objectives"]["chips"]),
                "point": f"dp{p['dp']}xtp{p['tp']}"}

    def coverage_cells(self, workload_key: str) -> list:
        """``arch/shape`` -> that workload at every default chip budget."""
        parsed = _arch_shape(workload_key)
        if parsed is None:
            return []
        arch, shape = parsed
        return self.expand_cells(archs=[arch], shapes=[shape],
                                 chips=PLACEMENT_COUNTS)

    def headline(self, rec: dict) -> str:
        o = rec["objectives"]
        return (f"step={o['step_time_s']:.3g}s mfu={o['mfu']:.2f} "
                f"hbm={o['hbm_gib']:.1f}GiB")

    def group_key(self, rec: dict) -> str:
        c = rec["cell"]
        return f"{c['arch']}/{c['shape']}"

    def table_header(self) -> str:
        return (f"{'cell':<58} {'dpxtp':<8} {'step_s':>10} {'mfu':>6} "
                f"{'hbm_gib':>8} {'chips':>6} {'bound':<10}")

    def table_row(self, rec: dict) -> str:
        o, p = rec["objectives"], rec["plan"]
        return (f"{rec['cell_key']:<58} {p['dp']}x{p['tp']:<6} "
                f"{o['step_time_s']:>10.4g} {o['mfu']:>6.3f} "
                f"{o['hbm_gib']:>8.2f} {int(o['chips']):>6} {p['bound']:<10}")

    def add_axis_arguments(self, ap) -> None:
        add_workload_arguments(ap)
        g = ap.add_argument_group("tpu campaign axes")
        g.add_argument("--chips", default="8,16,32",
                       help="comma list of chip counts (powers of two)")

    def cells_from_args(self, args) -> list[TPUCell]:
        return self.expand_cells(
            archs=_csv(args.archs), shapes=_csv(args.shapes),
            chips=[int(c) for c in _csv(args.chips)],
            remats=tuple(_csv(args.remats)),
            microbatches=tuple(int(m) for m in _csv(args.microbatches)))


# ---------------------------------------------------------------------------
# cuda — the GPU roofline retarget over repro.core.gpu_planner
# ---------------------------------------------------------------------------

#: CUDA campaign objective vector, in report order. Mirrors the TPU
#: vector, plus board watts: the GPU-part axis makes power a real
#: trade-off WITHIN the family (an H100 pod beats an A100 pod on step
#: time at the same count but burns 1.75x the board power).
GPU_OBJECTIVES: tuple[ObjectiveSpec, ...] = (
    ObjectiveSpec("step_time_s", False, "s"),
    ObjectiveSpec("mfu", True, "frac"),
    ObjectiveSpec("hbm_gib", False, "GiB"),
    ObjectiveSpec("gpus", False, "gpus"),
    ObjectiveSpec("watts", False, "W"),
)

#: Latency-first by default, same as the TPU backend.
GPU_DEFAULT_WEIGHTS: Mapping[str, float] = {"step_time_s": 1.0}


@dataclasses.dataclass(frozen=True)
class CUDACell:
    """One point of the CUDA campaign grid: a (workload, GPU part,
    GPU-count budget) triple. As on the TPU side, the dp x tp
    factorization of ``gpus`` is searched INSIDE the cell."""

    arch: str
    shape: str
    gpu: str             # GPUSpec name (a100-40g, a100-80g, h100)
    gpus: int
    remat: str
    microbatches: int

    @property
    def key(self) -> str:
        return (f"arch={self.arch}|shape={self.shape}|gpu={self.gpu}"
                f"|gpus={self.gpus}|remat={self.remat}"
                f"|mb={self.microbatches}")


class CUDABackend(Backend):
    """Sweep (arch x shape x GPU part x GPU count x remat x microbatches)
    through the analytic GPU roofline; per cell, keep the best (dp, tp)
    mapping under the cell's scalarization (feasible mappings first)."""

    name = "cuda"
    objectives = GPU_OBJECTIVES
    default_weights = GPU_DEFAULT_WEIGHTS
    default_store = "results/dse_campaign_cuda.jsonl"

    def expand_cells(self, *, archs: Sequence[str], shapes: Sequence[str],
                     gpus: Sequence[int],
                     gpu_types: Sequence[str] = ("a100-80g",),
                     remats: Sequence[str] = ("full", "dots", "none"),
                     microbatches: Sequence[int] = (1, 2, 4),
                     ) -> list[CUDACell]:
        """The CUDA campaign grid: the TPU backend's workload axes crossed
        with the GPU-part axis. Inference shapes collapse (remat, mb) to
        ``(none, 1)``; spec-disabled (arch, shape) combos are skipped."""
        for s in shapes:
            if s not in SHAPES:
                raise KeyError(f"unknown shape {s!r}; known: {sorted(SHAPES)}")
        for g in gpu_types:
            if g not in GPUS:
                raise KeyError(f"unknown gpu {g!r}; known: {sorted(GPUS)}")
        for n in gpus:
            if n <= 0 or n & (n - 1):
                raise ValueError(f"gpus must be a positive power of two "
                                 f"(got {n}); the planner factorizes the "
                                 f"mesh into power-of-two dp x tp ways")
        for r in remats:
            if r not in ("full", "dots", "none"):
                raise ValueError(f"unknown remat policy {r!r}; "
                                 f"choose from full, dots, none")
        cells, seen = [], set()
        for arch in archs:
            cfg = get_config(arch)  # raises KeyError on unknown arch
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                enabled, _why = cell_enabled(cfg, shape)
                if not enabled:
                    continue
                train = shape.kind == "train"
                for gpu in gpu_types:
                    for n in gpus:
                        for remat in (remats if train else ("none",)):
                            for mb in (microbatches if train else (1,)):
                                cell = CUDACell(arch, shape_name, gpu, n,
                                                remat, mb)
                                if cell.key not in seen:
                                    seen.add(cell.key)
                                    cells.append(cell)
        return cells

    def run_cell(self, cell: CUDACell, *, base_seed=0, population=20,
                 iterations=30, weights=None, searcher="pso",
                 searcher_config=None, calibration=None) -> dict:
        """Enumerate the (dp, tp) factorizations of the cell's GPU count
        on the cell's part; keep the best mapping: feasible first, then
        highest scalarized objective (ties to the smaller tp)."""
        t0 = time.perf_counter()
        cfg = get_config(cell.arch)
        shape = SHAPES[cell.shape]
        hw = GPUS[cell.gpu]
        best, best_rank, evaluated = None, None, 0
        for dp, tp in factorizations(cell.gpus):
            if shape.global_batch % dp:
                continue
            plan = gpu_planner.evaluate_point(cfg, shape, cell.gpus, dp, tp,
                                              cell.remat, cell.microbatches,
                                              hw, calibration=calibration)
            evaluated += 1
            obj = self._plan_objectives(cell, plan, hw)
            # rank ignoring the feasibility gate (an all-infeasible cell
            # still reports its least-bad mapping), feasible plans first
            raw = scalarize_values({**obj, "feasible": True},
                                   self.objectives, weights,
                                   self.default_weights)
            rank = (plan.fits, raw)
            if best_rank is None or rank > best_rank:
                best, best_rank = (plan, obj), rank
        if best is None:
            raise ValueError(f"no valid dp x tp factorization for {cell.key} "
                             f"(global_batch={shape.global_batch})")
        plan, obj = best
        rec = {
            "schema": SCHEMA_VERSION,
            "backend": self.name,
            "cell_key": cell.key,
            "cell": dataclasses.asdict(cell),
            "arch_name": cfg.name,
            "search": self.search_config(base_seed=base_seed,
                                         population=population,
                                         iterations=iterations,
                                         weights=weights,
                                         calibration=calibration),
            "plan": {"dp": plan.dp, "tp": plan.tp,
                     "bound": plan.roofline.bound},
            "objectives": obj,
            "fitness": self.scalarize(obj, weights),
            "evaluations": evaluated,
            "search_time_s": round(time.perf_counter() - t0, 4),
            "weights": dict(weights) if weights else None,
            "trace": enumeration_trace(evaluated),
        }
        info = calibration.record_info(cell.gpu) if calibration else None
        if info:
            rec["calibration"] = info
        return rec

    @staticmethod
    def _plan_objectives(cell: CUDACell, plan, hw) -> dict:
        return {
            "step_time_s": plan.predicted_step_s,
            "mfu": plan.mfu,
            "hbm_gib": plan.hbm_per_gpu / 2**30,
            "gpus": float(cell.gpus),
            "watts": cell.gpus * hw.tdp_watts,
            "feasible": bool(plan.fits),
        }

    def search_config(self, *, base_seed, population, iterations,
                      weights, searcher="pso", searcher_config=None,
                      calibration=None) -> dict:
        """Deterministic exhaustive enumeration, like the TPU backend:
        only the scalarization (which picks the per-cell mapping) and a
        non-identity calibration invalidate stored cells."""
        return stamp_calibration(
            {"weights": {k: float(v) for k, v in weights.items()}
             if weights else None}, calibration)

    def normalized(self, rec: Mapping) -> dict:
        """Delivered TFLOP/s from the stored MFU against the pod's
        power/price/peak for the cell's GPU part."""
        o = rec["objectives"]
        hw = GPUS[rec["cell"]["gpu"]]
        n = float(o["gpus"])
        peak_tflops = n * hw.peak_flops / 1e12
        return normalized_throughput(o["mfu"] * peak_tflops, o["watts"],
                                     n * hw.usd_per_hour, peak_tflops,
                                     feasible=o.get("feasible", True))

    def record_cost(self, rec: Mapping) -> tuple[float, float]:
        return pod_cost(GPUS[rec["cell"]["gpu"]],
                        int(rec["objectives"]["gpus"]))

    def placement_point(self, rec: Mapping) -> dict:
        p = rec["plan"]
        return {"part": rec["cell"]["gpu"],
                "count": int(rec["objectives"]["gpus"]),
                "point": f"dp{p['dp']}xtp{p['tp']}"}

    def coverage_cells(self, workload_key: str) -> list:
        """``arch/shape`` -> that workload at every default GPU-count
        budget, across every part in the GPU table."""
        parsed = _arch_shape(workload_key)
        if parsed is None:
            return []
        arch, shape = parsed
        return self.expand_cells(archs=[arch], shapes=[shape],
                                 gpus=PLACEMENT_COUNTS,
                                 gpu_types=tuple(sorted(GPUS)))

    def headline(self, rec: dict) -> str:
        o = rec["objectives"]
        return (f"step={o['step_time_s']:.3g}s mfu={o['mfu']:.2f} "
                f"hbm={o['hbm_gib']:.1f}GiB {int(o['watts'])}W")

    def group_key(self, rec: dict) -> str:
        c = rec["cell"]
        return f"{c['arch']}/{c['shape']}"

    def table_header(self) -> str:
        return (f"{'cell':<64} {'dpxtp':<8} {'step_s':>10} {'mfu':>6} "
                f"{'hbm_gib':>8} {'gpus':>5} {'watts':>7} {'bound':<10}")

    def table_row(self, rec: dict) -> str:
        o, p = rec["objectives"], rec["plan"]
        return (f"{rec['cell_key']:<64} {p['dp']}x{p['tp']:<6} "
                f"{o['step_time_s']:>10.4g} {o['mfu']:>6.3f} "
                f"{o['hbm_gib']:>8.2f} {int(o['gpus']):>5} "
                f"{int(o['watts']):>7} {p['bound']:<10}")

    def add_axis_arguments(self, ap) -> None:
        add_workload_arguments(ap)
        g = ap.add_argument_group("cuda campaign axes")
        g.add_argument("--gpus", default="8,16,32",
                       help="comma list of GPU counts (powers of two)")
        g.add_argument("--gpu-types", default="a100-80g",
                       help="comma list from: " + ",".join(sorted(GPUS)))

    def cells_from_args(self, args) -> list[CUDACell]:
        return self.expand_cells(
            archs=_csv(args.archs), shapes=_csv(args.shapes),
            gpus=[int(n) for n in _csv(args.gpus)],
            gpu_types=tuple(_csv(args.gpu_types)),
            remats=tuple(_csv(args.remats)),
            microbatches=tuple(int(m) for m in _csv(args.microbatches)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BACKENDS: dict[str, Backend] = {b.name: b for b in (FPGABackend(),
                                                    TPUBackend(),
                                                    CUDABackend())}


def get_backend(backend: str | Backend) -> Backend:
    """Resolve a backend by name (or pass an instance through)."""
    if isinstance(backend, Backend):
        return backend
    try:
        return BACKENDS[backend]
    except KeyError:
        raise KeyError(f"unknown backend {backend!r}; "
                       f"known: {sorted(BACKENDS)}") from None


def record_backend(rec: Mapping) -> str:
    """Which backend wrote a store record. Legacy (PR-1) FPGA records
    predate the field and carry no ``backend`` key."""
    return rec.get("backend", "fpga")


def run_cell_by_backend(backend_name: str, cell, base_seed: int,
                        population: int, iterations: int,
                        weights: Mapping[str, float] | None,
                        obs: Mapping | None = None,
                        searcher: str = "pso",
                        searcher_config: Mapping | None = None,
                        screen_fits=None, calibration=None,
                        attempt: int = 1, faults=None) -> dict:
    """Top-level (picklable) pool entry point: resolve the backend by name
    in the worker and evaluate one cell.

    ``obs`` (``{events_dir, t_submit}``) turns on worker-side telemetry:
    the worker opens its own sidecar under ``events_dir``
    (:func:`repro.obs.worker_tracer`), back-fills a ``queue.wait`` span
    from the parent's submit time, nests a ``cell.eval`` span inside
    ``cell.run``, and gauges the batched engine's cache stats — the
    parent merges every sidecar after the pool drains. ``obs=None`` (the
    default, and the disabled-tracing path) touches no files.

    ``screen_fits`` forwards the cell's precomputed rung-0 screening
    fitnesses (:func:`repro.dse.campaign.prescreen_cells_jax`) and is
    only ever non-None for the fpga backend — the exhaustive
    enumerators never see the keyword. ``calibration`` (picklable)
    forwards the campaign's correction factors into the worker.

    ``attempt`` is the 1-based retry attempt the resilience layer is on
    — workers are stateless across retries, so the attempt number rides
    in. ``faults`` arms the deterministic fault-injection harness
    (:mod:`repro.testing.faults`): a plan path/dict/FaultPlan, defaulting
    to the ``REPRO_FAULTS`` env var (inherited by spawn workers). Unset
    — the production case — the check is a single dict lookup and the
    harness module is never imported."""
    if faults is None:
        faults = os.environ.get(_FAULTS_ENV)
    plan = None
    if faults:
        from repro.testing.faults import load_plan
        plan = load_plan(faults)
        plan.fire_before(cell.key, attempt)
    be = get_backend(backend_name)
    kw = {} if screen_fits is None else {"screen_fits": screen_fits}
    if not obs:
        rec = be.run_cell(cell, base_seed=base_seed, population=population,
                          iterations=iterations, weights=weights,
                          searcher=searcher,
                          searcher_config=searcher_config,
                          calibration=calibration, **kw)
        return plan.mangle_after(cell.key, attempt, rec) if plan else rec
    from repro.obs import worker_tracer
    with worker_tracer(obs["events_dir"]) as tracer:
        tracer.span_at("queue.wait", obs["t_submit"],
                       time.time() - obs["t_submit"], cell=cell.key)
        with tracer.span("cell.run", cell=cell.key, backend=backend_name):
            with tracer.span("cell.eval", cell=cell.key):
                rec = be.run_cell(cell, base_seed=base_seed,
                                  population=population,
                                  iterations=iterations, weights=weights,
                                  searcher=searcher,
                                  searcher_config=searcher_config,
                                  calibration=calibration, **kw)
            if backend_name == "fpga":
                from repro.core.batch_eval import cache_stats
                for cache, st in cache_stats().items():
                    tracer.gauge(f"cache.{cache}.hits", st["hits"],
                                 cell=cell.key)
                    tracer.gauge(f"cache.{cache}.misses", st["misses"],
                                 cell=cell.key)
    return plan.mangle_after(cell.key, attempt, rec) if plan else rec
