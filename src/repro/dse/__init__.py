"""repro.dse — batch multi-objective DSE campaigns over the paper's flow.

:mod:`repro.core.explorer` runs DNNExplorer's 3-step flow (Fig. 4) for ONE
(DNN, FPGA) pair and one scalar objective. This package lifts that to the
campaign scale the paper's evaluation actually operates at ("different
combinations of DNN workloads and targeted FPGAs", Tables 3/4, Figs. 9-11):

1. *Campaign expansion* — :mod:`repro.dse.campaign` sweeps the cross
   product of (network x input size x FPGA x precision x batch cap),
   fanning independent PSO searches out over a process pool with a
   deterministic seed per cell.
2. *Multi-objective evaluation* — :mod:`repro.dse.objectives` turns each
   :class:`repro.core.DesignPoint` into an objective vector (throughput
   img/s, GOP/s, latency, DSP efficiency, BRAM footprint) plus a
   scalarization knob; the paper's throughput-only search is the
   default-weights special case.
3. *Frontier extraction* — :mod:`repro.dse.pareto` non-dominated-sorts
   the campaign's designs into Pareto fronts, so "fastest", "smallest"
   and "most efficient" survive side by side instead of collapsing into
   one scalar winner.
4. *Persistence* — :mod:`repro.dse.store` appends every finished cell to
   a JSON-lines store keyed on (campaign cell, RAV hash); re-running a
   campaign reuses stored cells, which makes killed campaigns resumable
   and repeat cells free across runs.

Quickstart (see also ``examples/dse_campaign.py``)::

    python -m repro.dse.campaign --nets vgg16 --fpgas ku115,zcu102 \\
        --precisions 16,8 --store results/dse.jsonl
"""
from .objectives import (OBJECTIVES, ObjectiveSpec, Objectives,
                         scalarized_objective)
from .pareto import dominates, non_dominated, nondominated_sort, pareto_front
from .store import ResultStore, rav_hash

# Campaign exports resolve lazily (PEP 562) so `python -m repro.dse.campaign`
# doesn't import the module twice (runpy's found-in-sys.modules warning).
_CAMPAIGN_EXPORTS = ("CampaignCell", "CampaignReport", "cell_seed",
                     "expand_cells", "run_campaign", "run_cell")

__all__ = [
    *_CAMPAIGN_EXPORTS, "OBJECTIVES", "ObjectiveSpec", "Objectives",
    "scalarized_objective", "dominates", "non_dominated",
    "nondominated_sort", "pareto_front", "ResultStore", "rav_hash",
]


def __getattr__(name: str):
    if name in _CAMPAIGN_EXPORTS:
        from . import campaign
        return getattr(campaign, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
