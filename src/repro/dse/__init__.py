"""repro.dse — backend-agnostic multi-objective DSE campaigns.

:mod:`repro.core.explorer` runs DNNExplorer's 3-step flow (Fig. 4) for ONE
(DNN, FPGA) pair and one scalar objective. This package lifts that to the
campaign scale the paper's evaluation actually operates at ("different
combinations of DNN workloads and targeted FPGAs", Tables 3/4, Figs. 9-11)
— and widens "targeted FPGAs" to targeted *device families*:

1. *Backends* — :mod:`repro.dse.backends` gives each device family a
   campaign contract: an objective schema, cell expansion over that
   family's axes, per-cell evaluation, and a resume-match search config.
   The ``fpga`` backend (default) sweeps (network x input size x FPGA x
   precision x batch cap) with one PSO search per cell; the ``tpu``
   backend sweeps (arch x shape x chip count x remat x microbatches)
   through the analytic planner in :mod:`repro.core.tpu_planner`; the
   ``cuda`` backend adds a GPU-part axis (A100-40G/A100-80G/H100) over
   the GPU roofline in :mod:`repro.core.gpu_model` /
   :mod:`repro.core.gpu_planner`.
2. *Campaign running* — :mod:`repro.dse.campaign` fans a backend's cells
   out over a process pool with deterministic per-cell seeds, collecting
   records into a resumable JSONL store as they finish.
3. *Multi-objective evaluation* — :mod:`repro.dse.objectives` defines the
   schema machinery (canonical maximization form, weighted
   scalarization); each backend declares its own vector (FPGA:
   throughput img/s, GOP/s, latency, DSP efficiency, BRAM; TPU: step
   time, MFU, HBM per chip, chips used; CUDA: the TPU vector plus board
   watts) — plus the NORMALIZED cross-backend schema (delivered TFLOP/s,
   per watt, per dollar-proxy, per peak TFLOP) every backend can emit
   via ``Backend.normalized(record)``, so one frontier compares device
   families.
4. *Frontier extraction* — :mod:`repro.dse.pareto` non-dominated-sorts
   the campaign's designs into Pareto fronts and, NSGA-II-style, orders
   them by crowding distance so a truncated frontier is a SPREAD across
   the trade-off surface (extremes kept, clumps thinned);
   ``CampaignReport.frontier(k=N)`` returns the N most-diverse designs.
5. *Persistence* — :mod:`repro.dse.store` appends every finished cell to
   a JSON-lines store keyed on the cell key; re-running a campaign reuses
   stored cells, which makes killed campaigns resumable and repeat cells
   free across runs. FPGA records are byte-compatible with PR-1 stores.
6. *Reporting* — :mod:`repro.dse.report` renders any store (plus optional
   ``benchmarks/run.py --json`` output) into a Markdown campaign report:
   frontier tables, per-workload winners, objective trade-off summaries,
   and — for stores mixing device families — a cross-backend normalized
   frontier. ``--compare A B [C ...]`` renders the trajectory between
   stores: per-workload winner deltas, best-objective trajectories, and
   a pooled cross-backend frontier.
7. *Telemetry* — :mod:`repro.obs` threads structured spans, counters,
   and gauges through the campaign runner (``--trace``): per-cell
   queue-wait/eval/append spans from every pool worker land in
   ``<store>.events.jsonl`` (merged deterministically from per-worker
   sidecars) plus a Chrome trace at ``<store>.trace.json``; every
   record carries a ``trace`` field with the search's convergence
   history and stop reason. ``python -m repro.dse.obs`` summarizes,
   validates, and exports; the report gains a campaign-health section.

Quickstart (see also ``examples/dse_campaign.py`` and ``README.md``)::

    # FPGA campaign (the paper's flow; default backend):
    python -m repro.dse.campaign --nets vgg16 --fpgas ku115,zcu102 \\
        --precisions 16,8 --store results/dse.jsonl

    # TPU campaign (beyond-paper retarget of the same engine):
    python -m repro.dse.campaign --backend tpu --archs starcoder2-3b,xlstm-350m \\
        --shapes train_4k,decode_32k --chips 8,16,32 --store results/dse_tpu.jsonl

    # CUDA campaign (GPU roofline; the GPU part is a campaign axis):
    python -m repro.dse.campaign --backend cuda --archs starcoder2-3b \\
        --shapes train_4k,decode_32k --gpus 8,16,32 \\
        --gpu-types a100-80g,h100 --store results/dse_cuda.jsonl

    # Markdown report (frontier tables, per-workload winners, trade-offs;
    # mixed stores also get a cross-backend normalized frontier):
    python -m repro.dse.report results/dse.jsonl --out docs/reports/fpga.md

    # Compare stores: winner deltas + objective trajectories:
    python -m repro.dse.report --compare results/dse_tpu.jsonl \\
        results/dse_cuda.jsonl --out docs/reports/tpu_vs_cuda.md
"""
from .objectives import (NORMALIZED_DEFAULT_WEIGHTS, NORMALIZED_OBJECTIVES,
                         OBJECTIVES, ObjectiveSpec, Objectives,
                         canonical_vector, normalized_throughput,
                         scalarize_values, scalarized_objective)
from .frontier import FrontierIndex
from .pareto import (crowding_distance, diverse_front, dominance_split,
                     dominates, non_dominated, nondominated_sort,
                     pareto_front, select_diverse)

# Campaign/backend/report/store exports resolve lazily (PEP 562) so
# `python -m repro.dse.campaign` / `python -m repro.dse.report` /
# `python -m repro.dse.store` don't import their module twice (runpy's
# found-in-sys.modules warning).
_CAMPAIGN_EXPORTS = ("CampaignCell", "CampaignReport", "cell_seed",
                     "expand_cells", "prescreen_cells_jax", "run_campaign",
                     "run_cell")
_BACKEND_EXPORTS = ("BACKENDS", "Backend", "CUDABackend", "CUDACell",
                    "FPGABackend", "GPU_OBJECTIVES", "TPUBackend",
                    "TPUCell", "TPU_OBJECTIVES", "get_backend",
                    "workload_families")
_REPORT_EXPORTS = ("fixture_events", "fixture_records", "health_section",
                   "render_compare", "render_placement", "render_report")
_OBS_EXPORTS = ("events_for_store", "example_health_md")
_STORE_EXPORTS = ("CampaignStore", "ResultStore", "is_ok", "open_store",
                  "rav_hash", "record_status")
_RESILIENCE_EXPORTS = ("CellOutcome", "CellTimeout", "CorruptRecord",
                       "RetryPolicy", "WorkerCrash", "execute_cell",
                       "interrupt_scope", "quarantine_record",
                       "run_resilient_pool")
_PLACEMENT_EXPORTS = ("Assignment", "BudgetInfeasibleError", "Candidate",
                      "CoverageError", "PlacementError", "PlacementResult",
                      "candidates_by_workload", "ensure_coverage",
                      "marginal_upgrades", "parse_workloads", "place",
                      "pooled_records", "prune_candidates")

__all__ = [
    *_CAMPAIGN_EXPORTS, *_BACKEND_EXPORTS, *_REPORT_EXPORTS,
    *_PLACEMENT_EXPORTS, *_OBS_EXPORTS, *_RESILIENCE_EXPORTS,
    "is_ok", "record_status",
    "NORMALIZED_DEFAULT_WEIGHTS", "NORMALIZED_OBJECTIVES",
    "OBJECTIVES", "ObjectiveSpec", "Objectives", "canonical_vector",
    "normalized_throughput", "scalarize_values", "scalarized_objective",
    "crowding_distance", "diverse_front", "dominance_split", "dominates",
    "non_dominated", "nondominated_sort", "pareto_front", "select_diverse",
    "CampaignStore", "FrontierIndex", "ResultStore", "open_store",
    "rav_hash",
]


def __getattr__(name: str):
    if name in _CAMPAIGN_EXPORTS:
        from . import campaign
        return getattr(campaign, name)
    if name in _BACKEND_EXPORTS:
        from . import backends
        return getattr(backends, name)
    if name in _REPORT_EXPORTS:
        from . import report
        return getattr(report, name)
    if name in _PLACEMENT_EXPORTS:
        from . import placement
        return getattr(placement, name)
    if name in _OBS_EXPORTS:
        from . import obs
        return getattr(obs, name)
    if name in _STORE_EXPORTS:
        from . import store
        return getattr(store, name)
    if name in _RESILIENCE_EXPORTS:
        from . import resilience
        return getattr(resilience, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
