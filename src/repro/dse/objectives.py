"""Multi-objective view of a design point, with scalarization knobs.

The analytical models already produce every quantity the related work ranks
on (HybridDNN: throughput + latency; Being-ahead: resource efficiency); a
campaign keeps all of them per design instead of collapsing to throughput
inside the fitness. ``Objectives.canonical()`` maps the vector to pure
maximization form (minimized objectives negated) so Pareto dominance and
weighted scalarization are sign-uniform downstream.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.local_opt import DesignPoint


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    name: str
    maximize: bool
    units: str


#: Campaign objective vector, in report order.
OBJECTIVES: tuple[ObjectiveSpec, ...] = (
    ObjectiveSpec("throughput_ips", True, "img/s"),
    ObjectiveSpec("gops", True, "GOP/s"),
    ObjectiveSpec("latency_s", False, "s"),
    ObjectiveSpec("dsp_eff", True, "frac"),
    ObjectiveSpec("bram_used", False, "blocks"),
)

OBJECTIVE_NAMES: tuple[str, ...] = tuple(s.name for s in OBJECTIVES)

#: The paper's original search objective (single-objective special case).
DEFAULT_WEIGHTS: Mapping[str, float] = {"throughput_ips": 1.0}


@dataclasses.dataclass(frozen=True)
class Objectives:
    throughput_ips: float
    gops: float
    latency_s: float
    dsp_eff: float
    bram_used: float
    feasible: bool = True

    @classmethod
    def from_design(cls, d: DesignPoint) -> "Objectives":
        return cls(throughput_ips=d.throughput_ips, gops=d.gops,
                   latency_s=d.latency_s, dsp_eff=d.dsp_eff,
                   bram_used=float(d.bram_used), feasible=d.feasible)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "Objectives":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})

    def canonical(self, names: Sequence[str] = OBJECTIVE_NAMES,
                  ) -> tuple[float, ...]:
        """Maximization-form vector (minimized objectives negated)."""
        sense = {s.name: s.maximize for s in OBJECTIVES}
        vals = dataclasses.asdict(self)
        return tuple(vals[n] if sense[n] else -vals[n] for n in names)

    def scalarize(self, weights: Mapping[str, float] | None = None) -> float:
        """Weighted sum over the canonical (max-form) vector. Infeasible
        designs score 0.0 — with ``DEFAULT_WEIGHTS`` this equals
        :attr:`DesignPoint.fitness` exactly."""
        if not self.feasible:
            return 0.0
        w = DEFAULT_WEIGHTS if weights is None else weights
        canon = dict(zip(OBJECTIVE_NAMES, self.canonical()))
        unknown = set(w) - set(canon)
        if unknown:
            raise KeyError(f"unknown objectives: {sorted(unknown)}; "
                           f"choose from {OBJECTIVE_NAMES}")
        return sum(wi * canon[n] for n, wi in w.items())


def scalarized_objective(weights: Mapping[str, float] | None = None,
                         ) -> Callable[[DesignPoint], float]:
    """A ``DesignPoint -> float`` fitness for :func:`repro.core.explore`'s
    ``objective`` hook (picklable arguments, so campaigns can ship the
    weights to pool workers and rebuild the closure there)."""
    def objective(d: DesignPoint) -> float:
        return Objectives.from_design(d).scalarize(weights)
    return objective
