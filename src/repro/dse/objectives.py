"""Multi-objective view of a design point, with scalarization knobs.

The analytical models already produce every quantity the related work ranks
on (HybridDNN: throughput + latency; Being-ahead: resource efficiency); a
campaign keeps all of them per design instead of collapsing to throughput
inside the fitness. ``Objectives.canonical()`` maps the vector to pure
maximization form (minimized objectives negated) so Pareto dominance and
weighted scalarization are sign-uniform downstream.

Two layers live here:

* the *generic* helpers (:func:`canonical_vector`,
  :func:`scalarize_values`) work on any ``{name: value}`` objectives dict
  against any :class:`ObjectiveSpec` schema — each campaign backend
  (:mod:`repro.dse.backends`) declares its own schema and reuses these;
* the *normalized* cross-backend schema (:data:`NORMALIZED_OBJECTIVES` +
  :func:`normalized_throughput`): delivered TFLOP/s, per watt, per
  dollar-proxy, and per peak TFLOP — units every device family can emit,
  so one frontier can compare FPGA, TPU, and GPU designs;
* the FPGA-specific :class:`Objectives` dataclass (the paper's five
  quantities) keeps the original typed API and record layout.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.local_opt import DesignPoint


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    name: str
    maximize: bool
    units: str


#: Campaign objective vector, in report order.
OBJECTIVES: tuple[ObjectiveSpec, ...] = (
    ObjectiveSpec("throughput_ips", True, "img/s"),
    ObjectiveSpec("gops", True, "GOP/s"),
    ObjectiveSpec("latency_s", False, "s"),
    ObjectiveSpec("dsp_eff", True, "frac"),
    ObjectiveSpec("bram_used", False, "blocks"),
)

OBJECTIVE_NAMES: tuple[str, ...] = tuple(s.name for s in OBJECTIVES)

#: The paper's original search objective (single-objective special case).
DEFAULT_WEIGHTS: Mapping[str, float] = {"throughput_ips": 1.0}


#: The cross-backend objective vector: every backend can express its
#: designs in these units (useful TFLOP/s achieved, then that throughput
#: normalized by board power, by an hourly dollar proxy, and by the
#: part's peak TFLOP/s), so ONE Pareto frontier can compare device
#: families. ``tflops_per_peak`` generalizes the paper's DSP efficiency
#: and the TPU side's MFU; the watt/dollar terms follow Being-ahead's
#: practice of ranking heterogeneous accelerators on delivered
#: performance per unit cost rather than raw throughput. All values are
#: analytic-model predictions (roofline upper bounds with recompute
#: FLOPs excluded from the numerator), comparable across families
#: because every family is modeled the same way — they rank designs,
#: they don't certify absolute hardware numbers.
NORMALIZED_OBJECTIVES: tuple[ObjectiveSpec, ...] = (
    ObjectiveSpec("tflops", True, "TFLOP/s"),
    ObjectiveSpec("tflops_per_watt", True, "TFLOP/s/W"),
    ObjectiveSpec("tflops_per_dollar", True, "TFLOP/s/($/h)"),
    ObjectiveSpec("tflops_per_peak", True, "frac"),
)

#: Raw delivered throughput ranks cross-backend winners by default;
#: re-weight with e.g. ``tflops_per_watt=1`` for efficiency frontiers.
NORMALIZED_DEFAULT_WEIGHTS: Mapping[str, float] = {"tflops": 1.0}


def normalized_throughput(tflops: float, watts: float, usd_per_hour: float,
                          peak_tflops: float, *,
                          feasible: bool = True) -> dict:
    """Fold one design's delivered TFLOP/s and its hardware's power/price/
    peak into the :data:`NORMALIZED_OBJECTIVES` vector. Each backend's
    ``normalized(record)`` reduces to this after computing its own
    delivered-throughput and hardware terms."""
    return {
        "tflops": tflops,
        "tflops_per_watt": tflops / watts if watts else 0.0,
        "tflops_per_dollar": tflops / usd_per_hour if usd_per_hour else 0.0,
        "tflops_per_peak": tflops / peak_tflops if peak_tflops else 0.0,
        "feasible": bool(feasible),
    }


def canonical_vector(values: Mapping[str, float],
                     specs: Sequence[ObjectiveSpec]) -> tuple[float, ...]:
    """``{name: value}`` -> maximization-form tuple in spec order
    (minimized objectives negated). Schema-generic: works for any
    backend's objective dict."""
    return tuple(float(values[s.name]) if s.maximize else -float(values[s.name])
                 for s in specs)


def scalarize_values(values: Mapping, specs: Sequence[ObjectiveSpec],
                     weights: Mapping[str, float] | None = None,
                     default_weights: Mapping[str, float] | None = None,
                     ) -> float:
    """Weighted sum over the canonical (max-form) vector of any backend's
    objectives dict. Infeasible designs (``values["feasible"]`` falsy)
    score 0.0. Unknown weight names raise ``KeyError``."""
    if not values.get("feasible", True):
        return 0.0
    w = weights if weights is not None else (default_weights or
                                             {specs[0].name: 1.0})
    names = tuple(s.name for s in specs)
    canon = dict(zip(names, canonical_vector(values, specs)))
    unknown = set(w) - set(canon)
    if unknown:
        raise KeyError(f"unknown objectives: {sorted(unknown)}; "
                       f"choose from {names}")
    return sum(wi * canon[n] for n, wi in w.items())


@dataclasses.dataclass(frozen=True)
class Objectives:
    throughput_ips: float
    gops: float
    latency_s: float
    dsp_eff: float
    bram_used: float
    feasible: bool = True

    @classmethod
    def from_design(cls, d: DesignPoint) -> "Objectives":
        return cls(throughput_ips=d.throughput_ips, gops=d.gops,
                   latency_s=d.latency_s, dsp_eff=d.dsp_eff,
                   bram_used=float(d.bram_used), feasible=d.feasible)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "Objectives":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})

    def canonical(self, names: Sequence[str] = OBJECTIVE_NAMES,
                  ) -> tuple[float, ...]:
        """Maximization-form vector (minimized objectives negated)."""
        sense = {s.name: s.maximize for s in OBJECTIVES}
        vals = dataclasses.asdict(self)
        return tuple(vals[n] if sense[n] else -vals[n] for n in names)

    def scalarize(self, weights: Mapping[str, float] | None = None) -> float:
        """Weighted sum over the canonical (max-form) vector. Infeasible
        designs score 0.0 — with ``DEFAULT_WEIGHTS`` this equals
        :attr:`DesignPoint.fitness` exactly."""
        return scalarize_values(self.as_dict(), OBJECTIVES, weights,
                                DEFAULT_WEIGHTS)


def scalarized_objective(weights: Mapping[str, float] | None = None,
                         ) -> Callable[[DesignPoint], float]:
    """A ``DesignPoint -> float`` fitness for :func:`repro.core.explore`'s
    ``objective`` hook (picklable arguments, so campaigns can ship the
    weights to pool workers and rebuild the closure there)."""
    def objective(d: DesignPoint) -> float:
        return Objectives.from_design(d).scalarize(weights)
    return objective
