"""Campaign runner: a backend's campaign grid, one search per cell, fanned
out over a process pool.

Each *cell* is an independent single-workload exploration (FPGA: the whole
of :func:`repro.core.explore`; TPU: a mapping enumeration through
:mod:`repro.core.tpu_planner` — see :mod:`repro.dse.backends`), so
campaigns parallelize embarrassingly; the pool fans cells out and the
JSONL store collects them as they finish. FPGA seeds are derived per cell
from ``(base_seed, cell key)``, so a campaign's results are reproducible
regardless of worker count, completion order, or which cells a resumed run
still has to do.

The module-level grid/evaluation functions here (``expand_cells``,
``run_cell``, ...) are the FPGA backend's implementation — kept at module
level both for backward compatibility and so pool workers can pickle them.

Run as a module for the CLI::

    python -m repro.dse.campaign --nets vgg16 --fpgas ku115,zcu102 \\
        --precisions 16,8
    python -m repro.dse.campaign --backend tpu --archs starcoder2-3b \\
        --shapes train_4k --chips 8,16
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import multiprocessing
import time
from concurrent.futures import ProcessPoolExecutor
from pathlib import Path
from typing import TYPE_CHECKING, Callable, Iterable, Mapping, Sequence

from repro.core.explorer import explore
from repro.core.hw_specs import FPGAS
from repro.core.netinfo import NetInfo, TABLE1_NETS, vgg16, vgg19
from repro.core.pso import PSOConfig
from repro.obs import (NULL, Tracer, chrome_path_for, chrome_trace,
                       events_dir_for, events_path_for, merge_events)

from .frontier import FrontierIndex
from .objectives import Objectives, scalarized_objective
from .pareto import select_diverse
from .resilience import (RetryPolicy, execute_cell, interrupt_scope,
                         run_resilient_pool)
from .store import (SCHEMA_VERSION, CampaignStore, is_ok, open_store,
                    rav_hash, record_status)

if TYPE_CHECKING:  # pragma: no cover - circular-import-free type hints
    from .backends import Backend

#: Nets whose input resolution is a campaign axis (the paper's Fig. 1/9/10
#: sweep). Fixed-topology nets from Table 1 run at their native input.
RESIZABLE_NETS: dict[str, Callable[[int, int], NetInfo]] = {
    "vgg16": lambda h, w: vgg16(h, w),
    "vgg19": lambda h, w: vgg19(h, w, with_fc=False),
}


@dataclasses.dataclass(frozen=True)
class CampaignCell:
    """One point of the campaign grid. ``h == w == 0`` means the network's
    native input (fixed-topology nets)."""

    net: str
    h: int
    w: int
    fpga: str
    precision: int   # data & weight bits (the paper quantizes both together)
    batch_max: int

    @property
    def key(self) -> str:
        size = f"{self.h}x{self.w}" if self.h else "native"
        return (f"net={self.net}|in={size}|fpga={self.fpga}"
                f"|prec={self.precision}|bmax={self.batch_max}")


def build_net(name: str, h: int = 0, w: int = 0) -> NetInfo:
    if name in RESIZABLE_NETS:
        if h <= 0:
            h = w = 224
        return RESIZABLE_NETS[name](h, w)
    if name in TABLE1_NETS:
        return TABLE1_NETS[name]()
    known = sorted(set(RESIZABLE_NETS) | set(TABLE1_NETS))
    raise KeyError(f"unknown net {name!r}; known: {known}")


def expand_cells(nets: Sequence[str], inputs: Sequence[tuple[int, int]],
                 fpgas: Sequence[str], precisions: Sequence[int],
                 batch_caps: Sequence[int]) -> list[CampaignCell]:
    """The campaign grid. Input sizes multiply only the resizable nets;
    fixed nets contribute one (native-input) row per remaining axis."""
    for f in fpgas:
        if f not in FPGAS:
            raise KeyError(f"unknown fpga {f!r}; known: {sorted(FPGAS)}")
    cells = []
    for net in nets:
        sizes = list(inputs) if net in RESIZABLE_NETS else [(0, 0)]
        for h, w in sizes:
            for fpga in fpgas:
                for prec in precisions:
                    for bmax in batch_caps:
                        cells.append(CampaignCell(net, h, w, fpga, prec, bmax))
    return cells


def cell_seed(base_seed: int, cell: CampaignCell) -> int:
    """Deterministic PSO seed for one cell: stable across runs, worker
    counts, and cell orderings."""
    digest = hashlib.sha256(f"{base_seed}|{cell.key}".encode()).digest()
    return int.from_bytes(digest[:4], "big") & 0x7FFFFFFF


def _search_config(base_seed: int, population: int, iterations: int,
                   weights: Mapping[str, float] | None,
                   searcher: str = "pso",
                   searcher_config: Mapping | None = None,
                   calibration=None) -> dict:
    """What a record was searched *with*. Stored per record and compared on
    resume, so a store never silently serves results found under different
    search settings or objective weights — including a different search
    ENGINE: a store written by one engine resumed under another re-runs
    instead of mixing results. JSON-native values only (the dict must
    survive a json round trip unchanged). The ``searcher`` keys are only
    present when non-default, so PR-1 stores (written before engines were
    pluggable) still resume byte-for-byte under the default PSO; likewise
    a ``calibration`` key appears only for a non-identity calibration
    (its fingerprint — corrected and uncorrected results never mix)."""
    cfg = {"base_seed": int(base_seed), "population": int(population),
           "iterations": int(iterations),
           "weights": {k: float(v) for k, v in weights.items()} if weights
           else None}
    if searcher != "pso" or searcher_config:
        cfg["searcher"] = searcher
        cfg["searcher_config"] = dict(searcher_config) \
            if searcher_config else None
    from .backends import stamp_calibration
    return stamp_calibration(cfg, calibration)


def run_cell(cell: CampaignCell, base_seed: int = 0, population: int = 20,
             iterations: int = 30,
             weights: Mapping[str, float] | None = None,
             searcher: str = "pso",
             searcher_config: Mapping | None = None,
             screen_fits=None, calibration=None) -> dict:
    """One full explore() for one cell -> a store record. Top-level (and all
    arguments picklable) so ProcessPoolExecutor can ship it to workers.
    ``screen_fits`` optionally carries this cell's precomputed rung-0
    screening fitnesses (:func:`prescreen_cells_jax`). ``calibration``
    (a :class:`repro.calib.Calibration`) rescales the board's clock and
    bandwidth to measured delivered rates before the search — every
    evaluation inside :func:`repro.core.explore` (scalar reference and
    batched engine alike) then sees the corrected part."""
    net = build_net(cell.net, cell.h, cell.w)
    fpga = FPGAS[cell.fpga]
    if calibration is not None:
        fpga = calibration.for_spec(fpga)
    cfg = PSOConfig(population=population, iterations=iterations,
                    seed=cell_seed(base_seed, cell))
    res = explore(net, fpga, dw=cell.precision, ww=cell.precision,
                  batch_max=cell.batch_max, cfg=cfg,
                  objective=scalarized_objective(weights),
                  searcher=searcher, searcher_config=searcher_config,
                  screen_fits=screen_fits)
    d = res.design
    rec = {
        "schema": SCHEMA_VERSION,
        "cell_key": cell.key,
        "cell": dataclasses.asdict(cell),
        "net_name": net.name,
        "search": _search_config(base_seed, population, iterations, weights,
                                 searcher, searcher_config, calibration),
        "seed": cfg.seed,
        "rav": dataclasses.asdict(d.rav),
        "rav_hash": rav_hash(d.rav),
        "objectives": Objectives.from_design(d).as_dict(),
        "fitness": res.pso.best_fitness,
        "evaluations": res.pso.evaluations,
        "iterations": res.pso.iterations_run,
        "search_time_s": round(res.search_time_s, 4),
        "weights": dict(weights) if weights else None,
        "trace": res.convergence_trace(),
    }
    info = calibration.record_info(cell.fpga) if calibration else None
    if info:
        rec["calibration"] = info
    return rec


def prescreen_cells_jax(cells: Sequence[CampaignCell], *,
                        base_seed: int = 0, population: int = 20,
                        iterations: int = 30,
                        searcher_config: Mapping | None = None,
                        calibration=None) -> dict | None:
    """Screen every cell's hyperband rung 0 in ONE jitted jax call.

    Reproduces each cell's :class:`~repro.core.search.HyperbandConfig`
    through the same construction path the searcher uses
    (:func:`repro.core.search.searcher_config_for`), generates the exact
    rung-0 position block the engine will ask for
    (:func:`repro.core.search.hyperband_rung0`), and evaluates the whole
    (cells x screen) batch through the cross-cell jax kernel
    (:mod:`repro.core.screen_jax` — bit-identical to the per-cell NumPy
    reference). Returns ``{cell_key: (screen,) fitness array}`` to hand
    to :func:`run_cell` as ``screen_fits``, or ``None`` when jax is
    unavailable (callers fall back to the per-cell NumPy screen).
    """
    from repro.core import screen_jax
    from repro.core.search import (SearchSpace, hyperband_rung0,
                                   searcher_config_for)
    if not screen_jax.available():
        return None
    import numpy as np
    tables, blocks, keys = [], [], []
    for cell in cells:
        net = build_net(cell.net, cell.h, cell.w)
        fpga = FPGAS[cell.fpga]
        if calibration is not None:
            # same corrected part run_cell will search, so the screening
            # fitnesses match the engine's own rung-0 evaluations
            fpga = calibration.for_spec(fpga)
        pso = PSOConfig(population=population, iterations=iterations,
                        seed=cell_seed(base_seed, cell))
        cfg = searcher_config_for(
            "hyperband",
            base=dict(population=pso.population, iterations=pso.iterations,
                      patience=pso.patience, seed=pso.seed),
            overrides=searcher_config)
        space = SearchSpace(sp_max=len(net.major_layers),
                            batch_max=cell.batch_max)
        blocks.append(hyperband_rung0(space, cfg))
        tables.append(screen_jax.cell_tables(net, fpga, cell.precision,
                                             cell.precision))
        keys.append(cell.key)
    if not keys:
        return {}
    ips = screen_jax.screen_cells(screen_jax.stack_cells(tables),
                                  np.stack(blocks))
    return {k: ips[i] for i, k in enumerate(keys)}


@dataclasses.dataclass
class CampaignReport:
    cells: list                  # backend cells (CampaignCell, TPUCell, ...)
    records: list[dict]          # per cell in cell order; quarantined
    #                              (status "failed") records included,
    #                              cells interrupted before running absent
    reused_cells: int
    new_cells: int
    new_evaluations: int         # search evaluations actually run this time
    wall_time_s: float
    backend: "Backend | None" = None   # None == fpga (PR-1 compatibility)
    events_path: Path | None = None    # merged events JSONL (traced runs)
    trace_path: Path | None = None     # Chrome trace export (traced runs)
    failed_cells: int = 0        # quarantined records among `records`
    retried_cells: int = 0       # cells that succeeded after >= 1 retry
    missing_cells: int = 0       # requested cells with no record at all
    pool_rebuilds: int = 0       # worker-pool replacements (crash/timeout)
    interrupted: bool = False    # SIGINT/SIGTERM stopped the campaign

    def _backend(self) -> "Backend":
        if self.backend is None:
            from .backends import get_backend
            self.backend = get_backend("fpga")
        return self.backend

    @property
    def partial(self) -> bool:
        """True when the campaign did NOT deliver every requested cell as
        a normal result — interrupted, quarantined, or missing cells.
        The CLI exits 3 on partial campaigns (with a resume hint)."""
        return bool(self.interrupted or self.failed_cells
                    or self.missing_cells)

    def failures(self) -> list[dict]:
        """The quarantined (``status: "failed"``) records, cell order."""
        return [r for r in self.records if not is_ok(r)]

    def feasible(self) -> list[dict]:
        return [r for r in self.records
                if is_ok(r) and r.get("objectives", {}).get("feasible")]

    def frontier_index(self) -> FrontierIndex:
        """The campaign's incremental Pareto archive: feasible records
        streamed once into a :class:`repro.dse.frontier.FrontierIndex`
        (keys are feasible-record positions, payloads the records), built
        lazily and cached — :meth:`frontier` and the report generator
        read the front off this index instead of re-sorting the full
        record list."""
        if getattr(self, "_fi", None) is None:
            be = self._backend()
            fi = FrontierIndex()
            for i, r in enumerate(self.feasible()):
                fi.insert(i, be.canonical(r["objectives"]), payload=r)
            self._fi = fi
        return self._fi

    def ranked(self, weights: Mapping[str, float] | None = None) -> list[dict]:
        be = self._backend()
        recs = self.feasible()
        return sorted(recs, key=lambda r: be.scalarize(r["objectives"],
                                                       weights), reverse=True)

    def frontier(self, k: int | None = None) -> list[dict]:
        """Pareto-optimal designs across every feasible one in the campaign.

        ``k=None`` returns the whole first front in campaign-cell order
        (the original behavior). With ``k``, NSGA-II selection returns up
        to ``k`` designs ordered by (front rank, crowding distance): a
        SPREAD across the trade-off surface — extremes always included,
        clumps thinned — topped up from later fronts when the first front
        has fewer than ``k`` members.

        Both paths read the cached :meth:`frontier_index`; only ``k``
        larger than the first front falls back to the full NSGA-II sort
        (the incremental archive keeps front 0 only).
        """
        fi = self.frontier_index()
        if k is None:
            return [fi.payload(key) for key in fi.front_keys()]
        if k <= fi.front_size():
            return [fi.payload(key) for key in fi.diverse(k)]
        be = self._backend()
        recs = self.feasible()
        vecs = [be.canonical(r["objectives"]) for r in recs]
        return [recs[i] for i in select_diverse(vecs, k)]


def run_campaign(cells: Iterable,
                 store: CampaignStore | str, *, base_seed: int = 0,
                 population: int = 20, iterations: int = 30,
                 weights: Mapping[str, float] | None = None,
                 workers: int = 1,
                 progress: Callable[[str], None] | None = None,
                 backend: "str | Backend" = "fpga",
                 trace: bool = False,
                 verbose: bool = False,
                 searcher: str = "pso",
                 searcher_config: Mapping | None = None,
                 shard: int | str = 0,
                 jax_screen: bool = False,
                 calibration=None,
                 policy: RetryPolicy | None = None,
                 retry_failed: bool = False,
                 install_signal_handlers: bool = True,
                 ) -> CampaignReport:
    """Run (or resume) a campaign against a JSONL store.

    ``backend`` selects the device family (``"fpga"`` — the default and
    the paper's flow — or ``"tpu"``; see :mod:`repro.dse.backends`) and
    must match the cells. Cells already in the store *with the same search
    config* (for FPGA: base seed, population, iterations, weights) are
    reused verbatim — zero new search evaluations — so re-running a
    finished campaign is free and a killed one picks up where it stopped;
    changing the search config re-runs the affected cells instead of
    serving stale designs. ``workers > 1`` fans the remaining cells over a
    spawn-based process pool; results land in the store in completion
    order, the report in cell order either way.

    ``trace=True`` records structured telemetry (:mod:`repro.obs`):
    per-cell queue-wait / eval / store-append spans and pool gauges land
    in per-process sidecars under ``<store>.events/``, which the parent
    merges into ``<store>.events.jsonl`` and exports as a Chrome trace
    (``<store>.trace.json``) when the campaign finishes; the report's
    ``events_path`` / ``trace_path`` point at both. Disabled (the
    default), no telemetry files are touched and the only residue is a
    no-op tracer. ``verbose`` adds per-cell convergence detail (stop
    reason, PSO cache hits) to the progress lines.

    ``store`` may name a v1 single JSONL file (the default layout) or a
    sharded ``<store>.d/`` directory (see :mod:`repro.dse.store`);
    ``shard`` names the shard THIS campaign process appends to, so
    several hosts can run disjoint slices of one grid against the same
    sharded store — each writes its own shard, resume reads them all —
    with no lock contention.

    ``searcher`` picks the FPGA cells' search engine
    (:data:`repro.core.search.SEARCHERS`; default ``"pso"``) and
    ``searcher_config`` overrides that engine's config fields. Both ride
    in the stored search config, so a store written by one engine never
    silently serves a campaign run under another — mismatched cells
    re-run. Backends that enumerate exhaustively (tpu, cuda) accept only
    the default engine.

    ``jax_screen=True`` (fpga backend + ``searcher="hyperband"`` only)
    precomputes every to-run cell's rung-0 screening fitnesses in ONE
    jitted cross-cell jax call (:func:`prescreen_cells_jax`) and hands
    each cell its slice — results are bit-identical to the per-cell
    NumPy screen, which also remains the silent fallback when jax is
    not importable.

    ``calibration`` (a :class:`repro.calib.Calibration`) applies fitted
    per-part correction factors to every hardware spec the cells are
    evaluated against and stamps each record with the factors' provenance;
    its fingerprint joins the stored search config, so calibrated and
    uncalibrated results never mix on resume. ``None`` (the default) and
    the identity calibration are byte-identical to pre-calibration runs.

    Execution is fault-tolerant (:mod:`repro.dse.resilience`): ``policy``
    (default :class:`~repro.dse.resilience.RetryPolicy` seeded from
    ``base_seed``) retries transient per-cell failures with deterministic
    backoff, enforces an optional per-cell wall-clock timeout on the pool
    path, and survives worker crashes by rebuilding the pool and
    resubmitting the lost in-flight cells. A cell that exhausts its
    attempts is *quarantined* — stored as a ``status: "failed"`` record
    carrying the exception and per-attempt history — instead of aborting
    the campaign; quarantined cells resume as done until
    ``retry_failed=True`` (CLI ``--retry-failed``) opts them back in.
    SIGINT/SIGTERM (``install_signal_handlers``, main thread only) stop
    submissions, drain/cancel in-flight cells, flush the store and
    telemetry sidecars, and return a partial report
    (:attr:`CampaignReport.interrupted`; the CLI exits 3 with a resume
    hint). First-attempt successes are stored byte-identically to
    pre-resilience campaigns; only retried records gain a ``resilience``
    block.
    """
    from .backends import get_backend, run_cell_by_backend
    be = get_backend(backend)
    if searcher != "pso" and not getattr(be, "supports_searchers", False):
        raise ValueError(
            f"backend {be.name!r} enumerates its space exhaustively and "
            f"has no pluggable search engine; --searcher {searcher!r} is "
            f"only valid for the fpga backend")
    cells = list(cells)
    store = open_store(store, shard=shard)

    tracer, events_dir = NULL, None
    if trace:
        events_dir = events_dir_for(store.path)
        if events_dir.exists():  # stale sidecars would pollute the merge
            for old in events_dir.glob("*.jsonl"):
                old.unlink()
        tracer = Tracer(events_dir / "main.jsonl", proc="main")
        if store.corrupt_lines:
            tracer.count("store.corrupt_lines", store.corrupt_lines,
                         store=str(store.path))

    t0 = time.perf_counter()
    search = be.search_config(base_seed=base_seed, population=population,
                              iterations=iterations, weights=weights,
                              searcher=searcher,
                              searcher_config=searcher_config,
                              calibration=calibration)
    # A stored cell counts as done only if it was searched with the same
    # settings; a config change re-runs (and overwrites) stale records.
    # Quarantined cells count as done too — a permanent failure must not
    # be re-hit on every resume — unless retry_failed opts them back in.
    policy = policy or RetryPolicy(seed=base_seed)
    todo, quarantined_prior = [], 0
    for c in cells:
        prior = store.get(c.key)
        if prior is None or prior.get("search") != search:
            todo.append(c)
        elif record_status(prior) != "ok":
            if retry_failed:
                todo.append(c)
            else:
                quarantined_prior += 1
    say = progress or (lambda _msg: None)
    say(f"campaign[{be.name}]: {len(cells)} cells, "
        f"{len(cells) - len(todo)} reused, "
        f"{len(todo)} to run (workers={workers})"
        + (f" — {quarantined_prior} quarantined cell(s) skipped; "
           f"--retry-failed re-runs them" if quarantined_prior else ""))
    tracer.count("cells.reused", len(cells) - len(todo))

    screen_fits: dict = {}
    if jax_screen:
        if be.name != "fpga" or searcher != "hyperband":
            raise ValueError(
                "jax_screen precomputes hyperband rung-0 screening and "
                "applies only to the fpga backend with "
                "searcher='hyperband'")
        if todo:
            with tracer.span("screen.jax", cells=len(todo)):
                fits = prescreen_cells_jax(
                    todo, base_seed=base_seed, population=population,
                    iterations=iterations, searcher_config=searcher_config,
                    calibration=calibration)
            if fits is None:
                say("jax unavailable — cells fall back to the per-cell "
                    "NumPy screen (identical results)")
            else:
                screen_fits = fits
                n = len(next(iter(fits.values()))) if fits else 0
                say(f"jax-screened {len(fits)} cells x {n} rung-0 "
                    f"candidates in one call")
                tracer.count("screen.jax_cells", len(fits))

    new_evals = 0
    done = 0
    failed_now = 0
    retried_now = 0
    pool_rebuilds = 0
    interrupted = False

    def finish(outcome) -> None:
        """Store and narrate one CellOutcome (success or quarantine)."""
        nonlocal new_evals, done, failed_now, retried_now
        rec = outcome.record
        if rec is None:           # interrupted mid-cell: nothing stored
            return
        done += 1
        with tracer.span("store.append", cell=outcome.cell.key):
            store.put(rec)
        elapsed = time.perf_counter() - t0
        if outcome.failed:
            failed_now += 1
            say(f"  [{done}/{len(todo)}] {outcome.cell.key}: FAILED — "
                f"{rec['error_type']} after {rec['attempts']} attempt(s), "
                f"quarantined | elapsed {elapsed:.1f}s")
            return
        if outcome.retried:
            retried_now += 1
        new_evals += rec["evaluations"]
        tracer.count("cells.done")
        eta = elapsed / done * (len(todo) - done)
        extra = ""
        if verbose and rec.get("trace"):
            tr = rec["trace"]
            extra = (f" [{tr.get('stop_reason', '?')}"
                     f"@{tr.get('iterations', '?')}it"
                     f", {tr.get('cache_hits', 0)} cache hits]")
        if outcome.retried:
            extra += f" [ok on attempt {len(outcome.attempt_log)}]"
        say(f"  [{done}/{len(todo)}] {outcome.cell.key}: {be.headline(rec)}, "
            f"{rec['evaluations']} evals, {rec['search_time_s']:.2f}s"
            f"{extra} | elapsed {elapsed:.1f}s, eta {eta:.0f}s")

    with interrupt_scope(install_signal_handlers) as stop, \
            tracer.span("campaign", backend=be.name, cells=len(cells),
                        todo=len(todo), workers=workers):
        if workers > 1 and len(todo) > 1:
            # spawn, not fork: callers routinely have JAX (multithreaded)
            # initialized, and forking a threaded parent can deadlock
            # workers.
            ctx = multiprocessing.get_context("spawn")

            def make_pool():
                return ProcessPoolExecutor(max_workers=workers,
                                           mp_context=ctx)

            def submit(pool, c, attempt):
                obs = ({"events_dir": str(events_dir),
                        "t_submit": time.time()} if trace else None)
                return pool.submit(run_cell_by_backend, be.name, c,
                                   base_seed, population, iterations,
                                   weights, obs, searcher, searcher_config,
                                   screen_fits.get(c.key), calibration,
                                   attempt)

            stats = run_resilient_pool(
                todo, make_pool=make_pool, submit=submit,
                on_outcome=finish, policy=policy, search=search,
                backend=be.name, stop=stop, tracer=tracer,
                workers=workers)
            pool_rebuilds = stats.rebuilds
            interrupted = stats.interrupted
        else:
            def attempt_fn(cell, attempt):
                with tracer.span("cell.run", cell=cell.key,
                                 backend=be.name):
                    with tracer.span("cell.eval", cell=cell.key):
                        return run_cell_by_backend(
                            be.name, cell, base_seed, population,
                            iterations, weights, None, searcher,
                            searcher_config, screen_fits.get(cell.key),
                            calibration, attempt)

            for c in todo:
                if stop.is_set():
                    interrupted = True
                    break
                outcome = execute_cell(c, attempt_fn, policy,
                                       search=search, backend=be.name,
                                       stop=stop, tracer=tracer)
                interrupted = interrupted or outcome.interrupted
                finish(outcome)

    events_path = trace_json = None
    if trace:
        tracer.close()
        events_path = events_path_for(store.path)
        events = merge_events(events_dir, events_path)
        trace_json = chrome_path_for(store.path)
        trace_json.write_text(json.dumps(chrome_trace(events)))
        say(f"telemetry: {len(events)} events -> {events_path} "
            f"(chrome trace: {trace_json})")

    records = [rec for c in cells
               if (rec := store.get(c.key)) is not None]
    failed_total = sum(1 for r in records if not is_ok(r))
    missing = len(cells) - len(records)
    if interrupted:
        say(f"campaign interrupted — {done} of {len(todo)} scheduled "
            f"cell(s) stored and flushed; re-run the same command to "
            f"resume from here")
    return CampaignReport(cells, records, reused_cells=len(cells) - len(todo),
                          new_cells=done, new_evaluations=new_evals,
                          wall_time_s=time.perf_counter() - t0, backend=be,
                          events_path=events_path, trace_path=trace_json,
                          failed_cells=failed_total,
                          retried_cells=retried_now, missing_cells=missing,
                          pool_rebuilds=pool_rebuilds,
                          interrupted=interrupted)


if __name__ == "__main__":
    from .cli import run
    raise SystemExit(run())
