"""Cost-aware multi-family placement: which accelerator for which DNN.

Campaigns (:mod:`repro.dse.campaign`) answer "what is the best design of
ONE family for ONE workload"; the paper's end-to-end question — and the
benchmark-then-place loop Being-ahead (arXiv:2104.02251) frames across
heterogeneous accelerators — is one level up: *given a mix of workloads
and a budget, which hardware should each one run on?* This module answers
it from campaign evidence that already exists:

* **Workloads** are named entries of the campaign key space: an
  ``arch/shape`` pair from :mod:`repro.configs` (hostable by BOTH the
  ``tpu`` and ``cuda`` backends — they share that key space on purpose)
  or a ``net@size`` pair from the paper's FPGA domain.
* **Candidates** come from one or more campaign stores (mixed backends
  welcome; later stores win on duplicate cell keys, mirroring store
  concatenation). Every feasible record is re-expressed in ONE
  normalized objective (:data:`repro.dse.objectives.NORMALIZED_OBJECTIVES`)
  and costed in watts and hourly dollars via each backend's
  ``record_cost`` hook over the ``hw_specs`` TDP/$ tables.
* **The budget** is a :class:`repro.core.hw_specs.CostEnvelope` — a
  dollar-proxy cap, a watt cap, or both.
* **Solvers** pick one candidate per workload maximizing the summed
  objective under the budget (a multiple-choice knapsack): ``greedy``
  starts every workload at its cheapest feasible design and repeatedly
  applies the upgrade with the best marginal value per unit of budget
  pressure; ``exact`` enumerates the (dominance-pruned) assignment space
  with bound pruning and is exact for the small mixes it accepts;
  ``auto`` picks ``exact`` when the pruned space is small enough.
* When no store covers a workload, the per-backend ``coverage_cells``
  hook says what to evaluate, and ``--evaluate-missing`` runs those
  cells as a fresh campaign before placing.

The result renders as a Markdown report section
(:func:`repro.dse.report.render_placement`): the assignment table,
budget utilization, and marginal "next dollar / next watt" suggestions —
the cheapest budget raise that would change the answer.

Placement in 5 lines (the README carries this block verbatim)::

    # Which family/part/count for each workload, under $40/h and 10 kW:
    python -m repro.dse.placement --stores results/dse_tpu.jsonl results/dse_cuda.jsonl \\
        --workloads starcoder2-3b/train_4k,xlstm-350m/decode_32k \\
        --budget-usd 40 --budget-watts 10000 --solver auto \\
        --out docs/reports/placement.md
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import sys
from typing import Iterable, Mapping, Sequence

from repro.core.hw_specs import CostEnvelope

from .backends import BACKENDS, get_backend, record_backend, workload_families
from .objectives import NORMALIZED_OBJECTIVES
from .frontier import FrontierIndex
from .store import CampaignStore, is_ok, open_store

#: Normalized objective names a placement can maximize.
PLACEMENT_OBJECTIVES: tuple[str, ...] = tuple(
    s.name for s in NORMALIZED_OBJECTIVES)

#: ``auto`` uses the exact solver when the dominance-pruned assignment
#: space has at most this many points; beyond it, greedy.
EXACT_AUTO_LIMIT = 100_000

#: Hard node cap for the exact solver's search (safety valve; pruning
#: keeps realistic mixes far below it).
EXACT_NODE_LIMIT = 2_000_000


class PlacementError(Exception):
    """Base class; the CLI maps these to exit code 2 with a clean
    one-line diagnostic instead of a traceback."""


class CoverageError(PlacementError):
    def __init__(self, workloads: Sequence[str]):
        self.workloads = list(workloads)
        super().__init__(
            "no store coverage for workload(s): " + ", ".join(self.workloads)
            + " — run a campaign for them first, or pass --evaluate-missing "
              "to let placement fill the gap with fresh evaluations")


class BudgetInfeasibleError(PlacementError):
    def __init__(self, budget: CostEnvelope, cheapest: "list[Assignment]"):
        self.budget, self.cheapest = budget, cheapest
        usd = sum(a.candidate.usd_per_hour for a in cheapest)
        watts = sum(a.candidate.watts for a in cheapest)
        floor = ", ".join(
            f"{a.workload}: ${a.candidate.usd_per_hour:g}/h"
            f"+{a.candidate.watts:g}W" for a in cheapest)
        super().__init__(
            f"budget {budget.describe()} is infeasible: the cheapest "
            f"assignment already needs ${usd:g}/h and {watts:g} W "
            f"({floor})")


@dataclasses.dataclass
class Candidate:
    """One store record as a placement option: its workload key, its
    value under the chosen normalized objective, and its hardware cost."""

    workload: str
    backend: str
    cell_key: str
    value: float
    watts: float
    usd_per_hour: float
    part: str
    count: int
    point: str
    record: Mapping


@dataclasses.dataclass
class Assignment:
    workload: str
    candidate: Candidate


@dataclasses.dataclass
class Suggestion:
    """A beneficial upgrade the budget rejects: the marginal "next
    dollar / next watt" evidence in the report."""

    workload: str
    candidate: Candidate
    gain: float
    d_usd: float
    d_watts: float
    blocked_by: tuple[str, ...]   # ("usd_per_hour",), ("watts",), or both


@dataclasses.dataclass
class PlacementResult:
    objective: str
    solver: str                        # solver actually used
    budget: CostEnvelope
    assignments: list[Assignment]      # input workload order
    suggestions: list[Suggestion]
    options: dict[str, tuple[int, int]]  # workload -> (raw, pruned) counts
    explored: int                      # upgrade steps / search nodes

    @property
    def total_value(self) -> float:
        return sum(a.candidate.value for a in self.assignments)

    @property
    def total_usd(self) -> float:
        return sum(a.candidate.usd_per_hour for a in self.assignments)

    @property
    def total_watts(self) -> float:
        return sum(a.candidate.watts for a in self.assignments)

    def utilization(self) -> dict[str, tuple[float, float | None]]:
        """Per budget axis: (used, cap). Uncapped axes report cap None."""
        return {"usd_per_hour": (self.total_usd, self.budget.usd_per_hour),
                "watts": (self.total_watts, self.budget.watts)}


# ---------------------------------------------------------------------------
# workloads and candidates
# ---------------------------------------------------------------------------


def normalize_workload(token: str) -> str:
    """A CLI workload token -> its canonical store key. ``arch/shape``
    passes through; FPGA tokens normalize to the backend's group-key form
    (``vgg16@224`` -> ``vgg16@224x224``, bare fixed nets -> ``@native``).
    Unknown names raise ``KeyError`` listing the accepted forms."""
    token = token.strip()
    if workload_families(token) == ("tpu", "cuda"):
        return token
    net, sep, size = token.partition("@")
    if not sep or size in ("", "native"):
        key = f"{net}@native"
    else:
        from .campaign import RESIZABLE_NETS
        if net not in RESIZABLE_NETS and workload_families(f"{net}@native"):
            # fixed-topology nets always record as @native; a sized key
            # could never match any store record
            raise KeyError(f"bad workload {token!r}: {net} has a fixed "
                           f"input topology; use {net!r} or "
                           f"'{net}@native'")
        h, _, w = size.partition("x")
        try:
            key = f"{net}@{int(h)}x{int(w or h)}"
        except ValueError:
            raise KeyError(f"bad workload {token!r}: input size {size!r} "
                           f"is not H or HxW") from None
    if not workload_families(key):
        raise KeyError(
            f"unknown workload {token!r}; expected arch/shape (e.g. "
            f"starcoder2-3b/train_4k) or net[@HxW] (e.g. vgg16@224x224)")
    return key


def parse_workloads(text: str) -> list[str]:
    """Comma list of workload tokens -> canonical keys, deduped in order."""
    out: list[str] = []
    for tok in text.split(","):
        if not tok.strip():
            continue
        key = normalize_workload(tok)
        if key not in out:
            out.append(key)
    if not out:
        raise KeyError("empty workload list")
    return out


def pooled_records(stores: Sequence[CampaignStore | Iterable[Mapping]],
                   ) -> list[dict]:
    """Records of several stores merged by cell key, LATER STORES WINNING
    — the same last-wins rule a concatenated JSONL store follows, so a
    resumed or re-run store never double-counts a cell. Stores are
    streamed (``iter_records``), never materialized. Quarantined
    (``status: failed``) records participate in last-wins — a later
    success supersedes a failure and vice versa — and are filtered out
    downstream by ``candidates_by_workload``."""
    merged: dict[str, dict] = {}
    for s in stores:
        recs = s.iter_records() if isinstance(s, CampaignStore) else s
        for rec in recs:
            key = rec.get("cell_key")
            if key:
                merged[key] = rec
    return list(merged.values())


def candidates_by_workload(records: Sequence[Mapping], objective: str,
                           ) -> dict[str, list[Candidate]]:
    """Feasible records of known backends -> placement candidates grouped
    by workload key, each valued under one normalized objective and
    costed via the backend's ``record_cost`` hook."""
    if objective not in PLACEMENT_OBJECTIVES:
        raise KeyError(f"unknown objective {objective!r}; "
                       f"choose from {PLACEMENT_OBJECTIVES}")
    out: dict[str, list[Candidate]] = {}
    for rec in records:
        if not is_ok(rec):
            continue  # quarantined (status: failed) — never placeable
        name = record_backend(rec)
        if name not in BACKENDS:
            continue
        be = get_backend(name)
        try:
            norm = be.normalized(rec)
        except (KeyError, TypeError):
            continue  # foreign/truncated record: not placeable
        if not norm["feasible"]:
            continue
        watts, usd = be.record_cost(rec)
        pp = be.placement_point(rec)
        c = Candidate(workload=be.group_key(rec), backend=name,
                      cell_key=rec["cell_key"], value=float(norm[objective]),
                      watts=watts, usd_per_hour=usd, part=pp["part"],
                      count=pp["count"], point=pp["point"], record=rec)
        out.setdefault(c.workload, []).append(c)
    for cands in out.values():
        cands.sort(key=lambda c: (c.cell_key, c.backend))
    return out


def _dominated(c: Candidate, by: Candidate, axes: Sequence[str]) -> bool:
    """``by`` is at least as good on value and every budgeted cost axis,
    and strictly better somewhere (exact ties defer to the smaller cell
    key, so duplicates collapse deterministically)."""
    if by.value < c.value:
        return False
    if any(getattr(by, a) > getattr(c, a) for a in axes):
        return False
    if by.value > c.value or any(getattr(by, a) < getattr(c, a)
                                 for a in axes):
        return True
    return by.cell_key < c.cell_key  # exact tie: one survivor


def prune_candidates(cands: Sequence[Candidate], budget: CostEnvelope,
                     ) -> list[Candidate]:
    """Drop candidates another one beats on value without costing more on
    any budgeted axis. With no caps this keeps just the best-value
    design; with caps it keeps the value-vs-cost frontier.

    Runs through the incremental dominance archive
    (:class:`repro.dse.frontier.FrontierIndex`) — O(n · front) instead of
    the old all-pairs O(n²) — with :func:`_dominated`'s exact-tie rule
    (identical vectors collapse to the smallest cell key) applied on top,
    since the archive itself keeps duplicates."""
    axes = budget.capped_axes()
    if not cands:
        return []
    # canonical maximization form: value up, every budgeted cost down
    vecs = [(c.value,) + tuple(-getattr(c, a) for a in axes) for c in cands]
    tie_winner: dict[tuple, str] = {}
    for c, v in zip(cands, vecs):
        if v not in tie_winner or c.cell_key < tie_winner[v]:
            tie_winner[v] = c.cell_key
    fi = FrontierIndex()
    for i, v in enumerate(vecs):
        fi.insert(i, v)
    on_front = set(fi.front_keys())
    return [c for i, c in enumerate(cands)
            if i in on_front and c.cell_key == tie_winner[vecs[i]]]


# ---------------------------------------------------------------------------
# solvers (multiple-choice knapsack)
# ---------------------------------------------------------------------------


def _pressure(budget: CostEnvelope, d_usd: float, d_watts: float) -> float:
    """How much of the budget an upgrade's marginal cost eats: the max
    over capped axes of (cost increase / cap). The greedy ratio divides
    value gained by this, so a watt-capped and a dollar-capped run rank
    upgrades in their own currency."""
    terms = []
    if budget.usd_per_hour:
        terms.append(max(0.0, d_usd) / budget.usd_per_hour)
    if budget.watts:
        terms.append(max(0.0, d_watts) / budget.watts)
    return max(terms) if terms else 0.0


def _cheapest(cands: Sequence[Candidate], budget: CostEnvelope) -> Candidate:
    """The candidate that strains the budget least: minimal pressure
    (the max of cost/cap over capped axes — NOT lexicographic, so a
    $1/h-but-100W design doesn't beat a $2/h-but-10W one under a tight
    watt cap), then raw costs, value, and key for determinism."""
    def key(c: Candidate):
        return (_pressure(budget, c.usd_per_hour, c.watts),
                c.usd_per_hour, c.watts, -c.value, c.cell_key)
    return min(cands, key=key)


def _upgrade_better(a: tuple, b: tuple) -> bool:
    """Greedy upgrade preference: higher ratio, then higher gain, then
    the lexicographically first (workload, cell key) for determinism."""
    (ra, ga, wa, ca), (rb, gb, wb, cb) = a, b
    if ra != rb:
        return ra > rb
    if ga != gb:
        return ga > gb
    return (wa, ca.cell_key) < (wb, cb.cell_key)


def _solve_greedy(workloads: Sequence[str],
                  cands: Mapping[str, Sequence[Candidate]],
                  budget: CostEnvelope) -> tuple[dict[str, Candidate], int]:
    """Start every workload at its least-straining candidate, then apply
    best-ratio upgrades while they fit. A heuristic: near-optimal in
    practice, but its infeasibility verdict is conservative when the two
    caps pull different ways across workloads — the exact solver is
    authoritative there."""
    assign = {w: _cheapest(cands[w], budget) for w in workloads}
    usd = sum(c.usd_per_hour for c in assign.values())
    watts = sum(c.watts for c in assign.values())
    if not budget.admits(usd, watts):
        raise BudgetInfeasibleError(
            budget, [Assignment(w, assign[w]) for w in workloads])
    steps = 0
    while True:
        best = None
        for w in workloads:
            cur = assign[w]
            for c in cands[w]:
                gain = c.value - cur.value
                if gain <= 0:
                    continue
                du, dw = c.usd_per_hour - cur.usd_per_hour, c.watts - cur.watts
                if not budget.admits(usd + du, watts + dw):
                    continue
                steps += 1
                p = _pressure(budget, du, dw)
                cand = (gain / p if p > 0 else math.inf, gain, w, c)
                if best is None or _upgrade_better(cand, best):
                    best = cand
        if best is None:
            return assign, steps
        _, _, w, c = best
        usd += c.usd_per_hour - assign[w].usd_per_hour
        watts += c.watts - assign[w].watts
        assign[w] = c


def _solve_exact(workloads: Sequence[str],
                 cands: Mapping[str, Sequence[Candidate]],
                 budget: CostEnvelope) -> tuple[dict[str, Candidate], int]:
    """Depth-first enumeration with value/cost bound pruning. Exact (and
    deterministic: value, then lower cost, then lexicographic cell keys)
    for the small mixes ``auto`` routes here."""
    # cheapest-first within a workload tightens the cost bound early;
    # fewest-options-first shrinks the branching factor at the top.
    order = sorted(workloads, key=lambda w: (len(cands[w]), w))
    opts = [sorted(cands[w], key=lambda c: (c.usd_per_hour, c.watts,
                                            -c.value, c.cell_key))
            for w in order]
    n = len(order)
    min_usd = [0.0] * (n + 1)
    min_watts = [0.0] * (n + 1)
    max_val = [0.0] * (n + 1)
    for i in range(n - 1, -1, -1):
        min_usd[i] = min_usd[i + 1] + min(c.usd_per_hour for c in opts[i])
        min_watts[i] = min_watts[i + 1] + min(c.watts for c in opts[i])
        max_val[i] = max_val[i + 1] + max(c.value for c in opts[i])

    best: dict = {"key": None, "tie": None, "picks": None}
    picks: list[Candidate] = []
    nodes = 0

    def dfs(i: int, usd: float, watts: float, value: float) -> None:
        nonlocal nodes
        nodes += 1
        if nodes > EXACT_NODE_LIMIT:
            raise PlacementError(
                f"exact solver exceeded {EXACT_NODE_LIMIT} nodes; "
                f"re-run with --solver greedy")
        if not budget.admits(usd + min_usd[i], watts + min_watts[i]):
            return
        if best["key"] is not None and value + max_val[i] < best["key"][0]:
            return
        if i == n:
            key = (value, -usd, -watts)
            tie = tuple(c.cell_key for c in picks)
            if best["key"] is None or key > best["key"] or \
                    (key == best["key"] and tie < best["tie"]):
                best.update(key=key, tie=tie, picks=list(picks))
            return
        for c in opts[i]:
            picks.append(c)
            dfs(i + 1, usd + c.usd_per_hour, watts + c.watts, value + c.value)
            picks.pop()

    dfs(0, 0.0, 0.0, 0.0)
    if best["picks"] is None:
        raise BudgetInfeasibleError(
            budget, [Assignment(w, _cheapest(cands[w], budget))
                     for w in workloads])
    return dict(zip(order, best["picks"])), nodes


def marginal_upgrades(assign: Mapping[str, Candidate],
                      cands: Mapping[str, Sequence[Candidate]],
                      budget: CostEnvelope) -> list[Suggestion]:
    """Per workload, the best value-raising upgrade the budget REJECTS —
    what the next dollar (or watt) of budget would buy. In-budget
    upgrades are excluded: the solvers already took them."""
    usd = sum(c.usd_per_hour for c in assign.values())
    watts = sum(c.watts for c in assign.values())
    out = []
    for w in sorted(assign):
        cur, best = assign[w], None
        for c in cands[w]:
            gain = c.value - cur.value
            if gain <= 0:
                continue
            du, dw = c.usd_per_hour - cur.usd_per_hour, c.watts - cur.watts
            if budget.admits(usd + du, watts + dw):
                continue
            p = _pressure(budget, du, dw)
            cand = (gain / p if p > 0 else math.inf, gain, w, c)
            if best is None or _upgrade_better(cand, best):
                best = cand
        if best is not None:
            _, gain, _, c = best
            du = c.usd_per_hour - cur.usd_per_hour
            dw = c.watts - cur.watts
            blocked = tuple(
                a for a, used, delta, cap in (
                    ("usd_per_hour", usd, du, budget.usd_per_hour),
                    ("watts", watts, dw, budget.watts))
                if cap is not None and used + delta > cap)
            out.append(Suggestion(w, c, gain, du, dw, blocked))
    out.sort(key=lambda s: (-(s.gain / p if (p := _pressure(
        budget, s.d_usd, s.d_watts)) > 0 else math.inf), s.workload))
    return out


# ---------------------------------------------------------------------------
# the placement entry point
# ---------------------------------------------------------------------------


def place(workloads: Sequence[str], records: Sequence[Mapping],
          budget: CostEnvelope, *, objective: str = "tflops",
          solver: str = "auto",
          candidates: Mapping[str, Sequence[Candidate]] | None = None,
          ) -> PlacementResult:
    """Assign each workload the best-covering design under the budget.

    ``records`` is any pooled record list (see :func:`pooled_records`);
    ``workloads`` are canonical keys (see :func:`parse_workloads`).
    ``candidates`` short-circuits extraction when the caller already ran
    :func:`candidates_by_workload` on the same records and objective.
    Raises :class:`CoverageError` when a workload has no feasible
    candidate and :class:`BudgetInfeasibleError` when even the cheapest
    assignment busts the budget.
    """
    if solver not in ("auto", "greedy", "exact"):
        raise KeyError(f"unknown solver {solver!r}; "
                       f"choose from auto, greedy, exact")
    workloads = list(workloads)
    all_cands = (candidates if candidates is not None
                 else candidates_by_workload(records, objective))
    missing = [w for w in workloads if not all_cands.get(w)]
    if missing:
        raise CoverageError(missing)
    raw = {w: all_cands[w] for w in workloads}
    pruned = {w: prune_candidates(raw[w], budget) for w in workloads}
    options = {w: (len(raw[w]), len(pruned[w])) for w in workloads}

    if solver == "auto":
        space = math.prod(len(pruned[w]) for w in workloads)
        solver = "exact" if space <= EXACT_AUTO_LIMIT else "greedy"
    if solver == "exact":
        assign, explored = _solve_exact(workloads, pruned, budget)
    else:
        assign, explored = _solve_greedy(workloads, pruned, budget)

    return PlacementResult(
        objective=objective, solver=solver, budget=budget,
        assignments=[Assignment(w, assign[w]) for w in workloads],
        suggestions=marginal_upgrades(assign, pruned, budget),
        options=options, explored=explored)


def ensure_coverage(workloads: Sequence[str], store: CampaignStore,
                    known: Mapping[str, Sequence[Candidate]], *,
                    progress=None, workers: int = 1) -> list[str]:
    """Run the per-backend default campaign (``coverage_cells``) for every
    workload ``known`` has no candidates for, into ``store``. Returns the
    workloads it evaluated. The fresh records land in the store like any
    campaign's would, so the next placement resumes them for free."""
    from .campaign import run_campaign
    evaluated = []
    for w in workloads:
        if known.get(w):
            continue
        for family in workload_families(w):
            cells = get_backend(family).coverage_cells(w)
            if cells:
                run_campaign(cells, store, backend=family, workers=workers,
                             progress=progress)
        evaluated.append(w)
    return evaluated


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _summary_lines(result: PlacementResult) -> list[str]:
    unit = {s.name: s.units for s in NORMALIZED_OBJECTIVES}[result.objective]
    lines = [f"placement[{result.solver}]: {len(result.assignments)} "
             f"workload(s), objective {result.objective} ({unit}), "
             f"budget {result.budget.describe()}"]
    for a in result.assignments:
        c = a.candidate
        lines.append(f"  {a.workload:<32} -> {c.backend}:{c.part} x{c.count} "
                     f"[{c.point}] {c.value:.4g} {unit}  "
                     f"(${c.usd_per_hour:g}/h, {c.watts:g} W)")
    lines.append(f"  total: {result.total_value:.4g} {unit}, "
                 f"${result.total_usd:g}/h, {result.total_watts:g} W")
    for s in result.suggestions[:3]:
        lines.append(f"  next: {s.workload} -> {s.candidate.cell_key} "
                     f"(+{s.gain:.4g} {unit} for {s.d_usd:+g} $/h, "
                     f"{s.d_watts:+g} W; blocked by "
                     f"{', '.join(s.blocked_by) or 'budget'})")
    return lines


def selftest() -> int:
    """Deterministic end-to-end check on the built-in fixture store: both
    solvers agree, re-running is byte-identical, and the rendered report
    has every section. The CI docs job runs this."""
    from .report import fixture_records, render_placement
    recs = fixture_records()
    workloads = parse_workloads(
        "starcoder2-3b/train_4k,xlstm-350m/decode_32k,vgg16@224x224")
    budget = CostEnvelope(usd_per_hour=60.0, watts=25000.0)
    exact = place(workloads, recs, budget, solver="exact")
    greedy = place(workloads, recs, budget, solver="greedy")
    again = place(workloads, recs, budget, solver="exact")
    pick = lambda r: [(a.workload, a.candidate.cell_key)
                      for a in r.assignments]
    if pick(exact) != pick(again):
        raise SystemExit("selftest: exact placement is not deterministic")
    if pick(exact) != pick(greedy):
        raise SystemExit(f"selftest: greedy diverged from exact on the "
                         f"fixture: {pick(greedy)} vs {pick(exact)}")
    if not exact.suggestions:
        raise SystemExit("selftest: fixture budget should leave a rejected "
                         "upgrade for the marginal table")
    md = render_placement(exact, title="selftest placement")
    for must in ("## Assignment", "## Budget utilization",
                 "## Marginal upgrades", "workload", "family"):
        if must not in md:
            raise SystemExit(f"selftest: section {must!r} missing from "
                             f"rendered placement report")
    try:
        place(workloads, recs, CostEnvelope(usd_per_hour=1.0))
    except BudgetInfeasibleError:
        pass
    else:
        raise SystemExit("selftest: $1/h budget should be infeasible")
    try:
        place(["whisper-base/train_4k"], recs, budget)
    except CoverageError:
        pass
    else:
        raise SystemExit("selftest: uncovered workload should raise")
    print(f"selftest OK: {len(md)} chars, exact==greedy on "
          f"{len(workloads)} workloads, infeasible/uncovered diagnostics "
          f"raised")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.placement",
        description="Cost-aware multi-family placement: assign each "
                    "workload of a mix the best campaign design (any "
                    "family) under a dollar/watt budget.")
    ap.add_argument("--stores", nargs="+", default=[], metavar="STORE",
                    help="campaign JSONL stores to draw candidates from "
                         "(mixed backends welcome; later stores win on "
                         "duplicate cells)")
    ap.add_argument("--workloads", default="",
                    help="comma list of workload keys: arch/shape (tpu+"
                         "cuda) or net[@HxW] (fpga); 'all' = every "
                         "workload the stores cover")
    ap.add_argument("--budget-usd", type=float, default=None, metavar="USD",
                    help="hourly dollar-proxy cap (hw_specs usd_per_hour "
                         "tables)")
    ap.add_argument("--budget-watts", type=float, default=None, metavar="W",
                    help="board-power cap (hw_specs tdp_watts tables)")
    ap.add_argument("--objective", default="tflops",
                    choices=PLACEMENT_OBJECTIVES,
                    help="normalized objective to maximize "
                         "(default: %(default)s)")
    ap.add_argument("--solver", default="auto",
                    choices=("auto", "greedy", "exact"),
                    help="auto = exact for small mixes, else greedy")
    ap.add_argument("--evaluate-missing", action="store_true",
                    help="run the default campaign for workloads the "
                         "stores don't cover (into --eval-store)")
    ap.add_argument("--eval-store", default=None, metavar="STORE",
                    help="where fresh coverage evaluations land "
                         "(default: the first --stores entry)")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width for coverage evaluations")
    ap.add_argument("--out", default=None, metavar="MD",
                    help="write the Markdown placement report here")
    ap.add_argument("--title", default=None)
    ap.add_argument("--fixture", action="store_true",
                    help="use the built-in three-backend fixture store "
                         "instead of --stores (deterministic; the docs "
                         "worked example)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the deterministic fixture checks and exit")
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()

    from .report import fixture_records, render_placement
    if args.fixture:
        records = fixture_records()
    elif args.stores:
        records = pooled_records([open_store(p) for p in args.stores])
        if not records:
            ap.error(f"stores {args.stores} are empty or missing")
    else:
        ap.error("pass --stores (or --fixture / --selftest)")

    budget = CostEnvelope(usd_per_hour=args.budget_usd,
                          watts=args.budget_watts)
    # one candidate extraction serves the "all" listing, the coverage
    # check, and the solve — unless fresh evaluations change the records
    known = candidates_by_workload(records, args.objective)
    try:
        if args.workloads.strip().lower() in ("", "all"):
            workloads = sorted(known)
            if not workloads:
                ap.error("no placeable workloads in the stores")
        else:
            workloads = parse_workloads(args.workloads)
    except KeyError as e:
        ap.error(str(e.args[0] if e.args else e))

    if args.evaluate_missing and not args.fixture:
        eval_store = open_store(args.eval_store or args.stores[0])
        filled = ensure_coverage(workloads, eval_store, known,
                                 progress=print, workers=args.workers)
        if filled:
            records = pooled_records([records, eval_store.iter_records()])
            known = candidates_by_workload(records, args.objective)

    try:
        result = place(workloads, records, budget,
                       objective=args.objective, solver=args.solver,
                       candidates=known)
    except PlacementError as e:
        print(f"placement failed: {e}", file=sys.stderr)
        return 2

    print("\n".join(_summary_lines(result)))
    if args.out:
        from pathlib import Path
        md = render_placement(result, title=args.title)
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(md)
        print(f"placement report -> {out} ({len(md)} chars)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
