"""Incremental Pareto frontier: an insert-time dominance archive.

The campaign engine historically recomputed frontiers by full O(n^2)
non-dominated sort per report — fine at hundreds of cells, hopeless at
the million-cell scale the ROADMAP targets. :class:`FrontierIndex` keeps
the first front *as records stream in*: each insert is one vectorized
dominance check against the current front (O(front), not O(n)), so a
100k-record store is frontier-ready in a single streaming pass.

Semantics are locked to the :mod:`repro.dse.pareto` oracle and
property-tested against it (``tests/test_frontier.py``):

* the front equals ``non_dominated(current vectors)`` — same members,
  same order (first-appearance key order, the order a JSONL store's
  last-wins dict iterates in);
* duplicate vectors coexist on the front (strict dominance only),
  exactly like the oracle;
* re-inserting an existing key REPLACES its vector (last wins, like a
  store re-run) and repairs the front, resurrecting points the old
  vector had been shadowing;
* :meth:`diverse` returns the front in NSGA-II crowding order —
  bit-compatible with ``pareto.diverse_front`` over the same vectors.

Payloads: each insert may carry an opaque payload (typically the full
store record). Payloads are retained only for CURRENT front members, so
memory stays O(front), not O(records); after a replacement-triggered
repair a resurrected member's payload may be ``None`` (the stream that
dominated it away did not keep it), and consumers fall back to
``store.get(key)``.
"""
from __future__ import annotations

from typing import Any, Hashable, Iterable, Sequence

import numpy as np

from .pareto import crowding_distance, dominance_split

Vector = Sequence[float]


class FrontierIndex:
    """Insert-time dominance archive over keyed objective vectors
    (canonical maximization form, like everything in
    :mod:`repro.dse.pareto`)."""

    def __init__(self, dim: int | None = None):
        self._dim = dim
        #: key -> current vector, in FIRST-APPEARANCE key order (dict
        #: reassignment keeps the slot, mirroring store last-wins).
        self._points: dict[Hashable, tuple] = {}
        self._front: dict[Hashable, tuple] = {}
        self._payloads: dict[Hashable, Any] = {}
        self._mat: np.ndarray | None = None  # cached front matrix
        #: Total insert calls (including rejected and replacement ones).
        self.inserts = 0
        #: Front repairs forced by replacing a front member's vector.
        self.rebuilds = 0

    # -- queries ----------------------------------------------------------

    @property
    def dim(self) -> int | None:
        return self._dim

    def __len__(self) -> int:
        """Number of CURRENT points (last version per key)."""
        return len(self._points)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._points

    def front_size(self) -> int:
        return len(self._front)

    def on_front(self, key: Hashable) -> bool:
        return key in self._front

    def front_keys(self) -> list[Hashable]:
        """Front member keys in first-appearance order — the order the
        ``non_dominated`` oracle would emit over the current points."""
        return list(self._front)

    def front_vectors(self) -> list[tuple]:
        return list(self._front.values())

    def payload(self, key: Hashable) -> Any:
        """The payload of a CURRENT front member (``None`` when the
        member was resurrected by a repair and its payload was not
        retained — re-fetch from the store by key)."""
        return self._payloads.get(key)

    def front(self) -> list[tuple[Hashable, tuple, Any]]:
        """``(key, vector, payload)`` per front member, in order."""
        return [(k, v, self._payloads.get(k))
                for k, v in self._front.items()]

    def diverse(self, k: int | None = None) -> list[Hashable]:
        """Front keys in NSGA-II crowding order (extremes first, clumps
        thinned; ties by front position), optionally truncated to ``k``
        — the exact read-off order of ``pareto.diverse_front``."""
        vecs = self.front_vectors()
        cd = crowding_distance(vecs)
        order = sorted(range(len(vecs)), key=lambda j: (-cd[j], j))
        if k is not None and k > 0:
            order = order[:k]
        keys = self.front_keys()
        return [keys[j] for j in order]

    # -- mutation ---------------------------------------------------------

    def insert(self, key: Hashable, vec: Vector, payload: Any = None,
               ) -> bool:
        """Insert (or last-wins replace) one keyed vector. Returns True
        iff ``key`` sits on the front afterwards."""
        self.inserts += 1
        v = tuple(float(x) for x in vec)
        if self._dim is None:
            self._dim = len(v)
        elif len(v) != self._dim:
            raise ValueError(
                f"objective arity mismatch: got {len(v)}, index holds "
                f"{self._dim}-dim vectors")
        old = self._points.get(key)
        if old is not None:
            if old == v:
                # Same key, same vector: a no-op for the geometry; only
                # refresh the payload when the member is live.
                if key in self._front and payload is not None:
                    self._payloads[key] = payload
                return key in self._front
            # Replacement: the old vector may have been propping the
            # front up (as a member) — rebuild from the surviving points
            # so anything it shadowed is resurrected. Rare (one per
            # store re-run of a cell), O(points * front).
            self._points[key] = v
            self._payloads.pop(key, None)
            if payload is not None:
                self._payloads[key] = payload
            self._rebuild()
            return key in self._front
        self._points[key] = v
        return self._admit(key, v, payload)

    def extend(self, items: Iterable[tuple[Hashable, Vector]]) -> None:
        for key, vec in items:
            self.insert(key, vec)

    # -- internals --------------------------------------------------------

    def _matrix(self) -> np.ndarray:
        if self._mat is None:
            self._mat = (np.array(list(self._front.values()), dtype=float)
                         if self._front else
                         np.zeros((0, self._dim or 0)))
        return self._mat

    def _admit(self, key: Hashable, v: tuple, payload: Any) -> bool:
        """Pure insert-time dominance step for a NEW front candidate."""
        arr = np.asarray(v, dtype=float)
        dominated, kills = dominance_split(self._matrix(), arr)
        if dominated:
            return False
        if kills.any():
            for k in [fk for fk, dead in zip(self._front, kills) if dead]:
                del self._front[k]
                self._payloads.pop(k, None)
        self._front[key] = v
        if payload is not None:
            self._payloads[key] = payload
        self._mat = None
        return True

    def _rebuild(self) -> None:
        """Recompute the front from the current points, preserving
        first-appearance order (one insert-only pass — exactly the
        oracle's semantics). Payloads survive for members that stayed
        on the front; resurrected members keep theirs only if it was
        explicitly re-supplied."""
        self.rebuilds += 1
        kept = self._payloads
        self._front, self._payloads, self._mat = {}, {}, None
        for k, v in self._points.items():
            self._admit(k, v, kept.get(k))
