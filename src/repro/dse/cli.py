"""CLI for DSE campaigns: ranked report + Pareto frontier dump.

    python -m repro.dse.campaign --nets vgg16,alexnet --fpgas ku115,zcu102 \\
        --precisions 16,8 --batch-caps 1,8 --workers 4 \\
        --store results/dse.jsonl --frontier-json results/frontier.json
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.hw_specs import FPGAS
from repro.core.netinfo import TABLE1_NETS

from .campaign import (RESIZABLE_NETS, CampaignReport, expand_cells,
                       run_campaign)
from .objectives import DEFAULT_WEIGHTS, OBJECTIVES
from .store import ResultStore


def _csv(text: str) -> list[str]:
    return [t.strip() for t in text.split(",") if t.strip()]


def parse_inputs(text: str) -> list[tuple[int, int]]:
    """``"224,320x480"`` -> ``[(224, 224), (320, 480)]``."""
    out = []
    for tok in _csv(text):
        h, _, w = tok.partition("x")
        out.append((int(h), int(w or h)))
    return out


def parse_weights(text: str) -> dict[str, float] | None:
    """``"throughput_ips=1,dsp_eff=500"`` -> weight dict (None if empty)."""
    if not text:
        return None
    out = {}
    for tok in _csv(text):
        name, _, val = tok.partition("=")
        out[name] = float(val) if val else 1.0
    return out


def _row(rec: dict) -> str:
    o, r = rec["objectives"], rec["rav"]
    return (f"{rec['cell_key']:<48} sp={r['sp']:>2} b={r['batch']:>2} "
            f"{o['throughput_ips']:>8.1f} {o['gops']:>8.1f} "
            f"{o['latency_s'] * 1e3:>8.2f} {o['dsp_eff']:>6.3f} "
            f"{int(o['bram_used']):>6}")


_HEADER = (f"{'cell':<48} {'rav':<10} {'img/s':>8} {'GOP/s':>8} "
           f"{'lat_ms':>8} {'eff':>6} {'bram':>6}")


def print_report(report: CampaignReport, weights: dict | None,
                 top: int) -> None:
    print(f"\n== campaign: {len(report.cells)} cells "
          f"({report.new_cells} new, {report.reused_cells} reused; "
          f"{report.new_evaluations} new evaluations, "
          f"{report.wall_time_s:.1f}s) ==")

    shown = dict(weights or DEFAULT_WEIGHTS)
    print(f"\n-- top {top} by scalarized objective {shown} --")
    print(_HEADER)
    for rec in report.ranked(weights)[:top]:
        print(_row(rec))

    front = report.frontier()
    names = ", ".join(f"{s.name}[{'max' if s.maximize else 'min'}]"
                      for s in OBJECTIVES)
    print(f"\n-- Pareto frontier: {len(front)} of "
          f"{len(report.feasible())} feasible designs ({names}) --")
    print(_HEADER)
    for rec in front:
        print(_row(rec))


def main(argv: list[str] | None = None) -> CampaignReport:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.campaign",
        description="Batch multi-objective DSE campaign over "
                    "(net x input x FPGA x precision x batch cap).")
    ap.add_argument("--nets", default="vgg16",
                    help="comma list; resizable: %s; fixed: %s" % (
                        ",".join(RESIZABLE_NETS),
                        ",".join(n for n in TABLE1_NETS
                                 if n not in RESIZABLE_NETS)))
    ap.add_argument("--inputs", default="224",
                    help="comma list of H or HxW for resizable nets")
    ap.add_argument("--fpgas", default="ku115",
                    help="comma list from: " + ",".join(sorted(FPGAS)))
    ap.add_argument("--precisions", default="16",
                    help="comma list of bit-widths (data == weights)")
    ap.add_argument("--batch-caps", default="1",
                    help="comma list of PSO batch upper bounds")
    ap.add_argument("--store", default="results/dse_campaign.jsonl",
                    help="JSONL result store (resumable/memoized)")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width; 0 = one per CPU")
    ap.add_argument("--population", type=int, default=20)
    ap.add_argument("--iterations", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed; per-cell seeds derive from it")
    ap.add_argument("--weights", default="",
                    help="scalarization, e.g. throughput_ips=1,dsp_eff=500 "
                         "(default: throughput only, the paper's objective)")
    ap.add_argument("--top", type=int, default=8, help="ranked rows to print")
    ap.add_argument("--frontier-json", default=None,
                    help="also dump the frontier records to this JSON file")
    args = ap.parse_args(argv)

    weights = parse_weights(args.weights)
    workers = args.workers if args.workers > 0 else (os.cpu_count() or 1)
    cells = expand_cells(_csv(args.nets), parse_inputs(args.inputs),
                         _csv(args.fpgas),
                         [int(p) for p in _csv(args.precisions)],
                         [int(b) for b in _csv(args.batch_caps)])
    report = run_campaign(cells, ResultStore(args.store),
                          base_seed=args.seed, population=args.population,
                          iterations=args.iterations, weights=weights,
                          workers=workers, progress=print)
    print_report(report, weights, args.top)

    if args.frontier_json:
        with open(args.frontier_json, "w") as f:
            json.dump(report.frontier(), f, indent=2, sort_keys=True)
        print(f"\nfrontier -> {args.frontier_json}")
    print(f"store -> {args.store}")
    return report


if __name__ == "__main__":
    main()
