"""CLI for DSE campaigns: ranked report + Pareto frontier dump, for any
registered backend (``--backend fpga`` is the default and the paper's
flow; ``--backend tpu`` sweeps the analytic TPU planner; ``--backend
cuda`` sweeps the GPU roofline with the GPU part as a campaign axis).

    python -m repro.dse.campaign --nets vgg16,alexnet --fpgas ku115,zcu102 \\
        --precisions 16,8 --batch-caps 1,8 --workers 4 \\
        --store results/dse.jsonl --frontier-json results/frontier.json

    python -m repro.dse.campaign --backend tpu --archs starcoder2-3b \\
        --shapes train_4k,decode_32k --chips 8,16,32 \\
        --store results/dse_tpu.jsonl

    python -m repro.dse.campaign --backend cuda --archs starcoder2-3b \\
        --shapes train_4k --gpus 8,16 --gpu-types a100-80g,h100 \\
        --store results/dse_cuda.jsonl

Stores render to Markdown with ``python -m repro.dse.report <store>``;
two stores (e.g. the tpu and cuda campaigns above) compare with
``python -m repro.dse.report --compare A.jsonl B.jsonl``.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.search import searcher_names

from .backends import (BACKENDS, get_backend, parse_inputs,  # noqa: F401
                       parse_searcher_config, parse_weights)
from .campaign import CampaignReport, run_campaign
from .resilience import RetryPolicy


def print_report(report: CampaignReport, weights: dict | None,
                 top: int) -> list[dict]:
    """Print the ranked + frontier tables; returns the first Pareto front
    (crowding-distance order, extremes first) so callers can reuse it
    without redoing the dominance sort."""
    be = report._backend()
    print(f"\n== campaign[{be.name}]: {len(report.cells)} cells "
          f"({report.new_cells} new, {report.reused_cells} reused; "
          f"{report.new_evaluations} new evaluations, "
          f"{report.wall_time_s:.1f}s) ==")

    shown = dict(weights or be.default_weights)
    print(f"\n-- top {top} by scalarized objective {shown} --")
    print(be.table_header())
    for rec in report.ranked(weights)[:top]:
        print(be.table_row(rec))

    # print the frontier as a diversity-ordered spread (rank, then
    # crowding distance) so a truncated read-off still covers the
    # surface — read off the report's incremental frontier index
    fi = report.frontier_index()
    front = [fi.payload(key) for key in fi.diverse()]
    names = ", ".join(f"{s.name}[{'max' if s.maximize else 'min'}]"
                      for s in be.objectives)
    print(f"\n-- Pareto frontier: {len(front)} of "
          f"{len(fi)} feasible designs ({names}) --")
    print(be.table_header())
    for rec in front:
        print(be.table_row(rec))
    return front


def main(argv: list[str] | None = None) -> CampaignReport:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.campaign",
        description="Batch multi-objective DSE campaign over a backend's "
                    "axis grid (fpga: net x input x FPGA x precision x "
                    "batch cap; tpu: arch x shape x chips x remat x "
                    "microbatches; cuda: the tpu axes with a GPU-part "
                    "axis instead of chips).")
    ap.add_argument("--backend", choices=sorted(BACKENDS), default="fpga",
                    help="device family to sweep (default: fpga, the "
                         "paper's flow)")
    for be in BACKENDS.values():
        be.add_axis_arguments(ap)
    ap.add_argument("--store", default=None,
                    help="JSONL result store (resumable/memoized; default "
                         "per backend, e.g. results/dse_campaign.jsonl). "
                         "A <name>.d path selects the sharded v2 layout "
                         "(see docs/store.md)")
    ap.add_argument("--shard", default="0",
                    help="shard id THIS process appends to when --store "
                         "is sharded — give each concurrent campaign host "
                         "its own id and they share one store without "
                         "lock contention")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width; 0 = one per CPU")
    ap.add_argument("--population", type=int, default=20)
    ap.add_argument("--iterations", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed; per-cell seeds derive from it "
                         "(fpga only; the tpu planner is deterministic)")
    ap.add_argument("--searcher", choices=searcher_names(), default="pso",
                    help="search engine per fpga cell (default: pso, the "
                         "paper's Algorithm 1; hyperband = multi-fidelity "
                         "successive halving). Stored in the resume-match "
                         "config: a store written by one engine re-runs "
                         "under another instead of mixing results")
    ap.add_argument("--searcher-config", default="",
                    help="engine config overrides, e.g. "
                         "screen=2048,survivors=8 (fields of the engine's "
                         "config dataclass; see docs/search.md)")
    ap.add_argument("--jax-screen", action="store_true",
                    help="precompute every cell's hyperband rung-0 "
                         "screening in ONE jitted cross-cell jax call "
                         "(fpga backend + --searcher hyperband only; "
                         "bit-identical to the per-cell NumPy screen, "
                         "which stays the fallback when jax is missing)")
    ap.add_argument("--calibration", default=None, metavar="JSON",
                    help="apply a fitted calibration (python -m repro.calib "
                         "fit) to every hardware spec the cells evaluate "
                         "against; its fingerprint joins the stored search "
                         "config, so calibrated and uncalibrated results "
                         "never mix on resume")
    ap.add_argument("--max-attempts", type=int, default=3,
                    help="attempts per cell before it is quarantined as a "
                         "status:failed record (transient failures retry "
                         "with deterministic seeded backoff; permanent "
                         "model errors never retry). Default: 3")
    ap.add_argument("--cell-timeout", type=float, default=None,
                    metavar="S",
                    help="per-cell wall-clock deadline in seconds "
                         "(workers>1 only: a cell past its deadline is "
                         "charged a timeout attempt and the pool is "
                         "rebuilt). Default: none")
    ap.add_argument("--backoff", type=float, default=0.05, metavar="S",
                    help="base retry backoff in seconds (exponential per "
                         "attempt, deterministic per-cell jitter). "
                         "Default: 0.05")
    ap.add_argument("--retry-failed", action="store_true",
                    help="re-run cells quarantined by a previous run "
                         "(by default failed records resume as done so a "
                         "permanent failure is not re-hit every resume)")
    ap.add_argument("--weights", default="",
                    help="scalarization, e.g. throughput_ips=1,dsp_eff=500 "
                         "(fpga default: throughput only, the paper's "
                         "objective; tpu default: step_time_s)")
    ap.add_argument("--top", type=int, default=8, help="ranked rows to print")
    ap.add_argument("--frontier-json", default=None,
                    help="also dump the frontier records to this JSON file")
    ap.add_argument("--trace", action="store_true",
                    help="record campaign telemetry (repro.obs): per-cell "
                         "spans + pool gauges into <store>.events.jsonl "
                         "and a Chrome trace at <store>.trace.json; "
                         "inspect with python -m repro.dse.obs <store>")
    vq = ap.add_mutually_exclusive_group()
    vq.add_argument("-v", "--verbose", action="store_true",
                    help="per-cell convergence detail (stop reason, PSO "
                         "cache hits) on the progress lines")
    vq.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-cell progress lines (the final "
                         "report still prints)")
    args = ap.parse_args(argv)

    backend = get_backend(args.backend)
    weights = parse_weights(args.weights)
    workers = args.workers if args.workers > 0 else (os.cpu_count() or 1)
    cells = backend.cells_from_args(args)
    store_path = args.store or backend.default_store
    shard = int(args.shard) if str(args.shard).isdigit() else args.shard
    calibration = None
    if args.calibration:
        from repro.calib import Calibration
        calibration = Calibration.load(args.calibration)
        print(f"calibration: {args.calibration} "
              f"({len(calibration.parts())} part(s), "
              f"fingerprint {calibration.fingerprint()})")
    policy = RetryPolicy(max_attempts=args.max_attempts,
                         backoff_s=args.backoff,
                         cell_timeout_s=args.cell_timeout,
                         seed=args.seed)
    report = run_campaign(cells, store_path,
                          base_seed=args.seed, population=args.population,
                          iterations=args.iterations, weights=weights,
                          workers=workers,
                          progress=None if args.quiet else print,
                          backend=backend, trace=args.trace,
                          verbose=args.verbose, searcher=args.searcher,
                          searcher_config=parse_searcher_config(
                              args.searcher_config), shard=shard,
                          jax_screen=args.jax_screen,
                          calibration=calibration, policy=policy,
                          retry_failed=args.retry_failed)
    front = print_report(report, weights, args.top)

    if args.frontier_json:
        with open(args.frontier_json, "w") as f:
            json.dump(front, f, indent=2, sort_keys=True)
        print(f"\nfrontier -> {args.frontier_json}")
    print(f"store -> {store_path}")
    if report.events_path:
        print(f"events -> {report.events_path}")
        print(f"chrome trace -> {report.trace_path}")
    if report.partial:
        print_partial_summary(report, store_path)
    return report


def print_partial_summary(report: CampaignReport, store_path) -> None:
    """The honest-failure epilogue for a partial campaign: what was lost,
    why, and the exact resume move."""
    bits = []
    if report.interrupted:
        bits.append("interrupted by signal")
    if report.failed_cells:
        bits.append(f"{report.failed_cells} cell(s) quarantined")
    if report.missing_cells:
        bits.append(f"{report.missing_cells} cell(s) not run")
    print(f"\n!! partial campaign ({'; '.join(bits)}) — exit code 3")
    for rec in report.failures():
        print(f"   FAILED {rec['cell_key']}: {rec['error_type']} "
              f"after {rec['attempts']} attempt(s)")
    hint = f"python -m repro.dse.campaign ... --store {store_path}"
    if report.failed_cells and not report.missing_cells \
            and not report.interrupted:
        hint += " --retry-failed"
    print(f"   resume: re-run the same command ({hint}); completed "
          f"cells are reused from the store")


def exit_code(report: CampaignReport) -> int:
    """0 for a full campaign, 3 for a partial one (interrupted,
    quarantined, or missing cells — resumable either way)."""
    return 3 if report.partial else 0


def run(argv: list[str] | None = None) -> int:
    """CLI entry point with exit-code semantics (``main`` returns the
    report for programmatic callers)."""
    return exit_code(main(argv))


if __name__ == "__main__":
    raise SystemExit(run())
