"""Persistent campaign results: streaming append-only JSONL stores.

One line per finished campaign cell, keyed on the cell key (what was
searched) and stamped with the RAV hash (what was found). Appending after
every cell makes a killed campaign resumable from its last completed cell;
loading keys-last-wins makes re-runs and store concatenation safe. The
format is deliberately plain JSONL so stores diff, grep, and feed
``jq``/pandas without a reader.

Two on-disk layouts share one reader (:class:`CampaignStore`):

* **v1 — single file** (``<store>.jsonl``): the original PR-1 format.
  Unchanged on disk; old stores load, resume, and append byte-for-byte
  as before.
* **v2 — sharded directory** (``<store>.d/`` holding a ``manifest.json``
  plus ``shard-*.jsonl`` files): the million-cell layout. Each writer
  appends to ITS OWN shard (no lock contention between campaign hosts);
  readers merge all shards keys-last-wins in sorted shard order. Opt in
  with :func:`open_store`'s ``layout="sharded"`` or by pointing any
  store consumer at the directory — ``auto`` detection does the rest.

The reader is *streaming*: loading builds only a key -> (shard, byte
offset) index, so memory stays O(cells), not O(records);
:meth:`CampaignStore.iter_records` replays records one at a time in
first-appearance key order (exactly the order the old dict-materializing
loader produced) and :meth:`CampaignStore.get` seeks one line.

Maintenance CLI (also ``python -m repro.dse.store``)::

    python -m repro.dse.store info    results/dse.jsonl
    python -m repro.dse.store compact results/dse.jsonl     # last-wins rewrite
    python -m repro.dse.store migrate results/dse.jsonl results/dse.d

:class:`ResultStore` remains as a thin compatibility alias whose
``.records()`` (the list-materializing call) emits a
``DeprecationWarning`` — new code iterates ``iter_records()``.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import re
import warnings
from pathlib import Path
from typing import Iterator

from repro.core.local_opt import RAV
from repro.obs import NULL

#: Per-record schema version (the ``schema`` field on each record).
SCHEMA_VERSION = 1
#: Sharded-directory format version (the manifest's ``store_format``).
STORE_FORMAT_VERSION = 2
MANIFEST_NAME = "manifest.json"
_SHARD_RE = re.compile(r"^shard-[A-Za-z0-9_.-]+\.jsonl$")


def record_status(rec) -> str:
    """A record's lifecycle status: ``"ok"`` for normal result records
    (including every pre-resilience record — they carry no ``status``
    field), ``"failed"`` for quarantined cells
    (:func:`repro.dse.resilience.quarantine_record`), ``"missing"`` for
    ``None``. Every frontier/report/placement consumer gates on this so
    failed records never masquerade as results."""
    if rec is None:
        return "missing"
    return rec.get("status", "ok")


def is_ok(rec) -> bool:
    """True for a normal result record (see :func:`record_status`)."""
    return rec is not None and rec.get("status", "ok") == "ok"


def rav_hash(rav: RAV) -> str:
    """Stable short hash of an RAV (fractions rounded to the PSO's cache
    resolution, so re-discovered designs hash identically)."""
    t = rav.as_tuple()
    canon = (t[0], t[1], round(t[2], 2), round(t[3], 2), round(t[4], 2))
    return hashlib.sha256(repr(canon).encode()).hexdigest()[:12]


def shard_name(shard: int | str) -> str:
    """Normalize a shard id to its file name (``7`` -> ``shard-007.jsonl``,
    ``"worker-a"`` -> ``shard-worker-a.jsonl``)."""
    if isinstance(shard, int):
        return f"shard-{shard:03d}.jsonl"
    name = str(shard)
    if not name.startswith("shard-"):
        name = f"shard-{name}"
    if not name.endswith(".jsonl"):
        name += ".jsonl"
    if not _SHARD_RE.match(name):
        raise ValueError(f"bad shard name {name!r}")
    return name


def sharded_dir_for(path: str | os.PathLike) -> Path:
    """Where the sharded twin of ``path`` lives: the path itself when it
    already names a ``*.d`` directory, else ``<path>.d``."""
    p = Path(path)
    return p if p.suffix == ".d" else Path(str(p) + ".d")


def open_store(path: "str | os.PathLike | CampaignStore", *,
               layout: str = "auto", shard: int | str = 0,
               tracer=NULL) -> "CampaignStore":
    """Open (or create) a campaign store.

    ``layout="auto"`` (default) keeps byte compatibility: an existing
    ``*.d`` directory (or a ``<path>.d`` sibling of the given path)
    opens sharded, anything else opens as a v1 single file — including
    fresh paths, so old workflows create exactly the files they always
    did. ``layout="v1"`` / ``layout="sharded"`` force a layout; ``shard``
    names the shard THIS writer appends to (sharded layout only).
    """
    if isinstance(path, CampaignStore):
        return path
    return CampaignStore(path, tracer=tracer, layout=layout, shard=shard)


class CampaignStore:
    """Streaming dict-like view over one JSONL store (either layout).

    Loading is corruption-aware, per file: a torn FINAL line is the
    expected leftover of a killed run and is dropped silently, but an
    undecodable line anywhere else means real damage (truncation
    mid-file, a bad concatenation, disk trouble) and is surfaced —
    counted on :attr:`corrupt_lines`, warned about, and reported to
    ``tracer`` as the ``store.corrupt_lines`` obs counter.
    :attr:`skipped_lines` counts every dropped line including torn
    tails. Re-``put`` of a byte-identical record is skipped and counted
    on :attr:`noop_puts` (the ``store.noop_puts`` obs counter) so
    long-resumed stores stop accreting duplicate lines.
    """

    def __init__(self, path: str | os.PathLike, tracer=NULL, *,
                 layout: str = "auto", shard: int | str = 0):
        self.path = Path(path)
        self.tracer = tracer
        #: key -> (file index, byte offset, line length, backend name).
        self._index: dict[str, tuple[int, int, int, str]] = {}
        self._files: list[Path] = []
        #: Undecodable lines dropped on load (torn final lines included).
        self.skipped_lines = 0
        #: Undecodable NON-final lines — real corruption, never the
        #: benign torn tail of a killed run.
        self.corrupt_lines = 0
        #: Puts skipped because the stored record was already identical.
        self.noop_puts = 0
        self._resolve_layout(layout, shard)
        self._load()

    # -- layout -----------------------------------------------------------

    def _resolve_layout(self, layout: str, shard: int | str) -> None:
        p = self.path
        if layout == "auto":
            alt = sharded_dir_for(p)
            if p.is_dir() or p.suffix == ".d":
                layout = "sharded"
            elif alt != p and alt.is_dir():
                layout, p = "sharded", alt
            else:
                layout = "v1"
        if layout in ("v1", "file", "jsonl"):
            self.sharded = False
            self._files = [p]
            self._append_to = 0
            return
        if layout not in ("sharded", "v2"):
            raise ValueError(f"unknown store layout {layout!r}; "
                             f"use 'auto', 'v1', or 'sharded'")
        self.sharded = True
        self.dir = sharded_dir_for(p)
        self.dir.mkdir(parents=True, exist_ok=True)
        manifest = self.dir / MANIFEST_NAME
        if manifest.exists():
            meta = json.loads(manifest.read_text())
            fmt = meta.get("store_format")
            if fmt != STORE_FORMAT_VERSION:
                raise ValueError(
                    f"store {self.dir}: unsupported store_format {fmt!r} "
                    f"(this reader speaks {STORE_FORMAT_VERSION})")
        else:
            manifest.write_text(json.dumps(
                {"store_format": STORE_FORMAT_VERSION,
                 "schema": SCHEMA_VERSION}, sort_keys=True) + "\n")
        self._files = sorted(f for f in self.dir.glob("shard-*.jsonl")
                             if _SHARD_RE.match(f.name))
        own = self.dir / shard_name(shard)
        if own not in self._files:
            self._files.append(own)
        self._append_to = self._files.index(own)

    # -- loading ----------------------------------------------------------

    def _load(self) -> None:
        for fi, fpath in enumerate(self._files):
            if fpath.exists():
                self._scan_file(fi, fpath)
        if self.corrupt_lines:
            self.tracer.count("store.corrupt_lines", self.corrupt_lines,
                              store=str(self.path))
            warnings.warn(
                f"store {self.path}: skipped {self.corrupt_lines} corrupt "
                f"non-final line(s) — the file is damaged beyond a torn "
                f"final append; affected cells will re-run",
                RuntimeWarning, stacklevel=4)

    def _scan_file(self, fi: int, fpath: Path) -> None:
        """Index one JSONL file: byte offset + length per current record,
        one line in memory at a time."""
        bad: list[int] = []       # line numbers of undecodable lines
        last_nonblank = -1
        lineno = -1
        offset = 0
        with fpath.open("rb") as f:
            for raw in f:
                lineno += 1
                line = raw.strip()
                if line:
                    last_nonblank = lineno
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        bad.append(lineno)
                    else:
                        key = rec.get("cell_key")
                        if key:
                            self._index[key] = (
                                fi, offset, len(raw),
                                rec.get("backend", "fpga"))
                offset += len(raw)
        self.skipped_lines += len(bad)
        self.corrupt_lines += sum(1 for b in bad if b != last_nonblank)

    # -- the CampaignStore protocol ---------------------------------------

    def _read_line(self, loc: tuple[int, int, int, str]) -> bytes:
        fi, off, length, _ = loc
        with self._files[fi].open("rb") as f:
            f.seek(off)
            return f.read(length)

    def get(self, cell_key: str) -> dict | None:
        loc = self._index.get(cell_key)
        if loc is None:
            return None
        return json.loads(self._read_line(loc))

    def put(self, record: dict) -> None:
        """Append one record and flush, so a kill right after still leaves
        the cell on disk. A record byte-identical to the stored one under
        the same key is a counted no-op (resume-churn protection)."""
        key = record["cell_key"]
        data = (json.dumps(record, sort_keys=True) + "\n").encode()
        loc = self._index.get(key)
        if loc is not None and self._read_line(loc).rstrip(b"\n") == \
                data.rstrip(b"\n"):
            self.noop_puts += 1
            self.tracer.count("store.noop_puts", store=str(self.path))
            return
        fpath = self._files[self._append_to]
        fpath.parent.mkdir(parents=True, exist_ok=True)
        with fpath.open("ab") as f:
            f.seek(0, os.SEEK_END)
            if f.tell() and not self._ends_with_newline(fpath, f):
                # healing append after a torn final line: never glue the
                # new record onto the damaged tail
                f.write(b"\n")
            off = f.tell()
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        self._index[key] = (self._append_to, off, len(data),
                            record.get("backend", "fpga"))

    @staticmethod
    def _ends_with_newline(fpath: Path, f) -> bool:
        end = f.tell()
        with fpath.open("rb") as r:
            r.seek(end - 1)
            return r.read(1) == b"\n"

    def iter_records(self, backend: str | None = None) -> Iterator[dict]:
        """Stream current records (last version per key) in
        first-appearance key order, one line in memory at a time.
        ``backend`` filters to one backend's records; legacy (PR-1)
        records carry no ``backend`` field and count as ``"fpga"``."""
        handles: dict[int, object] = {}
        try:
            for fi, off, length, bk in self._index.values():
                if backend is not None and bk != backend:
                    continue
                fh = handles.get(fi)
                if fh is None:
                    fh = handles[fi] = self._files[fi].open("rb")
                fh.seek(off)
                yield json.loads(fh.read(length))
        finally:
            for fh in handles.values():
                fh.close()

    def keys(self) -> Iterator[str]:
        return iter(self._index)

    def __contains__(self, cell_key: str) -> bool:
        return cell_key in self._index

    def __len__(self) -> int:
        return len(self._index)

    def __iter__(self) -> Iterator[dict]:
        return self.iter_records()

    def backends(self) -> list[str]:
        """Backend names present in the store, sorted."""
        return sorted({bk for _, _, _, bk in self._index.values()})

    def frontier_index(self, backend: str | None = None):
        """One streaming pass -> the incremental Pareto frontier
        (:class:`repro.dse.frontier.FrontierIndex`) over the feasible
        records' canonical objective vectors, keyed by cell key with the
        full record as each front member's payload.

        Canonical vectors are backend-specific, so a mixed store must
        pick one ``backend`` (cross-family comparison goes through the
        normalized schema in :mod:`repro.dse.report` instead).
        """
        from .backends import get_backend
        from .frontier import FrontierIndex
        names = self.backends() if backend is None else [backend]
        if len(names) > 1:
            raise ValueError(
                f"store mixes backends {names}; pass backend=... (their "
                f"canonical objective vectors are not comparable)")
        fi = FrontierIndex()
        be = get_backend(names[0]) if names else None
        for rec in self.iter_records(backend):
            if is_ok(rec) and rec.get("objectives", {}).get("feasible"):
                fi.insert(rec["cell_key"], be.canonical(rec["objectives"]),
                          payload=rec)
        return fi

    # -- maintenance ------------------------------------------------------

    def compact(self) -> int:
        """Last-wins rewrite: drop superseded/undecodable lines, keeping
        current records in first-appearance key order (v1: rewrite the
        file; sharded: collapse every shard into this writer's shard).
        Atomic (write-temp-then-rename) and idempotent — compacting a
        compacted store is a byte no-op. Returns the record count."""
        target = self._files[self._append_to]
        tmp = target.with_suffix(target.suffix + ".tmp")
        n = 0
        with tmp.open("wb") as f:
            for loc in self._index.values():
                f.write(self._read_line(loc).rstrip(b"\n") + b"\n")
                n += 1
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, target)
        for fi, fpath in enumerate(self._files):
            if fi != self._append_to and fpath.exists():
                fpath.unlink()
        # reopen against the rewritten layout
        self._files = [target]
        self._append_to = 0
        self._index.clear()
        self.skipped_lines = self.corrupt_lines = 0
        self._scan_file(0, target)
        return n


class ResultStore(CampaignStore):
    """PR-1 compatibility alias of :class:`CampaignStore`.

    The one behavioral difference is :meth:`records`, the historical
    materialize-everything call: it still works but emits a
    ``DeprecationWarning`` — stream :meth:`CampaignStore.iter_records`
    instead.
    """

    def records(self, backend: str | None = None) -> list[dict]:
        """All records as a list (deprecated — this materializes the
        whole store; iterate :meth:`iter_records` instead)."""
        warnings.warn(
            "ResultStore.records() materializes every record; iterate "
            "iter_records() instead (streaming, same order)",
            DeprecationWarning, stacklevel=2)
        return list(self.iter_records(backend))


# ---------------------------------------------------------------------------
# maintenance CLI
# ---------------------------------------------------------------------------


def _bulk_copy(src: CampaignStore, dst: CampaignStore) -> int:
    """Stream every current record of ``src`` into ``dst`` (no per-line
    fsync: one flush+fsync at the end of the append file)."""
    fpath = dst._files[dst._append_to]
    fpath.parent.mkdir(parents=True, exist_ok=True)
    n = 0
    with fpath.open("ab") as f:
        f.seek(0, os.SEEK_END)
        for key, loc in src._index.items():
            data = src._read_line(loc).rstrip(b"\n") + b"\n"
            off = f.tell()
            f.write(data)
            n += 1
            dst._index[key] = (dst._append_to, off, len(data), loc[3])
        f.flush()
        os.fsync(f.fileno())
    return n


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dse.store",
        description="Maintain campaign JSONL stores: inspect, last-wins "
                    "compact, migrate between the single-file (v1) and "
                    "sharded (v2) layouts.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_info = sub.add_parser("info", help="layout, shard, and record counts")
    p_info.add_argument("store")

    p_compact = sub.add_parser(
        "compact", help="last-wins rewrite (drops superseded and "
                        "undecodable lines; atomic and idempotent)")
    p_compact.add_argument("store")

    p_mig = sub.add_parser(
        "migrate", help="copy a store's current records into another "
                        "layout (dst ending in .d -> sharded, else v1)")
    p_mig.add_argument("src")
    p_mig.add_argument("dst")
    p_mig.add_argument("--shard", default="0",
                       help="destination shard id (sharded dst only)")
    args = ap.parse_args(argv)

    if args.cmd == "info":
        s = open_store(args.store)
        kind = (f"sharded ({len(s._files)} shard(s) in {s.dir})"
                if s.sharded else "v1 single file")
        per_be = {b: sum(1 for loc in s._index.values() if loc[3] == b)
                  for b in s.backends()}
        failed = sum(1 for rec in s.iter_records() if not is_ok(rec))
        print(f"{args.store}: {kind}")
        print(f"  records: {len(s)}  backends: "
              + (", ".join(f"{b}={n}" for b, n in per_be.items()) or "-"))
        print(f"  skipped lines: {s.skipped_lines} "
              f"(corrupt: {s.corrupt_lines})")
        if failed:
            print(f"  quarantined: {failed} failed record(s) — resume "
                  f"with --retry-failed to re-run them")
        if s.sharded:
            for f in s._files:
                size = f.stat().st_size if f.exists() else 0
                print(f"  {f.name}: {size} bytes")
        return 0

    if args.cmd == "compact":
        s = open_store(args.store)
        before = sum(f.stat().st_size for f in s._files if f.exists())
        n = s.compact()
        after = sum(f.stat().st_size for f in s._files if f.exists())
        print(f"compacted {args.store}: {n} records, "
              f"{before} -> {after} bytes")
        return 0

    if args.cmd == "migrate":
        src = open_store(args.src)
        dst_layout = ("sharded" if Path(args.dst).suffix == ".d"
                      or Path(args.dst).is_dir() else "v1")
        shard = (int(args.shard) if str(args.shard).isdigit()
                 else args.shard)
        dst = open_store(args.dst, layout=dst_layout, shard=shard)
        n = _bulk_copy(src, dst)
        print(f"migrated {args.src} -> {args.dst} "
              f"({dst_layout}): {n} records")
        return 0

    return 2  # pragma: no cover - argparse enforces the subcommand


if __name__ == "__main__":
    raise SystemExit(main())
