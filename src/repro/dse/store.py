"""Persistent campaign results: an append-only JSON-lines store.

One line per finished campaign cell, keyed on the cell key (what was
searched) and stamped with the RAV hash (what was found). Appending after
every cell makes a killed campaign resumable from its last completed cell;
loading keys-last-wins makes re-runs and store concatenation safe. The
format is deliberately plain JSONL so stores diff, grep, and feed
``jq``/pandas without a reader.
"""
from __future__ import annotations

import hashlib
import json
import os
import warnings
from pathlib import Path
from typing import Iterator

from repro.core.local_opt import RAV
from repro.obs import NULL

SCHEMA_VERSION = 1


def rav_hash(rav: RAV) -> str:
    """Stable short hash of an RAV (fractions rounded to the PSO's cache
    resolution, so re-discovered designs hash identically)."""
    t = rav.as_tuple()
    canon = (t[0], t[1], round(t[2], 2), round(t[3], 2), round(t[4], 2))
    return hashlib.sha256(repr(canon).encode()).hexdigest()[:12]


class ResultStore:
    """Dict-like view over a JSONL file of campaign cell records.

    Loading is corruption-aware: a torn FINAL line is the expected
    leftover of a killed run and is dropped silently, but an undecodable
    line anywhere else means real damage (truncation mid-file, a bad
    concatenation, disk trouble) and is surfaced — counted on
    :attr:`corrupt_lines`, warned about, and reported to ``tracer`` as
    the ``store.corrupt_lines`` obs counter. :attr:`skipped_lines`
    counts every dropped line including the torn tail.
    """

    def __init__(self, path: str | os.PathLike, tracer=NULL):
        self.path = Path(path)
        self.tracer = tracer
        self._records: dict[str, dict] = {}
        #: Undecodable lines dropped on load (torn final line included).
        self.skipped_lines = 0
        #: Undecodable NON-final lines — real corruption, never the
        #: benign torn tail of a killed run.
        self.corrupt_lines = 0
        self._load()

    def _load(self) -> None:
        if not self.path.exists():
            return
        with self.path.open() as f:
            lines = [ln.strip() for ln in f]
        while lines and not lines[-1]:
            lines.pop()
        last = len(lines) - 1
        for i, line in enumerate(lines):
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                self.skipped_lines += 1
                if i != last:  # torn final line from a killed run is fine
                    self.corrupt_lines += 1
                continue
            key = rec.get("cell_key")
            if key:
                self._records[key] = rec
        if self.corrupt_lines:
            self.tracer.count("store.corrupt_lines", self.corrupt_lines,
                              store=str(self.path))
            warnings.warn(
                f"store {self.path}: skipped {self.corrupt_lines} corrupt "
                f"non-final line(s) — the file is damaged beyond a torn "
                f"final append; affected cells will re-run",
                RuntimeWarning, stacklevel=3)

    def get(self, cell_key: str) -> dict | None:
        return self._records.get(cell_key)

    def put(self, record: dict) -> None:
        """Append one record and flush, so a kill right after still leaves
        the cell on disk."""
        key = record["cell_key"]
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self.path.open("a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._records[key] = record

    def __contains__(self, cell_key: str) -> bool:
        return cell_key in self._records

    def __len__(self) -> int:
        return len(self._records)

    def __iter__(self) -> Iterator[dict]:
        return iter(self._records.values())

    def records(self, backend: str | None = None) -> list[dict]:
        """All records, optionally only one backend's. Legacy (PR-1)
        records carry no ``backend`` field and count as ``"fpga"``."""
        recs = list(self._records.values())
        if backend is None:
            return recs
        return [r for r in recs if r.get("backend", "fpga") == backend]

    def backends(self) -> list[str]:
        """Backend names present in the store, sorted."""
        return sorted({r.get("backend", "fpga")
                       for r in self._records.values()})
