"""StarCoder2-15B: dense GQA + RoPE. [arXiv:2402.19173]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, d_ff=24576,
    vocab=49152, activation="gelu", gated_mlp=False, rope=True,
)
