"""H2O-Danube-3-4B: llama+mistral mix with sliding-window attention.
SWA makes long_500k decode sub-quadratic (bounded KV ring buffer).
[arXiv:2401.16818]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv=8, d_ff=10240,
    vocab=32000, activation="silu", gated_mlp=True, rope=True,
    window=4096, max_seq=524288,
)
