"""Config registry: ``get_config("<arch-id>")`` -> ArchConfig."""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, MoECfg, ShapeSpec, SSMCfg

ARCH_IDS = (
    "nemotron-4-340b",
    "starcoder2-3b",
    "starcoder2-15b",
    "h2o-danube-3-4b",
    "xlstm-350m",
    "llava-next-34b",
    "llama4-maverick-400b-a17b",
    "kimi-k2-1t-a32b",
    "zamba2-2.7b",
    "whisper-base",
)


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# Which (arch x shape) cells run. long_500k needs sub-quadratic attention:
# run for SSM/hybrid/SWA archs, skip for pure full-attention ones (noted in
# DESIGN.md SS Arch-applicability).
def cell_enabled(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full attention is quadratic at 500k; skipped per spec"
    return True, ""


__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "MoECfg", "SSMCfg",
           "ShapeSpec", "get_config", "all_configs", "cell_enabled"]
