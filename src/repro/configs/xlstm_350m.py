"""xLSTM-350M: sLSTM + mLSTM blocks (1 sLSTM per 6 blocks), no separate
FFN (d_ff=0); recurrent state => long_500k runnable. [arXiv:2405.04517]"""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv=4, d_ff=0,
    vocab=50304, rope=False,
    ssm=SSMCfg(state_dim=64, head_dim=256, chunk=256, slstm_every=6),
    max_seq=524288,
)
