"""LLaVA-NeXT-34B: Yi-34B text backbone + anyres vision tiling (frontend
STUBBED: input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-34b]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_ff=20480,
    vocab=64000, activation="silu", gated_mlp=True, rope=True,
    n_patches=576, vision_embed_dim=1024,
)
