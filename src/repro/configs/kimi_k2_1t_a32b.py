"""Kimi-K2 1T-A32B: trillion-param MoE, 384 experts top-8 + 1 shared,
d_ff_expert=2048. [arXiv:2501.kimi2]"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv=8, d_ff=2048,
    vocab=163840, activation="silu", gated_mlp=True, rope=True,
    moe=MoECfg(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1),
)
