"""Architecture + workload-shape configuration system.

Every assigned architecture is an :class:`ArchConfig` in its own module
under ``repro/configs``; ``repro.configs.get_config(name)`` resolves it.
Workload shapes (the 4 assigned input-shape cells) are :class:`ShapeSpec`.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 1          # shared-expert(s) run for every token
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 64        # N (per-head state size)
    head_dim: int = 64
    expansion: int = 2
    conv_width: int = 4
    chunk: int = 256           # SSD chunk length
    # xlstm: 1 sLSTM block per `slstm_every` mLSTM blocks (0 = none)
    slstm_every: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # family extras
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    window: int | None = None           # sliding-window attention
    rope: bool = True
    rope_theta: float = 10000.0
    activation: str = "silu"
    gated_mlp: bool = True
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    # hybrid (zamba2): one shared attention block applied every N layers
    shared_attn_every: int = 0
    # vlm: number of (precomputed, stubbed) vision patch embeddings per sample
    n_patches: int = 0
    vision_embed_dim: int = 0
    # audio (whisper): encoder config; decoder uses the top-level fields
    n_enc_layers: int = 0
    n_audio_frames: int = 0             # precomputed frame embeddings (stub)
    # attention is sub-quadratic (SSM state or bounded window) => long-context OK
    max_seq: int = 131072

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv * hd) * 2
        mlp = d * f * (3 if self.gated_mlp else 2)
        if self.moe:
            e = self.moe
            expert = d * e.d_ff_expert * 3
            mlp = e.n_experts * expert + e.n_shared * expert + d * e.n_experts
        per_layer = attn + mlp
        if self.family in ("ssm", "hybrid"):
            s = self.ssm or SSMCfg()
            d_in = s.expansion * d
            per_layer = d * (2 * d_in) + d_in * d  # in/out projections
            n_h = d_in // s.head_dim
            per_layer += d * (2 * n_h * s.state_dim) + d * n_h  # B,C,dt projs
            if self.family == "hybrid":
                pass  # shared attn counted once below
        total = self.n_layers * per_layer
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.shared_attn_every:
            total += attn + d * f * (3 if self.gated_mlp else 2)
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + mlp) + self.n_layers * attn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        e = self.moe
        expert = d * e.d_ff_expert * 3
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv * hd) * 2
        per_layer = attn + (e.top_k + e.n_shared) * expert + d * e.n_experts
        return self.n_layers * per_layer + self.vocab * d * 2

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 2 if not self.shared_attn_every
                         else max(2, min(4, self.shared_attn_every))),
            d_model=128,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            max_seq=512,
        )
        if self.moe:
            changes["moe"] = MoECfg(n_experts=4, top_k=min(self.moe.top_k, 2),
                                    d_ff_expert=64, n_shared=self.moe.n_shared)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=32, chunk=32,
                slstm_every=2 if self.ssm.slstm_every else 0)
        if self.window:
            changes["window"] = 64
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
        if self.n_patches:
            changes["n_patches"] = 16
            changes["vision_embed_dim"] = 128
        if self.n_enc_layers:
            changes["n_enc_layers"] = 2
            changes["n_audio_frames"] = 64
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int

    def reduced(self) -> "ShapeSpec":
        return dataclasses.replace(self, seq_len=min(self.seq_len, 64),
                                   global_batch=min(self.global_batch, 2))


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}
