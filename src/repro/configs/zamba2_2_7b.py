"""Zamba2-2.7B: Mamba2 backbone + ONE shared attention block reused every
6 layers (MHA kv=32), ssm_state=64. [arXiv:2411.15242]"""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240,
    vocab=32000, activation="silu", gated_mlp=True, rope=True,
    ssm=SSMCfg(state_dim=64, head_dim=64, expansion=2, chunk=256),
    shared_attn_every=6, max_seq=524288,
)
