"""Llama-4-Maverick 400B-A17B: MoE 128 experts top-1 + shared expert,
early-fusion multimodal (text path modeled). [hf:meta-llama/Llama-4]"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192,
    vocab=202048, activation="silu", gated_mlp=True, rope=True,
    moe=MoECfg(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1),
)
