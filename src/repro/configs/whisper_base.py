"""Whisper-base: 6L encoder + 6L decoder, conv frontend STUBBED
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048,
    vocab=51865, rope=False, gated_mlp=False, activation="gelu",
    norm="layernorm", tie_embeddings=True,
    n_enc_layers=6, n_audio_frames=1500, max_seq=32768,
)
