"""The ``Calibration`` object: per-part correction factors + provenance.

A :class:`Correction` multiplies one hardware part's delivered compute
rate (``compute_scale``) and external-memory bandwidth (``bw_scale``) so
the analytic models predict what the part *measures*, not what its
datasheet promises. Each correction carries a :class:`Provenance` record
(where the measurements came from, when, and of what kind) plus the fit
statistics (measurement counts, raw vs calibrated error) so every
corrected campaign result is auditable back to its evidence.

A :class:`Calibration` maps part names (``hw_specs`` spec names:
``ku115``, ``tpu_v5e``, ``a100-80g``, ...) to corrections. Parts with no
entry get the identity correction; the empty calibration — the planners'
default — changes nothing, and :func:`Calibration.for_spec` returns the
spec object itself in that case, so uncalibrated evaluations are
byte-identical to pre-calibration behavior.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Mapping

from repro.core.hw_specs import scaled_spec


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Where a correction's measurements came from.

    ``kind`` is one of ``hlo_dryrun`` (exact-HLO costs from
    ``launch/hlo_cost.py`` artifacts), ``microbench`` (the repo's own
    benchmark rows), ``published`` (committed MLPerf-style numbers), or
    ``synthetic`` (test fixtures); merged fits join kinds with ``+``."""

    source: str
    date: str
    kind: str

    def as_dict(self) -> dict:
        return {"source": self.source, "date": self.date, "kind": self.kind}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Provenance":
        return cls(source=str(d.get("source", "")),
                   date=str(d.get("date", "")),
                   kind=str(d.get("kind", "")))


@dataclasses.dataclass(frozen=True)
class Correction:
    """One part's fitted multipliers + the evidence behind them.

    ``compute_scale`` / ``bw_scale`` multiply the spec's delivered
    compute rate / bandwidth (see
    :func:`repro.core.hw_specs.scaled_spec`); a scale below 1.0 means
    the hardware delivers less than the datasheet the model assumed.
    ``raw_err_pct`` / ``cal_err_pct`` are geometric-RMS relative errors
    of the model against the fitted measurements before and after the
    correction — the error-table columns."""

    compute_scale: float = 1.0
    bw_scale: float = 1.0
    provenance: Provenance | None = None
    n_compute: int = 0
    n_bandwidth: int = 0
    raw_err_pct: float = 0.0
    cal_err_pct: float = 0.0

    def is_identity(self) -> bool:
        return self.compute_scale == 1.0 and self.bw_scale == 1.0

    def as_dict(self) -> dict:
        return {
            "compute_scale": self.compute_scale, "bw_scale": self.bw_scale,
            "n_compute": self.n_compute, "n_bandwidth": self.n_bandwidth,
            "raw_err_pct": self.raw_err_pct, "cal_err_pct": self.cal_err_pct,
            "provenance": self.provenance.as_dict() if self.provenance
            else None,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "Correction":
        prov = d.get("provenance")
        return cls(compute_scale=float(d.get("compute_scale", 1.0)),
                   bw_scale=float(d.get("bw_scale", 1.0)),
                   provenance=Provenance.from_dict(prov) if prov else None,
                   n_compute=int(d.get("n_compute", 0)),
                   n_bandwidth=int(d.get("n_bandwidth", 0)),
                   raw_err_pct=float(d.get("raw_err_pct", 0.0)),
                   cal_err_pct=float(d.get("cal_err_pct", 0.0)))


_IDENTITY_CORRECTION = Correction()

#: On-disk schema version of ``Calibration.save`` files.
SCHEMA_VERSION = 1


class Calibration:
    """Part name -> :class:`Correction`; identity for unknown parts.

    Plain picklable container (campaign workers receive it through the
    process pool). JSON round-trips via :meth:`as_dict`/:meth:`from_dict`
    and :meth:`save`/:meth:`load`; :meth:`fingerprint` is the stable
    digest campaigns store in their resume-match search config."""

    def __init__(self, corrections: Mapping[str, Correction] | None = None):
        self._corrections: dict[str, Correction] = {
            k: v for k, v in (corrections or {}).items()
            if not v.is_identity()}

    def correction(self, part: str) -> Correction:
        return self._corrections.get(part, _IDENTITY_CORRECTION)

    def parts(self) -> tuple[str, ...]:
        return tuple(sorted(self._corrections))

    def is_identity(self) -> bool:
        return not self._corrections

    def __eq__(self, other) -> bool:
        return (isinstance(other, Calibration)
                and self._corrections == other._corrections)

    def __repr__(self) -> str:
        return f"Calibration({self._corrections!r})"

    def for_spec(self, spec):
        """``spec`` with this calibration's correction for ``spec.name``
        applied (via :func:`repro.core.hw_specs.scaled_spec`). Identity
        corrections return ``spec`` itself — the uncalibrated path is
        literally the existing code path."""
        c = self.correction(spec.name)
        return scaled_spec(spec, c.compute_scale, c.bw_scale)

    # -- serialization -------------------------------------------------------

    def as_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION,
                "corrections": {k: v.as_dict()
                                for k, v in sorted(self._corrections.items())}}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Calibration":
        return cls({k: Correction.from_dict(v)
                    for k, v in d.get("corrections", {}).items()})

    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.as_dict(), indent=1, sort_keys=True)
                        + "\n")
        return path

    @classmethod
    def load(cls, path: str | Path) -> "Calibration":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def fingerprint(self) -> str:
        """Stable short digest of the correction factors (provenance and
        fit stats excluded — two fits that land on the same multipliers
        resume each other's stores)."""
        scales = {k: [v.compute_scale, v.bw_scale]
                  for k, v in sorted(self._corrections.items())}
        blob = json.dumps(scales, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    def record_info(self, part: str) -> dict | None:
        """The per-record calibration stamp campaign backends attach to
        store records evaluated under a non-identity correction: the
        factors actually applied plus their provenance, so corrected
        results stay auditable after a store resume. ``None`` when the
        part is uncorrected."""
        c = self.correction(part)
        if c.is_identity():
            return None
        return {"fingerprint": self.fingerprint(), "part": part,
                "compute_scale": c.compute_scale, "bw_scale": c.bw_scale,
                "provenance": c.provenance.as_dict() if c.provenance
                else None}


#: The planners' default: corrects nothing, fingerprints to the empty fit.
IDENTITY = Calibration()
