"""Measurement sources: predicted-vs-measured pairs the fit consumes.

A :class:`Measurement` is one (part, axis) comparison in TIME units:
what the analytic model predicted for a workload term vs what was
measured (or what a published number implies). Three sources feed
:func:`repro.calib.fit.fit_corrections`:

* :func:`hlo_dryrun_measurements` — ``repro.launch.dryrun`` artifacts:
  the exact-HLO compute term (``launch/hlo_cost.py`` loop-aware FLOPs at
  the part's peak) against the analytic roofline's compute term for the
  same (arch x shape x mesh) cell. This wires the dryrun's exact costs
  into the tpu/cuda evaluation loop *as ground truth for the model*.
* :func:`bench_measurements` — ``benchmarks/run.py --json`` rows: any
  row whose ``derived`` string carries ``calib_part/calib_axis/
  calib_pred_s/calib_meas_s`` fields contributes one measurement, so
  Pallas kernel microbenches become calibration evidence wherever real
  hardware runs the bench suite.
* :func:`repro.calib.published.published_measurements` — the committed
  MLPerf-style table for the GPU parts.

:func:`fixture_measurements` is the deterministic synthetic set (known
skews per part) used by tests, the CLI smoke, and the committed example
report.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Iterable, Mapping

from repro.core.hw_specs import TPU_V5E, TPUSpec

from .calibration import Provenance

#: The two correction axes a spec exposes (see ``hw_specs.scaled_spec``).
AXES = ("compute", "bandwidth")

#: Fixed date stamped on fixture measurements so fixture-derived reports
#: are byte-stable for drift tests.
FIXTURE_DATE = "2026-08-01"


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One predicted-vs-measured time pair for a (part, axis).

    ``predicted_s``: the analytic model's time for the term;
    ``measured_s``: what the hardware (or the exact-HLO proxy, or a
    published delivered-rate) implies for the same term. The fitted
    scale divides predicted time — scale = predicted/measured — so a
    model that is optimistic (measured > predicted) fits a scale < 1."""

    part: str
    axis: str            # "compute" | "bandwidth"
    workload: str        # human label, e.g. "starcoder2-3b/train_4k"
    predicted_s: float
    measured_s: float
    provenance: Provenance

    def __post_init__(self):
        if self.axis not in AXES:
            raise ValueError(f"unknown axis {self.axis!r}; choose from {AXES}")
        if self.predicted_s <= 0 or self.measured_s <= 0:
            raise ValueError(f"measurement times must be positive "
                             f"(got predicted={self.predicted_s}, "
                             f"measured={self.measured_s})")


# ---------------------------------------------------------------------------
# source 1: exact-HLO dryrun artifacts (launch/hlo_cost.py)
# ---------------------------------------------------------------------------


def _artifact_mesh(name: str):
    from repro.core.tpu_model import MeshDesc
    if name.startswith("single"):
        return MeshDesc.single_pod()
    if name.startswith("multi"):
        return MeshDesc.multi_pod()
    return None


def hlo_dryrun_measurements(dryrun_dir: str = "results/dryrun",
                            hw: TPUSpec = TPU_V5E) -> list[Measurement]:
    """Compute-axis measurements from ``repro.launch.dryrun`` artifacts.

    Per ``status: ok`` artifact: the analytic roofline's compute term for
    the cell vs the exact parsed-HLO FLOPs (loop-aware, fusion-descended
    — see :mod:`repro.launch.hlo_cost`) at the part's peak. The HLO
    memory term is NOT used: CPU-backend operand bytes are inflated by
    unfused materialization (see ``benchmarks/roofline.py``). Returns
    ``[]`` when the directory has no artifacts — calibration degrades
    gracefully on machines that never ran a dryrun."""
    from repro.configs import SHAPES, get_config
    from repro.core.tpu_model import analytic_roofline, hlo_roofline
    out: list[Measurement] = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        try:
            with open(path) as f:
                cell = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if cell.get("status") != "ok" or "exact" not in cell:
            continue
        mesh = _artifact_mesh(str(cell.get("mesh", "")))
        if mesh is None:
            continue
        try:
            cfg = get_config(cell["arch"])
            shape = SHAPES[cell["shape"]]
        except KeyError:
            continue
        ana = analytic_roofline(cfg, shape, mesh, hw)
        hlo = hlo_roofline(cell["exact"], hw)
        if ana.t_compute <= 0 or hlo.t_compute <= 0:
            continue
        out.append(Measurement(
            part=hw.name, axis="compute",
            workload=f"{cell['arch']}/{cell['shape']}@{cell['mesh']}",
            predicted_s=ana.t_compute, measured_s=hlo.t_compute,
            provenance=Provenance(source=os.path.basename(path),
                                  date=str(cell.get("date", "")),
                                  kind="hlo_dryrun")))
    return out


# ---------------------------------------------------------------------------
# source 2: the repo's own microbenches (benchmarks/run.py --json)
# ---------------------------------------------------------------------------


def _derived_fields(derived: str) -> dict[str, str]:
    out = {}
    for tok in derived.split(";"):
        name, sep, val = tok.partition("=")
        if sep:
            out[name.strip()] = val.strip()
    return out


def bench_measurements(bench: Mapping,
                       date: str = "") -> list[Measurement]:
    """Measurements from a ``benchmarks/run.py --json`` dump.

    Any row whose ``derived`` string carries the four fields
    ``calib_part=<spec name>;calib_axis=compute|bandwidth;
    calib_pred_s=<s>;calib_meas_s=<s>`` contributes one measurement —
    the convention kernel microbenches use to publish ground truth when
    they run on real hardware. Rows without the fields are ignored, so
    the full bench dump can be fed in unfiltered."""
    out: list[Measurement] = []
    for bench_name, rows in sorted(bench.get("benchmarks", {}).items()):
        for row in rows:
            d = _derived_fields(str(row.get("derived", "")))
            if not {"calib_part", "calib_axis", "calib_pred_s",
                    "calib_meas_s"} <= d.keys():
                continue
            try:
                pred, meas = float(d["calib_pred_s"]), float(d["calib_meas_s"])
            except ValueError:
                continue
            if pred <= 0 or meas <= 0:
                continue
            out.append(Measurement(
                part=d["calib_part"], axis=d["calib_axis"],
                workload=str(row.get("name", bench_name)),
                predicted_s=pred, measured_s=meas,
                provenance=Provenance(
                    source=f"benchmarks/run.py:{row.get('name', bench_name)}",
                    date=date, kind="microbench")))
    return out


# ---------------------------------------------------------------------------
# fixture: deterministic synthetic measurements with known skew
# ---------------------------------------------------------------------------

#: (part, axis, workload, predicted_s, measured_s, kind). Skews are
#: deliberate: the model is optimistic on every part (measured > predicted)
#: with a small per-workload spread, so a fit improves — but cannot zero —
#: the error, exercising every column of the error table.
_FIXTURE_ROWS = (
    ("tpu_v5e", "compute", "starcoder2-3b/train_4k", 10.0, 12.4, "hlo_dryrun"),
    ("tpu_v5e", "compute", "xlstm-350m/train_4k", 1.00, 1.31, "hlo_dryrun"),
    ("tpu_v5e", "compute", "starcoder2-3b/decode_32k", 0.020, 0.024,
     "hlo_dryrun"),
    ("tpu_v5e", "bandwidth", "starcoder2-3b/train_4k", 4.00, 4.52,
     "microbench"),
    ("tpu_v5e", "bandwidth", "xlstm-350m/decode_32k", 0.0005, 0.00059,
     "microbench"),
    ("ku115", "compute", "vgg16@224x224", 0.0069, 0.0074, "microbench"),
    ("ku115", "compute", "vgg16@64x64", 0.00061, 0.00063, "microbench"),
    ("ku115", "bandwidth", "vgg16@32x32", 0.00020, 0.00023, "microbench"),
    ("a100-80g", "compute", "mlperf/train_large", 1.00, 1.92, "published"),
    ("a100-80g", "compute", "mlperf/train_small", 1.00, 1.79, "published"),
    ("a100-80g", "bandwidth", "stream/triad", 1.00, 1.18, "published"),
    ("h100", "compute", "mlperf/train_large", 1.00, 2.21, "published"),
    ("h100", "compute", "mlperf/train_small", 1.00, 2.02, "published"),
    ("h100", "bandwidth", "stream/triad", 1.00, 1.25, "published"),
)


def fixture_measurements() -> list[Measurement]:
    """The deterministic synthetic measurement set (known per-part skews,
    fixed provenance dates) behind tests, the CI smoke, and the committed
    ``docs/reports/example_calibration.md``."""
    return [Measurement(part=p, axis=a, workload=w, predicted_s=pred,
                        measured_s=meas,
                        provenance=Provenance(source=f"fixture:{w}",
                                              date=FIXTURE_DATE, kind=kind))
            for p, a, w, pred, meas, kind in _FIXTURE_ROWS]


def collect_measurements(*, dryrun_dir: str | None = None,
                         bench_json: str | None = None,
                         published: bool = False,
                         fixture: bool = False) -> list[Measurement]:
    """Gather measurements from every requested source (the CLI's input
    stage). Sources that yield nothing contribute nothing."""
    out: list[Measurement] = []
    if fixture:
        out += fixture_measurements()
    if dryrun_dir:
        out += hlo_dryrun_measurements(dryrun_dir)
    if bench_json:
        with open(bench_json) as f:
            out += bench_measurements(json.load(f))
    if published:
        from .published import published_measurements
        out += published_measurements()
    return out


def by_part_axis(measurements: Iterable[Measurement]
                 ) -> dict[tuple[str, str], list[Measurement]]:
    out: dict[tuple[str, str], list[Measurement]] = {}
    for m in measurements:
        out.setdefault((m.part, m.axis), []).append(m)
    return out
