"""Fit per-part corrections from measurements; compute error tables.

The fit is deliberately simple and provably safe: for each (part, axis)
the scale is the geometric mean of ``predicted_s / measured_s`` over
that group's measurements. Scaling the axis's delivered rate by that
factor divides every predicted time in the group by it, which minimizes
the RMS *log* error — so per part, the calibrated geometric-RMS error
can never exceed the raw error on the fitted set. That inequality is the
error table's contract (and a test).

Errors are reported as geometric-RMS relative error in percent:
``(exp(rms(ln(pred/meas))) - 1) * 100`` — symmetric in over/under
prediction, and 0% iff the model matches every measurement exactly.
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence

from .calibration import Calibration, Correction, Provenance
from .measure import Measurement, by_part_axis


def _geomean_ratio(ms: Sequence[Measurement]) -> float:
    """exp(mean ln(predicted/measured)) — the RMS-log-optimal scale."""
    return math.exp(sum(math.log(m.predicted_s / m.measured_s) for m in ms)
                    / len(ms))


def _rms_log_err_pct(ms: Sequence[Measurement], compute_scale: float,
                     bw_scale: float) -> float:
    """Geometric-RMS relative error (%) of the model over ``ms`` after
    scaling each axis's rate — i.e. dividing each predicted time by its
    axis's scale."""
    if not ms:
        return 0.0
    logs = []
    for m in ms:
        scale = compute_scale if m.axis == "compute" else bw_scale
        logs.append(math.log(m.predicted_s / scale / m.measured_s))
    rms = math.sqrt(sum(v * v for v in logs) / len(logs))
    return (math.exp(rms) - 1.0) * 100.0


def _merge_provenance(ms: Sequence[Measurement]) -> Provenance:
    """One provenance for a part's fit: sources joined (deduplicated,
    first-seen order), the latest date, kinds joined with ``+``."""
    sources, kinds, dates = [], [], []
    for m in ms:
        if m.provenance.source not in sources:
            sources.append(m.provenance.source)
        if m.provenance.kind not in kinds:
            kinds.append(m.provenance.kind)
        if m.provenance.date:
            dates.append(m.provenance.date)
    return Provenance(source="; ".join(sources),
                      date=max(dates) if dates else "",
                      kind="+".join(sorted(kinds)))


def fit_corrections(measurements: Iterable[Measurement]) -> Calibration:
    """Fit one :class:`Correction` per part appearing in ``measurements``.

    Per (part, axis): scale = geomean(predicted/measured). An axis with
    no measurements keeps scale 1.0 (and its count records 0, so the
    error table shows which axis the evidence actually covered)."""
    groups = by_part_axis(measurements)
    corrections: dict[str, Correction] = {}
    for part in sorted({p for p, _ in groups}):
        comp = groups.get((part, "compute"), [])
        bw = groups.get((part, "bandwidth"), [])
        compute_scale = _geomean_ratio(comp) if comp else 1.0
        bw_scale = _geomean_ratio(bw) if bw else 1.0
        part_ms = comp + bw
        corrections[part] = Correction(
            compute_scale=compute_scale, bw_scale=bw_scale,
            provenance=_merge_provenance(part_ms),
            n_compute=len(comp), n_bandwidth=len(bw),
            raw_err_pct=_rms_log_err_pct(part_ms, 1.0, 1.0),
            cal_err_pct=_rms_log_err_pct(part_ms, compute_scale, bw_scale))
    return Calibration(corrections)


def error_rows(calibration: Calibration) -> list[dict]:
    """The predicted-vs-measured error table, one dict per corrected
    part — rendered by ``repro.dse.report`` and the CLI. Self-contained:
    every column comes from the fit statistics the corrections carry, so
    a saved calibration file is enough to render the table."""
    rows = []
    for part in calibration.parts():
        c = calibration.correction(part)
        prov = c.provenance or Provenance("", "", "")
        rows.append({
            "part": part,
            "compute_scale": c.compute_scale, "bw_scale": c.bw_scale,
            "n": c.n_compute + c.n_bandwidth,
            "raw_err_pct": c.raw_err_pct, "cal_err_pct": c.cal_err_pct,
            "kind": prov.kind, "source": prov.source, "date": prov.date,
        })
    return rows


def validate_calibration(calibration: Calibration,
                         measurements: Iterable[Measurement] | None = None
                         ) -> list[str]:
    """Sanity-check a calibration; returns a list of problem strings
    (empty = valid). Checks the error-table contract (calibrated error
    <= raw error per part), provenance presence, scale sanity, and — when
    ``measurements`` are supplied — that recomputing the errors against
    them reproduces the stored fit statistics."""
    problems = []
    for part in calibration.parts():
        c = calibration.correction(part)
        if c.compute_scale <= 0 or c.bw_scale <= 0:
            problems.append(f"{part}: non-positive scale "
                            f"({c.compute_scale}, {c.bw_scale})")
        if not (0.05 <= c.compute_scale <= 20 and 0.05 <= c.bw_scale <= 20):
            problems.append(f"{part}: scale outside plausible 20x band "
                            f"({c.compute_scale:.4g}, {c.bw_scale:.4g})")
        if c.cal_err_pct > c.raw_err_pct + 1e-9:
            problems.append(f"{part}: calibrated error {c.cal_err_pct:.3f}% "
                            f"exceeds raw error {c.raw_err_pct:.3f}%")
        if c.provenance is None or not c.provenance.source:
            problems.append(f"{part}: correction has no provenance")
    if measurements is not None:
        groups = by_part_axis(measurements)
        for part in calibration.parts():
            c = calibration.correction(part)
            part_ms = groups.get((part, "compute"), []) + \
                groups.get((part, "bandwidth"), [])
            if not part_ms:
                problems.append(f"{part}: no measurements supplied for "
                                f"stored correction")
                continue
            raw = _rms_log_err_pct(part_ms, 1.0, 1.0)
            cal = _rms_log_err_pct(part_ms, c.compute_scale, c.bw_scale)
            if abs(raw - c.raw_err_pct) > 1e-6 or \
                    abs(cal - c.cal_err_pct) > 1e-6:
                problems.append(
                    f"{part}: stored errors (raw {c.raw_err_pct:.4f}%, cal "
                    f"{c.cal_err_pct:.4f}%) do not match the supplied "
                    f"measurements (raw {raw:.4f}%, cal {cal:.4f}%)")
    return problems
