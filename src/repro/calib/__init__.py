"""Measurement-grounded calibration: close the predicted-vs-measured loop.

The analytical models driving every campaign (the FPGA pipeline model,
``core/tpu_planner``, the GPU roofline) are napkin math until they are
held against measurements — DNNExplorer's own credibility rests on its
Table 3 board results, and HybridDNN validates its latency model before
trusting its DSE. This package gives the repo the same discipline:

* :mod:`repro.calib.calibration` — ``Provenance`` / ``Correction`` /
  ``Calibration``: per-part compute-rate and bandwidth multipliers with
  provenance (source, date, measurement kind), applied to ``hw_specs``
  specs via :func:`repro.core.hw_specs.scaled_spec`. The default is
  identity — uncalibrated runs stay byte-identical.
* :mod:`repro.calib.measure` — the three measurement sources feeding one
  fit: exact-HLO dryrun costs (``launch/hlo_cost.py`` artifacts), the
  repo's own microbench rows (``benchmarks/run.py --json``), and the
  committed published table (:mod:`repro.calib.published`).
* :mod:`repro.calib.fit` — geometric-mean fitting (minimizes RMS log
  error, so the calibrated error can never exceed the raw error on the
  fitted set) and the predicted-vs-measured error table.

CLI: ``python -m repro.calib fit|show|validate|example``.
"""
from .calibration import (Calibration, Correction, IDENTITY,  # noqa: F401
                          Provenance)
from .fit import error_rows, fit_corrections, validate_calibration
from .measure import (Measurement, bench_measurements, fixture_measurements,
                      hlo_dryrun_measurements)
from .published import published_measurements

__all__ = [
    "Calibration", "Correction", "IDENTITY", "Measurement", "Provenance",
    "bench_measurements", "error_rows", "fit_corrections",
    "fixture_measurements", "hlo_dryrun_measurements",
    "published_measurements", "validate_calibration",
]
