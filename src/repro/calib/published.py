"""Committed table of published delivered-performance numbers.

The GPU parts in ``hw_specs`` carry datasheet peaks; published MLPerf
training results and STREAM-style bandwidth studies consistently show
large transformer workloads delivering roughly half of bf16 dense peak
and 80-90% of HBM peak. Each row below is the *delivered fraction* a
published result implies for one (part, axis); it becomes a
:class:`~repro.calib.measure.Measurement` with ``predicted_s = 1.0`` and
``measured_s = 1/fraction`` — the model (at datasheet peak) predicts
unit time, the published hardware needs ``1/fraction`` of it.

Numbers are round, conservative digests of public results — calibration
anchors, not leaderboard entries. Refitting against fresher rounds means
editing this table; provenance keeps each correction traceable to it.
"""
from __future__ import annotations

from .calibration import Provenance
from .measure import Measurement

#: (part, axis, workload, delivered_fraction, source, date).
PUBLISHED_TABLE = (
    ("a100-40g", "compute", "mlperf-train/bert", 0.50,
     "MLPerf Training v2.1 closed division digest", "2022-11-09"),
    ("a100-40g", "bandwidth", "stream/hbm2", 0.85,
     "STREAM-triad HBM2 measurements digest", "2021-06-01"),
    ("a100-80g", "compute", "mlperf-train/gpt3-175b", 0.52,
     "MLPerf Training v3.0 closed division digest", "2023-06-27"),
    ("a100-80g", "bandwidth", "stream/hbm2e", 0.85,
     "STREAM-triad HBM2e measurements digest", "2021-11-01"),
    ("h100", "compute", "mlperf-train/gpt3-175b", 0.46,
     "MLPerf Training v3.1 closed division digest", "2023-11-08"),
    ("h100", "bandwidth", "stream/hbm3", 0.80,
     "STREAM-triad HBM3 measurements digest", "2023-03-01"),
)


def published_measurements() -> list[Measurement]:
    """The committed table as measurements (``kind="published"``)."""
    return [Measurement(part=part, axis=axis, workload=workload,
                        predicted_s=1.0, measured_s=1.0 / frac,
                        provenance=Provenance(source=source, date=date,
                                              kind="published"))
            for part, axis, workload, frac, source, date in PUBLISHED_TABLE]
