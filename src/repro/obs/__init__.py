"""repro.obs — lightweight structured telemetry for the DSE stack.

Spans, counters, and gauges emitted as plain JSONL; near-zero overhead
when disabled (:data:`~repro.obs.trace.NULL`); process-safe via
per-worker sidecar files merged deterministically by the campaign
parent; exportable to Chrome trace-event format. See
:mod:`repro.obs.trace` for the full design and
``docs/observability.md`` for the user-facing walkthrough.
"""
from .trace import (EVENT_KINDS, EVENTS_SCHEMA_VERSION, NULL, NullTracer,
                    SpanStats, Tracer, campaign_wall, chrome_path_for,
                    chrome_trace, counter_totals, events_dir_for,
                    events_path_for, load_events, merge_events,
                    slowest_spans, span_totals, spans, validate_events,
                    worker_tracer, worker_utilization)

__all__ = [
    "EVENT_KINDS", "EVENTS_SCHEMA_VERSION", "NULL", "NullTracer",
    "SpanStats", "Tracer", "campaign_wall", "chrome_path_for",
    "chrome_trace", "counter_totals", "events_dir_for", "events_path_for",
    "load_events", "merge_events", "slowest_spans", "span_totals", "spans",
    "validate_events", "worker_tracer", "worker_utilization",
]
