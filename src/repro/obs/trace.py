"""Structured campaign telemetry: spans, counters, and gauges as JSONL.

The DSE engine's scaling claims (ROADMAP: "campaign engine at a million
cells") need to be *measured*, not guessed: where wall-clock goes (search
vs. pool overhead vs. store fsync), whether PSO searches converged or hit
the iteration cap, which workers sat idle. This module is the substrate —
a deliberately tiny tracer that costs nothing when disabled and writes
plain JSONL when enabled, so events diff, grep, and feed ``jq``/pandas
exactly like the result store does.

Design:

* :class:`Tracer` emits three event kinds — context-manager **spans**
  (``with tracer.span("cell.eval", cell=key): ...``), **counters**
  (monotonic totals, e.g. cache hits), and **gauges** (point-in-time
  values, e.g. pool occupancy) — one JSON object per line, appended and
  line-buffered so a killed run keeps everything emitted so far.
* **Disabled mode is near-zero overhead**: :data:`NULL` is a shared
  no-op tracer whose ``span`` returns one reusable no-op context
  manager; instrumented code never branches on "is tracing on".
* **Process safety via sidecar files**: each process (the campaign
  parent and every pool worker) owns a private
  ``<store>.events/<proc>.jsonl`` sidecar — no locks, no interleaved
  writes. The parent merges the sidecars deterministically
  (:func:`merge_events`: sorted by ``(ts, proc, seq)``, independent of
  directory listing order) into ``<store>.events.jsonl``.
* **Exporters**: the merged events JSONL is the source of truth;
  :func:`chrome_trace` re-expresses it in Chrome trace-event format
  (one lane per process) loadable in Perfetto / ``chrome://tracing``.
* **Schema-versioned**: every event carries ``schema`` =
  :data:`EVENTS_SCHEMA_VERSION`; :func:`validate_events` is the check CI
  runs against a freshly traced campaign.

Timestamps are wall-clock seconds anchored once per tracer
(``time.time()`` at construction + ``perf_counter`` deltas), so events
from different processes on one host line up on a shared axis.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from pathlib import Path
from typing import Iterable, Mapping, Sequence

#: Version stamp on every emitted event (bump on breaking format change).
EVENTS_SCHEMA_VERSION = 1

#: Event kinds :func:`validate_events` accepts.
EVENT_KINDS = ("span", "counter", "gauge")


# ---------------------------------------------------------------------------
# emitting
# ---------------------------------------------------------------------------


class _NullSpan:
    """Reusable no-op context manager (the disabled-mode ``span``)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled-mode tracer: every operation is a no-op, nothing touches
    the filesystem. Instrumented code holds one of these by default and
    never checks an enabled flag."""

    enabled = False
    path = None
    proc = "null"

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def span_at(self, name: str, ts: float, dur: float, **attrs) -> None:
        pass

    def count(self, name: str, n: float = 1, **attrs) -> None:
        pass

    def gauge(self, name: str, value: float, **attrs) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


#: The shared disabled tracer (analogue of ``logging.NullHandler``).
NULL = NullTracer()


class _Span:
    """Context manager for one live span; emits on exit."""

    __slots__ = ("tracer", "name", "attrs", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self.t0 = time.perf_counter()
        self.depth = self.tracer._depth
        self.tracer._depth += 1
        return self

    def __exit__(self, *exc):
        self.tracer._depth -= 1
        dur = time.perf_counter() - self.t0
        self.tracer._emit("span", self.name, self.attrs,
                          ts=self.tracer._wall(self.t0), dur=dur,
                          depth=self.depth)
        return False


class Tracer:
    """Enabled tracer: appends one JSON line per event to ``path``.

    One tracer per process — spans nest via a per-tracer depth counter,
    and the per-tracer ``seq`` makes every event of one process totally
    ordered even when timestamps tie. Construction opens the file in
    append + line-buffered mode, so events survive a kill without an
    explicit flush and two tracers of the SAME process (rare, e.g. a
    resumed campaign) append rather than truncate.
    """

    enabled = True

    def __init__(self, path: str | os.PathLike, proc: str = "main"):
        self.path = Path(path)
        self.proc = proc
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = self.path.open("a", buffering=1)
        self._t0_wall = time.time()
        self._t0_pc = time.perf_counter()
        self._seq = 0
        self._depth = 0
        self.counters: dict[str, float] = {}

    # -- clock ---------------------------------------------------------------

    def _wall(self, pc: float | None = None) -> float:
        """perf_counter reading -> wall-clock seconds on the shared axis."""
        if pc is None:
            pc = time.perf_counter()
        return self._t0_wall + (pc - self._t0_pc)

    # -- event emission ------------------------------------------------------

    def _emit(self, kind: str, name: str, attrs: Mapping, *, ts: float,
              **fields) -> None:
        ev = {"schema": EVENTS_SCHEMA_VERSION, "kind": kind, "name": name,
              "proc": self.proc, "ts": round(ts, 6), "seq": self._seq}
        ev.update(fields)
        if attrs:
            ev["attrs"] = dict(attrs)
        self._seq += 1
        if not self._f.closed:
            self._f.write(json.dumps(ev, sort_keys=True) + "\n")

    def span(self, name: str, **attrs) -> _Span:
        """Time a block: ``with tracer.span("cell.eval", cell=key): ...``.
        The event is emitted at exit with the span's entry depth, so
        nested spans reconstruct as a tree."""
        return _Span(self, name, attrs)

    def span_at(self, name: str, ts: float, dur: float, **attrs) -> None:
        """Emit a span with an explicit start/duration — for intervals
        measured outside this process (e.g. queue wait: the parent's
        submit time to the worker's start)."""
        self._emit("span", name, attrs, ts=ts, dur=max(0.0, dur),
                   depth=self._depth)

    def count(self, name: str, n: float = 1, **attrs) -> None:
        """Add ``n`` to a monotonic counter and emit the increment.
        Totals accumulate on :attr:`counters` and at read time
        (:func:`counter_totals` sums increments across processes)."""
        self.counters[name] = self.counters.get(name, 0) + n
        self._emit("counter", name, attrs, ts=self._wall(), value=n)

    def gauge(self, name: str, value: float, **attrs) -> None:
        """Emit a point-in-time value (pool occupancy, cache-hit rate)."""
        self._emit("gauge", name, attrs, ts=self._wall(), value=value)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------------
# store-adjacent paths + worker construction
# ---------------------------------------------------------------------------


def events_dir_for(store_path: str | os.PathLike) -> Path:
    """Per-process sidecar directory for a store: ``<store>.events/``."""
    return Path(str(store_path) + ".events")


def events_path_for(store_path: str | os.PathLike) -> Path:
    """The merged events JSONL for a store: ``<store>.events.jsonl``."""
    return Path(str(store_path) + ".events.jsonl")


def chrome_path_for(store_path: str | os.PathLike) -> Path:
    """The Chrome trace-event export for a store: ``<store>.trace.json``."""
    return Path(str(store_path) + ".trace.json")


def worker_tracer(events_dir: str | os.PathLike,
                  proc: str | None = None) -> Tracer:
    """A pool worker's tracer: its own ``<events_dir>/<proc>.jsonl``
    sidecar, named by pid by default (each spawn-pool worker is a
    distinct process; re-used workers append to their own file)."""
    proc = proc or f"worker-{os.getpid()}"
    return Tracer(Path(events_dir) / f"{proc}.jsonl", proc=proc)


# ---------------------------------------------------------------------------
# loading, merging, validation
# ---------------------------------------------------------------------------


def load_events(path: str | os.PathLike,
                stats: dict | None = None) -> list[dict]:
    """Events from one JSONL file (blank lines skipped; a torn final
    line — what an append-only writer leaves behind when its process is
    killed mid-write — is dropped, matching the result store's reader).
    Pass a ``stats`` dict to count what was skipped: its
    ``"skipped_lines"`` entry is incremented per undecodable line."""
    out = []
    p = Path(path)
    if not p.exists():
        return out
    with p.open() as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if stats is not None:
                    stats["skipped_lines"] = \
                        stats.get("skipped_lines", 0) + 1
                continue
    return out


def _merge_key(ev: Mapping) -> tuple:
    return (ev.get("ts", 0.0), str(ev.get("proc", "")), ev.get("seq", 0))


def merge_events(events_dir: str | os.PathLike,
                 out_path: str | os.PathLike | None = None) -> list[dict]:
    """Merge every ``*.jsonl`` sidecar under ``events_dir`` into one
    deterministic event list: sorted by ``(ts, proc, seq)`` — a total
    order (seq is unique per proc), so the merge is independent of
    directory listing order and stable across re-merges. Optionally
    writes the merged JSONL to ``out_path``.

    A sidecar truncated mid-write (worker killed, disk full) does not
    poison the merge: undecodable lines are skipped and surfaced as a
    single ``UserWarning`` with the count, so a crashed campaign's
    surviving telemetry still renders."""
    files = sorted(Path(events_dir).glob("*.jsonl"))
    stats: dict = {}
    events = [ev for f in files for ev in load_events(f, stats)]
    skipped = stats.get("skipped_lines", 0)
    if skipped:
        warnings.warn(f"merge_events: skipped {skipped} undecodable "
                      f"line(s) under {events_dir} (truncated sidecar?)",
                      stacklevel=2)
    events.sort(key=_merge_key)
    if out_path is not None:
        out = Path(out_path)
        out.parent.mkdir(parents=True, exist_ok=True)
        with out.open("w") as f:
            for ev in events:
                f.write(json.dumps(ev, sort_keys=True) + "\n")
    return events


def validate_events(events: Iterable[Mapping]) -> list[str]:
    """Schema check for an event stream; returns problem strings
    (empty == valid). CI runs this against a freshly traced campaign."""
    problems = []
    for i, ev in enumerate(events):
        where = f"event {i}"
        if not isinstance(ev, Mapping):
            problems.append(f"{where}: not an object")
            continue
        if ev.get("schema") != EVENTS_SCHEMA_VERSION:
            problems.append(f"{where}: schema {ev.get('schema')!r} != "
                            f"{EVENTS_SCHEMA_VERSION}")
        kind = ev.get("kind")
        if kind not in EVENT_KINDS:
            problems.append(f"{where}: unknown kind {kind!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing/empty name")
        for field in ("ts",) + (("dur",) if kind == "span" else ("value",)):
            if not isinstance(ev.get(field), (int, float)) \
                    or isinstance(ev.get(field), bool):
                problems.append(f"{where}: {field} must be a number "
                                f"(got {ev.get(field)!r})")
        if kind == "span":
            if not isinstance(ev.get("depth"), int) or ev["depth"] < 0:
                problems.append(f"{where}: span depth must be an int >= 0")
            if isinstance(ev.get("dur"), (int, float)) \
                    and not isinstance(ev.get("dur"), bool) \
                    and ev["dur"] < 0:
                problems.append(f"{where}: span dur must be >= 0")
        if "attrs" in ev and not isinstance(ev["attrs"], Mapping):
            problems.append(f"{where}: attrs must be an object")
        if not isinstance(ev.get("proc"), str):
            problems.append(f"{where}: missing proc")
        if not isinstance(ev.get("seq"), int):
            problems.append(f"{where}: missing seq")
    return problems


# ---------------------------------------------------------------------------
# aggregation (shared by report.py's health section and the obs CLI)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpanStats:
    count: int = 0
    total_s: float = 0.0
    max_s: float = 0.0


def spans(events: Iterable[Mapping], name: str | None = None) -> list[dict]:
    """Span events, optionally filtered by name."""
    return [e for e in events if e.get("kind") == "span"
            and (name is None or e.get("name") == name)]


def span_totals(events: Iterable[Mapping]) -> dict[str, SpanStats]:
    """Per-span-name {count, total_s, max_s} — the wall-time breakdown."""
    out: dict[str, SpanStats] = {}
    for e in spans(events):
        st = out.setdefault(e["name"], SpanStats())
        st.count += 1
        st.total_s += e.get("dur", 0.0)
        st.max_s = max(st.max_s, e.get("dur", 0.0))
    return out


def counter_totals(events: Iterable[Mapping]) -> dict[str, float]:
    """Counter increments summed across all processes."""
    out: dict[str, float] = {}
    for e in events:
        if e.get("kind") == "counter":
            out[e["name"]] = out.get(e["name"], 0) + e.get("value", 0)
    return out


def campaign_wall(events: Sequence[Mapping]) -> float:
    """The campaign's wall time: the top-level ``campaign`` span if
    present, else the event-timestamp extent."""
    top = spans(events, "campaign")
    if top:
        return max(e.get("dur", 0.0) for e in top)
    ts = [e.get("ts", 0.0) for e in events]
    return (max(ts) - min(ts)) if len(ts) > 1 else 0.0


def worker_utilization(events: Sequence[Mapping],
                       busy_span: str = "cell.eval") -> dict[str, dict]:
    """Per-process busy accounting: ``{proc: {busy_s, cells, util}}``
    where ``util`` is busy time over the campaign wall time — the
    direct read on which workers sat idle."""
    wall = campaign_wall(events)
    out: dict[str, dict] = {}
    for e in spans(events, busy_span):
        row = out.setdefault(e.get("proc", "?"),
                             {"busy_s": 0.0, "cells": 0, "util": 0.0})
        row["busy_s"] += e.get("dur", 0.0)
        row["cells"] += 1
    for row in out.values():
        row["util"] = (row["busy_s"] / wall) if wall > 0 else 0.0
    return out


def slowest_spans(events: Iterable[Mapping], name: str = "cell.eval",
                  k: int = 10) -> list[dict]:
    """The ``k`` slowest spans of one name (slowest-cell table)."""
    return sorted(spans(events, name), key=lambda e: -e.get("dur", 0.0))[:k]


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------


def chrome_trace(events: Sequence[Mapping]) -> dict:
    """Events -> a Chrome trace-event JSON object (the ``traceEvents``
    array format), loadable in Perfetto / ``chrome://tracing``: one lane
    (tid) per process, spans as complete ``X`` events, counters and
    gauges as ``C`` counter samples. Timestamps are microseconds
    relative to the earliest event."""
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = min(e.get("ts", 0.0) for e in events)
    procs = sorted({str(e.get("proc", "?")) for e in events})
    tid = {p: i for i, p in enumerate(procs)}
    out = [{"ph": "M", "name": "thread_name", "pid": 0, "tid": tid[p],
            "args": {"name": p}} for p in procs]
    counters: dict[str, float] = {}
    for e in events:
        lane = tid[str(e.get("proc", "?"))]
        us = (e.get("ts", 0.0) - t0) * 1e6
        if e.get("kind") == "span":
            out.append({"ph": "X", "name": e["name"], "pid": 0, "tid": lane,
                        "ts": round(us, 1),
                        "dur": round(e.get("dur", 0.0) * 1e6, 1),
                        "args": dict(e.get("attrs") or {})})
        elif e.get("kind") in ("counter", "gauge"):
            # counters plot running totals; gauges plot the sampled value
            v = e.get("value", 0)
            if e["kind"] == "counter":
                v = counters[e["name"]] = counters.get(e["name"], 0) + v
            out.append({"ph": "C", "name": e["name"], "pid": 0, "tid": lane,
                        "ts": round(us, 1), "args": {e["name"]: v}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}
