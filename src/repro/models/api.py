"""Single entry point over the model zoo: init / loss / prefill / decode
dispatched on ``ArchConfig.family``. Everything the launcher, trainer, and
dry-run touch goes through these five functions.
"""
from __future__ import annotations


import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import encdec, moe, recurrent, transformer


def init_params(rng, cfg: ArchConfig, dtype=jnp.float32):
    if cfg.family == "moe":
        return moe.init_lm(rng, cfg, dtype)
    if cfg.family == "ssm":
        return recurrent.init_xlstm(rng, cfg, dtype)
    if cfg.family == "hybrid":
        return recurrent.init_zamba(rng, cfg, dtype)
    if cfg.family == "audio":
        return encdec.init_encdec(rng, cfg, dtype)
    return transformer.init_lm(rng, cfg, dtype)  # dense | vlm


def loss_fn(params, cfg: ArchConfig, batch: dict, **kw):
    """batch: tokens/labels (+ patch_embeds for vlm, frames for audio)."""
    if cfg.family == "moe":
        return moe.loss_fn(params, cfg, batch["tokens"], batch["labels"], **kw)
    if cfg.family == "ssm":
        logits = recurrent.xlstm_forward(params, cfg, batch["tokens"], **kw)
    elif cfg.family == "hybrid":
        logits = recurrent.zamba_forward(params, cfg, batch["tokens"], **kw)
    elif cfg.family == "audio":
        return encdec.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                              batch["frames"], **kw)
    elif cfg.family == "vlm":
        return transformer.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                                   batch["patch_embeds"], **kw)
    else:
        return transformer.loss_fn(params, cfg, batch["tokens"], batch["labels"], **kw)
    return transformer.softmax_xent(logits, batch["labels"])


def prefill_logits(params, cfg: ArchConfig, batch: dict, **kw):
    """Forward pass producing logits (the inference-prefill workload)."""
    if cfg.family == "moe":
        logits, _ = moe.forward(params, cfg, batch["tokens"], **kw)
        return logits
    if cfg.family == "ssm":
        return recurrent.xlstm_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "hybrid":
        return recurrent.zamba_forward(params, cfg, batch["tokens"], **kw)
    if cfg.family == "audio":
        return encdec.forward(params, cfg, batch["tokens"], batch["frames"], **kw)
    if cfg.family == "vlm":
        return transformer.forward(params, cfg, batch["tokens"],
                                   batch["patch_embeds"], **kw)
    return transformer.forward(params, cfg, batch["tokens"], **kw)


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    if cfg.family == "moe":
        return moe.init_cache(cfg, batch, s_max, dtype)
    if cfg.family == "ssm":
        return recurrent.xlstm_init_cache(cfg, batch, s_max, dtype)
    if cfg.family == "hybrid":
        return recurrent.zamba_init_cache(cfg, batch, s_max, dtype)
    if cfg.family == "audio":
        return encdec.init_cache(cfg, batch, s_max, cfg.n_audio_frames, dtype)
    return transformer.init_cache(cfg, batch, s_max, dtype)


def decode_step(params, cfg: ArchConfig, cache, tokens, pos, **kw):
    """(logits (B, vocab), new_cache) — one new token per sequence."""
    if cfg.family == "moe":
        return moe.decode_step(params, cfg, cache, tokens, pos, **kw)
    if cfg.family == "ssm":
        return recurrent.xlstm_decode_step(params, cfg, cache, tokens, pos, **kw)
    if cfg.family == "hybrid":
        return recurrent.zamba_decode_step(params, cfg, cache, tokens, pos, **kw)
    if cfg.family == "audio":
        return encdec.decode_step(params, cfg, cache, tokens, pos, **kw)
    return transformer.decode_step(params, cfg, cache, tokens, pos, **kw)
