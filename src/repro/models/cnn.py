"""The paper's own workload in JAX: VGG-like CNNs, executable either as a
plain jnp forward or through the DNNExplorer *hybrid* execution plan —
the first SP layers as dedicated pipeline stages (shard_map microbatch
pipeline = the paper's pipeline structure) and the rest through a single
reusable apply function (= the generic structure).

The conv compute can route through the Pallas direct-conv kernel
(``repro.kernels.conv2d``), which is the pipeline CE of the paper.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.netinfo import NetInfo


def init_vgg(key, net: NetInfo, dtype=jnp.float32):
    """Conv weights for every major layer of a netinfo VGG description."""
    params = []
    keys = jax.random.split(key, len(net.layers))
    for k, l in zip(keys, net.layers):
        if l.kind == "pool":
            params.append(None)
            continue
        w = jax.random.normal(k, (l.k, l.c, l.r, l.s), jnp.float32)
        w *= (2.0 / (l.c * l.r * l.s)) ** 0.5  # He init
        params.append(w.astype(dtype))
    return params


def _conv(x, w, use_pallas: bool):
    if use_pallas:
        from repro.kernels.conv2d.ops import conv2d
        return conv2d(x, w)
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW"))


def layer_apply(x, w, layer, use_pallas: bool = False):
    """One major layer (+ fused ReLU) or pool."""
    if layer.kind == "pool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            (1, 1, layer.r, layer.s), (1, 1, layer.stride, layer.stride),
            "VALID")
    return jax.nn.relu(_conv(x, w, use_pallas))


def forward(params, net: NetInfo, x, *, use_pallas: bool = False):
    """Plain sequential forward: x (N, 3, H, W) -> feature map."""
    for w, l in zip(params, net.layers):
        x = layer_apply(x, w, l, use_pallas)
    return x


# ---------------------------------------------------------------------------
# Hybrid execution: the paper's paradigm as a JAX execution plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HybridPlan:
    """Execution plan from an RAV: layers [0, sp) run as dedicated pipeline
    stages over a `stage` mesh axis; layers [sp, N) run recurrently through
    one generic apply (shared code path = the reusable MAC array)."""
    sp: int
    n_micro: int


def hybrid_forward(params, net: NetInfo, x, plan: HybridPlan, mesh=None):
    """Run the net under a hybrid plan. With a mesh (a ("stage",) axis),
    the head really pipelines via shard_map+ppermute; without one it
    falls back to the same math sequentially (CPU tests)."""
    layers = list(net.layers)
    sp = plan.sp

    if mesh is not None and sp > 1:
        from repro.parallel.pipeline import pipeline_apply, split_microbatches
        n_stages = mesh.shape["stage"]
        assert sp == n_stages, "one pipeline stage per head layer"
        # pipeline_apply stacks stage params -> stages must be homogeneous
        # (true for the paper's deepened VGG groups); fall back to a
        # sequential stage-split otherwise.
        shapes = {tuple(w.shape) for w in params[:sp] if w is not None}
        if len(shapes) == 1:
            stacked = jnp.stack([w for w in params[:sp]])

            def stage(w, h):
                return layer_apply(h, w, layers[0])

            mbs = split_microbatches(x, plan.n_micro)
            x = pipeline_apply(stage, stacked, mbs, mesh, axis="stage")
            x = x.reshape((-1,) + x.shape[2:])
        else:  # heterogeneous head: sequential per-stage (still stage-split)
            for w, l in zip(params[:sp], layers[:sp]):
                x = layer_apply(x, w, l)
    else:
        for w, l in zip(params[:sp], layers[:sp]):
            x = layer_apply(x, w, l)

    # generic structure: one reusable apply, recurrent over the tail
    for w, l in zip(params[sp:], layers[sp:]):
        x = layer_apply(x, w, l)
    return x
