"""Dense decoder-only LM (nemotron-4, starcoder2, h2o-danube, llava backbone).

Layers are stacked along a leading axis and executed with ``jax.lax.scan``
so the HLO stays compact for the 512-device dry-run compiles, with a
configurable remat (activation-checkpoint) policy per block.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.act import constrain
from .layers import (dense_init, embed_init, gqa_attention,
                     gqa_decode_attention, init_attention, init_mlp,
                     init_rmsnorm, mlp, rms_norm)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_block(key, cfg: ArchConfig, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                               cfg.head_dim, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def init_lm(key, cfg: ArchConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_layers + 3)
    params = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "blocks": _stack([init_block(keys[2 + i], cfg, dtype)
                          for i in range(cfg.n_layers)]),
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    if cfg.n_patches:
        params["projector"] = dense_init(keys[-1], cfg.vision_embed_dim,
                                         cfg.d_model, dtype)
    return params


def block_apply(x, bp, cfg: ArchConfig, attn_fn=None):
    x = x + gqa_attention(rms_norm(x, bp["ln1"]), bp["attn"], cfg.n_heads,
                          cfg.n_kv, rope=cfg.rope, rope_theta=cfg.rope_theta,
                          window=cfg.window, attn_fn=attn_fn)
    x = x + mlp(rms_norm(x, bp["ln2"]), bp["mlp"], cfg.activation)
    return x


def forward(params, cfg: ArchConfig, tokens, patch_embeds=None, *,
            compute_dtype=jnp.bfloat16, remat: str = "full", attn_fn=None,
            unroll: bool = False):
    """tokens (B, S_text) int32 -> logits (B, S, vocab) in fp32.

    VLM: ``patch_embeds`` (B, P, vision_embed_dim) are projected and
    prepended to the token embeddings (anyres frontend is a stub per spec).
    """
    x = constrain(params["embed"].astype(compute_dtype)[tokens], "act")
    if patch_embeds is not None:
        proj = patch_embeds.astype(compute_dtype) @ params["projector"].astype(compute_dtype)
        x = jnp.concatenate([proj, x], axis=1)

    body = partial(block_apply, cfg=cfg, attn_fn=attn_fn)
    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def step(x, bp):
        return constrain(body(x, bp), "act"), None

    x, _ = jax.lax.scan(step, x, params["blocks"],
                        unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["ln_f"])
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    return constrain((x @ head.astype(compute_dtype)).astype(jnp.float32),
                     "logits")


def softmax_xent(logits, labels):
    """Sharding-friendly cross entropy: contracts the (possibly
    model-sharded) vocab axis with a one-hot einsum instead of
    take_along_axis — a vocab-axis gather forces GSPMD to replicate the
    full (B, S, V) logits per device (hundreds of GiB at scale)."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    target = jnp.einsum("bsv,bsv->bs", logits, onehot).astype(jnp.float32)
    return (lse - target).mean()


def loss_fn(params, cfg: ArchConfig, tokens, labels, patch_embeds=None, **kw):
    logits = forward(params, cfg, tokens, patch_embeds, **kw)
    if patch_embeds is not None:
        logits = logits[:, patch_embeds.shape[1]:]  # only text positions scored
    return softmax_xent(logits, labels)


# ---------------------------------------------------------------------------
# Decode (one new token against a KV cache)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    """KV cache (L, B, S_max, n_kv, hd). Sliding-window archs only need the
    window slots (ring buffer) — this is what makes long_500k feasible for
    SWA models."""
    slots = min(s_max, cfg.window) if cfg.window else s_max
    shape = (cfg.n_layers, batch, slots, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cfg: ArchConfig, cache, tokens, pos, *,
                compute_dtype=jnp.bfloat16, unroll: bool = False):
    """tokens (B, 1) int32; pos (B,) int32 -> (logits (B, vocab), new cache).

    For windowed attention the cache slot is pos % window (ring buffer) and
    RoPE still uses the absolute position.
    """
    x = constrain(params["embed"].astype(compute_dtype)[tokens], "dec")
    slots = cache["k"].shape[2]
    if cfg.window:
        write_pos = pos % slots               # ring buffer
        valid = jnp.minimum(pos, slots - 1)   # full ring => all slots live
    else:
        write_pos, valid = pos, pos

    def step(x, layer):
        bp, k_c, v_c = layer
        h = rms_norm(x, bp["ln1"])
        out, k_c, v_c = gqa_decode_attention(
            h, bp["attn"], cfg.n_heads, cfg.n_kv, k_c, v_c, write_pos,
            rope_pos=pos, valid_upto=valid, rope=cfg.rope,
            rope_theta=cfg.rope_theta)
        x = x + out
        x = x + mlp(rms_norm(x, bp["ln2"]), bp["mlp"], cfg.activation)
        return constrain(x, "dec"), (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(step, x, (params["blocks"], cache["k"], cache["v"]),
                                     unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["ln_f"])
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = (x[:, 0] @ head.astype(compute_dtype)).astype(jnp.float32)
    return logits, {"k": k_new, "v": v_new}
