"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

The Mamba2 forward uses the chunked SSD algorithm (quadratic within a
chunk, linear across chunks) — the same tiling the Pallas kernel in
``repro.kernels.ssd`` implements; this module is its jnp reference user.
Decode is O(1) per token via the recurrent state — this is why the
``long_500k`` shape is runnable for SSM/hybrid archs but skipped for pure
full-attention ones.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.parallel.act import constrain
from .layers import dense_init, init_rmsnorm, rms_norm


# ---------------------------------------------------------------------------
# Chunked SSD (Mamba2 core): y = SSM(A, B, C)(x)
# ---------------------------------------------------------------------------


def _segsum(a):
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums:
    out[i, j] = sum(a[j+1..i]) for j < i."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """Chunked selective-state-space scan (Mamba2 Listing 1, jnp).

    x  (B, S, H, P)   input heads
    dt (B, S, H)      softplus'd timestep
    a_log (H,)        log of -A (per head)
    b,c (B, S, N)     input/output projections (single group)
    Returns y (B, S, H, P).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    nc = s // q
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"

    a = -jnp.exp(a_log.astype(jnp.float32))            # (H,) negative
    da = dt.astype(jnp.float32) * a[None, None, :]      # (B, S, H)

    # reshape into chunks
    xc = (x * dt[..., None]).reshape(bsz, nc, q, h, p)
    dac = da.reshape(bsz, nc, q, h)
    bc = b.reshape(bsz, nc, q, n)
    cc = c.reshape(bsz, nc, q, n)

    # -- intra-chunk (quadratic within chunk) --
    l = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))     # (B, NC, H, Q, Q)
    cb = jnp.einsum("bzqn,bzkn->bzqk", cc, bc)          # (B, NC, Q, Q)
    y_intra = jnp.einsum("bzqk,bzhqk,bzkhp->bzqhp", cb.astype(jnp.float32),
                         l, xc.astype(jnp.float32))

    # -- chunk states --
    da_cum = jnp.cumsum(dac, axis=2)                    # (B, NC, Q, H)
    da_total = da_cum[:, :, -1]                         # (B, NC, H)
    decay_out = jnp.exp(da_total[:, :, None] - da_cum)  # (B, NC, Q, H)
    states = jnp.einsum("bzqn,bzqh,bzqhp->bzhpn", bc.astype(jnp.float32),
                        decay_out, xc.astype(jnp.float32))  # (B, NC, H, P, N)

    # -- inter-chunk recurrence (linear scan over chunks) --
    def scan_fn(prev, inp):
        st, dtot = inp
        new = st + jnp.exp(dtot)[..., None, None] * prev
        return new, prev

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), da_total.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, NC, H, P, N)

    decay_in = jnp.exp(da_cum)                          # (B, NC, Q, H)
    y_inter = jnp.einsum("bzqn,bzqh,bzhpn->bzqhp", cc.astype(jnp.float32),
                         decay_in, prev_states)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype)


def ssd_decode(state, x, dt, a_log, b, c):
    """One-step recurrent update. state (B,H,P,N); x (B,H,P); dt (B,H);
    b,c (B,N). Returns (y (B,H,P), new state)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = dt.astype(jnp.float32) * a[None]                       # (B,H)
    state = (jnp.exp(da)[..., None, None] * state
             + jnp.einsum("bhp,bn,bh->bhpn", x.astype(jnp.float32),
                          b.astype(jnp.float32), dt.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32))
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def init_mamba2(key, d_model: int, s: SSMCfg, dtype=jnp.float32):
    d_in = s.expansion * d_model
    n_h = d_in // s.head_dim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_in, dtype),     # z, x
        "bc_proj": dense_init(ks[1], d_model, 2 * s.state_dim, dtype),
        "dt_proj": dense_init(ks[2], d_model, n_h, dtype),
        "dt_bias": jnp.zeros((n_h,), dtype),
        "a_log": jnp.zeros((n_h,), dtype),                          # A = -1
        "d_skip": jnp.ones((n_h,), dtype),
        "conv_w": (jax.random.normal(ks[3], (s.conv_width, d_in), jnp.float32)
                   * 0.1).astype(dtype),
        "out_proj": dense_init(ks[4], d_in, d_model, dtype),
        "norm": init_rmsnorm(d_in, dtype),
    }


def _causal_conv(x, w):
    """x (B, S, D), w (W, D) depthwise causal conv."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i][None, None] for i in range(width))
    return out


def mamba2_apply(x, p, s: SSMCfg):
    bsz, sl, d = x.shape
    cd = x.dtype
    d_in = p["conv_w"].shape[1]
    n_h = p["a_log"].shape[0]

    zx = constrain(x @ p["in_proj"].astype(cd), "ffn2")
    z, xin = jnp.split(zx, 2, axis=-1)
    xin = jax.nn.silu(_causal_conv(xin, p["conv_w"].astype(cd)))
    bc = x @ p["bc_proj"].astype(cd)
    b, c = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((x @ p["dt_proj"].astype(cd)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    xh = xin.reshape(bsz, sl, n_h, s.head_dim)
    y = ssd_chunked(xh, dt, p["a_log"], b, c, s.chunk)
    y = y + xh * p["d_skip"].astype(cd)[None, None, :, None]
    y = y.reshape(bsz, sl, d_in)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(cd)


def mamba2_decode(x, p, s: SSMCfg, conv_state, ssm_state):
    """x (B, 1, D). conv_state (B, W-1, d_in); ssm_state (B, H, P, N)."""
    bsz, _, d = x.shape
    cd = x.dtype
    n_h = p["a_log"].shape[0]

    zx = x @ p["in_proj"].astype(cd)
    z, xin = jnp.split(zx, 2, axis=-1)          # (B, 1, d_in)
    # causal conv with rolling state
    w = p["conv_w"].astype(cd)
    seq = jnp.concatenate([conv_state, xin], axis=1)     # (B, W, d_in)
    conv_out = jnp.einsum("bwd,wd->bd", seq, w)[:, None]
    new_conv = seq[:, 1:]
    xin = jax.nn.silu(conv_out)

    bc = x @ p["bc_proj"].astype(cd)
    b, c = jnp.split(bc[:, 0], 2, axis=-1)               # (B, N)
    dt = jax.nn.softplus((x @ p["dt_proj"].astype(cd))[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B, H)

    xh = xin[:, 0].reshape(bsz, n_h, s.head_dim)
    y, new_ssm = ssd_decode(ssm_state, xh, dt, p["a_log"], b, c)
    y = y + xh * p["d_skip"].astype(cd)[None, :, None]
    y = y.reshape(bsz, 1, -1)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(cd), new_conv, new_ssm


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) + sLSTM (scalar memory)
# ---------------------------------------------------------------------------


def init_mlstm(key, d_model: int, n_heads: int, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    hd = d_model // n_heads
    return {
        "wq": dense_init(ks[0], d_model, d_model, dtype),
        "wk": dense_init(ks[1], d_model, d_model, dtype),
        "wv": dense_init(ks[2], d_model, d_model, dtype),
        "wi": dense_init(ks[3], d_model, n_heads, dtype),
        "wf": dense_init(ks[4], d_model, n_heads, dtype),
        "wo": dense_init(ks[5], d_model, d_model, dtype),
        "norm": init_rmsnorm(d_model, dtype),
    }


def mlstm_apply(x, p, n_heads: int, chunk: int = 256):
    """Chunkwise-parallel mLSTM (linear attention with stabilized
    exponential gating): quadratic within a chunk, O(1) state across
    chunks — the same chunking xLSTM's TFLA kernels use. O(S) memory in
    sequence length, so prefill_32k is feasible."""
    bsz, s, d = x.shape
    hd = d // n_heads
    cd = x.dtype
    q_len = min(chunk, s)
    nc = s // q_len
    assert s % q_len == 0, f"seq {s} not divisible by chunk {q_len}"

    q = (x @ p["wq"].astype(cd)).reshape(bsz, s, n_heads, hd)
    k = (x @ p["wk"].astype(cd)).reshape(bsz, s, n_heads, hd) / math.sqrt(hd)
    v = (x @ p["wv"].astype(cd)).reshape(bsz, s, n_heads, hd)
    i_g = (x @ p["wi"].astype(cd)).astype(jnp.float32)      # (B,S,H)
    f_g = (x @ p["wf"].astype(cd)).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_g)

    # chunked views: (B, NC, Q, ...)
    qc = q.reshape(bsz, nc, q_len, n_heads, hd).astype(jnp.float32)
    kc = k.reshape(bsz, nc, q_len, n_heads, hd).astype(jnp.float32)
    vc = v.reshape(bsz, nc, q_len, n_heads, hd).astype(jnp.float32)
    ic = i_g.reshape(bsz, nc, q_len, n_heads)
    fc = logf.reshape(bsz, nc, q_len, n_heads)
    cumf = jnp.cumsum(fc, axis=2)                           # (B,NC,Q,H)
    g_total = cumf[:, :, -1]                                # (B,NC,H)

    # intra-chunk decay matrix D[t,j] = cumf_t - cumf_j + i_j  (j <= t)
    dmat = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + ic[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((q_len, q_len), bool))
    dmat = jnp.where(mask[None, None, :, :, None], dmat, -jnp.inf)
    m_local = jnp.max(dmat, axis=3)                         # (B,NC,Q,H)

    # per-chunk state contribution (to be carried): sum_j exp(G - F_j + i_j) k v
    s_decay = g_total[:, :, None, :] - cumf + ic            # (B,NC,Q,H)
    m_state_local = jnp.max(s_decay, axis=2)                # (B,NC,H)

    def scan_fn(carry, inp):
        c_prev, n_prev, m_prev = carry                      # (B,H,hd,hd),(B,H,hd),(B,H)
        kcz, vcz, qcz, dz, mz_local, sdz, ms_local, gz, cumfz = inp
        # numerator/denominator stabilizers combine inter & intra
        m_inter = cumfz + m_prev[:, None, :]                # (B,Q,H)
        m_t = jnp.maximum(mz_local, m_inter)
        # inter contribution
        w_inter = jnp.exp(m_inter - m_t)                    # (B,Q,H)
        num_i = jnp.einsum("bqnh,bnhp->bqnp", qcz, c_prev) * w_inter[..., None]
        den_i = jnp.einsum("bqnh,bnh->bqn", qcz, n_prev) * w_inter
        # intra contribution
        wd = jnp.exp(dz - m_t[:, :, None, :])               # (B,Q,Q,H)
        sc = jnp.einsum("bqnh,bjnh->bqjn", qcz, kcz) * wd
        num = num_i + jnp.einsum("bqjn,bjnp->bqnp", sc, vcz)
        den = den_i + sc.sum(2)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        y = num / den[..., None]                            # (B,Q,H,hd)
        # state update
        m_next = jnp.maximum(gz + m_prev, ms_local)         # (B,H)
        w_keep = jnp.exp(gz + m_prev - m_next)
        w_new = jnp.exp(sdz - m_next[:, None, :])           # (B,Q,H)
        c_new = (w_keep[..., None, None] * c_prev
                 + jnp.einsum("bqnh,bqnp,bqn->bnhp", kcz, vcz, w_new))
        n_new = w_keep[..., None] * n_prev + jnp.einsum("bqnh,bqn->bnh", kcz, w_new)
        return (c_new, n_new, m_next), y

    init = (jnp.zeros((bsz, n_heads, hd, hd), jnp.float32),
            jnp.zeros((bsz, n_heads, hd), jnp.float32),
            jnp.full((bsz, n_heads), -1e30, jnp.float32))
    swap = lambda t: t.transpose(1, 0, *range(2, t.ndim))
    inputs = tuple(swap(t) for t in (kc, vc, qc, dmat, m_local, s_decay,
                                     m_state_local, g_total, cumf))
    _, ys = jax.lax.scan(scan_fn, init, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, d).astype(cd)
    y = rms_norm(y, p["norm"])
    return y @ p["wo"].astype(cd)


def mlstm_decode(x, p, n_heads: int, c_state, n_state, m_state):
    """Recurrent mLSTM step. c (B,H,hd,hd), n (B,H,hd), m (B,H)."""
    bsz, _, d = x.shape
    hd = d // n_heads
    cd = x.dtype
    q = (x @ p["wq"].astype(cd)).reshape(bsz, n_heads, hd)
    k = (x @ p["wk"].astype(cd)).reshape(bsz, n_heads, hd) / math.sqrt(hd)
    v = (x @ p["wv"].astype(cd)).reshape(bsz, n_heads, hd)
    i_g = (x @ p["wi"].astype(cd)).reshape(bsz, n_heads).astype(jnp.float32)
    f_g = (x @ p["wf"].astype(cd)).reshape(bsz, n_heads).astype(jnp.float32)

    logf = jax.nn.log_sigmoid(f_g)
    m_new = jnp.maximum(logf + m_state, i_g)
    fs = jnp.exp(logf + m_state - m_new)[..., None]
    is_ = jnp.exp(i_g - m_new)[..., None]
    c_new = fs[..., None] * c_state + is_[..., None] * jnp.einsum(
        "bnh,bnp->bnhp", k.astype(jnp.float32), v.astype(jnp.float32))
    n_new = fs * n_state + is_ * k.astype(jnp.float32)
    num = jnp.einsum("bnh,bnhp->bnp", q.astype(jnp.float32), c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bnh,bnh->bn", q.astype(jnp.float32),
                                         n_new)), jnp.exp(-m_new))[..., None]
    y = (num / den).astype(cd).reshape(bsz, 1, d)
    y = rms_norm(y, p["norm"])
    return y @ p["wo"].astype(cd), c_new, n_new, m_new


def init_slstm(key, d_model: int, n_heads: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "w_gates": dense_init(ks[0], d_model, 4 * d_model, dtype),
        "r_gates": dense_init(ks[1], d_model, 4 * d_model, dtype),
        "norm": init_rmsnorm(d_model, dtype),
    }


def slstm_apply(x, p, h0=None, c0=None):
    """Sequential sLSTM (scan over time). x (B, S, D)."""
    bsz, s, d = x.shape
    cd = x.dtype
    gates_x = x @ p["w_gates"].astype(cd)  # precompute input part

    def step(carry, gx):
        h, c = carry
        g = gx + h @ p["r_gates"].astype(cd)
        i, f, z, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jnp.exp(jnp.minimum(i, 0.0)) * jnp.tanh(z)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h.astype(cd), c), h.astype(cd)

    h0 = jnp.zeros((bsz, d), cd) if h0 is None else h0
    c0 = jnp.zeros((bsz, d), jnp.float32) if c0 is None else c0
    (h, c), ys = jax.lax.scan(step, (h0, c0), gates_x.transpose(1, 0, 2))
    y = rms_norm(ys.transpose(1, 0, 2), p["norm"])
    return y, h, c
