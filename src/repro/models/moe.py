"""Mixture-of-Experts decoder (llama4-maverick, kimi-k2).

Dispatch design note (TPU adaptation): GShard-style one-hot einsum dispatch
costs O(T * E*C * d) *dense* FLOPs in XLA — at kimi-k2 scale that is ~1e16
FLOPs/layer of pure dispatch, drowning the real compute. We instead use a
scatter/gather dispatch: O(T*k*d) data movement, expert GEMMs are the only
large FLOPs, and expert-parallel sharding over the "model" axis lowers to
all-to-all-ish collectives under GSPMD. Tokens over capacity are dropped
(standard capacity-factor semantics); gates renormalize over kept experts.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.act import constrain
from .layers import (dense_init, embed_init, gqa_attention,
                     gqa_decode_attention, init_attention, init_mlp,
                     init_rmsnorm, mlp, rms_norm)
from .transformer import _stack, softmax_xent


def init_moe_mlp(key, cfg: ArchConfig, dtype=jnp.float32):
    e = cfg.moe
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, e.d_ff_expert
    scale = 1.0 / math.sqrt(d)

    def ew(k, a, b):
        return (jax.random.normal(k, (e.n_experts, a, b), jnp.float32)
                * (1.0 / math.sqrt(a))).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e.n_experts, dtype),
        "w_up": ew(ks[1], d, f),
        "w_gate": ew(ks[2], d, f),
        "w_down": ew(ks[3], f, d),
    }
    if e.n_shared:
        p["shared"] = init_mlp(ks[4], d, e.n_shared * f, gated=True, dtype=dtype)
    return p


def moe_mlp(x, params, cfg: ArchConfig):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    If the activation-spec table advertises a mesh with a `model` axis
    (``_ep_mesh`` key), dispatch runs expert-parallel inside a shard_map
    that is *manual over model, auto over data*: every model shard routes
    the (data-sharded, model-replicated) tokens to its local experts and
    the partial outputs are psum'd over `model` — O(T*d) ICI traffic per
    layer instead of the gather-based exchange GSPMD derives for a global
    scatter (measured 12x heavier on kimi-k2; see EXPERIMENTS.md §Perf).
    """
    from repro.parallel.act import ep_mesh
    mesh_axis = ep_mesh()
    if mesh_axis is not None:
        return _moe_mlp_ep_shardmap(x, params, cfg, *mesh_axis)
    return _moe_mlp_dense(x, params, cfg)


def _moe_mlp_dense(x, params, cfg: ArchConfig):
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    cd = x.dtype
    xf = x.reshape(t, d)

    logits = (xf @ params["router"].astype(cd)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, e.top_k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.T.reshape(-1)                                  # (k*T,)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
    me = probs.mean(0)
    counts = jnp.zeros((e.n_experts,), jnp.int32).at[flat_e].add(1)
    aux = e.n_experts * jnp.sum(me * counts.astype(jnp.float32)) / (t * e.top_k)

    capacity = int(math.ceil(t * e.top_k * e.capacity_factor / e.n_experts))
    capacity = max(capacity, 4)

    # Slot of each assignment within its expert. A (T*k, E) one-hot cumsum
    # would materialize O(T*E) ints (terabytes at kimi-k2 train scale), so
    # rank via a stable sort instead: O(T*k log T*k) and O(T*k) memory.
    kt = t * e.top_k
    order = jnp.argsort(flat_e, stable=True)                           # (k*T,)
    starts = jnp.cumsum(counts) - counts                               # (E,)
    slot_sorted = jnp.arange(kt, dtype=jnp.int32) - starts[flat_e[order]]
    slot = jnp.zeros((kt,), jnp.int32).at[order].set(slot_sorted)
    keep = (slot < capacity)
    slot = jnp.clip(slot, 0, capacity - 1)

    # Scatter tokens into per-expert buffers (dropped tokens contribute 0).
    buf_idx = flat_e * capacity + slot                                 # (k*T,)
    xk = constrain(jnp.tile(xf, (e.top_k, 1)) * keep[:, None].astype(cd),
                   "tokens_flat")
    base_buf = constrain(jnp.zeros((e.n_experts * capacity, d), cd),
                         "experts_flat")
    buffers = base_buf.at[buf_idx].add(xk)
    buffers = constrain(buffers.reshape(e.n_experts, capacity, d), "experts")

    # Expert GEMMs (the only large FLOPs): (E, C, d) x (E, d, f).
    up = jnp.einsum("ecd,edf->ecf", buffers, params["w_up"].astype(cd))
    gatep = jnp.einsum("ecd,edf->ecf", buffers, params["w_gate"].astype(cd))
    h = jax.nn.silu(up) * gatep
    out = constrain(jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(cd)),
                    "experts")
    out = out.reshape(e.n_experts * capacity, d)

    # Gather back and combine with renormalized gates.
    out = constrain(out, "experts_flat")
    yk = out[buf_idx] * (keep.astype(cd) * gate_vals.T.reshape(-1).astype(cd))[:, None]
    y = constrain(yk, "tokens_flat").reshape(e.top_k, t, d).sum(0)

    if "shared" in params:
        y = y + mlp(xf, params["shared"], "silu")
    return y.reshape(b, s, d), aux


def _expert_compute(xf, params, cfg: ArchConfig, n_local: int, e_offset,
                    gate_vals, expert_idx, capacity: int):
    """Dispatch xf (T, d) to `n_local` experts [e_offset, e_offset+n_local),
    run the expert GEMMs, and combine. Pure function of *local* expert
    weights — the shard_map EP body."""
    e = cfg.moe
    t, d = xf.shape
    cd = xf.dtype
    kt = t * e.top_k
    flat_e = expert_idx.T.reshape(-1) - e_offset                  # (k*T,)
    in_range = (flat_e >= 0) & (flat_e < n_local)
    flat_e = jnp.clip(flat_e, 0, n_local - 1)

    counts = jnp.zeros((n_local,), jnp.int32).at[flat_e].add(
        in_range.astype(jnp.int32))
    order = jnp.argsort(jnp.where(in_range, flat_e, n_local), stable=True)
    starts = jnp.cumsum(counts) - counts
    slot_sorted = jnp.arange(kt, dtype=jnp.int32) - starts[flat_e[order]]
    slot = jnp.zeros((kt,), jnp.int32).at[order].set(slot_sorted)
    keep = in_range & (slot < capacity)
    slot = jnp.clip(slot, 0, capacity - 1)

    buf_idx = flat_e * capacity + slot
    xk = jnp.tile(xf, (e.top_k, 1)) * keep[:, None].astype(cd)
    buffers = jnp.zeros((n_local * capacity, d), cd).at[buf_idx].add(xk)
    buffers = buffers.reshape(n_local, capacity, d)

    up = jnp.einsum("ecd,edf->ecf", buffers, params["w_up"].astype(cd))
    gatep = jnp.einsum("ecd,edf->ecf", buffers, params["w_gate"].astype(cd))
    h = jax.nn.silu(up) * gatep
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(cd))
    out = out.reshape(n_local * capacity, d)

    yk = out[buf_idx] * (keep.astype(cd)
                         * gate_vals.T.reshape(-1).astype(cd))[:, None]
    return yk.reshape(e.top_k, t, d).sum(0), counts


def _moe_mlp_ep_shardmap(x, params, cfg: ArchConfig, mesh, axis: str):
    """Expert-parallel MoE: shard_map manual over `axis` (model), auto over
    the data axes. Router + top-k run replicated per model shard; each
    shard computes only its local experts; partial y is psum'd."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.compat import shard_map

    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    cd = x.dtype
    ep = mesh.shape[axis]
    assert e.n_experts % ep == 0, f"experts {e.n_experts} % ep {ep}"
    n_local = e.n_experts // ep
    capacity = max(4, int(math.ceil(t * e.top_k * e.capacity_factor
                                    / e.n_experts)))

    def body(xf32, router, w_up, w_gate, w_down):
        idx = jax.lax.axis_index(axis)
        # xf enters in fp32: its cotangent is psum'd over the manual axis
        # in the backward pass, and XLA CPU's AllReducePromotion crashes
        # on bf16 all-reduce (TPU would take bf16 fine).
        xf = xf32.astype(cd)
        logits = (xf @ router.astype(cd)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, e.top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        lp = {"w_up": w_up, "w_gate": w_gate, "w_down": w_down}
        y, counts = _expert_compute(xf, lp, cfg, n_local, idx * n_local,
                                    gate_vals, expert_idx, capacity)
        # fp32 collectives only: XLA CPU's AllReducePromotion pass crashes
        # on bf16/int all-reduce at large device counts (fine on TPU).
        y = jax.lax.psum(y.astype(jnp.float32), axis).astype(cd)
        # aux loss: local slice of importance x local counts, psum'd
        me = probs.mean(0)                                 # (E,) per shard
        me_local = jax.lax.dynamic_slice(me, (idx * n_local,), (n_local,))
        partial = jnp.sum(me_local * counts.astype(jnp.float32))
        aux = e.n_experts * jax.lax.psum(partial, axis) / (t * e.top_k)
        return y, aux

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(), P(axis), P(axis), P(axis)),
                   out_specs=(P(), P()),
                   check_vma=False, axis_names=frozenset({axis}))

    xf = x.reshape(t, d)
    y, aux = fn(xf.astype(jnp.float32), params["router"], params["w_up"],
                params["w_gate"], params["w_down"])
    if "shared" in params:
        y = y + mlp(xf, params["shared"], "silu")
    return y.reshape(b, s, d), aux


def init_block(key, cfg: ArchConfig, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                               cfg.head_dim, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "moe": init_moe_mlp(k2, cfg, dtype),
    }


def init_lm(key, cfg: ArchConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_layers + 2)
    return {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "lm_head": dense_init(keys[1], cfg.d_model, cfg.vocab, dtype),
        "blocks": _stack([init_block(keys[2 + i], cfg, dtype)
                          for i in range(cfg.n_layers)]),
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }


def block_apply(carry, bp, cfg: ArchConfig, attn_fn=None):
    x, aux = carry
    x = x + gqa_attention(rms_norm(x, bp["ln1"]), bp["attn"], cfg.n_heads,
                          cfg.n_kv, rope=cfg.rope, rope_theta=cfg.rope_theta,
                          window=cfg.window, attn_fn=attn_fn)
    y, a = moe_mlp(rms_norm(x, bp["ln2"]), bp["moe"], cfg)
    return (x + y, aux + a)


def forward(params, cfg: ArchConfig, tokens, *, compute_dtype=jnp.bfloat16,
            remat: str = "full", attn_fn=None, unroll: bool = False):
    x = constrain(params["embed"].astype(compute_dtype)[tokens], "act")
    body = partial(block_apply, cfg=cfg, attn_fn=attn_fn)
    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def step(carry, bp):
        x2, aux2 = body(carry, bp)
        return (constrain(x2, "act"), aux2), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"],
                               unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["ln_f"])
    logits = constrain((x @ params["lm_head"].astype(compute_dtype))
                       .astype(jnp.float32), "logits")
    return logits, aux / cfg.n_layers


def loss_fn(params, cfg: ArchConfig, tokens, labels, aux_weight: float = 0.01,
            **kw):
    logits, aux = forward(params, cfg, tokens, **kw)
    return softmax_xent(logits, labels) + aux_weight * aux


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, s_max, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cfg: ArchConfig, cache, tokens, pos, *,
                compute_dtype=jnp.bfloat16, unroll: bool = False):
    x = constrain(params["embed"].astype(compute_dtype)[tokens], "dec")

    def step(x, layer):
        bp, k_c, v_c = layer
        h = rms_norm(x, bp["ln1"])
        out, k_c, v_c = gqa_decode_attention(
            h, bp["attn"], cfg.n_heads, cfg.n_kv, k_c, v_c, pos,
            rope=cfg.rope, rope_theta=cfg.rope_theta)
        x = x + out
        y, _ = moe_mlp(rms_norm(x, bp["ln2"]), bp["moe"], cfg)
        return constrain(x + y, "dec"), (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(step, x,
                                     (params["blocks"], cache["k"], cache["v"]),
                                     unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["lm_head"].astype(compute_dtype)).astype(jnp.float32)
    return logits, {"k": k_new, "v": v_new}
