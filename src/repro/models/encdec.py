"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment spec the conv frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d_model); the encoder
is the transformer stack on top of them. LayerNorm + GELU + learned-free
sinusoidal positions follow the Whisper paper.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.act import constrain
from .layers import (embed_init, gqa_attention,
                     gqa_decode_attention, init_attention, init_layernorm,
                     init_mlp, layer_norm, mlp)
from .transformer import _stack


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's sinusoidal position embedding."""
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _init_enc_block(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_layernorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                               cfg.head_dim, dtype),
        "ln2": init_layernorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    }


def _init_dec_block(key, cfg: ArchConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_layernorm(cfg.d_model, dtype),
        "self_attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                    cfg.head_dim, dtype),
        "ln_x": init_layernorm(cfg.d_model, dtype),
        "cross_attn": init_attention(k2, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                     cfg.head_dim, dtype),
        "ln2": init_layernorm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    }


def init_encdec(key, cfg: ArchConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_enc_layers + cfg.n_layers + 2)
    return {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "pos_dec": (jax.random.normal(keys[1], (cfg.max_seq, cfg.d_model),
                                      jnp.float32) * 0.01).astype(dtype),
        "enc_blocks": _stack([_init_enc_block(keys[2 + i], cfg, dtype)
                              for i in range(cfg.n_enc_layers)]),
        "dec_blocks": _stack([_init_dec_block(keys[2 + cfg.n_enc_layers + i],
                                              cfg, dtype)
                              for i in range(cfg.n_layers)]),
        "ln_enc": init_layernorm(cfg.d_model, dtype),
        "ln_f": init_layernorm(cfg.d_model, dtype),
    }


def encode(params, cfg: ArchConfig, frames, *, compute_dtype=jnp.bfloat16,
           attn_fn=None, unroll: bool = False):
    """frames (B, F, d_model): precomputed conv-frontend output (stub)."""
    x = frames.astype(compute_dtype)
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(compute_dtype)[None]

    def body(x, bp):
        h = gqa_attention(layer_norm(x, bp["ln1"]), bp["attn"], cfg.n_heads,
                          cfg.n_kv, rope=False, causal=False, attn_fn=attn_fn)
        x = x + h
        x = x + mlp(layer_norm(x, bp["ln2"]), bp["mlp"], "gelu")
        return constrain(x, "act"), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=cfg.n_enc_layers if unroll else 1)
    return layer_norm(x, params["ln_enc"])


def decode_train(params, cfg: ArchConfig, tokens, memory, *,
                 compute_dtype=jnp.bfloat16, remat: str = "full", attn_fn=None,
                 unroll: bool = False):
    b, s = tokens.shape
    x = params["embed"].astype(compute_dtype)[tokens]
    x = x + params["pos_dec"][:s].astype(compute_dtype)[None]

    def block(x, bp):
        x = x + gqa_attention(layer_norm(x, bp["ln1"]), bp["self_attn"],
                              cfg.n_heads, cfg.n_kv, rope=False, causal=True,
                              attn_fn=attn_fn)
        h = layer_norm(x, bp["ln_x"])
        cd = h.dtype
        hd = cfg.head_dim
        mk = (memory @ bp["cross_attn"]["wk"].astype(cd)).reshape(
            b, -1, cfg.n_kv, hd)
        mv = (memory @ bp["cross_attn"]["wv"].astype(cd)).reshape(
            b, -1, cfg.n_kv, hd)
        x = x + gqa_attention(h, bp["cross_attn"], cfg.n_heads, cfg.n_kv,
                              rope=False, causal=False, kv_override=(mk, mv))
        x = x + mlp(layer_norm(x, bp["ln2"]), bp["mlp"], "gelu")
        return constrain(x, "act")

    body = jax.checkpoint(block) if remat == "full" else block
    x, _ = jax.lax.scan(lambda h, bp: (body(h, bp), None), x,
                        params["dec_blocks"],
                        unroll=cfg.n_layers if unroll else 1)
    x = layer_norm(x, params["ln_f"])
    return constrain((x @ params["embed"].T.astype(compute_dtype))
                     .astype(jnp.float32), "logits")


def forward(params, cfg: ArchConfig, tokens, frames, **kw):
    memory = encode(params, cfg, frames,
                    compute_dtype=kw.get("compute_dtype", jnp.bfloat16),
                    unroll=kw.get("unroll", False))
    return decode_train(params, cfg, tokens, memory, **kw)


def loss_fn(params, cfg: ArchConfig, tokens, labels, frames, **kw):
    from .transformer import softmax_xent
    logits = forward(params, cfg, tokens, frames, **kw)
    return softmax_xent(logits, labels)


def init_cache(cfg: ArchConfig, batch: int, s_max: int, n_frames: int,
               dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((cfg.n_layers, batch, s_max, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, s_max, cfg.n_kv, cfg.head_dim), dtype),
        # cross-attention K/V precomputed once from encoder memory
        "xk": jnp.zeros((cfg.n_layers, batch, n_frames, cfg.n_kv, cfg.head_dim), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, n_frames, cfg.n_kv, cfg.head_dim), dtype),
    }


def prefill_cross(params, cfg: ArchConfig, memory, cache):
    """Fill the cross-attention K/V from encoder output (once per request)."""
    b = memory.shape[0]
    cd = memory.dtype
    hd = cfg.head_dim

    def per_layer(bp):
        mk = (memory @ bp["cross_attn"]["wk"].astype(cd)).reshape(b, -1, cfg.n_kv, hd)
        mv = (memory @ bp["cross_attn"]["wv"].astype(cd)).reshape(b, -1, cfg.n_kv, hd)
        return mk, mv

    xk, xv = jax.vmap(per_layer)(params["dec_blocks"])
    return {**cache, "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype)}


def decode_step(params, cfg: ArchConfig, cache, tokens, pos, *,
                compute_dtype=jnp.bfloat16, unroll: bool = False):
    """One decoder token against self KV cache + precomputed cross K/V."""
    b = tokens.shape[0]
    x = params["embed"].astype(compute_dtype)[tokens]
    x = x + jnp.take(params["pos_dec"].astype(compute_dtype), pos, axis=0)[:, None]

    def block(x, layer):
        bp, k_c, v_c, xk, xv = layer
        out, k_c, v_c = gqa_decode_attention(
            layer_norm(x, bp["ln1"]), bp["self_attn"], cfg.n_heads, cfg.n_kv,
            k_c, v_c, pos, rope=False)
        x = x + out
        x = x + gqa_attention(layer_norm(x, bp["ln_x"]), bp["cross_attn"],
                              cfg.n_heads, cfg.n_kv, rope=False, causal=False,
                              kv_override=(xk, xv))
        x = x + mlp(layer_norm(x, bp["ln2"]), bp["mlp"], "gelu")
        return x, (k_c, v_c)

    x, (k_n, v_n) = jax.lax.scan(
        block, x, (params["dec_blocks"], cache["k"], cache["v"],
                   cache["xk"], cache["xv"]),
        unroll=cfg.n_layers if unroll else 1)
    x = layer_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["embed"].T.astype(compute_dtype)).astype(jnp.float32)
    return logits, {**cache, "k": k_n, "v": v_n}
