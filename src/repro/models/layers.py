"""Shared neural-net building blocks (pure functions, explicit params).

Conventions
-----------
* Params are nested dicts of jnp arrays; ``init_*`` builds them, the
  matching ``apply`` function consumes them.
* Weights are stored in ``param_dtype`` (fp32 for training) and cast to
  ``compute_dtype`` (bf16) inside the ops — standard mixed precision.
* All sequence ops are batch-first: activations are (B, S, D).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.act import constrain


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x, params, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(x, params, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    if ang.ndim == 2:  # (S, hd/2) -> broadcast over batch
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window)
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, n_heads: int, n_kv: int,
                   head_dim: int | None = None, dtype=jnp.float32):
    hd = head_dim or d_model // n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * hd, dtype),
        "wk": dense_init(k2, d_model, n_kv * hd, dtype),
        "wv": dense_init(k3, d_model, n_kv * hd, dtype),
        "wo": dense_init(k4, n_heads * hd, d_model, dtype),
    }


def _causal_mask(s_q: int, s_k: int, window: int | None = None,
                 offset: int = 0) -> jax.Array:
    """(s_q, s_k) additive mask. ``offset`` = start position of the queries
    within the key timeline (for decode: offset = s_k - s_q)."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def gqa_attention(x, params, n_heads: int, n_kv: int, *, rope: bool = True,
                  rope_theta: float = 10000.0, window: int | None = None,
                  causal: bool = True, positions=None,
                  kv_override: tuple[jax.Array, jax.Array] | None = None,
                  attn_fn=None):
    """Full-sequence GQA self attention (training / prefill path).

    ``kv_override`` supplies external (k, v) for cross attention.
    ``attn_fn`` optionally replaces the core softmax(QK^T)V computation
    (e.g. with the Pallas flash-attention kernel).
    """
    b, s, d = x.shape
    hd = params["wq"].shape[1] // n_heads
    cd = x.dtype

    q = constrain((x @ params["wq"].astype(cd)).reshape(b, s, n_heads, hd),
                  "heads")
    if kv_override is None:
        k = (x @ params["wk"].astype(cd)).reshape(b, s, n_kv, hd)
        v = (x @ params["wv"].astype(cd)).reshape(b, s, n_kv, hd)
    else:
        k, v = kv_override
    s_k = k.shape[1]

    if rope:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, rope_theta)
        if kv_override is None:
            k = apply_rope(k, pos, rope_theta)

    if attn_fn is not None:
        out = attn_fn(q, k, v, causal=causal, window=window)
    else:
        g = n_heads // n_kv
        qg = q.reshape(b, s, n_kv, g, hd)
        scores = jnp.einsum("bsngh,btnh->bngst", qg, k).astype(jnp.float32)
        scores *= 1.0 / math.sqrt(hd)
        if causal:
            scores += _causal_mask(s, s_k, window, offset=s_k - s)[None, None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(cd)
        out = jnp.einsum("bngst,btnh->bsngh", probs, v).reshape(b, s, n_heads * hd)
    out = constrain(out.reshape(b, s, -1), "attn_out")
    return out @ params["wo"].astype(cd)


def gqa_decode_attention(x, params, n_heads: int, n_kv: int, k_cache, v_cache,
                         write_pos, *, rope_pos=None, valid_upto=None,
                         rope: bool = True, rope_theta: float = 10000.0):
    """One-token decode: x (B, 1, D); caches (B, S_slots, n_kv, hd).

    ``write_pos`` (B,) — cache slot the new KV is written to (for a
    sliding-window ring buffer this is ``pos % slots``).
    ``rope_pos`` (B,) — absolute position for RoPE (defaults to write_pos).
    ``valid_upto`` (B,) — highest valid slot index (defaults to write_pos;
    a full ring buffer passes slots-1 so every slot participates).
    Returns (out, new_k_cache, new_v_cache).
    """
    b, one, d = x.shape
    hd = params["wq"].shape[1] // n_heads
    cd = x.dtype
    s_slots = k_cache.shape[1]
    rope_pos = write_pos if rope_pos is None else rope_pos
    valid_upto = write_pos if valid_upto is None else valid_upto

    q = (x @ params["wq"].astype(cd)).reshape(b, 1, n_heads, hd)
    k = (x @ params["wk"].astype(cd)).reshape(b, 1, n_kv, hd)
    v = (x @ params["wv"].astype(cd)).reshape(b, 1, n_kv, hd)
    if rope:
        q = apply_rope(q, rope_pos[:, None], rope_theta)
        k = apply_rope(k, rope_pos[:, None], rope_theta)

    # Write new kv at write_pos (one-hot scatter keeps shapes static).
    onehot = jax.nn.one_hot(write_pos, s_slots, dtype=cd)  # (B, S_slots)
    k_cache = k_cache * (1 - onehot)[..., None, None] + onehot[..., None, None] * k
    v_cache = v_cache * (1 - onehot)[..., None, None] + onehot[..., None, None] * v

    g = n_heads // n_kv
    qg = q.reshape(b, n_kv, g, hd)
    scores = jnp.einsum("bngh,btnh->bngt", qg, k_cache).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    t = jnp.arange(s_slots)[None, None, None, :]
    ok = t <= valid_upto[:, None, None, None]
    scores = jnp.where(ok, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(cd)
    out = jnp.einsum("bngt,btnh->bngh", probs, v_cache).reshape(b, 1, n_heads * hd)
    return out @ params["wo"].astype(cd), k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(x, params, activation: str = "silu"):
    cd = x.dtype
    h = constrain(x @ params["w_up"].astype(cd), "ffn")
    if activation == "relu2":        # Nemotron squared ReLU
        h = jnp.square(jax.nn.relu(h))
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.silu(h)
    if "w_gate" in params:
        h = h * (x @ params["w_gate"].astype(cd))
    return h @ params["w_down"].astype(cd)
