"""JAX model zoo: dense/MoE/SSM/hybrid/enc-dec LMs + the paper's CNNs."""
from .api import decode_step, init_cache, init_params, loss_fn, prefill_logits

__all__ = ["decode_step", "init_cache", "init_params", "loss_fn",
           "prefill_logits"]
