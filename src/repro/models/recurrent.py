"""Recurrent-family LMs: xLSTM (sLSTM + mLSTM blocks) and Zamba2
(Mamba2 backbone + one *shared* attention block reused every N layers).

Both families decode with O(1) state per token — the long_500k cell runs
on these (and on SWA archs) while pure full-attention archs skip it.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.act import constrain
from .layers import (dense_init, embed_init, gqa_attention,
                     gqa_decode_attention, init_attention, init_mlp,
                     init_rmsnorm, mlp, rms_norm)
from .ssm import (init_mamba2, init_mlstm, init_slstm, mamba2_apply,
                  mamba2_decode, mlstm_apply, mlstm_decode, slstm_apply)
from .transformer import _stack


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------


def _is_slstm(cfg: ArchConfig, i: int) -> bool:
    ev = cfg.ssm.slstm_every
    return bool(ev) and (i % ev == ev - 1)


def init_xlstm(key, cfg: ArchConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            blocks.append({"kind_slstm": init_slstm(keys[2 + i], cfg.d_model,
                                                    cfg.n_heads, dtype),
                           "ln": init_rmsnorm(cfg.d_model, dtype)})
        else:
            blocks.append({"kind_mlstm": init_mlstm(keys[2 + i], cfg.d_model,
                                                    cfg.n_heads, dtype),
                           "ln": init_rmsnorm(cfg.d_model, dtype)})
    return {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "lm_head": dense_init(keys[1], cfg.d_model, cfg.vocab, dtype),
        "blocks": blocks,  # heterogeneous -> python list, not scanned
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }


def xlstm_forward(params, cfg: ArchConfig, tokens, *,
                  compute_dtype=jnp.bfloat16, remat: str = "full",
                  unroll: bool = False):  # layers are a python loop already
    x = constrain(params["embed"].astype(compute_dtype)[tokens], "act")
    chunk = cfg.ssm.chunk if cfg.ssm else 256

    for bp in params["blocks"]:
        if "kind_mlstm" in bp:
            def body(x, bp=bp):
                return x + mlstm_apply(rms_norm(x, bp["ln"]), bp["kind_mlstm"],
                                       cfg.n_heads, chunk)
        else:
            def body(x, bp=bp):
                y, _, _ = slstm_apply(rms_norm(x, bp["ln"]), bp["kind_slstm"])
                return x + y
        x = constrain(jax.checkpoint(body)(x) if remat == "full" else body(x),
                      "act")

    x = rms_norm(x, params["ln_f"])
    return constrain((x @ params["lm_head"].astype(compute_dtype))
                     .astype(jnp.float32), "logits")


def xlstm_init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    hd = cfg.d_model // cfg.n_heads
    caches = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            caches.append({"h": jnp.zeros((batch, cfg.d_model), dtype),
                           "c": jnp.zeros((batch, cfg.d_model), jnp.float32)})
        else:
            caches.append({"c": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
                           "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
                           "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32)})
    return caches


def xlstm_decode_step(params, cfg: ArchConfig, cache, tokens, pos, *,
                      compute_dtype=jnp.bfloat16, unroll: bool = False):
    x = params["embed"].astype(compute_dtype)[tokens]
    new_cache = []
    for bp, cc in zip(params["blocks"], cache):
        if "kind_mlstm" in bp:
            y, c, n, m = mlstm_decode(rms_norm(x, bp["ln"]), bp["kind_mlstm"],
                                      cfg.n_heads, cc["c"], cc["n"], cc["m"])
            x = x + y
            new_cache.append({"c": c, "n": n, "m": m})
        else:
            y, h, c = slstm_apply(rms_norm(x, bp["ln"]), bp["kind_slstm"],
                                  cc["h"], cc["c"])
            x = x + y
            new_cache.append({"h": h, "c": c})
    x = rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["lm_head"].astype(compute_dtype)).astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Zamba2 (hybrid)
# ---------------------------------------------------------------------------


def init_zamba(key, cfg: ArchConfig, dtype=jnp.float32):
    """cfg.shared_attn_every Mamba2 layers per group; ONE shared attention
    (+MLP) block reused after each group (the Zamba trick: attention
    quality at ~1/9th the attention parameter cost)."""
    n_groups = cfg.n_layers // cfg.shared_attn_every
    keys = jax.random.split(key, cfg.n_layers + 4)
    mamba = [init_mamba2(keys[2 + i], cfg.d_model, cfg.ssm, dtype)
             for i in range(cfg.n_layers)]
    stacked = _stack(mamba)
    # reshape leading dim (L,) -> (G, per)
    per = cfg.shared_attn_every
    stacked = jax.tree.map(
        lambda a: a.reshape(n_groups, per, *a.shape[1:]), stacked)
    k_attn, k_mlp = keys[-2], keys[-1]
    return {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "lm_head": dense_init(keys[1], cfg.d_model, cfg.vocab, dtype),
        "mamba": stacked,
        "shared": {
            "ln1": init_rmsnorm(cfg.d_model, dtype),
            "attn": init_attention(k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                   cfg.head_dim, dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
        },
        "mamba_ln": init_rmsnorm(cfg.d_model, dtype),  # shared pre-norm scale
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }


def zamba_forward(params, cfg: ArchConfig, tokens, *,
                  compute_dtype=jnp.bfloat16, remat: str = "full", attn_fn=None,
                  unroll: bool = False):
    x = constrain(params["embed"].astype(compute_dtype)[tokens], "act")
    shared = params["shared"]

    def inner(x, mp):
        return x + mamba2_apply(rms_norm(x, params["mamba_ln"]), mp, cfg.ssm)

    per = cfg.shared_attn_every

    def group(x, gp):
        x, _ = jax.lax.scan(lambda h, mp: (inner(h, mp), None), x, gp,
                            unroll=per if unroll else 1)
        # shared attention block (same params every group)
        x = x + gqa_attention(rms_norm(x, shared["ln1"]), shared["attn"],
                              cfg.n_heads, cfg.n_kv, rope=cfg.rope,
                              rope_theta=cfg.rope_theta, attn_fn=attn_fn)
        x = x + mlp(rms_norm(x, shared["ln2"]), shared["mlp"], cfg.activation)
        return constrain(x, "act")

    body = jax.checkpoint(group) if remat == "full" else group
    n_groups = cfg.n_layers // cfg.shared_attn_every
    x, _ = jax.lax.scan(lambda h, gp: (body(h, gp), None), x, params["mamba"],
                        unroll=n_groups if unroll else 1)
    x = rms_norm(x, params["ln_f"])
    return constrain((x @ params["lm_head"].astype(compute_dtype))
                     .astype(jnp.float32), "logits")


def zamba_init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_in = s.expansion * cfg.d_model
    n_h = d_in // s.head_dim
    n_groups = cfg.n_layers // cfg.shared_attn_every
    per = cfg.shared_attn_every
    return {
        "conv": jnp.zeros((n_groups, per, batch, s.conv_width - 1, d_in), dtype),
        "ssm": jnp.zeros((n_groups, per, batch, n_h, s.head_dim, s.state_dim),
                         jnp.float32),
        # one KV cache per *group* (the shared block runs n_groups times)
        "k": jnp.zeros((n_groups, batch, s_max, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((n_groups, batch, s_max, cfg.n_kv, cfg.head_dim), dtype),
    }


def zamba_decode_step(params, cfg: ArchConfig, cache, tokens, pos, *,
                      compute_dtype=jnp.bfloat16, unroll: bool = False):
    x = params["embed"].astype(compute_dtype)[tokens]
    shared = params["shared"]

    def group(x, gp):
        mp, conv_c, ssm_c, k_c, v_c = gp

        def inner(h, lp):
            mpl, cc, sc = lp
            y, cc, sc = mamba2_decode(rms_norm(h, params["mamba_ln"]), mpl,
                                      cfg.ssm, cc, sc)
            return h + y, (cc, sc)

        x, (conv_c, ssm_c) = jax.lax.scan(inner, x, (mp, conv_c, ssm_c))
        out, k_c, v_c = gqa_decode_attention(
            rms_norm(x, shared["ln1"]), shared["attn"], cfg.n_heads, cfg.n_kv,
            k_c, v_c, pos, rope=cfg.rope, rope_theta=cfg.rope_theta)
        x = x + out
        x = x + mlp(rms_norm(x, shared["ln2"]), shared["mlp"], cfg.activation)
        return x, (conv_c, ssm_c, k_c, v_c)

    x, (conv_n, ssm_n, k_n, v_n) = jax.lax.scan(
        group, x, (params["mamba"], cache["conv"], cache["ssm"],
                   cache["k"], cache["v"]),
        unroll=(cfg.n_layers // cfg.shared_attn_every) if unroll else 1)
    x = rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["lm_head"].astype(compute_dtype)).astype(jnp.float32)
    return logits, {"conv": conv_n, "ssm": ssm_n, "k": k_n, "v": v_n}
