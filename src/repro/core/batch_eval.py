"""Batched analytical-model engine: ``evaluate_rav`` as array kernels.

The paper's DSE throughput lives or dies on how fast the analytical
models evaluate ("fast exploration of various accelerator designs",
Sec. 7). The scalar reference path re-walks every layer in Python for
each of Algorithm 3's pf-doublings and rollbacks; this module evaluates
the same math over packed NumPy layer arrays
(:mod:`repro.core.layer_arrays`):

* the **generic structure**'s Algorithm-3 doubling sweep is one
  broadcasted ``(pf_levels, strategies, layers)`` latency tensor per
  rollback — every pf level and both buffer strategies at once — with the
  per-level MAC-array cycle table cached per ``(net, precision, split)``;
* the **pipeline structure**'s Algorithm-2 allocation (CTC allocate,
  halve-to-fit, bottleneck refinement) runs over plain int/float lists
  with zero ``StageDesign`` churn, calling the *same* formula helpers
  (``stage_dsp``/``stage_bram``/``split_pf``) as the dataclass path;
* :func:`evaluate_rav_batch` evaluates a whole PSO population: all
  candidates at one split point share the packed segment and cycle
  tables, with rollbacks diverging per candidate.

``local_opt.evaluate_rav`` stays the reference implementation. This
engine reproduces it decision-for-decision: every discrete output (RAV,
stage PF splits, strategy choice, DSP/BRAM usage, feasibility) is
identical, and float objectives agree to ~1e-9 relative (the only
difference is NumPy's pairwise summation over the layer axis vs Python's
sequential ``sum``) — enforced by the randomized equivalence sweep in
``tests/test_batch_eval.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

from .generic_model import (ABUFF_FRAC, BRAM_BITS, FMBUFF_FRAC, WBUFF_FRAC,
                            GenericDesign)
from .hw_specs import FPGASpec, alpha_for
from .layer_arrays import PackedLayers, pack_layers
from .local_opt import RAV, DesignPoint
from .netinfo import NetInfo
from .pipeline_model import (PipelineDesign, StageDesign, _pow2_floor,
                             split_pf, stage_bram, stage_dsp)


def _cdiv(a: np.ndarray, b) -> np.ndarray:
    """Exact integer ceil-division (== ``math.ceil(a / b)`` for our ranges)."""
    return -(-a // b)


# Pure int->int formulas whose arguments repeat massively across a
# population (pf ladders over the same layer dims): memoized views of the
# SAME pipeline_model functions, so results stay bit-identical.
_split_pf = functools.lru_cache(maxsize=1 << 16)(split_pf)
_stage_bram = functools.lru_cache(maxsize=1 << 16)(stage_bram)

# Hit/miss tallies for the one cache lru_cache can't see (the per-split
# level tables living on each PackedLayers instance). Plain int adds —
# no measurable cost next to the array math they sit beside.
_LEVELS_STATS = {"hits": 0, "misses": 0}


def cache_stats() -> dict[str, dict[str, int]]:
    """Hit/miss counters for every cache the batched engine leans on,
    as ``{cache: {hits, misses}}`` — the campaign tracer gauges these
    per cell so reports can show how much table reuse a search got.
    Counters are process-global and monotonic; diff two snapshots to
    attribute activity to one cell."""
    return {
        "pack_layers": _info(pack_layers.cache_info()),
        "split_pf": _info(_split_pf.cache_info()),
        "stage_bram": _info(_stage_bram.cache_info()),
        "levels": dict(_LEVELS_STATS),
    }


def _info(ci) -> dict[str, int]:
    return {"hits": ci.hits, "misses": ci.misses}


# ---------------------------------------------------------------------------
# Generic structure: per-split level tables + the Algorithm-3 sweep kernel
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class _Levels:
    """Everything about one generic segment that does NOT depend on the
    RAV: the pf-doubling ladder (up to MAC-array saturation) and the
    per-layer demand columns the latency kernel broadcasts against."""

    # pf ladder, int64 (P,)
    pf: np.ndarray
    cpf: np.ndarray
    kpf: np.ndarray
    ck: np.ndarray        # cpf * kpf (saturation check: ck < pf)
    dsp: np.ndarray       # GenericDesign.dsp() per level
    cycles_f: np.ndarray  # (P, L) MAC-array passes per frame, float64
    # segment layer columns (L,)
    w_f: np.ndarray       # weight bytes, float64
    fm_base: np.ndarray   # h*w*k*dw (accumulation-buffer demand), int64
    fit_base: np.ndarray  # (ifm+ofm)*8 bits (fm-buffer fit check), int64
    io_b: np.ndarray      # ifm+ofm bytes (spill traffic), int64
    ifm_f: np.ndarray
    ofm_f: np.ndarray
    needw_f: np.ndarray   # weight-buffer demand bits (0 for pools), float64


def _gen_levels(packed: PackedLayers, sp: int) -> _Levels | None:
    """Level table for ``packed``'s generic segment at split ``sp`` (None
    when the segment is empty), cached on the instance's ``derived`` dict
    so the tables live and die with the packed layers themselves."""
    try:
        lv = packed.derived[sp]
        _LEVELS_STATS["hits"] += 1
        return lv
    except KeyError:
        pass
    _LEVELS_STATS["misses"] += 1
    lv = packed.derived[sp] = _build_levels(packed, sp)
    return lv


def _build_levels(packed: PackedLayers, sp: int) -> _Levels | None:
    start, c_max, k_max = packed.segment(sp)
    if start >= packed.n_layers:
        return None
    sl = slice(start, packed.n_layers)
    h, w, c, k = packed.h[sl], packed.w[sl], packed.c[sl], packed.k[sl]
    r, s, groups = packed.r[sl], packed.s[sl], packed.groups[sl]
    is_pool, is_dw = packed.is_pool[sl], packed.is_dw[sl]
    alpha = alpha_for(min(packed.dw, packed.ww))

    # pf ladder: 1, 2, 4, ... until split_pf saturates (cpf*kpf < pf) —
    # Algorithm 3's inner loop can never visit a level past that.
    pf, ladder = 1, []
    while True:
        cpf, kpf = split_pf(pf, c_max, k_max)
        ladder.append((pf, cpf, kpf))
        if cpf * kpf < pf:
            break
        pf *= 2
    pfs = np.array([x[0] for x in ladder], dtype=np.int64)
    cpfs = np.array([x[1] for x in ladder], dtype=np.int64)
    kpfs = np.array([x[2] for x in ladder], dtype=np.int64)

    pix = h * w                      # ceil(h*w / pixel_par) at pixel_par=1
    base = pix * r * s
    cin = c // groups
    rows = []
    for _, cpf, kpf in ladder:
        # Eq. 6 per level: dwconv uses only the CPF lanes; pools are free.
        cyc = np.where(is_dw, base * _cdiv(c, cpf),
                       base * _cdiv(cin, cpf) * _cdiv(k, kpf))
        rows.append(np.where(is_pool, 0, cyc))
    ifm, ofm = packed.ifm_bytes[sl], packed.ofm_bytes[sl]
    return _Levels(
        pf=pfs, cpf=cpfs, kpf=kpfs, ck=cpfs * kpfs,
        dsp=np.maximum(1, (2 * cpfs * kpfs) // alpha),
        cycles_f=np.stack(rows).astype(np.float64),
        w_f=packed.weight_bytes[sl].astype(np.float64),
        fm_base=h * w * k * packed.dw,
        fit_base=(ifm + ofm) * 8,
        io_b=ifm + ofm,
        ifm_f=ifm.astype(np.float64), ofm_f=ofm.astype(np.float64),
        needw_f=np.where(is_pool, 0,
                         r * s * cin * k * packed.ww).astype(np.float64),
    )


def _alg3_sweep(lv: _Levels, batch: int, freq: float, bram_avail: int,
                bw_g: float, dsp_avail: int, target: float | None,
                pf_cap: int) -> tuple[int, int, float] | None:
    """One Algorithm-3 doubling sweep: the whole (pf level x strategy x
    layer) latency tensor in one broadcast, then the reference loop's
    stopping scan over it. Returns ``(level, strategy_index, latency)``
    for the level the scalar loop would settle on, or None when even
    PF=1 exceeds ``dsp_avail`` (the caller rolls the pipeline back)."""
    # Buffer capacities — the exact GenericDesign property expressions.
    bits = bram_avail * BRAM_BITS
    half_ab = np.array([max(1, int(bits * ABUFF_FRAC[s]) // 2)
                        for s in (1, 2)], dtype=np.float64)
    half_fm = np.array([int(bits * FMBUFF_FRAC[s]) // 2
                        for s in (1, 2)], dtype=np.int64)
    half_w2 = max(1, int(bits * WBUFF_FRAC[2]) // 2)

    # Traffic amplification (Eqs. 5/8/11-13) for both strategies at once.
    need_fm = (batch * lv.fm_base).astype(np.float64)
    g_fm = np.maximum(1.0, np.ceil(need_fm[None, :] / half_ab[:, None]))
    fits = (batch * lv.fit_base) <= half_fm[:, None]
    spill = (batch * lv.io_b).astype(np.float64)
    t_is = lv.w_f[None, :] * g_fm + np.where(fits, 0.0, spill[None, :])
    g_w2 = np.maximum(1.0, np.ceil(lv.needw_f / half_w2))
    t_ws = lv.w_f + batch * (lv.ifm_f * g_w2 + lv.ofm_f)
    traffic = np.stack([t_is[0], np.minimum(t_is[1], t_ws)])
    if bw_g > 0:
        mem = traffic / bw_g
    else:  # zero-traffic layers (on-chip pools) stay free even with no BW
        mem = np.where(traffic > 0, np.inf, 0.0)

    comp = batch * (lv.cycles_f / freq)                        # (P, L)
    lat = np.maximum(comp[:, None, :], mem[None, :, :]).sum(axis=2)

    # The reference inner loop's scan: advance while the generic half is
    # slower than the pipeline half and parallelism can still double.
    level, st, chosen = -1, 0, math.inf
    for i in range(len(lv.pf)):
        if lv.dsp[i] > dsp_avail:
            break
        level = i
        st = 0 if lat[i, 0] <= lat[i, 1] else 1   # ties: strategy 1
        chosen = float(lat[i, st])
        if target is not None and chosen <= target:
            break
        if lv.pf[i] >= pf_cap or lv.ck[i] < lv.pf[i]:
            break
    if level < 0:
        return None
    return level, st, chosen


# ---------------------------------------------------------------------------
# Pipeline structure: Algorithm 2 over plain lists (no dataclass churn)
# ---------------------------------------------------------------------------


class _PipeState:
    """``design_pipeline`` + ``scale_down`` + the latency roofline over
    int/float lists. Uses the same ``stage_dsp``/``stage_bram``/
    ``split_pf`` helpers as :class:`~repro.core.pipeline_model.StageDesign`,
    so every resource count and latency is bit-identical to the
    reference path."""

    __slots__ = ("packed", "n", "alpha", "freq", "batch",
                 "cpf", "kpf", "dsp_l", "bram_l", "comp",
                 "dsp_sum", "bram_sum")

    def __init__(self, packed: PackedLayers, sp: int, dsp_cap: int,
                 bram_cap: int, bw: float, freq: float, batch: int,
                 alpha: int):
        self.packed, self.n = packed, sp
        self.alpha, self.freq, self.batch = alpha, freq, batch
        m, c, k = packed.m_macs, packed.m_c, packed.m_k
        total_w = packed.m_wsum[sp]
        if total_w == 0 or bw <= 0:       # ctc_allocate's degenerate case
            pfs = [1] * sp
        else:                             # Algorithm 2 lines 4-6
            pfs = [max(1, _pow2_floor(m[i] * bw / total_w / freq))
                   for i in range(sp)]
        self.cpf, self.kpf = [], []
        for i in range(sp):
            a, b = _split_pf(pfs[i], c[i], k[i])
            self.cpf.append(a)
            self.kpf.append(b)
        self._refresh()
        # Algorithm 2 line 9: halve until resources fit.
        while sp and (self.dsp_sum > dsp_cap or self.bram_sum > bram_cap):
            if self.all_pf1():
                break
            self.scale_down()
        # Refinement: greedily double the slowest stage while it fits.
        while sp:
            i = max(range(sp), key=lambda j: self.comp[j])
            pf = self.cpf[i] * self.kpf[i]
            if pf >= c[i] * k[i]:
                break
            ncpf, nkpf = _split_pf(pf * 2, c[i], k[i])
            npf = ncpf * nkpf
            if npf <= pf:
                break
            nd = stage_dsp(npf, alpha)
            nb = _stage_bram(ncpf, nkpf, packed.dw, packed.ww,
                             packed.m_col_ceil[i], packed.m_rs[i])
            if (self.dsp_sum - self.dsp_l[i] + nd > dsp_cap
                    or self.bram_sum - self.bram_l[i] + nb > bram_cap):
                break
            self.cpf[i], self.kpf[i] = ncpf, nkpf
            self.dsp_sum += nd - self.dsp_l[i]
            self.bram_sum += nb - self.bram_l[i]
            self.dsp_l[i], self.bram_l[i] = nd, nb
            self.comp[i] = m[i] / (npf * freq)

    def _refresh(self) -> None:
        p = self.packed
        self.dsp_l, self.bram_l, self.comp = [], [], []
        for i in range(self.n):
            pf = self.cpf[i] * self.kpf[i]
            self.dsp_l.append(stage_dsp(pf, self.alpha))
            self.bram_l.append(_stage_bram(self.cpf[i], self.kpf[i], p.dw,
                                           p.ww, p.m_col_ceil[i], p.m_rs[i]))
            self.comp.append(p.m_macs[i] / (pf * self.freq))
        self.dsp_sum = sum(self.dsp_l)
        self.bram_sum = sum(self.bram_l)

    def all_pf1(self) -> bool:
        return all(self.cpf[i] * self.kpf[i] == 1 for i in range(self.n))

    def scale_down(self) -> None:
        """Algorithm 2 line 9 / Algorithm 3 line 13: PF_i = max(1, PF_i/2)."""
        c, k = self.packed.m_c, self.packed.m_k
        for i in range(self.n):
            a, b = _split_pf(max(1, (self.cpf[i] * self.kpf[i]) // 2),
                             c[i], k[i])
            self.cpf[i], self.kpf[i] = a, b
        self._refresh()

    def batch_latency(self, bw: float) -> float:
        if not self.n:
            return 0.0
        l_comp = self.batch * max(self.comp)
        stream = self.packed.m_wsum[self.n] + self.batch * self.packed.ifm0
        l_mem = stream / bw if bw > 0 else float("inf")
        return max(l_comp, l_mem)

    def throughput(self, bw: float) -> float:
        if not self.n:
            return float("inf")
        lat = self.batch_latency(bw)
        return self.batch / lat if lat > 0 else 0.0


# ---------------------------------------------------------------------------
# Whole-RAV evaluation + the population-batch entry point
# ---------------------------------------------------------------------------


def _eval_rav_fast(packed: PackedLayers, fpga: FPGASpec, rav: RAV,
                   max_rollbacks: int) -> DesignPoint:
    """Algorithms 2+3 for one RAV over packed arrays; mirrors
    ``local_opt.evaluate_rav`` decision-for-decision."""
    freq = fpga.freq
    sp = max(0, min(rav.sp, packed.n_major))
    batch = rav.batch
    dsp_p = int(fpga.dsp_usable * rav.dsp_frac) if sp else 0
    bram_p = int(fpga.bram_usable * rav.bram_frac) if sp else 0
    bw_p = fpga.bw_gbps * 1e9 * rav.bw_frac if sp else 0.0
    bw_g = fpga.bw_gbps * 1e9 - bw_p
    alpha = alpha_for(min(packed.dw, packed.ww))

    pipe = _PipeState(packed, sp, dsp_p, bram_p, bw_p, freq, batch, alpha)

    # ---- Algorithm 3: grow the generic structure until balanced ----------
    lv = _gen_levels(packed, sp)
    sel: tuple[int, int, float] | None = None
    bram_avail_g = 0
    if lv is not None:
        for _ in range(max_rollbacks):
            dsp_avail = fpga.dsp_usable - pipe.dsp_sum
            bram_avail = fpga.bram_usable - pipe.bram_sum
            if dsp_avail < 1 or bram_avail < 1:
                if not pipe.n or pipe.all_pf1():
                    break
                pipe.scale_down()
                continue
            target = pipe.batch_latency(bw_p) if pipe.n else None
            pf_cap = max(1, (dsp_avail * alpha) // 2)
            sel = _alg3_sweep(lv, batch, freq, bram_avail, bw_g, dsp_avail,
                              target, pf_cap)
            if sel is None:
                # Even PF=1 doesn't fit: roll the pipeline back.
                if not pipe.n or pipe.all_pf1():
                    break
                pipe.scale_down()
                continue
            bram_avail_g = bram_avail
            break

    # ---- Combine ----------------------------------------------------------
    stages = [StageDesign(packed.majors[i], pipe.cpf[i], pipe.kpf[i],
                          packed.dw, packed.ww) for i in range(pipe.n)]
    pipeline = PipelineDesign(stages, batch)
    gen = None
    lat_g = 0.0
    if sel is not None:
        lvl, st, lat_g = sel
        gen = GenericDesign(int(lv.cpf[lvl]), int(lv.kpf[lvl]), packed.dw,
                            packed.ww, bram_avail_g, bw_g, strategy=st + 1)

    if not stages and gen is None:
        return DesignPoint(rav, pipeline, gen, 0.0, 0.0, 0, 0, 0.0, 0.0,
                           feasible=False)

    rate_p = pipe.throughput(bw_p) if stages else float("inf")
    lat_p = pipe.batch_latency(bw_p) if stages else 0.0
    rate_g = (batch / lat_g if lat_g > 0 else float("inf")) \
        if gen is not None else float("inf")
    rate = min(rate_p, rate_g)
    if not math.isfinite(rate):
        rate = 0.0
    latency_s = lat_p + lat_g

    dsp_used = pipe.dsp_sum + (int(lv.dsp[sel[0]]) if sel is not None else 0)
    bram_used = pipe.bram_sum + (bram_avail_g if sel is not None else 0)
    feasible = dsp_used <= fpga.dsp_usable and bram_used <= fpga.bram_usable

    gops = rate * packed.total_ops / 1e9
    dsp_eff = (gops * 1e9) / (alpha * dsp_used * freq) if dsp_used else 0.0
    return DesignPoint(rav, pipeline, gen, rate, gops, dsp_used, bram_used,
                       dsp_eff, latency_s, feasible)


def _screen_tables(packed: PackedLayers) -> dict:
    """Per-split prefix/suffix tables for the screening relaxation,
    cached on the instance next to the per-split level tables (the key
    is a string, so it can't collide with the int split keys)."""
    try:
        return packed.derived["screen"]
    except KeyError:
        pass
    n = packed.n_major
    pipe_macs = np.zeros(n + 1, dtype=np.float64)
    pipe_macs[1:] = np.cumsum(np.asarray(packed.m_macs, dtype=np.float64))
    macs_np = np.where(packed.is_pool, 0, packed.macs).astype(np.float64)
    tail_macs = np.zeros(packed.n_layers + 1, dtype=np.float64)
    tail_macs[:-1] = np.cumsum(macs_np[::-1])[::-1]
    tail_w = np.zeros(packed.n_layers + 1, dtype=np.float64)
    tail_w[:-1] = np.cumsum(packed.weight_bytes[::-1].astype(np.float64))[::-1]
    t = packed.derived["screen"] = {
        "pipe_macs": pipe_macs,
        "pipe_w": np.asarray(packed.m_wsum, dtype=np.float64),
        "seg_start": np.asarray(packed.seg_start, dtype=np.int64),
        "tail_macs": tail_macs,
        "tail_w": tail_w,
    }
    return t


def screen_rav_batch(net: NetInfo, fpga: FPGASpec,
                     ravs: Sequence[RAV] | np.ndarray,
                     dw: int = 16, ww: int = 16) -> np.ndarray:
    """The batched engine at its capped screening budget: relaxed
    throughput (img/s) for every RAV, fully vectorized — microseconds
    per thousand candidates.

    The relaxation drops everything Algorithms 2+3 iterate over:
    parallelism is the continuous DSP roofline (``pf = dsp * alpha / 2``
    with the split's MACs allocated CTC-proportionally, the fixed point
    Algorithm 2 converges toward), BRAM feasibility and buffer-strategy
    spill are ignored, and memory traffic is the optimistic floor (the
    pipeline's weight+input stream, the generic structure's
    weights-once). The result is a rank proxy, not a bound — e.g. the
    real flow hands the generic structure whatever DSPs the pipeline
    did not consume, while the relaxation charges the full allocation —
    but it preserves enough of the fitness shape over [SP, batch,
    resource splits] to triage candidates: the hyperband engine triages thousands of RAVs
    here, then promotes only the survivors to :func:`evaluate_rav_batch`
    — whose per-candidate cost is ~100x this (Algorithm 2's allocate /
    halve-to-fit / refine loops dominate it at every ``max_rollbacks``
    setting, so capping rollbacks is NOT a usable cheap tier).
    """
    packed = pack_layers(net, dw, ww)
    t = _screen_tables(packed)
    alpha = alpha_for(min(dw, ww))
    freq, bw_total = fpga.freq, fpga.bw_gbps * 1e9

    # Accepts a raw (n, 5) position array (the search driver's screen
    # path — building n RAV objects would dwarf the screen itself) or
    # any RAV sequence; position rows round exactly like
    # SearchSpace.to_rav so both views rank identically.
    if isinstance(ravs, np.ndarray):
        arr = ravs.astype(np.float64, copy=False)
    else:
        arr = np.array([r.as_tuple() for r in ravs], dtype=np.float64)
    if not len(arr):
        return np.zeros(0)
    sp = np.clip(np.round(arr[:, 0]).astype(np.int64), 0, packed.n_major)
    batch = np.maximum(1.0, np.round(arr[:, 1]))
    has_pipe = sp > 0
    dsp_p = np.where(has_pipe, (fpga.dsp_usable * arr[:, 2]).astype(np.int64),
                     0)
    bw_p = np.where(has_pipe, bw_total * arr[:, 4], 0.0)

    with np.errstate(divide="ignore"):
        pf_p = np.maximum(1, dsp_p * alpha // 2).astype(np.float64)
        comp_p = batch * t["pipe_macs"][sp] / (pf_p * freq)
        stream = t["pipe_w"][sp] + batch * packed.ifm0
        mem_p = np.where(bw_p > 0, stream / bw_p,
                         np.where(stream > 0, np.inf, 0.0))
        lat_p = np.where(has_pipe, np.maximum(comp_p, mem_p), 0.0)

        start = t["seg_start"][sp]
        tm, tw = t["tail_macs"][start], t["tail_w"][start]
        has_tail = start < packed.n_layers
        pf_g = np.maximum(
            1, np.maximum(0, fpga.dsp_usable - dsp_p) * alpha // 2
        ).astype(np.float64)
        comp_g = batch * tm / (pf_g * freq)
        bw_g = bw_total - bw_p
        mem_g = np.where(bw_g > 0, tw / bw_g, np.where(tw > 0, np.inf, 0.0))
        lat_g = np.where(has_tail, np.maximum(comp_g, mem_g), 0.0)

    lat = np.maximum(lat_p, lat_g)
    with np.errstate(invalid="ignore"):
        ips = np.where((lat > 0) & np.isfinite(lat), batch / lat, 0.0)
    return ips


def evaluate_rav_batch(net: NetInfo, fpga: FPGASpec, ravs: Sequence[RAV],
                       dw: int = 16, ww: int = 16,
                       max_rollbacks: int = 12) -> list[DesignPoint]:
    """Batched ``evaluate_rav``: the whole population through the array
    kernels, results in input order.

    All candidates sharing a split point share one packed segment and one
    cached pf-ladder/cycle table (built on first touch, kept on the
    :class:`~repro.core.layer_arrays.PackedLayers` instance); each then
    runs the broadcasted Algorithm-3 sweep, with rollbacks diverging per
    candidate. Agreement with the scalar reference is exact on every
    discrete decision and ~1e-9 relative on float objectives (see module
    docstring).
    """
    packed = pack_layers(net, dw, ww)
    return [_eval_rav_fast(packed, fpga, r, max_rollbacks) for r in ravs]
