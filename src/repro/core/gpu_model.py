"""Analytical GPU cost model — the paper's *Accelerator Modeling* step
(Sec. 6) retargeted a second time, from FPGA/TPU to CUDA GPUs.

Same max-of-terms structure as :mod:`repro.core.tpu_model` (the paper's
latency law L = max(L_comp, L_w*G_fm, L_ifm, L_ofm), Eq. 11): per
(arch x shape x mesh) the step time is the max of

* **SM compute** — useful model FLOPs against the tensor-core peak;
* **HBM** — the napkin per-GPU traffic model (weight streams, activation
  round-trips, optimizer state, KV cache) against HBM bandwidth;
* **NVLink/IB** — the napkin per-GPU collective traffic against the
  interconnect. GPUs have a two-tier fabric: NVLink inside a
  ``node_size`` NVSwitch domain, InfiniBand per GPU across nodes. Ring
  collectives spanning nodes are gated by the slowest hop, so meshes
  larger than one node pay the IB rate on every collective — the
  conservative (weakest-link) approximation.

The per-token FLOP and per-step byte models are device-family-agnostic
(they describe the WORKLOAD, not the part), so they are shared with
:mod:`repro.core.tpu_model` verbatim; only the denominators — which
hardware ceiling each term divides by — are GPU-specific.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeSpec
from .hw_specs import A100_40G, A100_80G, GPUS, H100, GPUSpec
from .tpu_model import (MeshDesc, Roofline, model_collective_bytes,
                        model_flops, model_hbm_bytes)

__all__ = ["A100_40G", "A100_80G", "GPUS", "H100", "GPUSpec", "MeshDesc",
           "Roofline", "NVLINK_EFFICIENCY", "analytic_roofline",
           "collective_bw", "model_flops"]

#: Achievable fraction of the link peak for ring/tree collectives (NCCL
#: bus bandwidth vs datasheet rate; protocol + hierarchy overheads).
NVLINK_EFFICIENCY = 0.8


def collective_bw(mesh: MeshDesc, hw: GPUSpec) -> float:
    """Effective per-GPU collective bandwidth for a mesh: NVLink while the
    mesh fits one NVSwitch domain, the per-GPU IB rate once it spans
    nodes (the cross-node hop gates every ring that crosses it)."""
    link = hw.nvlink_bw if mesh.n_chips <= hw.node_size else hw.ib_bw
    return NVLINK_EFFICIENCY * link


def analytic_roofline(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshDesc,
                      hw: GPUSpec = A100_80G) -> Roofline:
    """SM-compute vs HBM vs NVLink/IB roofline for one (arch, shape, mesh)
    on one GPU part — the GPU analogue of
    :func:`repro.core.tpu_model.analytic_roofline`."""
    return Roofline(
        t_compute=model_flops(cfg, shape) / mesh.n_chips / hw.peak_flops,
        t_memory=model_hbm_bytes(cfg, shape, mesh) / hw.hbm_bw,
        t_collective=model_collective_bytes(cfg, shape, mesh)
        / collective_bw(mesh, hw),
    )
