"""Analytical model of the *generic structure* (paper Sec. 6.2).

A reusable CPF_g x KPF_g MAC array processes layers ``SP+1..N`` recurrently.
Two on-chip buffer allocation strategies (Sec. 5.3.2) and two dataflows
(input-stationary / weight-stationary) are modelled.

Simplification vs the paper (documented in DESIGN.md): instead of statically
splitting BW into (BW_w, BW_ifm, BW_ofm) and taking max of per-stream
latencies (Eq. 11/13), we use the *optimal* split — proportional to each
stream's total traffic — under which the max of the three stream latencies
equals ``total_traffic / BW``. This is the best case Eq. 11/13 can reach and
keeps the DSE smooth; the traffic amplification terms (G_fm, G_w) are exactly
the paper's.
"""
from __future__ import annotations

import dataclasses
import math

from .hw_specs import alpha_for
from .netinfo import LayerInfo

BRAM_BITS = 18 * 1024

#: Buffer-capacity fractions per strategy (Sec. 5.3.2). Strategy 1 spends
#: BRAM on the feature-map + accumulation buffers (weights live in LUTRAM);
#: strategy 2 carves out a resident weight buffer too. Shared with the
#: batched array kernels in :mod:`repro.core.batch_eval` so the scalar and
#: vectorized models cannot drift apart.
ABUFF_FRAC = {1: 0.25, 2: 0.15}
FMBUFF_FRAC = {1: 0.75, 2: 0.35}
WBUFF_FRAC = {1: 0.0, 2: 0.50}


@dataclasses.dataclass(frozen=True)
class GenericDesign:
    cpf: int
    kpf: int
    dw: int
    ww: int
    bram: int                 # BRAM blocks allocated to the generic structure
    bw_bytes: float           # external-memory bandwidth share, bytes/s
    strategy: int = 1         # 1: BRAM->fm+acc (weights in LUTRAM); 2: BRAM->all
    # Pixel-level parallelism of the MAC array. The paper's generic
    # structure is a GEMV engine (pp=1, Sec. 5.3.1); commercial IPs like the
    # Xilinx DPU additionally unroll over output pixels (pp=8 for B4096),
    # which *underutilizes* on small feature maps — the Fig. 2a effect.
    pixel_par: int = 1

    # -- buffer capacities (bits) -------------------------------------------
    @property
    def _bram_bits(self) -> int:
        return self.bram * BRAM_BITS

    @property
    def cap_abuff(self) -> int:
        # Accumulation buffer: wide/shallow; give it a fixed slice.
        return int(self._bram_bits * ABUFF_FRAC[self.strategy])

    @property
    def cap_fmbuff(self) -> int:
        return int(self._bram_bits * FMBUFF_FRAC[self.strategy])

    @property
    def cap_wbuff(self) -> int:
        # Strategy 1 keeps weights in LUTRAM (a double-buffered tile only).
        return int(self._bram_bits * WBUFF_FRAC[self.strategy])

    # -- resources ------------------------------------------------------------
    def dsp(self) -> int:
        alpha = alpha_for(min(self.dw, self.ww))
        return max(1, (2 * self.pixel_par * self.cpf * self.kpf) // alpha)

    # -- per-layer latency (seconds, one image) -------------------------------
    def _l_comp(self, l: LayerInfo, freq: float) -> float:
        """Eq. 6 with MAC-array *utilization* made explicit: a generic
        CPF x KPF array runs ceil(C/CPF)*ceil(K/KPF) passes per output
        pixel, so layers with C < CPF (e.g. the 3-channel input layer) or
        K < KPF waste lanes. This tail effect is exactly the DSP-efficiency
        loss of paradigm-A accelerators on early layers (paper Fig. 2a)."""
        pix = math.ceil(l.h * l.w / self.pixel_par)
        if l.kind == "dwconv":
            # Depthwise: each output channel consumes only its own input
            # channel — only the CPF dimension of the array can be used.
            cycles = pix * l.r * l.s * math.ceil(l.c / self.cpf)
        else:
            cin = l.c // l.groups
            cycles = (pix * l.r * l.s
                      * math.ceil(cin / self.cpf) * math.ceil(l.k / self.kpf))
        return cycles / freq

    def g_fm(self, l: LayerInfo, batch: int = 1) -> int:
        """Eq. 5 — output fm groups forced by the accumulation buffer
        (ping-pong halves the usable capacity). A batch of frames is
        grouped together so weight fetches amortize across the batch."""
        need = batch * l.h * l.w * l.k * self.dw
        return max(1, math.ceil(need / max(1, self.cap_abuff // 2)))

    def g_w(self, l: LayerInfo) -> int:
        """Eq. 12 — weight groups along K forced by the weight buffer."""
        if self.strategy == 1:
            return 1
        need = l.r * l.s * (l.c // l.groups) * l.k * self.ww
        return max(1, math.ceil(need / max(1, self.cap_wbuff // 2)))

    def _fm_fits(self, l: LayerInfo, batch: int = 1) -> bool:
        need = batch * (l.ifm_bytes(self.dw) + l.ofm_bytes(self.dw)) * 8
        return need <= self.cap_fmbuff // 2

    def layer_latency(self, l: LayerInfo, freq: float, batch: int = 1) -> float:
        """max(compute, memory) for a *batch* of frames, with the dataflow
        that minimizes external traffic (IS vs WS chosen per layer, as the
        paper's Algorithm 3 line 9 does under strategy 2)."""
        if l.kind == "pool":
            # Pool runs on the functional sub-module, overlapped with MACs;
            # only fm traffic if it spills.
            if self._fm_fits(l, batch):
                return 0.0
            if self.bw_bytes <= 0:
                return float("inf")
            return batch * (l.ifm_bytes(self.dw) + l.ofm_bytes(self.dw)) / self.bw_bytes

        l_comp = batch * self._l_comp(l, freq)
        w_bytes = l.weight_bytes(self.ww)
        ifm, ofm = l.ifm_bytes(self.dw), l.ofm_bytes(self.dw)

        if self._fm_fits(l, batch):
            # Eq. 8 regime: fm stays on chip; weights stream G_fm times.
            traffic_is = w_bytes * self.g_fm(l, batch)
        else:
            # Eq. 11 regime: line-partitioned fm swaps through ext. memory too.
            traffic_is = w_bytes * self.g_fm(l, batch) + batch * (ifm + ofm)

        candidates = [traffic_is]
        if self.strategy == 2:
            # Eq. 13 (WS): weights resident; ifm re-streamed per weight group.
            traffic_ws = w_bytes + batch * (ifm * self.g_w(l) + ofm)
            candidates.append(traffic_ws)

        l_mem = min(candidates) / self.bw_bytes if self.bw_bytes > 0 else float("inf")
        return max(l_comp, l_mem)

    def segment_latency(self, layers: list[LayerInfo], freq: float,
                        batch: int = 1) -> float:
        """Recurrent latency for a batch over layers SP+1..N."""
        return sum(self.layer_latency(l, freq, batch) for l in layers)


def best_generic(layers: list[LayerInfo], cpf: int, kpf: int, dw: int, ww: int,
                 bram: int, bw_bytes: float, freq: float,
                 batch: int = 1) -> GenericDesign:
    """Evaluate both buffer-allocation strategies, return the faster."""
    cands = [GenericDesign(cpf, kpf, dw, ww, bram, bw_bytes, strategy=s)
             for s in (1, 2)]
    return min(cands, key=lambda g: g.segment_latency(layers, freq, batch))
