"""DNNExplorer's 3-step design flow (paper Fig. 4):

1. *Model/HW Analysis* — :mod:`repro.core.netinfo` profiles the DNN.
2. *Accelerator Modeling* — :mod:`repro.core.pipeline_model` +
   :mod:`repro.core.generic_model` provide the analytical models.
3. *Architecture Exploration* — global PSO over the RAV
   (:mod:`repro.core.pso`) with local optimizers inside the fitness
   (:mod:`repro.core.local_opt`).
"""
from __future__ import annotations

import dataclasses
import time

from .hw_specs import FPGASpec
from .local_opt import RAV, DesignPoint, evaluate_rav
from .netinfo import NetInfo
from .pso import PSOConfig, PSOResult, optimize


@dataclasses.dataclass
class ExplorationResult:
    net: str
    fpga: str
    design: DesignPoint
    pso: PSOResult
    search_time_s: float

    @property
    def rav_pretty(self) -> str:
        r = self.design.rav
        return (f"[SP={r.sp}, Batch={r.batch}, DSP={r.dsp_frac:.1%}, "
                f"BRAM={r.bram_frac:.1%}, BW={r.bw_frac:.1%}]")


def explore(net: NetInfo, fpga: FPGASpec, dw: int = 16, ww: int = 16,
            batch_max: int = 1, cfg: PSOConfig | None = None) -> ExplorationResult:
    """Run the full DNNExplorer flow for one (DNN, FPGA) pair."""
    t0 = time.perf_counter()
    sp_max = len(net.major_layers)

    def fitness(rav: RAV) -> float:
        return evaluate_rav(net, fpga, rav, dw, ww).fitness

    pso = optimize(fitness, sp_max=sp_max, batch_max=batch_max, cfg=cfg)
    design = evaluate_rav(net, fpga, pso.best_rav, dw, ww)
    return ExplorationResult(net.name, fpga.name, design, pso,
                             time.perf_counter() - t0)
