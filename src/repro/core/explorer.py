"""DNNExplorer's 3-step design flow (paper Fig. 4):

1. *Model/HW Analysis* — :mod:`repro.core.netinfo` profiles the DNN.
2. *Accelerator Modeling* — :mod:`repro.core.pipeline_model` +
   :mod:`repro.core.generic_model` provide the analytical models
   (:mod:`repro.core.batch_eval` evaluates them population-at-a-time).
3. *Architecture Exploration* — a pluggable search engine over the RAV
   (:mod:`repro.core.search`; default is the paper's PSO, Algorithm 1)
   with local optimizers inside the fitness
   (:mod:`repro.core.local_opt`).

This module runs the flow for ONE (DNN, FPGA) pair and one scalar
objective — the paper's Table 3 setting. Campaign-scale sweeps over many
(network x input x FPGA x precision x batch) cells with multi-objective
Pareto frontiers live in :mod:`repro.dse`, which builds on this entry
point.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from .batch_eval import evaluate_rav_batch, screen_rav_batch
from .hw_specs import FPGASpec
from .local_opt import RAV, DesignPoint, evaluate_rav
from .netinfo import NetInfo
from .pso import PSOConfig, PSOResult, PSOSearcher, optimize  # noqa: F401
from .search import SearchSpace, make_searcher, run_search


#: Version stamp on the per-cell convergence ``trace`` dict (bump on
#: breaking change; readers must tolerate records without the field —
#: pre-trace stores resume unchanged).
TRACE_SCHEMA_VERSION = 1


@dataclasses.dataclass
class ExplorationResult:
    net: str
    fpga: str
    design: DesignPoint
    #: The search engine's result — historically always PSO's, now any
    #: registered engine's (:class:`repro.core.search.SearchResult`;
    #: the field name is kept for compatibility).
    pso: PSOResult
    search_time_s: float

    @property
    def rav_pretty(self) -> str:
        r = self.design.rav
        return (f"[SP={r.sp}, Batch={r.batch}, DSP={r.dsp_frac:.1%}, "
                f"BRAM={r.bram_frac:.1%}, BW={r.bw_frac:.1%}]")

    def convergence_trace(self) -> dict:
        """The paper's Fig.-8-style search-efficiency curve as a
        JSON-native dict: per-iteration best fitness, improvement tail,
        and why the search stopped. Rides in the campaign store record
        under ``trace``, so convergence diagnostics (which cells were
        still improving when the iteration cap hit) come from the store
        alone — no re-run needed. Multi-fidelity engines additionally
        report ``screened`` (candidates triaged through the cheap
        relaxation, never fully evaluated)."""
        p = self.pso
        hist = [round(float(h), 6) for h in p.history]
        trace = {
            "schema": TRACE_SCHEMA_VERSION,
            "engine": p.engine,
            "stop_reason": p.stop_reason,
            "iterations": p.iterations_run,
            "evaluations": p.evaluations,
            "cache_hits": p.cache_hits,
            "best_fitness": float(p.best_fitness),
            "final_delta": round(hist[-1] - hist[-2], 6)
            if len(hist) > 1 else 0.0,
            "history": hist,
        }
        if p.screened:
            trace["screened"] = p.screened
        return trace


def explore(net: NetInfo, fpga: FPGASpec, dw: int = 16, ww: int = 16,
            batch_max: int = 1, cfg: PSOConfig | None = None,
            objective: Callable[[DesignPoint], float] | None = None,
            searcher: str = "pso", searcher_config: dict | None = None,
            screen_fits: np.ndarray | None = None,
            ) -> ExplorationResult:
    """Run the full DNNExplorer flow for one (DNN, FPGA) pair.

    ``objective`` scalarizes a :class:`DesignPoint` into the fitness the
    search maximizes; the default is feasible throughput
    (``DesignPoint.fitness``), which keeps the paper's single-objective
    behavior. :mod:`repro.dse` passes weighted multi-objective
    scalarizations here.

    ``searcher`` picks the engine from the registry
    (:data:`repro.core.search.SEARCHERS`; default ``"pso"``, the
    paper's Algorithm 1) and ``searcher_config`` overrides that
    engine's config fields. ``cfg`` keeps its historical meaning: its
    population / iterations / patience / seed carry over to whichever
    engine runs (engines ignore knobs they don't have).

    The engine's fitness hook evaluates each population through the
    batched array-kernel engine (:mod:`repro.core.batch_eval`), which
    shares packed layer and per-split cycle tables across the whole
    search; multi-fidelity engines triage candidates through the
    vectorized screening relaxation
    (:func:`~repro.core.batch_eval.screen_rav_batch`) first. The
    winning RAV is re-evaluated once through the scalar reference path
    (:func:`~repro.core.local_opt.evaluate_rav`), so the returned
    design always comes from the reference implementation.

    ``screen_fits`` optionally supplies the FIRST screen-fidelity
    block's fitnesses, precomputed by the campaign-level cross-cell jax
    screen (:mod:`repro.core.screen_jax`): the engine's opening rung-0
    ask is served from it (lengths must match — a config drift falls
    back to the NumPy screen) and every later screen call goes through
    :func:`~repro.core.batch_eval.screen_rav_batch` as usual. Because
    the jax kernel is bit-identical to the NumPy reference and
    :func:`repro.core.search.hyperband_rung0` makes the asked positions
    deterministic, serving precomputed fitnesses leaves the search
    trajectory unchanged.
    """
    t0 = time.perf_counter()
    sp_max = len(net.major_layers)
    obj = objective if objective is not None else (lambda d: d.fitness)
    cfg = cfg or PSOConfig()

    def batch_fitness(ravs: list[RAV]) -> list[float]:
        """Whole-population fitness: one batched-engine call per step."""
        return [obj(d) for d in evaluate_rav_batch(net, fpga, ravs, dw, ww)]

    pre = ([np.asarray(screen_fits, dtype=float)]
           if screen_fits is not None else [])

    def screen(block: np.ndarray) -> np.ndarray:
        """Cheap-fidelity triage over a raw position block: relaxed
        throughput, NOT ``objective`` — multi-fidelity engines rank
        rungs on it, then score survivors with the true objective at
        full fidelity. A precomputed ``screen_fits`` serves the first
        matching block once; everything else hits the NumPy screen."""
        if pre and len(block) == len(pre[0]):
            return pre.pop()
        return screen_rav_batch(net, fpga, block, dw, ww)

    space = SearchSpace(sp_max=sp_max, batch_max=batch_max)
    if searcher == "pso" and not searcher_config:
        engine = PSOSearcher(space, cfg)    # the paper's exact path
    else:
        base = dict(population=cfg.population, iterations=cfg.iterations,
                    patience=cfg.patience, seed=cfg.seed)
        engine = make_searcher(searcher, space, base=base,
                               overrides=searcher_config)
    res = run_search(engine, batch_fitness_fn=batch_fitness, screen_fn=screen)
    design = evaluate_rav(net, fpga, res.best_rav, dw, ww)
    return ExplorationResult(net.name, fpga.name, design, res,
                             time.perf_counter() - t0)
