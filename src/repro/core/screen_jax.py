"""Cross-cell jax screening: the hyperband rung-0 relaxation for MANY
campaign cells in one jitted call.

:func:`repro.core.batch_eval.screen_rav_batch` vectorizes the screening
relaxation *within* one cell (one net x FPGA x precision instance). A
campaign, though, screens the same rung-0 budget for every cell, so the
natural batch axis is (cells x candidates): this module lifts the pure
array math of the NumPy screen to ``jax.numpy`` and ``vmap``s it across
cells, so a whole campaign's rung-0 triage is one XLA executable instead
of ``len(cells)`` NumPy passes.

The NumPy path stays the REFERENCE: the jax kernel mirrors its
expressions operation-for-operation in float64/int64 (``enable_x64``
scoped to the call — never the global flag), and a bit-equivalence test
(``tests/test_jax_screen.py``) pins ``screen_cells`` to
``screen_rav_batch`` exactly. Per-cell tables of different lengths are
zero-padded to a common shape before stacking; the padding is never
gathered, because each lane's split point is clipped to its OWN cell's
``n_major`` and the padded ``seg_start`` repeats its terminal value.

jax is optional here (the CI bench runner has none): import degrades to
``available() == False`` and callers fall back to the NumPy reference.

    tables = [cell_tables(net, fpga, dw, ww) for ... each cell]
    stacked = stack_cells(tables)
    ips = screen_cells(stacked, positions)   # (cells, n, 5) -> (cells, n)
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .hw_specs import FPGASpec, alpha_for
from .layer_arrays import pack_layers
from .netinfo import NetInfo

try:  # pragma: no cover - exercised via available() both ways
    import jax
    import jax.numpy as jnp
    HAVE_JAX = True
except Exception:  # pragma: no cover - CI bench image has no jax
    jax = jnp = None
    HAVE_JAX = False

_compiled = None


def available() -> bool:
    """True when jax imported and :func:`screen_cells` can run."""
    return HAVE_JAX


def cell_tables(net: NetInfo, fpga: FPGASpec, dw: int = 16,
                ww: int = 16) -> dict:
    """One cell's screening inputs: the NumPy reference's cached
    prefix/suffix tables (:func:`repro.core.batch_eval._screen_tables`,
    shared — not recomputed) plus the hardware scalars its kernel
    closes over."""
    from .batch_eval import _screen_tables
    packed = pack_layers(net, dw, ww)
    t = _screen_tables(packed)
    return {
        "pipe_macs": t["pipe_macs"], "pipe_w": t["pipe_w"],
        "seg_start": t["seg_start"],
        "tail_macs": t["tail_macs"], "tail_w": t["tail_w"],
        "n_major": packed.n_major, "n_layers": packed.n_layers,
        "ifm0": float(packed.ifm0),
        "alpha": alpha_for(min(dw, ww)),
        "freq": float(fpga.freq),
        "bw_total": float(fpga.bw_gbps * 1e9),
        "dsp_usable": int(fpga.dsp_usable),
    }


def stack_cells(tables: Sequence[dict]) -> dict:
    """Pad per-cell tables to common lengths and stack to (cells, ...)
    arrays — the pytree one ``vmap`` lane reads per cell. Zero padding
    is sound: a lane's gathers are clipped to its own ``n_major`` /
    terminal ``seg_start``, so padded entries are never addressed."""
    lp = max(len(t["pipe_macs"]) for t in tables)
    lt = max(len(t["tail_macs"]) for t in tables)

    def padf(key: str, width: int) -> np.ndarray:
        out = np.zeros((len(tables), width), dtype=np.float64)
        for i, t in enumerate(tables):
            a = np.asarray(t[key], dtype=np.float64)
            out[i, :len(a)] = a
        return out

    seg = np.zeros((len(tables), lp), dtype=np.int64)
    for i, t in enumerate(tables):
        a = np.asarray(t["seg_start"], dtype=np.int64)
        seg[i, :len(a)] = a
        if len(a) < lp:
            seg[i, len(a):] = a[-1] if len(a) else 0
    return {
        "pipe_macs": padf("pipe_macs", lp), "pipe_w": padf("pipe_w", lp),
        "seg_start": seg,
        "tail_macs": padf("tail_macs", lt), "tail_w": padf("tail_w", lt),
        **{k: np.asarray([t[k] for t in tables], dtype=np.int64)
           for k in ("n_major", "n_layers", "alpha", "dsp_usable")},
        **{k: np.asarray([t[k] for t in tables], dtype=np.float64)
           for k in ("ifm0", "freq", "bw_total")},
    }


def _screen_one(tab: dict, arr):
    """One cell's screen in jax — a line-for-line port of the NumPy
    reference in :func:`repro.core.batch_eval.screen_rav_batch` (same
    dtypes, same rounding, same where-guards), kept textually parallel
    so the bit-equivalence test stays reviewable."""
    sp = jnp.clip(jnp.round(arr[:, 0]).astype(jnp.int64), 0, tab["n_major"])
    batch = jnp.maximum(1.0, jnp.round(arr[:, 1]))
    has_pipe = sp > 0
    dsp_p = jnp.where(has_pipe,
                      (tab["dsp_usable"] * arr[:, 2]).astype(jnp.int64), 0)
    bw_p = jnp.where(has_pipe, tab["bw_total"] * arr[:, 4], 0.0)

    pf_p = jnp.maximum(1, dsp_p * tab["alpha"] // 2).astype(jnp.float64)
    comp_p = batch * tab["pipe_macs"][sp] / (pf_p * tab["freq"])
    stream = tab["pipe_w"][sp] + batch * tab["ifm0"]
    mem_p = jnp.where(bw_p > 0, stream / bw_p,
                      jnp.where(stream > 0, jnp.inf, 0.0))
    lat_p = jnp.where(has_pipe, jnp.maximum(comp_p, mem_p), 0.0)

    start = tab["seg_start"][sp]
    tm, tw = tab["tail_macs"][start], tab["tail_w"][start]
    has_tail = start < tab["n_layers"]
    pf_g = jnp.maximum(
        1, jnp.maximum(0, tab["dsp_usable"] - dsp_p) * tab["alpha"] // 2
    ).astype(jnp.float64)
    comp_g = batch * tm / (pf_g * tab["freq"])
    bw_g = tab["bw_total"] - bw_p
    mem_g = jnp.where(bw_g > 0, tw / bw_g, jnp.where(tw > 0, jnp.inf, 0.0))
    lat_g = jnp.where(has_tail, jnp.maximum(comp_g, mem_g), 0.0)

    lat = jnp.maximum(lat_p, lat_g)
    return jnp.where((lat > 0) & jnp.isfinite(lat), batch / lat, 0.0)


def _kernel():
    global _compiled
    if _compiled is None:
        _compiled = jax.jit(jax.vmap(_screen_one, in_axes=(0, 0)))
    return _compiled


def screen_cells(stacked: dict, positions: np.ndarray) -> np.ndarray:
    """Screen (cells x candidates) in ONE jitted call.

    ``stacked`` is :func:`stack_cells` output; ``positions`` is the
    (cells, n, 5) rung-0 position block, one row of raw search-space
    positions per candidate. Returns (cells, n) relaxed img/s,
    bit-identical to running the NumPy ``screen_rav_batch`` per cell.
    float64 is enabled only inside this call (scoped ``enable_x64``),
    so the process-global jax config is untouched.
    """
    if not HAVE_JAX:
        raise RuntimeError(
            "jax is unavailable; use the NumPy reference "
            "batch_eval.screen_rav_batch per cell instead")
    pos = np.asarray(positions, dtype=np.float64)
    if pos.ndim != 3 or pos.shape[2] != 5:
        raise ValueError(f"positions must be (cells, n, 5); "
                         f"got {pos.shape}")
    if pos.shape[0] != len(stacked["n_major"]):
        raise ValueError(
            f"positions batch {pos.shape[0]} != {len(stacked['n_major'])} "
            f"stacked cells")
    with jax.experimental.enable_x64():
        out = _kernel()(stacked, pos)
        return np.asarray(out)
