"""DNNExplorer's two-level DSE retargeted to CUDA GPU clusters
(beyond-paper), exactly parallel to :mod:`repro.core.tpu_planner`.

Global optimization (Sec. 7.2 analogue): enumerate the mapping space —
(n_gpus, dp x tp factorization, microbatches, remat) per GPU part — with
the analytic roofline (:mod:`repro.core.gpu_model`) as the fitness,
subject to the HBM-capacity constraint. The space stays small enough to
enumerate exhaustively (the degenerate optimizer, same as the TPU side).

Local optimization (Sec. 7.3 analogue): per plan, remat policy and
microbatch count balance HBM fit against recompute FLOPs — HBM in the
role of BRAM, unchanged from the TPU planner because the balance is a
property of the workload, not the part.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeSpec
from .gpu_model import A100_80G, GPUSpec, analytic_roofline
from .tpu_model import MeshDesc, Roofline, useful_flops
from .tpu_planner import candidate_meshes, factorizations, hbm_per_chip

__all__ = ["GPUPlan", "best_plan", "evaluate_point", "factorizations",
           "plan_arch"]


@dataclasses.dataclass
class GPUPlan:
    arch: str
    shape: str
    gpu: str             # GPUSpec name (a100-40g, a100-80g, h100, ...)
    n_gpus: int
    dp: int
    tp: int
    microbatches: int
    remat: str
    roofline: Roofline
    hbm_per_gpu: float
    fits: bool
    predicted_step_s: float
    mfu: float

    def pretty(self) -> str:
        r = self.roofline
        return (f"{self.arch}/{self.shape} on {self.n_gpus}x{self.gpu}: "
                f"dp={self.dp} tp={self.tp} mb={self.microbatches} "
                f"remat={self.remat} step={self.predicted_step_s:.3g}s "
                f"mfu={self.mfu:.2f} bound={r.bound} "
                f"hbm={self.hbm_per_gpu / 2**30:.1f}GiB fits={self.fits}")


def evaluate_point(cfg: ArchConfig, shape: ShapeSpec, gpus: int, dp: int,
                   tp: int, remat: str, microbatches: int,
                   hw: GPUSpec = A100_80G, calibration=None) -> GPUPlan:
    """Score ONE (mesh x remat x microbatch) mapping on one GPU part with
    the analytic roofline — the single-design evaluation the ``cuda``
    campaign backend loops over, mirroring
    :func:`repro.core.tpu_planner.evaluate_point`.

    ``calibration`` (a :class:`repro.calib.Calibration`, duck-typed via
    ``for_spec``) rescales ``hw`` to measured delivered rates before any
    model math; ``None`` — the default — evaluates against the datasheet
    spec exactly as before."""
    if calibration is not None:
        hw = calibration.for_spec(hw)
    mesh = MeshDesc(gpus, dp, tp)
    rl = analytic_roofline(cfg, shape, mesh, hw)
    if remat != "full" and shape.kind == "train":
        # less recompute: scale the compute term 8ND -> 6ND
        rl = Roofline(rl.t_compute * 0.75, rl.t_memory, rl.t_collective)
    # The static HBM demand model is workload napkin math, shared with the
    # TPU planner; only the capacity it is checked against is GPU-specific.
    hbm = hbm_per_chip(cfg, shape, mesh, remat, microbatches)
    fits = hbm <= hw.hbm_bytes * 0.9
    step = rl.step_time
    # MFU numerator excludes recompute FLOPs (see tpu_model.useful_flops).
    useful = useful_flops(cfg, shape) / gpus / hw.peak_flops
    mfu = min(useful / step, 1.0) if step else 0.0
    return GPUPlan(cfg.name, shape.name, hw.name, gpus, dp, tp, microbatches,
                   remat, rl, hbm, fits, step, mfu)


def plan_arch(cfg: ArchConfig, shape: ShapeSpec, hw: GPUSpec = A100_80G,
              max_gpus: int = 256, objective: str = "throughput_per_gpu"):
    """Enumerate the mesh/remat/microbatch space on one GPU part; return
    plans sorted by the objective (feasible first)."""
    plans: list[GPUPlan] = []
    for gpus, dp, tp in candidate_meshes(max_gpus):
        if shape.global_batch % dp:
            continue
        for remat in (("full", "dots", "none") if shape.kind == "train"
                      else ("none",)):
            for mb in (1, 2, 4, 8):
                if shape.kind != "train" and mb > 1:
                    continue
                plans.append(evaluate_point(cfg, shape, gpus, dp, tp,
                                            remat, mb, hw))
    key = {
        "throughput_per_gpu": lambda p: (-p.fits, p.predicted_step_s * p.n_gpus),
        "latency": lambda p: (-p.fits, p.predicted_step_s),
        "mfu": lambda p: (-p.fits, -p.mfu),
    }[objective]
    plans.sort(key=key)
    return plans


def best_plan(cfg: ArchConfig, shape: ShapeSpec, **kw) -> GPUPlan:
    return plan_arch(cfg, shape, **kw)[0]
