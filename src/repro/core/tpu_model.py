"""Analytical TPU cost model — the paper's *Accelerator Modeling* step
(Sec. 6) retargeted from FPGA to TPU v5e.

The paper's latency law L = max(L_comp, L_w*G_fm, L_ifm, L_ofm) (Eq. 11)
IS a roofline: compute term vs weight-stream term vs feature-map terms.
Here the same three families of terms are derived per (arch x shape x
mesh): MXU compute, HBM traffic, ICI collective traffic. They drive
(a) the §Roofline report, (b) the DSE fitness in tpu_planner, and (c) the
napkin math in the §Perf hillclimb — and are validated against the
dry-run's compiled HLO (the analogue of the paper's board measurements,
Figs. 7/8).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeSpec
from .hw_specs import TPU_V5E, TPUSpec


@dataclasses.dataclass(frozen=True)
class MeshDesc:
    n_chips: int
    dp: int          # data-parallel ways (incl. pod axis)
    tp: int          # model/tensor-parallel ways

    @classmethod
    def single_pod(cls):
        return cls(256, 16, 16)

    @classmethod
    def multi_pod(cls):
        return cls(512, 32, 16)


def _matmul_params(cfg: ArchConfig) -> float:
    """Params participating in per-token matmuls (embedding *gather* is
    free; the lm_head matmul is not)."""
    n = cfg.active_param_count()
    n -= cfg.vocab * cfg.d_model  # the gather-only embedding matrix
    return float(n)


def _attn_flops_per_token(cfg: ArchConfig, s_ctx: int, causal: bool = True) -> float:
    """QK^T + PV flops per token at context length s_ctx (per layer set)."""
    if cfg.family == "ssm":
        # mLSTM chunked: ~2 matmul-pairs of (chunk x hd) per token per head
        q = cfg.ssm.chunk if cfg.ssm else 256
        return 4.0 * cfg.n_layers * q * cfg.d_model
    ctx = min(s_ctx, cfg.window) if cfg.window else s_ctx
    eff = ctx / 2 if causal and not cfg.window else ctx
    d_attn = cfg.n_heads * cfg.head_dim
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // max(cfg.shared_attn_every, 1)
        # + SSD chunk work for the mamba layers
        q = cfg.ssm.chunk if cfg.ssm else 256
        ssd = 4.0 * cfg.n_layers * q * (cfg.ssm.expansion * cfg.d_model)
        return 4.0 * n_attn_layers * eff * d_attn + ssd
    return 4.0 * n_attn_layers * eff * d_attn


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Useful (MODEL) FLOPs per global step: 6*N*D for train (+remat -> 8),
    2*N*D for prefill, 2*N_active per decoded token + attention reads."""
    n_mat = _matmul_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * s
        per_tok = 2.0 * n_mat + _attn_flops_per_token(cfg, s)
        return 4.0 * tokens * per_tok  # fwd + full-remat recompute + 2x bwd
    if shape.kind == "prefill":
        tokens = b * s
        per_tok = 2.0 * n_mat + _attn_flops_per_token(cfg, s)
        return tokens * per_tok
    # decode: one token per sequence against an s-long context
    per_tok = 2.0 * n_mat
    if cfg.family in ("ssm", "hybrid"):
        # state update/readout, O(1) in s
        ssm = cfg.ssm
        d_in = ssm.expansion * cfg.d_model if ssm else cfg.d_model
        per_tok += 4.0 * cfg.n_layers * d_in * (ssm.state_dim if ssm else 64)
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // max(cfg.shared_attn_every, 1)
            per_tok += 4.0 * n_attn * s * cfg.n_kv * cfg.head_dim
    else:
        ctx = min(s, cfg.window) if cfg.window else s
        per_tok += 4.0 * cfg.n_layers * ctx * cfg.n_kv * cfg.head_dim
    return b * per_tok


def useful_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL FLOPs per step that do useful work: the standard MFU
    numerator. Train excludes the full-remat recompute pass —
    :func:`model_flops` counts fwd + recompute + 2x bwd (8ND-style), of
    which 6ND is model work — so a compute-bound full-remat design reports
    MFU 0.75, not a fictitious 1.0, and the DSE's normalized delivered
    TFLOP/s never exceeds what the hardware could usefully deliver."""
    f = model_flops(cfg, shape)
    return 0.75 * f if shape.kind == "train" else f


def kv_cache_bytes(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Global decode-state bytes (KV cache or recurrent state)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        hd = cfg.d_model // cfg.n_heads
        return float(b * cfg.n_layers * cfg.n_heads * (hd * hd + hd + 1) * 4)
    if cfg.family == "hybrid":
        ssm = cfg.ssm
        d_in = ssm.expansion * cfg.d_model
        state = b * cfg.n_layers * (d_in // ssm.head_dim) * ssm.head_dim * ssm.state_dim * 4
        n_groups = cfg.n_layers // cfg.shared_attn_every
        kv = b * n_groups * s * cfg.n_kv * cfg.head_dim * 2 * 2
        return float(state + kv)
    slots = min(s, cfg.window) if cfg.window else s
    layers = cfg.n_layers
    return float(b * layers * slots * cfg.n_kv * cfg.head_dim * 2 * 2)


def model_hbm_bytes(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshDesc) -> float:
    """Per-chip HBM traffic per step (napkin model).

    Weights: each chip streams its TP shard of the active params in bf16,
    once per pass (train: fwd + remat + bwd = 3 passes).
    Activations: ~10 residual-stream-sized tensors per block round-trip.
    Optimizer: fp32 params+mu+nu read & written (train only).
    Decode adds the chip's slice of the KV cache per token.
    """
    n_mat = _matmul_params(cfg)
    w_shard = 2.0 * n_mat / mesh.tp
    tokens_dev = shape.global_batch * shape.seq_len / mesh.dp
    act = 10.0 * cfg.n_layers * tokens_dev * cfg.d_model * 2.0
    if shape.kind == "train":
        opt = 20.0 * 4.0 * cfg.param_count() / mesh.n_chips
        return 3.0 * w_shard + act + opt
    if shape.kind == "prefill":
        return w_shard + act
    cache = kv_cache_bytes(cfg, shape) / mesh.n_chips
    act_dec = 10.0 * cfg.n_layers * (shape.global_batch / mesh.dp) * cfg.d_model * 2.0
    return w_shard + cache + act_dec


def model_collective_bytes(cfg: ArchConfig, shape: ShapeSpec,
                           mesh: MeshDesc) -> float:
    """Per-chip ICI traffic per step (napkin).

    Train: FSDP all-gathers (bf16 weights, 2 gathers: fwd-or-remat reuse +
    bwd) + gradient reduce-scatter (fp32/2 with int8 compression off) +
    TP all-reduces (2 per block on the residual stream).
    """
    n_mat = _matmul_params(cfg)
    tokens_dev = shape.global_batch * shape.seq_len / mesh.dp
    tp_ar = 2.0 * 2.0 * cfg.n_layers * tokens_dev * cfg.d_model * 2.0
    if shape.kind == "train":
        ag = 2.0 * 2.0 * n_mat / mesh.tp
        rs = 4.0 * n_mat / mesh.tp
        return ag + rs + tp_ar
    if shape.kind == "prefill":
        return 2.0 * n_mat / mesh.tp + tp_ar
    b_dev = shape.global_batch / mesh.dp
    tp_ar_dec = 2.0 * 2.0 * cfg.n_layers * b_dev * cfg.d_model * 2.0
    # sequence-sharded decode attention: logits/softmax partials ~ heads
    seq_ar = 4.0 * cfg.n_layers * b_dev * cfg.n_heads * 4.0
    return tp_ar_dec + seq_ar


@dataclasses.dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)


# Effective links per collective: a v5e chip has 4 ICI links; a ring
# collective keeps ~2 busy (send+recv per axis).
EFFECTIVE_LINKS = 2.0


def analytic_roofline(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshDesc,
                      hw: TPUSpec = TPU_V5E) -> Roofline:
    return Roofline(
        t_compute=model_flops(cfg, shape) / mesh.n_chips / hw.peak_flops,
        t_memory=model_hbm_bytes(cfg, shape, mesh) / hw.hbm_bw,
        t_collective=model_collective_bytes(cfg, shape, mesh)
        / (EFFECTIVE_LINKS * hw.ici_bw),
    )


def hlo_roofline(exact: dict, hw: TPUSpec = TPU_V5E) -> Roofline:
    """Roofline terms from the dry-run's parsed HLO (per-device numbers)."""
    return Roofline(
        t_compute=exact["flops"] / hw.peak_flops,
        t_memory=exact.get("mem_bytes", 0.0) / hw.hbm_bw,
        t_collective=exact["coll_total"] / (EFFECTIVE_LINKS * hw.ici_bw),
    )
