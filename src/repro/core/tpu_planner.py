"""DNNExplorer's two-level DSE retargeted to TPU meshes (beyond-paper).

Global optimization (Sec. 7.2 analogue): search the resource-allocation
vector — here (n_chips, dp x tp factorization, microbatches, remat) — with
the analytic roofline model (tpu_model) as the fitness, subject to the
HBM-capacity constraint. The FPGA version searches DSP/BRAM/BW splits with
PSO because the space is ~10^6 points; the TPU mapping space is small
enough (<=200 points) to enumerate exhaustively, which is the same global
step with a degenerate optimizer — PSO remains available via
``use_pso=True`` for extended spaces.

Local optimization (Sec. 7.3 analogue): per plan, pick the remat policy and
microbatch count that balance HBM fit against recompute FLOPs — the
balance-oriented step (Algorithm 3) with HBM in the role of BRAM.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, ShapeSpec
from .hw_specs import TPU_V5E, TPUSpec
from .tpu_model import (MeshDesc, Roofline, analytic_roofline,
                        kv_cache_bytes, useful_flops)


@dataclasses.dataclass
class Plan:
    arch: str
    shape: str
    n_chips: int
    dp: int
    tp: int
    microbatches: int
    remat: str
    roofline: Roofline
    hbm_per_chip: float
    fits: bool
    predicted_step_s: float
    mfu: float

    def pretty(self) -> str:
        r = self.roofline
        return (f"{self.arch}/{self.shape}: chips={self.n_chips} "
                f"dp={self.dp} tp={self.tp} mb={self.microbatches} "
                f"remat={self.remat} step={self.predicted_step_s:.3g}s "
                f"mfu={self.mfu:.2f} bound={r.bound} "
                f"hbm={self.hbm_per_chip / 2**30:.1f}GiB fits={self.fits}")


def hbm_per_chip(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshDesc,
                 remat: str, microbatches: int) -> float:
    """Static HBM demand: param + optimizer shards, activations, cache."""
    p = cfg.param_count()
    static = p * (4.0 + 8.0) / mesh.n_chips if shape.kind == "train" \
        else p * 2.0 / mesh.n_chips
    act = 0.0
    if shape.kind != "decode":
        tokens_dev = shape.global_batch * shape.seq_len / mesh.dp / microbatches
        per_layer = tokens_dev * cfg.d_model * 2.0 / max(mesh.tp // 4, 1)
        layers_live = cfg.n_layers if remat == "none" else (
            math.sqrt(cfg.n_layers) if remat == "dots" else 1.0)
        act = per_layer * max(layers_live, 1.0) * (4.0 if remat == "none" else 8.0)
    cache = kv_cache_bytes(cfg, shape) / mesh.n_chips if shape.kind == "decode" else 0.0
    return static + act + cache


def factorizations(chips: int):
    """All power-of-two (dp, tp) splits of a chip count, tp ascending.
    The chip count itself must be a positive power of two — anything else
    would silently yield splits with dp * tp != chips."""
    if chips <= 0 or chips & (chips - 1):
        raise ValueError(f"chips must be a positive power of two, "
                         f"got {chips}")
    tp = 1
    while tp <= chips:
        yield chips // tp, tp
        tp *= 2


def candidate_meshes(max_chips: int = 256):
    chips = 8
    while chips <= max_chips:
        for dp, tp in factorizations(chips):
            yield chips, dp, tp
        chips *= 2


def evaluate_point(cfg: ArchConfig, shape: ShapeSpec, chips: int, dp: int,
                   tp: int, remat: str, microbatches: int,
                   hw: TPUSpec = TPU_V5E, calibration=None) -> Plan:
    """Score ONE (mesh x remat x microbatch) mapping with the analytic
    roofline — the single-design evaluation both :func:`plan_arch` and the
    ``repro.dse`` TPU campaign backend loop over.

    ``calibration`` (a :class:`repro.calib.Calibration`, duck-typed via
    ``for_spec``) rescales ``hw`` to measured delivered rates before any
    model math; ``None`` — the default — evaluates against the datasheet
    spec exactly as before."""
    if calibration is not None:
        hw = calibration.for_spec(hw)
    mesh = MeshDesc(chips, dp, tp)
    rl = analytic_roofline(cfg, shape, mesh, hw)
    if remat != "full" and shape.kind == "train":
        # less recompute: scale the compute term 8ND -> 6ND
        rl = Roofline(rl.t_compute * 0.75, rl.t_memory, rl.t_collective)
    hbm = hbm_per_chip(cfg, shape, mesh, remat, microbatches)
    fits = hbm <= hw.hbm_bytes * 0.9
    step = rl.step_time
    # MFU numerator excludes recompute FLOPs (see tpu_model.useful_flops):
    # full-remat compute-bound designs top out at 0.75, not 1.0.
    useful = useful_flops(cfg, shape) / chips / hw.peak_flops
    mfu = min(useful / step, 1.0) if step else 0.0
    return Plan(cfg.name, shape.name, chips, dp, tp, microbatches, remat,
                rl, hbm, fits, step, mfu)


def plan_arch(cfg: ArchConfig, shape: ShapeSpec, hw: TPUSpec = TPU_V5E,
              max_chips: int = 256, objective: str = "throughput_per_chip"):
    """Enumerate the mesh/remat/microbatch space; return plans sorted by
    the objective (feasible first)."""
    plans: list[Plan] = []
    for chips, dp, tp in candidate_meshes(max_chips):
        if shape.global_batch % dp:
            continue
        for remat in (("full", "dots", "none") if shape.kind == "train"
                      else ("none",)):
            for mb in (1, 2, 4, 8):
                if shape.kind != "train" and mb > 1:
                    continue
                plans.append(evaluate_point(cfg, shape, chips, dp, tp,
                                            remat, mb, hw))
    key = {
        "throughput_per_chip": lambda p: (-p.fits, p.predicted_step_s * p.n_chips),
        "latency": lambda p: (-p.fits, p.predicted_step_s),
        "mfu": lambda p: (-p.fits, -p.mfu),
    }[objective]
    plans.sort(key=key)
    return plans


def best_plan(cfg: ArchConfig, shape: ShapeSpec, **kw) -> Plan:
    return plan_arch(cfg, shape, **kw)[0]
