"""Packed per-layer arrays: the layer-axis data of the batched engine.

:func:`pack_layers` lowers a :class:`~repro.core.netinfo.NetInfo` at one
precision into a single NumPy struct — geometry, MAC counts, and
external-memory byte demands per layer — plus the index tables the
batched evaluator (:mod:`repro.core.batch_eval`) needs to slice any
split point out of it without touching a ``LayerInfo`` object again:

* the **full layer axis** (pools included) backs the generic-structure
  kernels: the generic segment for split point ``sp`` is the contiguous
  suffix ``layers[seg_start[sp]:]`` (pools trailing major layers
  ``<= sp`` are fused into their pipeline stage, exactly
  ``local_opt._segment_after``), and ``c_sufmax``/``k_sufmax`` give that
  suffix's channel maxima in O(1);
* the **major-layer axis** (plain Python ints, not arrays — the pipeline
  loops are short and sequential, where int math beats NumPy dispatch)
  backs the fast pipeline-structure evaluation: per-stage MACs, channel
  dims, kernel areas, the constant column-buffer BRAM demand, and weight
  prefix sums for the stream-bytes roofline.

Packing is cached per ``(net, dw, ww)`` — ``NetInfo`` is frozen and
hashable — so a campaign cell pays the lowering once and every PSO
particle after that reads arrays.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .netinfo import LayerInfo, NetInfo
from .pipeline_model import stage_col_ceil


@dataclasses.dataclass(frozen=True, eq=False)
class PackedLayers:
    """One network at one precision, lowered to arrays (see module doc).

    ``eq=False`` keeps identity hashing: :func:`pack_layers` caching
    guarantees one instance per ``(net, dw, ww)``, so downstream caches
    (the per-split cycle tables in ``batch_eval``) can key on it.
    """

    net: NetInfo
    dw: int
    ww: int
    # -- full layer axis, int64 arrays of shape (L,) ------------------------
    h: np.ndarray
    w: np.ndarray
    c: np.ndarray
    k: np.ndarray
    r: np.ndarray
    s: np.ndarray
    groups: np.ndarray
    is_pool: np.ndarray      # bool
    is_dw: np.ndarray        # bool: depthwise conv
    macs: np.ndarray
    ifm_bytes: np.ndarray
    ofm_bytes: np.ndarray
    weight_bytes: np.ndarray
    # -- split-point index tables -------------------------------------------
    seg_start: np.ndarray    # (n_major+1,): generic segment = layers[seg_start[sp]:]
    c_sufmax: np.ndarray     # (L+1,): max(c) over layers[i:] (0 at i == L)
    k_sufmax: np.ndarray
    # -- major-layer axis (pipeline half), plain ints -----------------------
    majors: tuple[LayerInfo, ...]
    m_macs: tuple[int, ...]
    m_c: tuple[int, ...]
    m_k: tuple[int, ...]
    m_rs: tuple[int, ...]        # kernel area R*S per stage
    m_col_ceil: tuple[int, ...]  # column-buffer BRAM blocks per stage
    m_wsum: tuple[int, ...]      # prefix weight bytes: m_wsum[i] = sum majors[:i]
    ifm0: int                    # input-frame bytes of the first major layer
    total_ops: int
    # Per-split derived tables (batch_eval's pf-ladder/cycle tensors) live
    # ON the instance so they are evicted together with it, never pinned
    # past the pack_layers cache. Mutable contents on a frozen dataclass
    # are fine: the field itself is never reassigned.
    derived: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_layers(self) -> int:
        return len(self.net.layers)

    @property
    def n_major(self) -> int:
        return len(self.majors)

    def segment(self, sp: int) -> tuple[int, int, int]:
        """Generic-segment view for split point ``sp``:
        ``(start_index, c_max, k_max)`` — the suffix ``layers[start:]``
        and its channel maxima (both 0 when the segment is empty)."""
        start = int(self.seg_start[sp])
        return start, int(self.c_sufmax[start]), int(self.k_sufmax[start])


@functools.lru_cache(maxsize=128)
def pack_layers(net: NetInfo, dw: int = 16, ww: int = 16) -> PackedLayers:
    """Lower ``net`` at precision ``(dw, ww)`` into a :class:`PackedLayers`.

    All byte/MAC columns are produced by the same ``LayerInfo`` methods
    the scalar models call, so the packed values cannot diverge from the
    reference path; this runs once per (net, precision) and is cached.
    """
    layers = net.layers
    col = lambda f: np.array([f(l) for l in layers], dtype=np.int64)
    majors = net.major_layers
    m_idx = net.major_indices
    n_l, n_m = len(layers), len(majors)

    seg_start = np.array([m_idx[sp] if sp < n_m else n_l
                          for sp in range(n_m + 1)], dtype=np.int64)
    c_arr, k_arr = col(lambda l: l.c), col(lambda l: l.k)
    c_sufmax = np.zeros(n_l + 1, dtype=np.int64)
    k_sufmax = np.zeros(n_l + 1, dtype=np.int64)
    if n_l:
        c_sufmax[:n_l] = np.maximum.accumulate(c_arr[::-1])[::-1]
        k_sufmax[:n_l] = np.maximum.accumulate(k_arr[::-1])[::-1]

    wsum = [0]
    for l in majors:
        wsum.append(wsum[-1] + l.weight_bytes(ww))

    return PackedLayers(
        net=net, dw=dw, ww=ww,
        h=col(lambda l: l.h), w=col(lambda l: l.w), c=c_arr, k=k_arr,
        r=col(lambda l: l.r), s=col(lambda l: l.s),
        groups=col(lambda l: l.groups),
        is_pool=np.array([l.kind == "pool" for l in layers]),
        is_dw=np.array([l.kind == "dwconv" for l in layers]),
        macs=col(lambda l: l.macs),
        ifm_bytes=col(lambda l: l.ifm_bytes(dw)),
        ofm_bytes=col(lambda l: l.ofm_bytes(dw)),
        weight_bytes=col(lambda l: l.weight_bytes(ww)),
        seg_start=seg_start, c_sufmax=c_sufmax, k_sufmax=k_sufmax,
        majors=majors,
        m_macs=tuple(l.macs for l in majors),
        m_c=tuple(l.c for l in majors),
        m_k=tuple(l.k for l in majors),
        m_rs=tuple(l.r * l.s for l in majors),
        m_col_ceil=tuple(stage_col_ceil(l, dw) for l in majors),
        m_wsum=tuple(wsum),
        ifm0=majors[0].ifm_bytes(dw) if majors else 0,
        total_ops=net.total_ops,
    )
