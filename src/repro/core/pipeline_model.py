"""Analytical model of the *pipeline structure* (paper Sec. 6.1).

One dedicated stage per layer ``1..SP`` with two-dim parallelism
``(CPF_i, KPF_i)``; fine-grained (column-based) pipelining from DNNBuilder.

Latency (Eq. 3):   L_i = H*W*R*S*C*K / (CPF_i * KPF_i * FREQ)
Throughput (Eq. 4): Batch / max(L_i over a batch)

Batching: stages stream Batch frames back-to-back, so compute time scales
with Batch while the weight stream is fetched once per batch (DNNBuilder's
weight-bandwidth amortization — this is what makes Table 4's small-input
cases jump 4.6x at Batch=8: at 32x32 the weights dominate traffic and
Batch=1 is bandwidth-bound at 42% DSP efficiency).
"""
from __future__ import annotations

import dataclasses
import math

from .hw_specs import alpha_for
from .netinfo import LayerInfo

BRAM_BITS = 18 * 1024


def _pow2_floor(x: float) -> int:
    return 1 << max(0, int(math.floor(math.log2(max(x, 1)))))


def stage_dsp(pf: int, alpha: int) -> int:
    """DSPs for ``pf`` MACs/cycle at ``alpha`` MAC-ops per DSP (Eq. 1).
    Shared with :mod:`repro.core.batch_eval` so both paths use one formula."""
    return max(1, (2 * pf) // alpha)


def stage_col_ceil(l: LayerInfo, dw: int) -> int:
    """BRAM blocks demanded by a stage's column/row line buffer alone."""
    col_bits = l.c * l.h * l.stride * (l.s + 1) * dw
    return math.ceil(col_bits / BRAM_BITS)


def stage_bram(cpf: int, kpf: int, dw: int, ww: int, col_ceil: int,
               rs: int) -> int:
    """Column/row buffer + ping-pong weight buffer (Sec. 5.2.2); ``rs`` is
    the stage's kernel area R*S, ``col_ceil`` its :func:`stage_col_ceil`.
    BRAM ports are <=36b wide: a CPF-wide parallel read needs that many
    physical blocks even if shallow."""
    w_bits = 2 * rs * cpf * kpf * ww
    min_banks = max(1, math.ceil(cpf * dw / 36))
    return max(min_banks, col_ceil) + max(1, math.ceil(w_bits / BRAM_BITS))


def split_pf(pf: int, c: int, k: int) -> tuple[int, int]:
    """Factor a parallelism budget into (CPF, KPF), both powers of two,
    CPF<=C, KPF<=K; near-square split balances PE broadcast fan-out
    against accumulation fan-in."""
    pf = max(1, _pow2_floor(pf))
    cpf = min(_pow2_floor(math.sqrt(pf)), _pow2_floor(c))
    kpf = min(pf // cpf, _pow2_floor(k))
    cpf = min(pf // kpf, _pow2_floor(c))  # regrow CPF if KPF clipped by K
    return max(1, cpf), max(1, kpf)


@dataclasses.dataclass(frozen=True)
class StageDesign:
    layer: LayerInfo
    cpf: int
    kpf: int
    dw: int  # activation bits
    ww: int  # weight bits

    @property
    def pf(self) -> int:
        return self.cpf * self.kpf

    def comp_latency(self, freq: float) -> float:
        """Eq. 3 — cycles = MACs / (CPF*KPF), one frame."""
        return self.layer.macs / (self.pf * freq)

    def dsp(self) -> int:
        """DSPs for CPF*KPF MACs/cycle; 8-bit packs two MACs per DSP."""
        return stage_dsp(self.pf, alpha_for(min(self.dw, self.ww)))

    def bram(self) -> int:
        """Column/row buffer + ping-pong weight buffer (Sec. 5.2.2)."""
        l = self.layer
        return stage_bram(self.cpf, self.kpf, self.dw, self.ww,
                          stage_col_ceil(l, self.dw), l.r * l.s)


@dataclasses.dataclass
class PipelineDesign:
    stages: list[StageDesign]
    batch: int = 1

    def max_comp_latency(self, freq: float) -> float:
        return max((s.comp_latency(freq) for s in self.stages), default=0.0)

    def stream_bytes(self) -> float:
        """External traffic per batch: all stage weights once + Batch input frames."""
        if not self.stages:
            return 0.0
        w = sum(s.layer.weight_bytes(s.ww) for s in self.stages)
        ifm = self.stages[0].layer.ifm_bytes(self.stages[0].dw)
        return w + self.batch * ifm

    def batch_latency(self, freq: float, bw_bytes: float) -> float:
        """Steady-state time per batch = max(compute roofline, memory roofline)."""
        if not self.stages:
            return 0.0
        l_comp = self.batch * self.max_comp_latency(freq)
        l_mem = self.stream_bytes() / bw_bytes if bw_bytes > 0 else float("inf")
        return max(l_comp, l_mem)

    def throughput_ips(self, freq: float, bw_bytes: float) -> float:
        """Eq. 4 — frames/s."""
        if not self.stages:
            return float("inf")
        lat = self.batch_latency(freq, bw_bytes)
        return self.batch / lat if lat > 0 else 0.0

    def dsp(self) -> int:
        return sum(s.dsp() for s in self.stages)

    def bram(self) -> int:
        return sum(s.bram() for s in self.stages)


def ctc_allocate(layers: list[LayerInfo], bw_bytes: float, freq: float,
                 dw: int, ww: int) -> list[int]:
    """Algorithm 2 lines 4-6: CTC-based parallelism allocation.

    Gives every stage the same latency  T = total_bytes / BW_p  (perfect
    bandwidth match): PF_i = OP_i * BW_p / BW_total_norm / FREQ, with
    BW_total_norm = sum_j OP_j / CTC_j (= total weight-stream bytes)."""
    bw_norm_total = sum(l.weight_bytes(ww) for l in layers)
    if bw_norm_total == 0 or bw_bytes <= 0:
        return [1] * len(layers)
    pfs = []
    for l in layers:
        pf = l.macs * bw_bytes / bw_norm_total / freq
        pfs.append(max(1, _pow2_floor(pf)))
    return pfs


def scale_down(design: PipelineDesign) -> PipelineDesign:
    """Algorithm 2 line 9 / Algorithm 3 line 13: PF_i = max(1, PF_i/2)."""
    stages = [StageDesign(s.layer, *split_pf(max(1, s.pf // 2), s.layer.c, s.layer.k),
                          s.dw, s.ww) for s in design.stages]
    return PipelineDesign(stages, design.batch)


def design_pipeline(layers: list[LayerInfo], dsp_cap: int, bram_cap: int,
                    bw_bytes: float, freq: float, dw: int, ww: int,
                    batch: int = 1) -> PipelineDesign:
    """Algorithm 2: allocate PFs by CTC, then halve until resources fit."""
    pfs = ctc_allocate(layers, bw_bytes, freq, dw, ww)
    stages = [StageDesign(l, *split_pf(pf, l.c, l.k), dw, ww)
              for l, pf in zip(layers, pfs)]
    design = PipelineDesign(stages, batch)
    while design.stages and (design.dsp() > dsp_cap or design.bram() > bram_cap):
        if all(s.pf == 1 for s in design.stages):
            break
        design = scale_down(design)

    # Refinement: the pow2 floor can leave the bottleneck stage up to 2x
    # slower than its CTC-ideal latency; greedily double the slowest stage's
    # PF while resources allow (DNNBuilder's fine-grained allocation).
    while design.stages:
        i = max(range(len(design.stages)),
                key=lambda j: design.stages[j].comp_latency(freq))
        s = design.stages[i]
        if s.pf >= s.layer.c * s.layer.k:
            break
        bumped = StageDesign(s.layer, *split_pf(s.pf * 2, s.layer.c, s.layer.k),
                             dw, ww)
        if bumped.pf <= s.pf:
            break
        trial = PipelineDesign(design.stages[:i] + [bumped] + design.stages[i + 1:],
                               batch)
        if trial.dsp() > dsp_cap or trial.bram() > bram_cap:
            break
        design = trial
    return design
