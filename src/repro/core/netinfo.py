"""Model/HW Analysis (step 1 of the DNNExplorer design flow).

Extracts per-layer information from a DNN description: layer type and
configuration, computation (ops) and memory (bytes) demands, and the
computation-to-communication (CTC) ratio the whole paper keys on.

Conventions
-----------
* 1 MAC = 2 ops; ``ops`` counts ops (so GOP/s figures match the paper).
* ``*_bytes`` are *external-memory* traffic for one inference at the given
  data/weight bit-widths (weights + input fm + output fm), the denominator
  of the CTC ratio (Fig. 1).
* Feature maps are NCHW; convs are 'same'-padded unless a stride is given.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# ---------------------------------------------------------------------------
# Layer description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerInfo:
    """One *major* layer (CONV / FC / POOL / DWCONV); BN/activation are fused."""

    name: str
    kind: str  # conv | dwconv | fc | pool
    h: int  # output height
    w: int  # output width
    c: int  # input channels
    k: int  # output channels
    r: int = 1  # kernel height
    s: int = 1  # kernel width
    stride: int = 1
    groups: int = 1

    # -- computation -------------------------------------------------------
    @property
    def macs(self) -> int:
        if self.kind == "pool":
            return 0
        return self.h * self.w * self.r * self.s * (self.c // self.groups) * self.k

    @property
    def ops(self) -> int:
        return 2 * self.macs

    # -- memory ------------------------------------------------------------
    def weight_bytes(self, ww_bits: int = 16) -> int:
        if self.kind == "pool":
            return 0
        n = self.r * self.s * (self.c // self.groups) * self.k
        return (n * ww_bits) // 8

    def ifm_bytes(self, dw_bits: int = 16) -> int:
        ih, iw = self.h * self.stride, self.w * self.stride
        return (ih * iw * self.c * dw_bits) // 8

    def ofm_bytes(self, dw_bits: int = 16) -> int:
        return (self.h * self.w * self.k * dw_bits) // 8

    def total_bytes(self, dw_bits: int = 16, ww_bits: int = 16) -> int:
        return self.weight_bytes(ww_bits) + self.ifm_bytes(dw_bits) + self.ofm_bytes(dw_bits)

    def ctc(self, dw_bits: int = 16, ww_bits: int = 16) -> float:
        """Computation-to-communication ratio (the paper's *computation
        reuse factor*, Alg. 2 line 3): ops per byte of weights fetched.

        In the DNNBuilder-style dataflow feature maps stream on-chip between
        stages, so external traffic is the weight stream — this is why the
        paper's Fig. 1 CTC medians scale exactly with input area (256x from
        32x32 to 512x512: ops scale with H*W, weights are constant)."""
        b = self.weight_bytes(ww_bits)
        return self.ops / b if b else 0.0


@dataclasses.dataclass(frozen=True)
class NetInfo:
    name: str
    input_hw: tuple[int, int]
    input_c: int
    layers: tuple[LayerInfo, ...]

    @property
    def major_layers(self) -> tuple[LayerInfo, ...]:
        """Layers that get pipeline stages / generic passes (convs + fc)."""
        return tuple(l for l in self.layers if l.kind != "pool")

    @property
    def major_indices(self) -> tuple[int, ...]:
        """Index into ``layers`` of each major layer. The generic segment
        for split point ``sp`` is exactly ``layers[major_indices[sp]:]``
        (pools trailing major layers <= sp are fused into their stage) —
        :mod:`repro.core.layer_arrays` keys its packed segments on this."""
        return tuple(i for i, l in enumerate(self.layers) if l.kind != "pool")

    @property
    def total_ops(self) -> int:
        return sum(l.ops for l in self.layers)

    def ctc_list(self, dw: int = 16, ww: int = 16) -> list[float]:
        return [l.ctc(dw, ww) for l in self.major_layers]

    def half_variance_ratio(self, dw: int = 16, ww: int = 16) -> float:
        """Table 1: CTC variance of the first half (50% of MACs) over the second."""
        layers = self.major_layers
        total = sum(l.macs for l in layers)
        acc, split = 0, len(layers)
        for i, l in enumerate(layers):
            acc += l.macs
            if acc >= total / 2:
                split = i + 1
                break
        first = [l.ctc(dw, ww) for l in layers[:split]]
        second = [l.ctc(dw, ww) for l in layers[split:]]

        def var(xs: list[float]) -> float:
            if not xs:
                return 0.0
            m = sum(xs) / len(xs)
            return sum((x - m) ** 2 for x in xs) / len(xs)

        v1, v2 = var(first), var(second)
        return v1 / v2 if v2 else float("inf")


# ---------------------------------------------------------------------------
# Builder: tracks fm size while appending layers
# ---------------------------------------------------------------------------


class _B:
    def __init__(self, name: str, h: int, w: int, c: int):
        self.name, self.h, self.w, self.c = name, h, w, c
        self.layers: list[LayerInfo] = []
        self._n = 0
        self._ih, self._iw, self._ic = h, w, c

    def conv(self, k: int, r: int, s: int | None = None, stride: int = 1, groups: int = 1):
        s = r if s is None else s
        oh, ow = -(-self.h // stride), -(-self.w // stride)
        self._n += 1
        self.layers.append(
            LayerInfo(f"conv{self._n}", "conv" if groups == 1 else "dwconv",
                      oh, ow, self.c, k, r, s, stride, groups))
        self.h, self.w, self.c = oh, ow, k
        return self

    def dwconv(self, r: int, stride: int = 1):
        """Depthwise conv: groups == channels."""
        oh, ow = -(-self.h // stride), -(-self.w // stride)
        self._n += 1
        self.layers.append(
            LayerInfo(f"dw{self._n}", "dwconv", oh, ow, self.c, self.c, r, r, stride, self.c))
        self.h, self.w = oh, ow
        return self

    def pool(self, r: int = 2, stride: int | None = None):
        stride = r if stride is None else stride
        oh, ow = self.h // stride, self.w // stride
        self._n += 1
        self.layers.append(LayerInfo(f"pool{self._n}", "pool", oh, ow, self.c, self.c, r, r, stride))
        self.h, self.w = oh, ow
        return self

    def gap(self):
        self._n += 1
        self.layers.append(LayerInfo(f"gap{self._n}", "pool", 1, 1, self.c, self.c, self.h, self.w, 1))
        self.h = self.w = 1
        return self

    def fc(self, k: int):
        self._n += 1
        cin = self.h * self.w * self.c
        self.layers.append(LayerInfo(f"fc{self._n}", "fc", 1, 1, cin, k))
        self.h = self.w = 1
        self.c = k
        return self

    def done(self) -> NetInfo:
        return NetInfo(self.name, (self._ih, self._iw), self._ic, tuple(self.layers))


# ---------------------------------------------------------------------------
# The paper's workloads
# ---------------------------------------------------------------------------


def vgg16(h: int = 224, w: int | None = None, with_fc: bool = False,
          extra_per_group: int = 0) -> NetInfo:
    """VGG-16 (conv part). ``extra_per_group`` adds N convs to each of the 5
    groups — the paper's 18/28/38-layer VGG-like DNNs (Sec. 8.2)."""
    w = h if w is None else w
    n_layers = 13 + 5 * extra_per_group
    b = _B(f"vgg{n_layers}_{h}x{w}", h, w, 3)
    for k, reps in [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]:
        for _ in range(reps + extra_per_group):
            b.conv(k, 3)
        b.pool(2)
    if with_fc:
        b.fc(4096).fc(4096).fc(1000)
    return b.done()


def vgg19(h: int = 224, w: int | None = None, with_fc: bool = True) -> NetInfo:
    w = h if w is None else w
    b = _B(f"vgg19_{h}x{w}", h, w, 3)
    for k, reps in [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)]:
        for _ in range(reps):
            b.conv(k, 3)
        b.pool(2)
    if with_fc:
        b.fc(4096).fc(4096).fc(1000)
    return b.done()


def alexnet() -> NetInfo:
    b = _B("alexnet", 227, 227, 3)
    b.conv(96, 11, stride=4).pool(3, 2)
    b.conv(256, 5).pool(3, 2)
    b.conv(384, 3).conv(384, 3).conv(256, 3).pool(3, 2)
    b.fc(4096).fc(4096).fc(1000)
    return b.done()


def _inception_a(b: _B, n1: int, n3r: int, n3: int, n5r: int, n5: int, pp: int):
    """GoogLeNet inception module: four parallel branches, concatenated.

    Modelled as sequential layers sharing the same input fm (CTC analysis
    only cares about per-layer shapes, not the dataflow graph)."""
    h, w, c = b.h, b.w, b.c
    outs = []
    for cin, k, r in [(c, n1, 1), (c, n3r, 1), (n3r, n3, 3), (c, n5r, 1), (n5r, n5, 5), (c, pp, 1)]:
        if k == 0:
            continue
        b._n += 1
        b.layers.append(LayerInfo(f"conv{b._n}", "conv", h, w, cin, k, r, r, 1))
        outs.append(k)
    b.c = n1 + n3 + n5 + pp


def googlenet() -> NetInfo:
    b = _B("googlenet", 224, 224, 3)
    b.conv(64, 7, stride=2).pool(3, 2).conv(64, 1).conv(192, 3).pool(3, 2)
    _inception_a(b, 64, 96, 128, 16, 32, 32)
    _inception_a(b, 128, 128, 192, 32, 96, 64)
    b.pool(3, 2)
    _inception_a(b, 192, 96, 208, 16, 48, 64)
    _inception_a(b, 160, 112, 224, 24, 64, 64)
    _inception_a(b, 128, 128, 256, 24, 64, 64)
    _inception_a(b, 112, 144, 288, 32, 64, 64)
    _inception_a(b, 256, 160, 320, 32, 128, 128)
    b.pool(3, 2)
    _inception_a(b, 256, 160, 320, 32, 128, 128)
    _inception_a(b, 384, 192, 384, 48, 128, 128)
    b.gap().fc(1000)
    return b.done()


def inception_v3() -> NetInfo:
    """InceptionV3 approximated with the standard published stem + 11 mixed
    blocks (branch convs flattened, factorized 7x1/1x7 kept)."""
    b = _B("inceptionv3", 299, 299, 3)
    b.conv(32, 3, stride=2).conv(32, 3).conv(64, 3).pool(3, 2)
    b.conv(80, 1).conv(192, 3).pool(3, 2)
    for pp in (32, 64, 64):  # 3x Mixed5 (35x35)
        _inception_a(b, 64, 48, 64, 64, 96, pp)
    b.pool(3, 2)  # grid reduction (approx)
    for _ in range(4):  # 4x Mixed6 (17x17), 7x7 factorized -> 7x1 + 1x7
        h, w, c = b.h, b.w, b.c
        for cin, k, r, s in [(c, 192, 1, 1), (c, 160, 1, 1), (160, 160, 1, 7),
                             (160, 192, 7, 1), (c, 160, 1, 1), (160, 160, 7, 1),
                             (160, 160, 1, 7), (160, 160, 7, 1), (160, 192, 1, 7),
                             (c, 192, 1, 1)]:
            b._n += 1
            b.layers.append(LayerInfo(f"conv{b._n}", "conv", h, w, cin, k, r, s, 1))
        b.c = 768
    b.pool(3, 2)
    for _ in range(2):  # 2x Mixed7 (8x8)
        h, w, c = b.h, b.w, b.c
        for cin, k, r, s in [(c, 320, 1, 1), (c, 384, 1, 1), (384, 384, 1, 3),
                             (384, 384, 3, 1), (c, 448, 1, 1), (448, 384, 3, 3),
                             (384, 384, 1, 3), (384, 384, 3, 1), (c, 192, 1, 1)]:
            b._n += 1
            b.layers.append(LayerInfo(f"conv{b._n}", "conv", h, w, cin, k, r, s, 1))
        b.c = 2048
    b.gap().fc(1000)
    return b.done()


def _res_basic(b: _B, k: int, stride: int = 1):
    b.conv(k, 3, stride=stride).conv(k, 3)
    if stride != 1:
        pass  # projection shortcut folded into the main convs for analysis


def _res_bottleneck(b: _B, k: int, stride: int = 1):
    b.conv(k, 1, stride=stride).conv(k, 3).conv(4 * k, 1)


def resnet18() -> NetInfo:
    b = _B("resnet18", 224, 224, 3)
    b.conv(64, 7, stride=2).pool(3, 2)
    for k, reps, s in [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)]:
        _res_basic(b, k, s)
        for _ in range(reps - 1):
            _res_basic(b, k)
    b.gap().fc(1000)
    return b.done()


def resnet50() -> NetInfo:
    b = _B("resnet50", 224, 224, 3)
    b.conv(64, 7, stride=2).pool(3, 2)
    for k, reps, s in [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]:
        _res_bottleneck(b, k, s)
        for _ in range(reps - 1):
            _res_bottleneck(b, k)
    b.gap().fc(1000)
    return b.done()


def squeezenet() -> NetInfo:
    b = _B("squeezenet", 227, 227, 3)
    b.conv(96, 7, stride=2).pool(3, 2)
    fires = [(16, 64), (16, 64), (32, 128)]
    for s1, e in fires:
        b.conv(s1, 1).conv(e, 1).conv(e, 3)  # squeeze + expand1x1 + expand3x3
        b.c = 2 * e
    b.pool(3, 2)
    for s1, e in [(32, 128), (48, 192), (48, 192), (64, 256)]:
        b.conv(s1, 1).conv(e, 1).conv(e, 3)
        b.c = 2 * e
    b.pool(3, 2)
    b.conv(64, 1).conv(256, 1).conv(256, 3)
    b.c = 512
    b.conv(1000, 1).gap()
    return b.done()


def mobilenet() -> NetInfo:
    b = _B("mobilenet", 224, 224, 3)
    b.conv(32, 3, stride=2)
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)] + \
        [(512, 1)] * 5 + [(1024, 2), (1024, 1)]
    for k, s in plan:
        b.dwconv(3, stride=s).conv(k, 1)
    b.gap().fc(1000)
    return b.done()


def mobilenet_v2() -> NetInfo:
    b = _B("mobilenetv2", 224, 224, 3)
    b.conv(32, 3, stride=2)
    # (expansion t, out c, repeats, stride)
    plan = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, k, reps, s in plan:
        for i in range(reps):
            cin = b.c
            if t != 1:
                b.conv(cin * t, 1)
            b.dwconv(3, stride=s if i == 0 else 1)
            b.conv(k, 1)
    b.conv(1280, 1).gap().fc(1000)
    return b.done()


def yolo() -> NetInfo:
    """YOLOv1-tiny-like backbone used in the pipeline-model validation (Fig. 7)."""
    b = _B("yolo", 448, 448, 3)
    for k in (16, 32, 64, 128, 256, 512):
        b.conv(k, 3).pool(2)
    b.conv(1024, 3).conv(1024, 3).conv(1024, 3)
    return b.done()


def zfnet() -> NetInfo:
    b = _B("zf", 224, 224, 3)
    b.conv(96, 7, stride=2).pool(3, 2)
    b.conv(256, 5, stride=2).pool(3, 2)
    b.conv(384, 3).conv(384, 3).conv(256, 3).pool(3, 2)
    b.fc(4096).fc(4096).fc(1000)
    return b.done()


TABLE1_NETS: dict[str, Callable[[], NetInfo]] = {
    "alexnet": alexnet,
    "googlenet": googlenet,
    "inceptionv3": inception_v3,
    "vgg16": lambda: vgg16(224, with_fc=True),
    "vgg19": vgg19,
    "resnet18": resnet18,
    "resnet50": resnet50,
    "squeezenet": squeezenet,
    "mobilenet": mobilenet,
    "mobilenetv2": mobilenet_v2,
}

# The 12 input-resolution cases of Figs. 1/9/10 and Table 3.
INPUT_CASES: tuple[tuple[int, int], ...] = (
    (32, 32), (64, 64), (128, 128), (224, 224), (320, 320), (384, 384),
    (320, 480), (448, 448), (512, 512), (480, 800), (512, 1382), (720, 1280),
)
