"""Hardware descriptions for the two targets DNNExplorer runs against.

* ``FPGASpec`` — the paper's own domain (Xilinx parts; resource units match
  the paper: DSP48 slices, 18-Kb BRAM blocks, external-memory GB/s).
* ``TPUSpec`` — the retarget domain for the JAX runtime (per-chip peak
  FLOP/s, HBM capacity/bandwidth, ICI link bandwidth), used by
  ``core/tpu_planner.py`` and the roofline analysis.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# FPGA (faithful reproduction domain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FPGASpec:
    name: str
    dsp: int            # DSP48 slices
    bram18k: int        # 18-Kb BRAM blocks
    bw_gbps: float      # external memory bandwidth, GB/s
    freq_mhz: float = 200.0
    # Place-and-route headroom: the paper's best designs use <=85% of DSPs
    # (Table 3 peaks at 4686 of 5520) — routing congestion caps utilization.
    usable_frac: float = 0.85

    @property
    def freq(self) -> float:
        return self.freq_mhz * 1e6

    @property
    def dsp_usable(self) -> int:
        return int(self.dsp * self.usable_frac)

    @property
    def bram_usable(self) -> int:
        return int(self.bram18k * self.usable_frac)

    @property
    def bram_bits(self) -> int:
        return self.bram18k * 18 * 1024

    def peak_gops(self, alpha: int = 2) -> float:
        """Peak throughput (GOP/s) per Eq. 1: alpha ops per DSP per cycle."""
        return alpha * self.dsp_usable * self.freq / 1e9


# Specs from Xilinx datasheets; BW = one effective DDR4-2400 channel per
# accelerator (calibrated so the batch=1 small-input cases of Table 3 are
# bandwidth-bound at the paper's measured throughput).
KU115 = FPGASpec("ku115", dsp=5520, bram18k=4320, bw_gbps=19.2)
ZC706 = FPGASpec("zc706", dsp=900, bram18k=1090, bw_gbps=12.8)    # DDR3-1600
VU9P = FPGASpec("vu9p", dsp=6840, bram18k=4320, bw_gbps=38.4)     # 2 channels
ZCU102 = FPGASpec("zcu102", dsp=2520, bram18k=1824, bw_gbps=19.2)

FPGAS = {f.name: f for f in (KU115, ZC706, VU9P, ZCU102)}


def alpha_for(bits: int) -> int:
    """MAC-ops per DSP per cycle (Eq. 1): 2 for 16-bit, 4 for 8-bit inputs."""
    if bits <= 8:
        return 4
    return 2


# ---------------------------------------------------------------------------
# TPU (retarget domain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops: float       # per-chip, bf16
    hbm_bytes: float        # per-chip capacity
    hbm_bw: float           # per-chip, bytes/s
    ici_bw: float           # per-link, bytes/s
    vmem_bytes: float = 128 * 2 ** 20
    # 2D torus: each chip has links on both mesh axes.
    links_per_chip: int = 4


TPU_V5E = TPUSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bytes=16 * 2 ** 30,
    hbm_bw=819e9,
    ici_bw=50e9,
)

TPUS = {TPU_V5E.name: TPU_V5E}
