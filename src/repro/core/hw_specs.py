"""Hardware descriptions for the three device families DNNExplorer's DSE
runs against.

* ``FPGASpec`` — the paper's own domain (Xilinx parts; resource units match
  the paper: DSP48 slices, 18-Kb BRAM blocks, external-memory GB/s).
* ``TPUSpec`` — the retarget domain for the JAX runtime (per-chip peak
  FLOP/s, HBM capacity/bandwidth, ICI link bandwidth), used by
  ``core/tpu_planner.py`` and the roofline analysis.
* ``GPUSpec`` — the CUDA retarget domain (per-GPU SM peak FLOP/s, HBM
  capacity/bandwidth, NVLink/InfiniBand interconnect), used by
  ``core/gpu_model.py`` / ``core/gpu_planner.py``.

Every family also carries a TDP and an hourly dollar proxy (cloud
on-demand list prices, board-power estimates for the FPGAs) so the
``repro.dse`` normalized objectives (throughput per watt / per dollar /
per peak TFLOP) can compare designs ACROSS families; the proxies are
deliberately coarse — they normalize frontiers, they don't bill anyone.
"""
from __future__ import annotations

import dataclasses


# ---------------------------------------------------------------------------
# Budget metadata (shared by repro.dse.placement)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostEnvelope:
    """A placement budget: joint caps on the hourly dollar proxy and on
    board power. ``None`` leaves that axis uncapped. The dollar/watt
    terms come from the per-part ``usd_per_hour``/``tdp_watts`` fields
    below, so a budget is expressed in the same (deliberately coarse)
    units the normalized objectives already use."""

    usd_per_hour: float | None = None
    watts: float | None = None

    #: Relative slack when testing a cost against a cap, so float sums
    #: that are *exactly* at budget don't flap infeasible.
    _REL_EPS = 1e-9

    def admits(self, usd_per_hour: float, watts: float) -> bool:
        """True iff a (dollars/hour, watts) total fits under both caps."""
        if self.usd_per_hour is not None and \
                usd_per_hour > self.usd_per_hour * (1 + self._REL_EPS):
            return False
        if self.watts is not None and \
                watts > self.watts * (1 + self._REL_EPS):
            return False
        return True

    def capped_axes(self) -> tuple[str, ...]:
        """The budgeted axis names, in (dollars, watts) order."""
        out = []
        if self.usd_per_hour is not None:
            out.append("usd_per_hour")
        if self.watts is not None:
            out.append("watts")
        return tuple(out)

    def describe(self) -> str:
        parts = []
        if self.usd_per_hour is not None:
            parts.append(f"${self.usd_per_hour:g}/h")
        if self.watts is not None:
            parts.append(f"{self.watts:g} W")
        return " and ".join(parts) if parts else "unbounded"


def pod_cost(spec, count: int = 1) -> tuple[float, float]:
    """(watts, usd_per_hour) of ``count`` instances of one part. Works for
    any spec class below — they all carry ``tdp_watts``/``usd_per_hour``
    — so placement costs FPGAs, TPU pods, and GPU pods the same way."""
    return count * spec.tdp_watts, count * spec.usd_per_hour


def scaled_spec(spec, compute_scale: float = 1.0, bw_scale: float = 1.0):
    """A copy of ``spec`` with its delivered compute rate and external
    memory bandwidth multiplied by measured correction factors — the hook
    :mod:`repro.calib` applies fitted corrections through. Family-aware:

    * ``FPGASpec`` — compute scales the clock (Eq. 1's ``freq`` term, the
      one knob that moves every pipeline/generic latency together),
      bandwidth scales ``bw_gbps``;
    * ``TPUSpec`` / ``GPUSpec`` — compute scales ``peak_flops``,
      bandwidth scales ``hbm_bw``.

    Identity scales return ``spec`` itself (not a copy), so uncalibrated
    paths stay byte-identical to passing the table spec directly."""
    if compute_scale == 1.0 and bw_scale == 1.0:
        return spec
    if isinstance(spec, FPGASpec):
        return dataclasses.replace(spec, freq_mhz=spec.freq_mhz * compute_scale,
                                   bw_gbps=spec.bw_gbps * bw_scale)
    if isinstance(spec, (TPUSpec, GPUSpec)):
        return dataclasses.replace(spec, peak_flops=spec.peak_flops * compute_scale,
                                   hbm_bw=spec.hbm_bw * bw_scale)
    raise TypeError(f"scaled_spec: unknown spec family {type(spec).__name__}")


# ---------------------------------------------------------------------------
# FPGA (faithful reproduction domain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FPGASpec:
    name: str
    dsp: int            # DSP48 slices
    bram18k: int        # 18-Kb BRAM blocks
    bw_gbps: float      # external memory bandwidth, GB/s
    freq_mhz: float = 200.0
    # Place-and-route headroom: the paper's best designs use <=85% of DSPs
    # (Table 3 peaks at 4686 of 5520) — routing congestion caps utilization.
    usable_frac: float = 0.85
    # Board power and hourly dollar proxy for the normalized objectives.
    tdp_watts: float = 75.0
    usd_per_hour: float = 1.0

    @property
    def freq(self) -> float:
        return self.freq_mhz * 1e6

    @property
    def dsp_usable(self) -> int:
        return int(self.dsp * self.usable_frac)

    @property
    def bram_usable(self) -> int:
        return int(self.bram18k * self.usable_frac)

    @property
    def bram_bits(self) -> int:
        return self.bram18k * 18 * 1024

    def peak_gops(self, alpha: int = 2) -> float:
        """Peak throughput (GOP/s) per Eq. 1: alpha ops per DSP per cycle."""
        return alpha * self.dsp_usable * self.freq / 1e9


# Specs from Xilinx datasheets; BW = one effective DDR4-2400 channel per
# accelerator (calibrated so the batch=1 small-input cases of Table 3 are
# bandwidth-bound at the paper's measured throughput). Power = typical
# board TDP; dollars = cloud FPGA proxy (VU9P anchors at the AWS F1 rate,
# the others scale by fabric size).
KU115 = FPGASpec("ku115", dsp=5520, bram18k=4320, bw_gbps=19.2,
                 tdp_watts=75.0, usd_per_hour=1.35)
ZC706 = FPGASpec("zc706", dsp=900, bram18k=1090, bw_gbps=12.8,    # DDR3-1600
                 tdp_watts=20.0, usd_per_hour=0.35)
VU9P = FPGASpec("vu9p", dsp=6840, bram18k=4320, bw_gbps=38.4,     # 2 channels
                tdp_watts=85.0, usd_per_hour=1.65)
ZCU102 = FPGASpec("zcu102", dsp=2520, bram18k=1824, bw_gbps=19.2,
                  tdp_watts=40.0, usd_per_hour=0.60)

FPGAS = {f.name: f for f in (KU115, ZC706, VU9P, ZCU102)}


def alpha_for(bits: int) -> int:
    """MAC-ops per DSP per cycle (Eq. 1): 2 for 16-bit, 4 for 8-bit inputs."""
    if bits <= 8:
        return 4
    return 2


# ---------------------------------------------------------------------------
# TPU (retarget domain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops: float       # per-chip, bf16
    hbm_bytes: float        # per-chip capacity
    hbm_bw: float           # per-chip, bytes/s
    ici_bw: float           # per-link, bytes/s
    vmem_bytes: float = 128 * 2 ** 20
    # 2D torus: each chip has links on both mesh axes.
    links_per_chip: int = 4
    # Chip power and hourly dollar proxy for the normalized objectives.
    tdp_watts: float = 200.0
    usd_per_hour: float = 1.20


TPU_V5E = TPUSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bytes=16 * 2 ** 30,
    hbm_bw=819e9,
    ici_bw=50e9,
)

TPUS = {TPU_V5E.name: TPU_V5E}


# ---------------------------------------------------------------------------
# GPU (CUDA retarget domain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    """One NVIDIA datacenter part, as the analytic roofline in
    ``core/gpu_model.py`` sees it: SM compute peak, HBM capacity and
    bandwidth, and a two-tier interconnect — NVLink within a node of
    ``node_size`` GPUs, InfiniBand per GPU across nodes."""

    name: str
    peak_flops: float       # per-GPU, bf16 tensor-core dense
    hbm_bytes: float        # per-GPU capacity
    hbm_bw: float           # per-GPU, bytes/s
    nvlink_bw: float        # per-GPU NVLink bandwidth (one direction), bytes/s
    ib_bw: float            # per-GPU inter-node bandwidth, bytes/s
    sm_count: int
    tdp_watts: float
    usd_per_hour: float     # cloud on-demand proxy, $/GPU-hr
    node_size: int = 8      # GPUs sharing an NVLink/NVSwitch domain


# Datasheet peaks (bf16 dense, no sparsity); NVLink = per-direction
# aggregate (NVLink3: 600 GB/s bidir -> 300; NVLink4: 900 -> 450); IB = one
# NIC per GPU (DGX A100: 200 Gb/s; DGX H100: 400 Gb/s). Dollars = typical
# cloud on-demand per-GPU rates.
A100_40G = GPUSpec("a100-40g", peak_flops=312e12, hbm_bytes=40 * 2 ** 30,
                   hbm_bw=1555e9, nvlink_bw=300e9, ib_bw=25e9, sm_count=108,
                   tdp_watts=400.0, usd_per_hour=3.05)
A100_80G = GPUSpec("a100-80g", peak_flops=312e12, hbm_bytes=80 * 2 ** 30,
                   hbm_bw=2039e9, nvlink_bw=300e9, ib_bw=25e9, sm_count=108,
                   tdp_watts=400.0, usd_per_hour=3.67)
H100 = GPUSpec("h100", peak_flops=989e12, hbm_bytes=80 * 2 ** 30,
               hbm_bw=3350e9, nvlink_bw=450e9, ib_bw=50e9, sm_count=132,
               tdp_watts=700.0, usd_per_hour=6.98)

GPUS = {g.name: g for g in (A100_40G, A100_80G, H100)}
