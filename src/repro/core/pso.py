"""Global optimization: particle-swarm search over the RAV (Algorithm 1).

Each particle is a 5-dim position [SP, Batch, dsp_frac, bram_frac, bw_frac];
fitness is the throughput returned by the local optimizers
(:func:`repro.core.local_opt.evaluate_rav`). Early termination fires when the
global best fails to improve for ``patience`` consecutive iterations (the
paper uses 2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .local_opt import RAV


@dataclasses.dataclass
class PSOConfig:
    population: int = 24
    iterations: int = 40
    inertia: float = 0.729       # w
    c_local: float = 1.494       # c1
    c_global: float = 1.494      # c2
    patience: int = 2            # early-termination window (paper Sec. 7.2)
    seed: int = 0


@dataclasses.dataclass
class PSOResult:
    best_rav: RAV
    best_fitness: float
    iterations_run: int
    evaluations: int
    history: list[float]


def _clip_round(pos: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    return np.clip(pos, lo, hi)


def _to_rav(pos: np.ndarray) -> RAV:
    return RAV(sp=int(round(pos[0])), batch=max(1, int(round(pos[1]))),
               dsp_frac=float(pos[2]), bram_frac=float(pos[3]),
               bw_frac=float(pos[4]))


def optimize(fitness_fn: Callable[[RAV], float], sp_max: int,
             batch_max: int = 1, cfg: PSOConfig | None = None) -> PSOResult:
    """Algorithm 1. ``fitness_fn`` must be deterministic (results are memoized
    on the rounded RAV so repeated positions are free)."""
    cfg = cfg or PSOConfig()
    rng = np.random.default_rng(cfg.seed)
    lo = np.array([0.0, 1.0, 0.05, 0.05, 0.05])
    hi = np.array([float(sp_max), float(batch_max), 0.95, 0.95, 0.95])

    pos = rng.uniform(lo, hi, size=(cfg.population, 5))
    # Seed a few canonical particles: pure-generic, half-split, pure-pipeline.
    pos[0] = [0.0, 1.0, 0.05, 0.05, 0.05]
    pos[1] = [sp_max / 2, 1.0, 0.5, 0.5, 0.5]
    pos[2] = [float(sp_max), 1.0, 0.95, 0.95, 0.95]
    vel = rng.uniform(-1, 1, size=(cfg.population, 5)) * (hi - lo) * 0.1

    cache: dict[tuple, float] = {}
    evals = 0

    def fit(p: np.ndarray) -> float:
        nonlocal evals
        rav = _to_rav(p)
        key = rav.as_tuple()
        # Round fractions to 2 decimals for cache hits without losing much.
        key = (key[0], key[1], round(key[2], 2), round(key[3], 2), round(key[4], 2))
        if key not in cache:
            cache[key] = fitness_fn(rav)
            evals += 1
        return cache[key]

    pbest = pos.copy()
    pbest_fit = np.array([fit(p) for p in pos])
    g_idx = int(np.argmax(pbest_fit))
    gbest, gbest_fit = pbest[g_idx].copy(), float(pbest_fit[g_idx])

    history = [gbest_fit]
    stale = 0
    it = 0
    for it in range(1, cfg.iterations + 1):
        r1 = rng.random((cfg.population, 5))
        r2 = rng.random((cfg.population, 5))
        vel = (cfg.inertia * vel
               + cfg.c_local * r1 * (pbest - pos)
               + cfg.c_global * r2 * (gbest[None, :] - pos))
        pos = _clip_round(pos + vel, lo, hi)
        improved = False
        for i in range(cfg.population):
            f = fit(pos[i])
            if f > pbest_fit[i]:
                pbest[i], pbest_fit[i] = pos[i].copy(), f
            if f > gbest_fit:
                gbest, gbest_fit = pos[i].copy(), f
                improved = True
        history.append(gbest_fit)
        stale = 0 if improved else stale + 1
        if stale >= cfg.patience:
            break
    return PSOResult(_to_rav(gbest), gbest_fit, it, evals, history)
