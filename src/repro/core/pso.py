"""Global optimization: particle-swarm search over the RAV (Algorithm 1).

Each particle is a 5-dim position [SP, Batch, dsp_frac, bram_frac, bw_frac];
fitness is the (scalarized) objective returned by the local optimizers
(:func:`repro.core.local_opt.evaluate_rav`). Early termination fires when the
global best fails to improve for ``patience`` consecutive iterations (the
paper uses 2).

The swarm is one engine behind the ask/tell :class:`~repro.core.search.Searcher`
protocol: :class:`PSOSearcher` keeps the algorithm state (positions,
velocities, bests) and :func:`repro.core.search.run_search` owns the
shared bookkeeping — the rounded-RAV memo, budget accounting, result
assembly. The update loop is vectorized: per iteration the whole
population is pushed through one *batched* fitness call and
personal/global bests are refreshed with NumPy where/argmax.
:func:`repro.core.explore` hands in a hook backed by the batched
array-kernel engine (:mod:`repro.core.batch_eval`), so the math under
the hook is batched too; callers that only have a scalar ``fitness_fn``
get the same semantics (the batch is evaluated element-wise).

Trajectories are bit-identical to the pre-protocol loop under a fixed
seed — the RNG draw order (init positions, velocities, then r1/r2 per
iteration) is pinned by the golden-trajectory fixture in
``tests/test_search.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .local_opt import RAV
from .search import (SearchResult, Searcher, SearchSpace, register_searcher,
                     run_search)

#: Historical name: every engine now returns this shared result type.
PSOResult = SearchResult


def _clip(pos: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    return np.clip(pos, lo, hi)


def _to_rav(pos: np.ndarray) -> RAV:
    return RAV(sp=int(round(pos[0])), batch=max(1, int(round(pos[1]))),
               dsp_frac=float(pos[2]), bram_frac=float(pos[3]),
               bw_frac=float(pos[4]))


@dataclasses.dataclass
class PSOConfig:
    population: int = 24
    iterations: int = 40
    inertia: float = 0.729       # w
    c_local: float = 1.494       # c1
    c_global: float = 1.494      # c2
    patience: int = 2            # early-termination window (paper Sec. 7.2)
    seed: int = 0

    def eval_cap(self) -> int:
        return self.population * (self.iterations + 1)


class PSOSearcher(Searcher):
    """Algorithm 1 as an ask/tell engine. ``init_positions`` overrides
    the canonical seed particles (rows 0..n-1) — the hook hyperband's
    refinement stage uses to start the swarm at its screen survivors;
    the default path plants the canonical three exactly as the
    pre-protocol loop did."""

    name = "pso"

    def __init__(self, space: SearchSpace, cfg: PSOConfig,
                 init_positions: np.ndarray | None = None):
        super().__init__(space, cfg)
        rng = np.random.default_rng(cfg.seed)
        self._rng = rng
        self._lo, self._hi = space.lo(), space.hi()
        pos = rng.uniform(self._lo, self._hi, size=(cfg.population, 5))
        if init_positions is None:
            pos[:3] = space.canonical()
        else:
            n = min(len(init_positions), cfg.population)
            pos[:n] = init_positions[:n]
        self._pos = pos
        self._vel = rng.uniform(-1, 1, size=(cfg.population, 5)) \
            * (self._hi - self._lo) * 0.1
        self._pbest = None
        self._pbest_fit = None
        self._stale = 0

    def ask(self) -> np.ndarray | None:
        if self.done:
            return None
        if self._pbest is None:      # initial population
            return self._pos
        cfg = self.cfg
        r1 = self._rng.random((cfg.population, 5))
        r2 = self._rng.random((cfg.population, 5))
        self._vel = (cfg.inertia * self._vel
                     + cfg.c_local * r1 * (self._pbest - self._pos)
                     + cfg.c_global * r2 * (self.best_pos[None, :] - self._pos))
        self._pos = np.clip(self._pos + self._vel, self._lo, self._hi)
        return self._pos

    def tell(self, fits: np.ndarray) -> None:
        if self._pbest is None:      # init round
            self._pbest = self._pos.copy()
            self._pbest_fit = fits
            g = int(np.argmax(fits))
            self.best_pos = self._pbest[g].copy()
            self.best_fit = float(fits[g])
            self.history = [self.best_fit]
            if self.cfg.iterations <= 0:
                self.done = True
            return
        better = fits > self._pbest_fit
        self._pbest = np.where(better[:, None], self._pos, self._pbest)
        self._pbest_fit = np.where(better, fits, self._pbest_fit)
        best_i = int(np.argmax(fits))
        improved = bool(fits[best_i] > self.best_fit)
        if improved:
            self.best_pos = self._pos[best_i].copy()
            self.best_fit = float(fits[best_i])
        self.iterations_run += 1
        self.history.append(self.best_fit)
        self._stale = 0 if improved else self._stale + 1
        if self._stale >= self.cfg.patience:
            self.stop_reason = "converged"
            self.done = True
        elif self.iterations_run >= self.cfg.iterations:
            self.done = True


register_searcher("pso", PSOSearcher, PSOConfig)


def optimize(fitness_fn: Callable[[RAV], float] | None = None, *,
             sp_max: int, batch_max: int = 1,
             cfg: PSOConfig | None = None,
             batch_fitness_fn: Callable[[Sequence[RAV]], Sequence[float]] | None = None,
             ) -> PSOResult:
    """Algorithm 1. Fitness must be deterministic (results are memoized on the
    rounded RAV so repeated positions are free). Exactly one of ``fitness_fn``
    (scalar, one RAV per call) or ``batch_fitness_fn`` (whole population per
    call) is required; with both given the batch hook wins.
    """
    if fitness_fn is None and batch_fitness_fn is None:
        raise TypeError("optimize() needs fitness_fn or batch_fitness_fn")
    space = SearchSpace(sp_max=sp_max, batch_max=batch_max)
    searcher = PSOSearcher(space, cfg or PSOConfig())
    return run_search(searcher, fitness_fn=fitness_fn,
                      batch_fitness_fn=batch_fitness_fn)
