"""Global optimization: particle-swarm search over the RAV (Algorithm 1).

Each particle is a 5-dim position [SP, Batch, dsp_frac, bram_frac, bw_frac];
fitness is the (scalarized) objective returned by the local optimizers
(:func:`repro.core.local_opt.evaluate_rav`). Early termination fires when the
global best fails to improve for ``patience`` consecutive iterations (the
paper uses 2).

The update loop is vectorized: per iteration the whole population is pushed
through one *batched* fitness call (``batch_fitness_fn``) and personal/global
bests are refreshed with NumPy where/argmax — no per-particle Python
bookkeeping. :func:`repro.core.explore` hands in a hook backed by the
batched array-kernel engine (:mod:`repro.core.batch_eval`), so the math
under the hook is batched too; callers that only have a scalar
``fitness_fn`` get the same semantics (the batch is evaluated
element-wise).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .local_opt import RAV


@dataclasses.dataclass
class PSOConfig:
    population: int = 24
    iterations: int = 40
    inertia: float = 0.729       # w
    c_local: float = 1.494       # c1
    c_global: float = 1.494      # c2
    patience: int = 2            # early-termination window (paper Sec. 7.2)
    seed: int = 0


@dataclasses.dataclass
class PSOResult:
    best_rav: RAV
    best_fitness: float
    iterations_run: int
    evaluations: int
    history: list[float]
    #: Why the search stopped: ``"converged"`` (patience exhausted — the
    #: paper's early termination) or ``"iteration_cap"`` (budget ran out
    #: while the best was still moving — the signal multi-fidelity DSE
    #: uses to promote survivors to a deeper search).
    stop_reason: str = "iteration_cap"
    #: Fitness lookups served from the rounded-RAV memo instead of the
    #: analytical models (``evaluations`` counts the model calls).
    cache_hits: int = 0


def _clip(pos: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    return np.clip(pos, lo, hi)


def _to_rav(pos: np.ndarray) -> RAV:
    return RAV(sp=int(round(pos[0])), batch=max(1, int(round(pos[1]))),
               dsp_frac=float(pos[2]), bram_frac=float(pos[3]),
               bw_frac=float(pos[4]))


def _cache_key(rav: RAV) -> tuple:
    # Round fractions to 2 decimals for cache hits without losing much.
    t = rav.as_tuple()
    return (t[0], t[1], round(t[2], 2), round(t[3], 2), round(t[4], 2))


def optimize(fitness_fn: Callable[[RAV], float] | None = None, *,
             sp_max: int, batch_max: int = 1,
             cfg: PSOConfig | None = None,
             batch_fitness_fn: Callable[[Sequence[RAV]], Sequence[float]] | None = None,
             ) -> PSOResult:
    """Algorithm 1. Fitness must be deterministic (results are memoized on the
    rounded RAV so repeated positions are free). Exactly one of ``fitness_fn``
    (scalar, one RAV per call) or ``batch_fitness_fn`` (whole population per
    call) is required; with both given the batch hook wins.
    """
    if fitness_fn is None and batch_fitness_fn is None:
        raise TypeError("optimize() needs fitness_fn or batch_fitness_fn")
    cfg = cfg or PSOConfig()
    rng = np.random.default_rng(cfg.seed)
    lo = np.array([0.0, 1.0, 0.05, 0.05, 0.05])
    hi = np.array([float(sp_max), float(batch_max), 0.95, 0.95, 0.95])

    pos = rng.uniform(lo, hi, size=(cfg.population, 5))
    # Seed a few canonical particles: pure-generic, half-split, pure-pipeline.
    pos[0] = [0.0, 1.0, 0.05, 0.05, 0.05]
    pos[1] = [sp_max / 2, 1.0, 0.5, 0.5, 0.5]
    pos[2] = [float(sp_max), 1.0, 0.95, 0.95, 0.95]
    vel = rng.uniform(-1, 1, size=(cfg.population, 5)) * (hi - lo) * 0.1

    cache: dict[tuple, float] = {}
    evals = 0
    hits = 0

    def fit_batch(block: np.ndarray) -> np.ndarray:
        """Fitness for every row of ``block``; uncached keys (deduped, in
        first-appearance order — same order the old per-particle loop
        evaluated them) go through one batched call."""
        nonlocal evals, hits
        ravs = [_to_rav(p) for p in block]
        keys = [_cache_key(r) for r in ravs]
        pending: dict[tuple, RAV] = {}
        for k, r in zip(keys, ravs):
            if k not in cache and k not in pending:
                pending[k] = r
        if pending:
            if batch_fitness_fn is not None:
                vals = batch_fitness_fn(list(pending.values()))
            else:
                vals = [fitness_fn(r) for r in pending.values()]
            for k, v in zip(pending, vals):
                cache[k] = float(v)
            evals += len(pending)
        hits += len(keys) - len(pending)
        return np.array([cache[k] for k in keys])

    pbest = pos.copy()
    pbest_fit = fit_batch(pos)
    g_idx = int(np.argmax(pbest_fit))
    gbest, gbest_fit = pbest[g_idx].copy(), float(pbest_fit[g_idx])

    history = [gbest_fit]
    stale = 0
    stop_reason = "iteration_cap"
    it = 0
    for it in range(1, cfg.iterations + 1):
        r1 = rng.random((cfg.population, 5))
        r2 = rng.random((cfg.population, 5))
        vel = (cfg.inertia * vel
               + cfg.c_local * r1 * (pbest - pos)
               + cfg.c_global * r2 * (gbest[None, :] - pos))
        pos = _clip(pos + vel, lo, hi)
        fits = fit_batch(pos)
        better = fits > pbest_fit
        pbest = np.where(better[:, None], pos, pbest)
        pbest_fit = np.where(better, fits, pbest_fit)
        best_i = int(np.argmax(fits))
        improved = bool(fits[best_i] > gbest_fit)
        if improved:
            gbest, gbest_fit = pos[best_i].copy(), float(fits[best_i])
        history.append(gbest_fit)
        stale = 0 if improved else stale + 1
        if stale >= cfg.patience:
            stop_reason = "converged"
            break
    return PSOResult(_to_rav(gbest), gbest_fit, it, evals, history,
                     stop_reason=stop_reason, cache_hits=hits)
