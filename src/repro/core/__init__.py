"""DNNExplorer core: model analysis, analytical accelerator models, and the
two-level DSE engine (the paper's primary contribution), plus the TPU
retarget used by the JAX runtime."""
from .batch_eval import evaluate_rav_batch, screen_rav_batch
from .explorer import ExplorationResult, explore
from .generic_model import GenericDesign, best_generic
from .layer_arrays import PackedLayers, pack_layers
from .hw_specs import (A100_40G, A100_80G, FPGAS, GPUS, H100, KU115, TPU_V5E,
                       TPUS, VU9P, ZC706, ZCU102, FPGASpec, GPUSpec, TPUSpec)
from .local_opt import (RAV, DesignPoint, dnnbuilder_design, evaluate_rav,
                        generic_only_design)
from .netinfo import INPUT_CASES, TABLE1_NETS, LayerInfo, NetInfo, vgg16
from .pipeline_model import PipelineDesign, StageDesign, design_pipeline
from .pso import PSOConfig, PSOResult, optimize
from .search import (SearchResult, Searcher, SearchSpace, SEARCHERS,
                     hyperband_rung0, make_searcher, run_search,
                     searcher_config_for, searcher_names)

__all__ = [
    "ExplorationResult", "explore", "GenericDesign", "best_generic",
    "evaluate_rav_batch", "screen_rav_batch", "PackedLayers", "pack_layers",
    "SearchResult", "Searcher", "SearchSpace", "SEARCHERS",
    "hyperband_rung0", "make_searcher", "run_search",
    "searcher_config_for", "searcher_names",
    "A100_40G", "A100_80G", "FPGAS", "GPUS", "H100", "KU115", "TPU_V5E",
    "TPUS", "VU9P", "ZC706", "ZCU102", "FPGASpec", "GPUSpec", "TPUSpec",
    "RAV", "DesignPoint", "dnnbuilder_design",
    "evaluate_rav", "generic_only_design", "INPUT_CASES", "TABLE1_NETS",
    "LayerInfo", "NetInfo", "vgg16", "PipelineDesign", "StageDesign",
    "design_pipeline", "PSOConfig", "PSOResult", "optimize",
]
