"""Local optimization + full design-point evaluation (paper Sec. 7.3).

Given an RAV from the global optimizer, this module runs:

* Algorithm 2 — CTC-based parallelism allocation for the pipeline half
  (in ``pipeline_model.design_pipeline``), and
* Algorithm 3 — balance-oriented growth of the generic structure:
  double PF_g until the generic half keeps up with the pipeline half
  (``L_g <= L_p^max``), rolling the pipeline back if resources run out.

The result is a :class:`DesignPoint` with throughput, GOP/s, DSP efficiency
and resource usage — the fitness the PSO sees.
"""
from __future__ import annotations

import dataclasses
import math

from .generic_model import GenericDesign, best_generic
from .hw_specs import FPGASpec, alpha_for
from .netinfo import LayerInfo, NetInfo
from .pipeline_model import (PipelineDesign, design_pipeline, scale_down,
                             split_pf)


@dataclasses.dataclass(frozen=True)
class RAV:
    """Resource Allocation Vector (Eq. 2): task split + resources for the
    pipeline structure; the generic structure gets the complement."""

    sp: int          # split-point: #major layers in the pipeline half
    batch: int
    dsp_frac: float  # fraction of usable DSPs given to the pipeline half
    bram_frac: float
    bw_frac: float

    def as_tuple(self) -> tuple:
        return (self.sp, self.batch, self.dsp_frac, self.bram_frac, self.bw_frac)


@dataclasses.dataclass
class DesignPoint:
    rav: RAV
    pipeline: PipelineDesign
    generic: GenericDesign | None
    throughput_ips: float
    gops: float
    dsp_used: int
    bram_used: int
    dsp_eff: float
    latency_s: float = 0.0   # end-to-end batch latency (pipeline + generic)
    feasible: bool = True

    @property
    def fitness(self) -> float:
        return self.throughput_ips if self.feasible else 0.0


def _segment_after(net: NetInfo, sp: int) -> list[LayerInfo]:
    """All layers (incl. pools) after the sp-th major layer. Always a
    contiguous suffix of ``net.layers`` — ``layer_arrays.pack_layers``
    exploits that to index any split's segment in O(1) (identity is
    regression-tested in ``tests/test_batch_eval.py``)."""
    majors = 0
    out: list[LayerInfo] = []
    for l in net.layers:
        if l.kind != "pool":
            majors += 1
        # A pool directly after major layer <= sp is fused into that stage.
        if majors > sp:
            out.append(l)
    return out


def evaluate_rav(net: NetInfo, fpga: FPGASpec, rav: RAV, dw: int = 16,
                 ww: int = 16, max_rollbacks: int = 12,
                 calibration=None) -> DesignPoint:
    """Algorithms 2+3 for one RAV. Deterministic, pure.

    This is the scalar *reference* implementation: readable, paper-shaped,
    one layer at a time. The PSO's population fitness goes through the
    batched array-kernel twin (:func:`repro.core.batch_eval.
    evaluate_rav_batch`), which must agree with this function on every
    discrete decision and to <=1e-9 relative on float objectives
    (``tests/test_batch_eval.py`` enforces it); the winning RAV is always
    re-evaluated here.

    ``calibration`` (a :class:`repro.calib.Calibration`, duck-typed via
    ``for_spec``) rescales the part's clock and bandwidth to measured
    delivered rates before anything is modeled; ``None`` — the default —
    evaluates against the datasheet spec exactly as before. Callers that
    batch-evaluate (the PSO) apply the same rescale once, up front, via
    ``calibration.for_spec`` so the scalar and batched twins stay in
    lockstep."""
    if calibration is not None:
        fpga = calibration.for_spec(fpga)
    freq = fpga.freq
    majors = net.major_layers
    sp = max(0, min(rav.sp, len(majors)))
    pipe_layers = list(majors[:sp])
    gen_layers = _segment_after(net, sp)

    dsp_p = int(fpga.dsp_usable * rav.dsp_frac) if sp else 0
    bram_p = int(fpga.bram_usable * rav.bram_frac) if sp else 0
    bw_p = fpga.bw_gbps * 1e9 * rav.bw_frac if sp else 0.0
    bw_g = fpga.bw_gbps * 1e9 - bw_p

    pipe = design_pipeline(pipe_layers, dsp_p, bram_p, bw_p, freq, dw, ww,
                           rav.batch)

    # ---- Algorithm 3: grow the generic structure until balanced ----------
    gen: GenericDesign | None = None
    if gen_layers:
        for _ in range(max_rollbacks):
            dsp_avail = fpga.dsp_usable - pipe.dsp()
            bram_avail = fpga.bram_usable - pipe.bram()
            if dsp_avail < 1 or bram_avail < 1:
                if not pipe.stages or all(s.pf == 1 for s in pipe.stages):
                    break
                pipe = scale_down(pipe)
                continue
            target = pipe.batch_latency(freq, bw_p) if pipe.stages else None
            alpha = alpha_for(min(dw, ww))
            pf_cap = max(1, (dsp_avail * alpha) // 2)
            c_max = max(l.c for l in gen_layers)
            k_max = max(l.k for l in gen_layers)
            pf = 1
            gen = None
            while True:
                cpf, kpf = split_pf(pf, c_max, k_max)
                cand = best_generic(gen_layers, cpf, kpf, dw, ww, bram_avail,
                                    bw_g, freq, rav.batch)
                if cand.dsp() > dsp_avail:
                    break
                gen = cand
                lat = gen.segment_latency(gen_layers, freq, rav.batch)
                if target is not None and lat <= target:
                    break  # balanced (Alg. 3 line 5 condition met)
                if pf >= pf_cap or cpf * kpf < pf:
                    break  # parallelism saturated
                pf *= 2
            if gen is None:
                # Even PF=1 doesn't fit: roll the pipeline back.
                if not pipe.stages or all(s.pf == 1 for s in pipe.stages):
                    break
                pipe = scale_down(pipe)
                continue
            break

    # ---- Combine ----------------------------------------------------------
    if not pipe.stages and gen is None:
        return DesignPoint(rav, pipe, gen, 0.0, 0.0, 0, 0, 0.0, 0.0,
                           feasible=False)

    rate_p = pipe.throughput_ips(freq, bw_p) if pipe.stages else float("inf")
    lat_p = pipe.batch_latency(freq, bw_p) if pipe.stages else 0.0
    if gen is not None:
        lat_g = gen.segment_latency(gen_layers, freq, rav.batch)
        rate_g = rav.batch / lat_g if lat_g > 0 else float("inf")
    else:
        lat_g = 0.0
        rate_g = float("inf")
    rate = min(rate_p, rate_g)
    if not math.isfinite(rate):
        rate = 0.0
    # One batch crosses both halves back-to-back (steady-state throughput
    # overlaps them, first-batch latency does not).
    latency_s = lat_p + lat_g

    dsp_used = pipe.dsp() + (gen.dsp() if gen else 0)
    bram_used = pipe.bram() + (gen.bram if gen else 0)
    feasible = dsp_used <= fpga.dsp_usable and bram_used <= fpga.bram_usable

    gops = rate * net.total_ops / 1e9
    alpha = alpha_for(min(dw, ww))
    dsp_eff = (gops * 1e9) / (alpha * dsp_used * freq) if dsp_used else 0.0
    return DesignPoint(rav, pipe, gen, rate, gops, dsp_used, bram_used,
                       dsp_eff, latency_s, feasible)


# ---------------------------------------------------------------------------
# Paper baselines (Sec. 8 comparisons), built from the same primitives
# ---------------------------------------------------------------------------


def dnnbuilder_design(net: NetInfo, fpga: FPGASpec, dw: int = 16, ww: int = 16,
                      batch: int = 1) -> DesignPoint:
    """Paradigm B baseline: pure layer-wise pipeline (SP = all layers)."""
    rav = RAV(len(net.major_layers), batch, 1.0, 1.0, 1.0)
    return evaluate_rav(net, fpga, rav, dw, ww)


def generic_only_design(net: NetInfo, fpga: FPGASpec, dw: int = 16,
                        ww: int = 16, batch: int = 1) -> DesignPoint:
    """Paradigm A baseline: one reusable GEMV compute unit (SP = 0),
    analytical proxy for HybridDNN."""
    rav = RAV(0, batch, 0.0, 0.0, 0.0)
    return evaluate_rav(net, fpga, rav, dw, ww)


def dpu_proxy_design(net: NetInfo, fpga: FPGASpec, dw: int = 16, ww: int = 16,
                     batch: int = 1, pixel_par: int = 8, cpf: int = 16,
                     kpf: int = 32) -> DesignPoint:
    """Analytical proxy for a fixed-geometry commercial IP (Xilinx DPU
    B4096-like: 8 pixel x 16 input-ch x 32 output-ch MAC cube). The fixed
    pixel unroll underutilizes on small feature maps — Fig. 2a."""
    gen_layers = list(net.layers)
    gen = GenericDesign(cpf, kpf, dw, ww, fpga.bram_usable,
                        fpga.bw_gbps * 1e9, strategy=1, pixel_par=pixel_par)
    lat = gen.segment_latency(gen_layers, fpga.freq, batch)
    rate = batch / lat if lat > 0 else 0.0
    gops = rate * net.total_ops / 1e9
    alpha = alpha_for(min(dw, ww))
    dsp_eff = (gops * 1e9) / (alpha * gen.dsp() * fpga.freq) if gen.dsp() else 0.0
    rav = RAV(0, batch, 0.0, 0.0, 0.0)
    return DesignPoint(rav, PipelineDesign([], batch), gen, rate, gops,
                       gen.dsp(), gen.bram, dsp_eff, lat)
