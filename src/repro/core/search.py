"""Pluggable search engines over the RAV: the ask/tell ``Searcher``
protocol, the budget-accounting driver, and the engine registry.

The paper fixes one global optimizer (PSO, Algorithm 1), but engine
choice and multi-fidelity screening dominate search quality at fixed
compute (arXiv:1903.07676, arXiv:2104.02251). This module factors the
search loop out of :mod:`repro.core.pso` so any engine can drive the
same batched fitness path:

* a :class:`Searcher` *asks* for a population block of RAV positions and
  is *told* their fitnesses; it never calls the models itself;
* :func:`run_search` owns what every engine shares — the rounded-RAV
  memo cache (dedup in first-appearance order, exactly the old PSO
  loop's semantics, so trajectories stay bit-identical), evaluation /
  cache-hit / screened counters, and assembly of the final
  :class:`SearchResult`;
* engines declare a per-block ``fidelity``: ``"full"`` routes through
  the batched Algorithm-2+3 evaluation, ``"screen"`` through the cheap
  vectorized relaxation (:func:`repro.core.batch_eval.screen_rav_batch`)
  that multi-fidelity search uses to triage thousands of candidates.

Registered engines (``SEARCHERS``): ``pso`` (the paper's Algorithm 1,
lives in :mod:`repro.core.pso`), ``random`` (uniform baseline),
``anneal`` (geometric-cooling simulated annealing over a population of
independent chains), and ``hyperband`` (successive halving: screen
thousands of RAVs at the capped-budget fidelity, promote the survivors
to full Algorithm-2+3 evaluation, then refine with a survivor-seeded
PSO).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .local_opt import RAV

#: Fraction bounds shared by every engine (the PSO's historical bounds).
FRAC_LO, FRAC_HI = 0.05, 0.95


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The 5-dim RAV box: [SP, Batch, dsp_frac, bram_frac, bw_frac]."""

    sp_max: int
    batch_max: int = 1

    def lo(self) -> np.ndarray:
        return np.array([0.0, 1.0, FRAC_LO, FRAC_LO, FRAC_LO])

    def hi(self) -> np.ndarray:
        return np.array([float(self.sp_max), float(self.batch_max),
                         FRAC_HI, FRAC_HI, FRAC_HI])

    def canonical(self) -> np.ndarray:
        """The three seed particles every engine plants: pure-generic,
        half-split, pure-pipeline (covers the paradigm extremes)."""
        return np.array([
            [0.0, 1.0, FRAC_LO, FRAC_LO, FRAC_LO],
            [self.sp_max / 2, 1.0, 0.5, 0.5, 0.5],
            [float(self.sp_max), 1.0, FRAC_HI, FRAC_HI, FRAC_HI],
        ])

    def to_rav(self, pos: np.ndarray) -> RAV:
        return RAV(sp=int(round(pos[0])), batch=max(1, int(round(pos[1]))),
                   dsp_frac=float(pos[2]), bram_frac=float(pos[3]),
                   bw_frac=float(pos[4]))


@dataclasses.dataclass
class SearchResult:
    """What any engine's search produced. Field order (and defaults) are
    the historical ``PSOResult`` layout — positional construction from
    older code keeps working, and ``repro.core.pso.PSOResult`` is an
    alias of this class."""

    best_rav: RAV
    best_fitness: float
    iterations_run: int
    evaluations: int
    history: list[float]
    #: Why the search stopped: ``"converged"`` (patience exhausted — the
    #: paper's early termination) or ``"iteration_cap"`` (budget ran out
    #: while the best was still moving — the signal multi-fidelity DSE
    #: uses to promote survivors to a deeper search).
    stop_reason: str = "iteration_cap"
    #: Fitness lookups served from the rounded-RAV memo instead of the
    #: analytical models (``evaluations`` counts the model calls).
    cache_hits: int = 0
    #: Registry name of the engine that produced this result.
    engine: str = "pso"
    #: Candidates triaged through the cheap screening fidelity
    #: (:func:`repro.core.batch_eval.screen_rav_batch`); these never
    #: touch the full models and are NOT counted in ``evaluations``.
    screened: int = 0


class Searcher:
    """Ask/tell engine protocol. Subclasses keep all algorithm state;
    the driver (:func:`run_search`) keeps all bookkeeping.

    Contract per round: :meth:`ask` returns a ``(n, 5)`` position block
    (or ``None`` when done); the driver evaluates it at the engine's
    current :attr:`fidelity` and calls :meth:`tell` with the fitness
    array. After ``tell`` the engine must expose ``best_pos``,
    ``best_fit``, ``history`` (best-so-far per iteration),
    ``iterations_run``, ``stop_reason``, and ``done``.
    """

    #: Registry name; subclasses override.
    name = "base"
    #: Fidelity of the NEXT asked block: ``"full"`` or ``"screen"``.
    fidelity = "full"

    def __init__(self, space: SearchSpace, cfg):
        self.space = space
        self.cfg = cfg
        self.done = False
        self.stop_reason = "iteration_cap"
        self.history: list[float] = []
        self.iterations_run = 0
        self.best_pos: np.ndarray | None = None
        self.best_fit = float("-inf")

    def ask(self) -> np.ndarray | None:  # pragma: no cover - interface
        raise NotImplementedError

    def tell(self, fits: np.ndarray) -> None:  # pragma: no cover
        raise NotImplementedError

    def eval_cap(self) -> int:
        """Upper bound on full-fidelity evaluations this engine may
        request (budget the conformance tests hold every engine to)."""
        return self.cfg.eval_cap()


def _cache_key(rav: RAV) -> tuple:
    # Round fractions to 2 decimals for cache hits without losing much.
    t = rav.as_tuple()
    return (t[0], t[1], round(t[2], 2), round(t[3], 2), round(t[4], 2))


def run_search(searcher: Searcher, *,
               fitness_fn: Callable[[RAV], float] | None = None,
               batch_fitness_fn: Callable[[Sequence[RAV]], Sequence[float]] | None = None,
               screen_fn: Callable[[Sequence[RAV]], np.ndarray] | None = None,
               ) -> SearchResult:
    """Drive one engine to completion and account for its budget.

    Exactly one of ``fitness_fn`` (scalar) or ``batch_fitness_fn``
    (population per call) is required; with both given the batch hook
    wins. ``screen_fn`` serves ``"screen"``-fidelity blocks — it is
    called with the raw ``(n, 5)`` position array, not RAV objects (an
    engine asking for screening without one is an error). Full-fidelity
    results
    are memoized on the rounded RAV — uncached keys are deduped in
    first-appearance order and go through ONE batched call, exactly the
    semantics of the pre-protocol PSO loop (bit-identity depends on it).
    """
    if fitness_fn is None and batch_fitness_fn is None:
        raise TypeError("run_search() needs fitness_fn or batch_fitness_fn")
    space = searcher.space
    cache: dict[tuple, float] = {}
    evals = hits = screened = 0

    def fit_batch(block: np.ndarray) -> np.ndarray:
        nonlocal evals, hits
        ravs = [space.to_rav(p) for p in block]
        keys = [_cache_key(r) for r in ravs]
        pending: dict[tuple, RAV] = {}
        for k, r in zip(keys, ravs):
            if k not in cache and k not in pending:
                pending[k] = r
        if pending:
            if batch_fitness_fn is not None:
                vals = batch_fitness_fn(list(pending.values()))
            else:
                vals = [fitness_fn(r) for r in pending.values()]
            for k, v in zip(pending, vals):
                cache[k] = float(v)
            evals += len(pending)
        hits += len(keys) - len(pending)
        return np.array([cache[k] for k in keys])

    while True:
        block = searcher.ask()
        if block is None:
            break
        if searcher.fidelity == "screen":
            if screen_fn is None:
                raise ValueError(
                    f"searcher {searcher.name!r} asked for screen-fidelity "
                    f"evaluation but no screen_fn was provided")
            # The raw (n, 5) position block goes straight through —
            # materializing n RAV objects would cost more than the
            # entire vectorized screen.
            fits = np.asarray(screen_fn(block), dtype=float)
            screened += len(block)
        else:
            fits = fit_batch(block)
        searcher.tell(fits)

    return SearchResult(space.to_rav(searcher.best_pos),
                        float(searcher.best_fit), searcher.iterations_run,
                        evals, searcher.history,
                        stop_reason=searcher.stop_reason, cache_hits=hits,
                        engine=searcher.name, screened=screened)


# ---------------------------------------------------------------------------
# Engine registry
# ---------------------------------------------------------------------------

#: name -> (searcher class, config class). Engines self-register at
#: import; :func:`_load_engines` pulls in the out-of-module ones.
SEARCHERS: dict[str, tuple[type, type]] = {}


def register_searcher(name: str, searcher_cls: type, config_cls: type) -> None:
    SEARCHERS[name] = (searcher_cls, config_cls)


def _load_engines() -> None:
    from . import pso  # noqa: F401  (registers "pso" on import)


def searcher_names() -> list[str]:
    _load_engines()
    return sorted(SEARCHERS)


def searcher_config_for(name: str, *, base: dict | None = None,
                        overrides: dict | None = None):
    """Build a registered engine's config instance — the exact object
    :func:`make_searcher` would hand its searcher, factored out so
    campaign-level precomputation (e.g. the cross-cell jax screen, which
    must reproduce each cell's hyperband config bit-for-bit) shares one
    construction path with the search itself.

    ``base`` carries the campaign-level knobs every engine understands
    (``population``, ``iterations``, ``patience``, ``seed``) — keys the
    engine's config class lacks are dropped. ``overrides`` is the
    ``--searcher-config`` dict and must name real config fields (typos
    raise with the valid field list)."""
    _load_engines()
    if name not in SEARCHERS:
        raise ValueError(f"unknown searcher {name!r}; "
                         f"registered: {', '.join(sorted(SEARCHERS))}")
    _, config_cls = SEARCHERS[name]
    fields = {f.name: f for f in dataclasses.fields(config_cls)}
    kw = {k: v for k, v in (base or {}).items() if k in fields}
    for k, v in (overrides or {}).items():
        if k not in fields:
            raise ValueError(
                f"searcher {name!r} has no config field {k!r}; "
                f"valid: {', '.join(sorted(fields))}")
        # Coerce to the field's default's type so "--searcher-config
        # screen=512" (a string from the CLI) lands as the right kind.
        kw[k] = type(fields[k].default)(v)
    return config_cls(**kw)


def make_searcher(name: str, space: SearchSpace, *, base: dict | None = None,
                  overrides: dict | None = None) -> Searcher:
    """Instantiate a registered engine (see :func:`searcher_config_for`
    for how ``base`` and ``overrides`` assemble its config)."""
    cfg = searcher_config_for(name, base=base, overrides=overrides)
    searcher_cls, _ = SEARCHERS[name]
    return searcher_cls(space, cfg)


# ---------------------------------------------------------------------------
# random: uniform-sampling baseline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RandomConfig:
    population: int = 24
    iterations: int = 40
    patience: int = 0        # 0 = no early termination
    seed: int = 0

    def eval_cap(self) -> int:
        return self.population * (self.iterations + 1)


class RandomSearcher(Searcher):
    """Uniform random search: one fresh population per iteration, the
    three canonical particles planted in the first. The floor any real
    engine must beat at equal budget."""

    name = "random"

    def __init__(self, space: SearchSpace, cfg: RandomConfig):
        super().__init__(space, cfg)
        self._rng = np.random.default_rng(cfg.seed)
        self._stale = 0
        self._first = True

    def ask(self) -> np.ndarray | None:
        if self.done:
            return None
        pos = self._rng.uniform(self.space.lo(), self.space.hi(),
                                size=(self.cfg.population, 5))
        if self._first:
            can = self.space.canonical()
            pos[:len(can)] = can
        self._pos = pos
        return pos

    def tell(self, fits: np.ndarray) -> None:
        i = int(np.argmax(fits))
        improved = bool(fits[i] > self.best_fit)
        if improved:
            self.best_pos, self.best_fit = self._pos[i].copy(), float(fits[i])
        if self._first:
            self._first = False
            self.history = [self.best_fit]
            if self.cfg.iterations <= 0:
                self.done = True
            return
        self.iterations_run += 1
        self.history.append(self.best_fit)
        self._stale = 0 if improved else self._stale + 1
        if self.cfg.patience and self._stale >= self.cfg.patience:
            self.stop_reason = "converged"
            self.done = True
        elif self.iterations_run >= self.cfg.iterations:
            self.done = True


# ---------------------------------------------------------------------------
# anneal: geometric-cooling simulated annealing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnnealConfig:
    population: int = 24     # independent chains
    iterations: int = 40
    patience: int = 0        # 0 = no early termination
    seed: int = 0
    t0: float = 0.05         # initial temperature, relative to |best|
    cooling: float = 0.85    # geometric cooling factor per iteration
    step: float = 0.25       # proposal width, fraction of each axis range

    def eval_cap(self) -> int:
        return self.population * (self.iterations + 1)


class AnnealSearcher(Searcher):
    """Simulated annealing over a population of independent chains with
    a geometric cooling schedule (the fpgaHART-style sweep config:
    ``t0``/``cooling``/``step``). Proposals are Gaussian steps whose
    width shrinks with the temperature; uphill moves always accepted,
    downhill with probability ``exp(dfit / T)`` where ``T`` is scaled by
    the first population's best so the schedule is objective-magnitude
    invariant."""

    name = "anneal"

    def __init__(self, space: SearchSpace, cfg: AnnealConfig):
        super().__init__(space, cfg)
        self._rng = np.random.default_rng(cfg.seed)
        self._lo, self._hi = space.lo(), space.hi()
        pos = self._rng.uniform(self._lo, self._hi,
                                size=(cfg.population, 5))
        can = space.canonical()
        pos[:len(can)] = can
        self._pos = pos
        self._cur = None          # accepted positions after the init tell
        self._cur_fit = None
        self._temp = 0.0
        self._scale = 1.0         # proposal-width factor, cools with T
        self._stale = 0

    def ask(self) -> np.ndarray | None:
        if self.done:
            return None
        if self._cur is None:     # initial population
            return self._pos
        width = self.cfg.step * (self._hi - self._lo) * self._scale
        noise = self._rng.normal(0.0, 1.0, size=self._cur.shape)
        self._pos = np.clip(self._cur + noise * width, self._lo, self._hi)
        return self._pos

    def tell(self, fits: np.ndarray) -> None:
        i = int(np.argmax(fits))
        improved = bool(fits[i] > self.best_fit)
        if improved:
            self.best_pos, self.best_fit = self._pos[i].copy(), float(fits[i])
        if self._cur is None:     # init round: seed chains + temperature
            self._cur, self._cur_fit = self._pos.copy(), fits.copy()
            self._temp = self.cfg.t0 * max(1.0, abs(self.best_fit))
            self.history = [self.best_fit]
            if self.cfg.iterations <= 0:
                self.done = True
            return
        delta = fits - self._cur_fit
        accept = delta > 0
        if self._temp > 0:
            u = self._rng.random(len(fits))
            accept |= u < np.exp(np.minimum(0.0, delta) / self._temp)
        self._cur = np.where(accept[:, None], self._pos, self._cur)
        self._cur_fit = np.where(accept, fits, self._cur_fit)
        self._temp *= self.cfg.cooling
        self._scale *= self.cfg.cooling
        self.iterations_run += 1
        self.history.append(self.best_fit)
        self._stale = 0 if improved else self._stale + 1
        if self.cfg.patience and self._stale >= self.cfg.patience:
            self.stop_reason = "converged"
            self.done = True
        elif self.iterations_run >= self.cfg.iterations:
            self.done = True


# ---------------------------------------------------------------------------
# hyperband: successive halving over the two fidelity tiers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HyperbandConfig:
    #: Rung-0 candidates triaged through the screening fidelity.
    screen: int = 4096
    #: Survivors promoted from the screen to full Algorithm-2+3
    #: evaluation (after dedup at the memo-cache resolution).
    survivors: int = 16
    #: Survivor-seeded refinement PSO: swarm size / iteration budget.
    population: int = 12
    iterations: int = 8
    patience: int = 2
    seed: int = 0

    def eval_cap(self) -> int:
        # +3: the canonical particles are always promoted alongside the
        # screened survivors.
        return self.survivors + 3 + self.population * (self.iterations + 1)


def hyperband_rung0(space: SearchSpace, cfg: "HyperbandConfig") -> np.ndarray:
    """The exact ``(screen, 5)`` rung-0 block a
    :class:`HyperbandSearcher` with this config will ask to have
    screened: ``cfg.screen`` uniform draws from a fresh
    ``default_rng(cfg.seed)`` with the canonical three planted at the
    top. Factored out so the campaign-level cross-cell jax screen
    (:mod:`repro.core.screen_jax`) can precompute every cell's rung-0
    fitnesses in one jitted call and hand them back to the searcher —
    bit-identical positions are what makes that handoff sound."""
    rng = np.random.default_rng(cfg.seed)
    pos = rng.uniform(space.lo(), space.hi(), size=(cfg.screen, 5))
    can = space.canonical()
    pos[:len(can)] = can
    return pos


class HyperbandSearcher(Searcher):
    """Successive-halving multi-fidelity search.

    Rung 0 *screens* ``screen`` uniform candidates (plus the canonical
    three) through the vectorized roofline relaxation
    (:func:`repro.core.batch_eval.screen_rav_batch`) — the batched
    engine at a capped budget: parallelism relaxed to the continuous
    roofline, zero Algorithm-2/3 refinement iterations. The top
    ``survivors`` (deduped at the memo-cache resolution, so no full
    evaluation is wasted on a rounded duplicate) are promoted to full
    Algorithm-2+3 evaluation, and a short PSO seeded with the ranked
    survivors polishes the winner — so the result is never worse than
    the best survivor, and the effective search space is the screen
    size, ~2 orders of magnitude beyond what pure PSO visits at equal
    wall-clock."""

    name = "hyperband"
    fidelity = "screen"

    def __init__(self, space: SearchSpace, cfg: HyperbandConfig):
        super().__init__(space, cfg)
        self._phase = "screen"
        self._inner = None
        self._promoted: np.ndarray | None = None

    def ask(self) -> np.ndarray | None:
        if self.done:
            return None
        if self._phase == "screen":
            self._pos = hyperband_rung0(self.space, self.cfg)
            return self._pos
        if self._phase == "promote":
            return self._promoted
        return self._inner.ask()    # refine: delegate to the seeded PSO

    def tell(self, fits: np.ndarray) -> None:
        if self._phase == "screen":
            # Survivors = the canonical three (always — the screening
            # proxy must never be able to discard the paradigm extremes
            # every other engine evaluates at full fidelity) plus the
            # top screened candidates, deduped at the memo resolution.
            rows, seen = [], set()
            for p in self.space.canonical():
                key = _cache_key(self.space.to_rav(p))
                if key not in seen:
                    seen.add(key)
                    rows.append(p)
            cap = self.cfg.survivors + len(rows)
            for i in np.argsort(-fits, kind="stable"):
                if len(rows) >= cap:
                    break
                key = _cache_key(self.space.to_rav(self._pos[i]))
                if key in seen:
                    continue
                seen.add(key)
                rows.append(self._pos[i])
            self._promoted = np.array(rows)
            self._phase, self.fidelity = "promote", "full"
            return
        if self._phase == "promote":
            from .pso import PSOConfig, PSOSearcher
            i = int(np.argmax(fits))
            self.best_pos = self._promoted[i].copy()
            self.best_fit = float(fits[i])
            self.history = [self.best_fit]
            order = np.argsort(-fits, kind="stable")
            seeds = self._promoted[order[:self.cfg.population]]
            inner_cfg = PSOConfig(population=self.cfg.population,
                                  iterations=self.cfg.iterations,
                                  patience=self.cfg.patience,
                                  seed=self.cfg.seed + 1)
            self._inner = PSOSearcher(self.space, inner_cfg,
                                      init_positions=seeds)
            self._phase = "refine"
            return
        self._inner.tell(fits)
        if self._inner.best_fit > self.best_fit:
            self.best_pos = self._inner.best_pos.copy()
            self.best_fit = float(self._inner.best_fit)
        if self._inner.done:
            self.done = True
            self.history = self.history + self._inner.history
            self.iterations_run = self._inner.iterations_run
            self.stop_reason = self._inner.stop_reason


register_searcher("random", RandomSearcher, RandomConfig)
register_searcher("anneal", AnnealSearcher, AnnealConfig)
register_searcher("hyperband", HyperbandSearcher, HyperbandConfig)
