"""Fault-tolerant checkpointing: atomic sharded-array snapshots with a
manifest, auto-resume, and elastic resharding.

Layout::

    <dir>/step_000123/
        manifest.json     # step, flat tree spec, mesh/topology, user meta
        arrays.npz        # flattened param/opt arrays (host-gathered)
    <dir>/LATEST          # atomically-renamed pointer file

Write protocol: write into ``step_X.tmp-<nonce>``, fsync, rename to
``step_X``, then rewrite LATEST — a crash at any point leaves either the
previous checkpoint or a complete new one, never a torn state. On load the
arrays are ``device_put`` against the *current* mesh's shardings, so a
checkpoint taken on a 2x16x16 mesh restores onto 16x16 (or any other
topology) transparently — elastic rescaling after node loss.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import uuid

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, meta: dict | None = None) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat.keys()),
        "treedef": str(jax.tree_util.tree_structure(tree)),
        "n_devices": jax.device_count(),
        "meta": meta or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = os.path.join(ckpt_dir, f".LATEST.tmp-{uuid.uuid4().hex[:8]}")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Newest complete checkpoint step, verified against the manifest."""
    pointer = os.path.join(ckpt_dir, "LATEST")
    candidates = []
    if os.path.exists(pointer):
        with open(pointer) as f:
            candidates.append(f.read().strip())
    if os.path.isdir(ckpt_dir):  # fall back to a directory scan
        candidates += sorted((d for d in os.listdir(ckpt_dir)
                              if d.startswith("step_") and ".tmp" not in d),
                             reverse=True)
    for name in candidates:
        mf = os.path.join(ckpt_dir, name, "manifest.json")
        if os.path.exists(mf):
            try:
                with open(mf) as f:
                    return int(json.load(f)["step"])
            except (ValueError, KeyError, json.JSONDecodeError):
                continue  # torn manifest -> try older
    return None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load ``step`` into the structure of ``like_tree``. ``shardings`` (a
    matching tree of jax.sharding.Sharding, optional) reshards onto the
    current mesh — the elastic-rescale path."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}

    leaves_with_path = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    treedef = jax.tree_util.tree_structure(like_tree)
    out = []
    for path, like in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {like.shape}")
        out.append(arr.astype(like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree


def meta(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)
