"""int8 weight-only quantization for serving — the paper's 8-bit mode
(alpha=4 in Eq. 1) on the TPU side: per-(output-channel) symmetric int8
with fp32 scales. Weights live in HBM at 1 byte/param (4x less read
bandwidth per decode step, the dominant decode cost); XLA fuses the
dequant into the consuming matmul so the convert happens in registers.

Norm scales/biases and other 1-D params stay in full precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_leaf(w):
    if w.ndim < 2 or not jnp.issubdtype(w.dtype, jnp.floating):
        return w  # norms, biases, scalars: keep full precision
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=tuple(range(w.ndim - 1)),
                   keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return {"__q8__": q.astype(jnp.int8), "scale": scale.astype(jnp.float32)}


def _is_q(leaf) -> bool:
    return isinstance(leaf, dict) and "__q8__" in leaf


def quantize_params(params):
    """fp32/bf16 param tree -> int8(+scale) tree (storage form)."""
    return jax.tree.map(_quantize_leaf, params)


def dequantize_params(qparams, dtype=jnp.bfloat16):
    """Rebuild a compute-dtype view; under jit XLA fuses the converts into
    the consuming matmuls (int8 HBM reads)."""
    def deq(leaf):
        if _is_q(leaf):
            return (leaf["__q8__"].astype(jnp.float32) * leaf["scale"]).astype(dtype)
        return leaf

    return jax.tree.map(deq, qparams, is_leaf=_is_q)


def storage_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += leaf.size * leaf.dtype.itemsize
    return total
