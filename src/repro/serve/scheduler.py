"""Continuous-batching serving scheduler.

A production-shaped serving loop over the zoo's decode step: requests
arrive with prompts of different lengths; the scheduler admits them into
a fixed pool of sequence slots, teacher-forces prompts (prefill by
decode, one compiled program), emits tokens until EOS/max_tokens, and
backfills freed slots from the queue — continuous batching (Orca/vLLM
style) rather than static batches, which is what keeps utilization high
under ragged request lengths.

Single-host reference implementation; the decode step itself is the same
sharded `serve_step` the multi-pod dry-run compiles, so the scheduler
composes with the production mesh unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    eos: int | None = None


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list[int]
    prompt_len: int
    steps_in_flight: int


class ContinuousBatcher:
    """Fixed-slot continuous batching over api.decode_step."""

    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_seq: int = 256, greedy: bool = True):
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self.cache = api.init_cache(cfg, slots, max_seq)
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(p, cfg, c, t, pos))
        # per-slot state (host-side bookkeeping)
        self.active: list[dict | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self.done: list[Completion] = []
        self.steps = 0
        self.busy_slot_steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = {"req": req, "pos": 0, "out": [],
                                  "start_step": self.steps}
                # reset the slot's cache lines by zeroing positions lazily:
                # positions >= pos are masked by valid_upto, so no wipe needed.

    def _gather_inputs(self):
        toks = np.zeros((self.slots, 1), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        for s, st in enumerate(self.active):
            if st is None:
                continue
            req, p = st["req"], st["pos"]
            if p < len(req.prompt):
                toks[s, 0] = req.prompt[p]          # teacher-forced prefill
            else:
                toks[s, 0] = st["out"][-1] if st["out"] else 0
            pos[s] = p
        return jnp.asarray(toks), jnp.asarray(pos)

    def _commit(self, logits):
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, st in enumerate(self.active):
            if st is None:
                continue
            req = st["req"]
            st["pos"] += 1
            in_prefill = st["pos"] < len(req.prompt)
            if not in_prefill:
                tok = int(nxt[s])
                st["out"].append(tok)
                finished = (len(st["out"]) >= req.max_new
                            or (req.eos is not None and tok == req.eos)
                            or st["pos"] >= self.max_seq - 1)
                if finished:
                    self.done.append(Completion(
                        req.rid, st["out"], len(req.prompt),
                        self.steps - st["start_step"] + 1))
                    self.active[s] = None

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One decode tick for every occupied slot. Returns False when
        idle (no active work and empty queue)."""
        self._admit()
        if all(st is None for st in self.active):
            return False
        toks, pos = self._gather_inputs()
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        self.busy_slot_steps += sum(st is not None for st in self.active)
        self.steps += 1
        self._commit(logits)
        return True

    def run(self, max_steps: int = 10_000) -> list[Completion]:
        while self.step() and self.steps < max_steps:
            pass
        return self.done

    @property
    def utilization(self) -> float:
        """Occupied-slot fraction over the run — what continuous batching
        optimizes vs static batching."""
        return self.busy_slot_steps / max(self.steps * self.slots, 1)
