"""Production training loop: checkpoint/auto-resume, heartbeat + straggler
monitoring, failure injection (for tests) and retry-with-restore.

Designed for the 1000+-node regime:
* every batch is a pure function of (seed, step, shard) — no data-loader
  state to lose on failover (repro.data.pipeline);
* checkpoints are atomic and reshardable — a job restarted on a different
  mesh keeps training (repro.checkpoint.store);
* the heartbeat monitor flags steps slower than ``straggler_factor`` x the
  EWMA — on multi-host deployments this is the signal to evict/replace a
  slow host; here it feeds the log and the test hooks;
* transient step failures restore the last checkpoint and replay
  (bounded by ``max_restarts``).
"""
from __future__ import annotations

import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import store
from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import api
from repro.optim import adamw
from repro.parallel import sharding as shd

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    ewma: float = 0.9
    max_restarts: int = 3
    remat: str = "full"
    compute_dtype: str = "bfloat16"
    grad_compression: bool = False


class Trainer:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, tcfg: TrainConfig,
                 mesh=None, ocfg: adamw.AdamWConfig | None = None):
        self.cfg, self.shape, self.tcfg = cfg, shape, tcfg
        self.ocfg = ocfg or adamw.AdamWConfig(total_steps=tcfg.steps)
        if mesh is None:
            n = len(jax.devices())
            mesh = jax.make_mesh((n, 1), ("data", "model"))
        self.mesh = mesh
        self.data = TokenPipeline(DataConfig(
            vocab=cfg.vocab, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=tcfg.seed))
        self._build()
        self.step = 0
        self.stats: list[dict] = []
        self.straggler_events: list[int] = []
        self._fail_at: set[int] = set()  # test hook
        self._restarts = 0

    # ------------------------------------------------------------------
    def _build(self):
        cfg, mesh = self.cfg, self.mesh
        params_shapes = jax.eval_shape(
            lambda: api.init_params(jax.random.key(self.tcfg.seed), cfg))
        self.p_specs = shd.param_pspecs(params_shapes, mesh)
        self.p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                    self.p_specs)
        o_specs = adamw.OptState(mu=self.p_specs, nu=self.p_specs, count=P())
        self.o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs)
        dpa = shd.dp_axes(mesh)
        self.dpa = dpa if len(dpa) > 1 else dpa[0]
        self.b_shard = NamedSharding(mesh, P(self.dpa, None))

        ocfg, tcfg = self.ocfg, self.tcfg
        cd = jnp.bfloat16 if tcfg.compute_dtype == "bfloat16" else jnp.float32

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(api.loss_fn)(
                params, cfg, batch, remat=tcfg.remat, compute_dtype=cd)
            if tcfg.grad_compression:
                from repro.parallel.collectives import compress_grads
                grads, _ = compress_grads(
                    grads, jax.tree.map(jnp.zeros_like, grads))
            new_params, new_state, st = adamw.apply(grads, opt_state, params,
                                                    ocfg)
            return new_params, new_state, loss, st["grad_norm"]

        self.train_step = jax.jit(
            train_step,
            in_shardings=(self.p_shard, self.o_shard,
                          {"tokens": self.b_shard, "labels": self.b_shard}),
            out_shardings=(self.p_shard, self.o_shard,
                           NamedSharding(mesh, P()), NamedSharding(mesh, P())),
            donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init_state(self):
        with self.mesh:
            params = jax.jit(
                lambda: api.init_params(jax.random.key(self.tcfg.seed),
                                        self.cfg),
                out_shardings=self.p_shard)()
            opt = jax.jit(adamw.init, out_shardings=self.o_shard)(params)
        return params, opt

    def restore_or_init(self):
        last = store.latest_step(self.tcfg.ckpt_dir)
        params, opt = self.init_state()
        if last is not None:
            log.info("resuming from checkpoint step %d", last)
            tree = store.restore(
                self.tcfg.ckpt_dir, last, {"params": params, "opt": opt},
                {"params": self.p_shard, "opt": self.o_shard})
            params, opt = tree["params"], tree["opt"]
            self.step = last
        return params, opt

    def _make_batch(self, step: int):
        b = self.data.make(step)
        return {k: jax.device_put(v, self.b_shard) for k, v in b.items()}

    # ------------------------------------------------------------------
    def fail_at(self, *steps: int):
        """Test hook: inject a simulated node failure at given steps."""
        self._fail_at.update(steps)

    def run(self):
        params, opt = self.restore_or_init()
        ewma_t = None
        while self.step < self.tcfg.steps:
            s = self.step
            t0 = time.perf_counter()
            try:
                if s in self._fail_at:
                    self._fail_at.discard(s)
                    raise RuntimeError(f"injected node failure @ step {s}")
                batch = self._make_batch(s)
                params, opt, loss, gnorm = self.train_step(params, opt, batch)
                loss = float(loss)
            except Exception as e:  # noqa: BLE001 — failover path
                self._restarts += 1
                if self._restarts > self.tcfg.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring last checkpoint",
                            s, e)
                params, opt = self.restore_or_init()
                continue

            dt = time.perf_counter() - t0
            ewma_t = dt if ewma_t is None else (
                self.tcfg.ewma * ewma_t + (1 - self.tcfg.ewma) * dt)
            if dt > self.tcfg.straggler_factor * ewma_t and s > 2:
                self.straggler_events.append(s)
                log.warning("straggler: step %d took %.2fs (ewma %.2fs)",
                            s, dt, ewma_t)

            self.step = s + 1
            self.stats.append({"step": s, "loss": loss,
                               "grad_norm": float(gnorm), "time_s": dt})
            if s % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f gnorm %.3f %.2fs",
                         s, loss, float(gnorm), dt)
            if self.step % self.tcfg.ckpt_every == 0 or \
                    self.step == self.tcfg.steps:
                store.save(self.tcfg.ckpt_dir, self.step,
                           {"params": params, "opt": opt},
                           meta={"arch": self.cfg.name, "loss": loss})
        return params, opt


# convenience for checkpoints saved by Trainer (params+opt under one tree)
def restore_trainer_state(trainer: Trainer, step: int):
    params, opt = trainer.init_state()
    tree = store.restore(trainer.tcfg.ckpt_dir, step,
                         {"params": params, "opt": opt},
                         {"params": trainer.p_shard, "opt": trainer.o_shard})
    return tree["params"], tree["opt"]
