"""Jitted step-function builders shared by the trainer, the server,
and the multi-pod dry-run: train_step (loss+grad+AdamW), prefill_step,
and serve_step (one-token decode), each with full in/out shardings and
donation.

:class:`StepOptions` carries the §Perf hillclimb knobs:
* ``cast_params`` — cast fp32 master weights to bf16 ONCE at step entry,
  so FSDP all-gathers move bf16 (2x less ICI traffic than gathering fp32
  and converting after, which is where XLA otherwise puts the convert);
* ``constrain_grads`` — pin gradient shardings to the param shardings so
  the DP reduction lowers to reduce-scatter (ZeRO) instead of all-reduce;
* ``remat`` — activation-checkpoint policy ("full" recomputes the block,
  re-gathering weights in the backward pass; "dots" saves matmul outputs
  and skips the re-gather).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.specs import input_specs
from repro.models import api
from repro.optim import adamw
from repro.parallel import sharding as shd


@dataclasses.dataclass(frozen=True)
class StepOptions:
    remat: str = "full"          # full | dots | none
    cast_params: bool = False    # bf16 cast before FSDP gathers
    constrain_grads: bool = False  # force reduce-scatter grad reduction


BASELINE = StepOptions()
OPTIMIZED = StepOptions(remat="dots", cast_params=True, constrain_grads=True)


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _cast_bf16(params):
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
        params)


def build_step(cfg: ArchConfig, shape: ShapeSpec, mesh, unroll: bool = False,
               opts: StepOptions = BASELINE):
    """Returns (jitted_fn, example_args) ready to .lower(*args).

    ``unroll=True`` unrolls the layer scans so XLA's cost_analysis counts
    every layer (it prices while-loop bodies ONCE regardless of trip
    count); plain scan is used to prove compile scalability — our
    hlo_cost parser recovers exact costs either way."""
    params_shapes = jax.eval_shape(
        lambda: api.init_params(jax.random.key(0), cfg))
    p_specs = shd.param_pspecs(params_shapes, mesh)
    specs = input_specs(cfg, shape)
    b_specs = shd.batch_pspecs(cfg, shape, specs, mesh)
    dpa = shd.dp_axes(mesh)
    dpa = dpa if len(dpa) > 1 else dpa[0]

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw.init, params_shapes)
        o_specs = adamw.OptState(mu=p_specs, nu=p_specs, count=P())
        ocfg = adamw.AdamWConfig()

        def train_step(params, opt_state, batch):
            def loss_of(p):
                pc = _cast_bf16(p) if opts.cast_params else p
                return api.loss_fn(pc, cfg, batch, unroll=unroll,
                                   remat=opts.remat)

            loss, grads = jax.value_and_grad(loss_of)(params)
            if opts.constrain_grads:
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    grads, p_specs)
            new_params, new_state, stats = adamw.apply(grads, opt_state,
                                                       params, ocfg)
            return new_params, new_state, loss, stats["grad_norm"]

        fn = jax.jit(
            train_step,
            in_shardings=_ns(mesh, (p_specs, o_specs, b_specs)),
            out_shardings=_ns(mesh, (p_specs, o_specs, P(), P())),
            donate_argnums=(0, 1),
        )
        return fn, (params_shapes, opt_shapes, specs)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            p = _cast_bf16(params) if opts.cast_params else params
            return api.prefill_logits(p, cfg, batch, remat="none",
                                      unroll=unroll)

        logits_shape = jax.eval_shape(prefill_step, params_shapes, specs)
        out_spec = shd.fit_spec(P(dpa, None, "model"), logits_shape.shape, mesh)
        fn = jax.jit(
            prefill_step,
            in_shardings=_ns(mesh, (p_specs, b_specs)),
            out_shardings=_ns(mesh, out_spec),
        )
        return fn, (params_shapes, specs)

    # decode
    cache_shapes = specs.pop("cache")
    c_specs = b_specs.pop("cache")

    def serve_step(params, cache, tokens, pos):
        p = _cast_bf16(params) if opts.cast_params else params
        return api.decode_step(p, cfg, cache, tokens, pos, unroll=unroll)

    logits_shape, _ = jax.eval_shape(serve_step, params_shapes, cache_shapes,
                                     specs["tokens"], specs["pos"])
    lg_spec = shd.fit_spec(P(dpa, "model"), logits_shape.shape, mesh)
    fn = jax.jit(
        serve_step,
        in_shardings=_ns(mesh, (p_specs, c_specs, b_specs["tokens"],
                                b_specs["pos"])),
        out_shardings=_ns(mesh, (lg_spec, c_specs)),
        donate_argnums=(1,),
    )
    return fn, (params_shapes, cache_shapes, specs["tokens"], specs["pos"])
