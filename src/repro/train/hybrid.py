"""Hybrid execution plan for transformer LMs — the paper's paradigm
applied to the assigned architectures.

The DSE's split-point SP sends the first SP decoder blocks through
dedicated *pipeline stages* (one submesh slice per group of layers,
microbatches streaming via shard_map+ppermute — the paper's pipeline
structure) and the remaining blocks through the ordinary scanned
(generic, reusable) path. For uniform-layer LMs the DSE degenerates to
SP=0 (DESIGN.md §Arch-applicability); this module is what a nonzero SP
*executes*.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.layers import rms_norm
from repro.parallel.pipeline import pipeline_apply, split_microbatches


@dataclasses.dataclass(frozen=True)
class HybridLMPlan:
    sp: int                 # blocks in the pipelined head
    n_stages: int           # pipeline stages (sp % n_stages == 0)
    n_micro: int            # microbatches

    @property
    def layers_per_stage(self) -> int:
        return self.sp // self.n_stages


def _split_head(params, plan: HybridLMPlan):
    """blocks (L, ...) -> head (n_stages, layers_per_stage, ...), tail."""
    head = jax.tree.map(lambda a: a[:plan.sp].reshape(
        (plan.n_stages, plan.layers_per_stage) + a.shape[1:]),
        params["blocks"])
    tail = jax.tree.map(lambda a: a[plan.sp:], params["blocks"])
    return head, tail


def hybrid_lm_forward(params, cfg: ArchConfig, tokens, plan: HybridLMPlan,
                      mesh=None, *, compute_dtype=jnp.bfloat16):
    """Forward with a pipelined head. With ``mesh`` (a ("stage",) axis of
    size plan.n_stages) the head truly pipelines; without it the same
    math runs sequentially (CPU tests, numerics identical)."""
    x = params["embed"].astype(compute_dtype)[tokens]
    head, tail = _split_head(params, plan)

    def stage_fn(stage_params, h):
        def step(h, bp):
            return transformer.block_apply(h, bp, cfg), None
        h, _ = jax.lax.scan(step, h, stage_params)
        return h

    if mesh is not None and plan.sp > 0:
        mbs = split_microbatches(x, plan.n_micro)
        x = pipeline_apply(stage_fn, head, mbs, mesh, axis="stage")
        x = x.reshape((-1,) + x.shape[2:])
    else:
        for i in range(plan.n_stages):
            x = stage_fn(jax.tree.map(lambda a: a[i], head), x)

    def step(x, bp):
        return transformer.block_apply(x, bp, cfg), None

    x, _ = jax.lax.scan(step, x, tail)
    x = rms_norm(x, params["ln_f"])
    h = params["lm_head"] if "lm_head" in params else params["embed"].T
    return (x @ h.astype(compute_dtype)).astype(jnp.float32)


def hybrid_lm_loss(params, cfg: ArchConfig, tokens, labels,
                   plan: HybridLMPlan, mesh=None, **kw):
    logits = hybrid_lm_forward(params, cfg, tokens, plan, mesh, **kw)
    return transformer.softmax_xent(logits, labels)
