"""Deterministic, stateless synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — the property the
fault-tolerance story relies on: after a node failure ANY host can
recompute ANY shard for ANY step with no pipeline state to restore, and
elastic rescaling just changes the (shard, n_shards) factorization.
Tokens follow a Zipfian unigram draw with a repeated-ngram structure so
the LM loss actually decreases during the example runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_count: int = 64


class TokenPipeline:
    """make(step, shard, n_shards) -> {"tokens", "labels"} numpy arrays."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # fixed motif table: repeated n-grams give the model learnable signal
        ranks = base.zipf(cfg.zipf_a, size=(cfg.motif_count, cfg.motif_len))
        self._motifs = (ranks % (cfg.vocab - 1)).astype(np.int32)

    def batch_shape(self, n_shards: int) -> tuple[int, int]:
        assert self.cfg.global_batch % n_shards == 0
        return (self.cfg.global_batch // n_shards, self.cfg.seq_len)

    def make(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        cfg = self.cfg
        bs, sl = self.batch_shape(n_shards)
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard, n_shards]))
        ranks = rng.zipf(cfg.zipf_a, size=(bs, sl + 1))
        toks = (ranks % (cfg.vocab - 1)).astype(np.int32)
        # plant motifs at random offsets (learnable structure)
        n_plant = max(1, sl // (4 * cfg.motif_len))
        for b in range(bs):
            ids = rng.integers(0, cfg.motif_count, n_plant)
            offs = rng.integers(0, sl + 1 - cfg.motif_len, n_plant)
            for m, o in zip(ids, offs):
                toks[b, o:o + cfg.motif_len] = self._motifs[m]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
