"""Fused RMSNorm Pallas kernel.

Unfused, RMSNorm costs three HBM passes (read x for the mean-square,
read x again to scale, write y); fused it is one read + one write with
the reduction in VREGs — a pure memory-roofline win on the (B*S, D)
activations that bracket every block. grid tiles rows; D stays whole in
VMEM (d_model ≤ 18432 -> ≤ 72 KiB fp32/row, well inside VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (bm, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_rows(x, scale, *, eps: float = 1e-6, bm: int = 256,
                 interpret: bool = False):
    """x (N, D), scale (D,) -> (N, D). N must be a multiple of bm
    (wrapper pads)."""
    n, d = x.shape
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
