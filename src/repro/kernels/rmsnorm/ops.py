"""jit'd wrapper: model-native (B, S, D) RMSNorm over the fused kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .rmsnorm import rmsnorm_rows


@partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, bm: int = 128,
            interpret: bool = True):
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    bm_eff = min(bm, max(1, 1 << (n - 1).bit_length()))
    pad = (-n) % bm_eff
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = rmsnorm_rows(xf, scale, eps=eps, bm=bm_eff, interpret=interpret)
    return out[:n].reshape(shape)
