"""Oracle: the model's own rms_norm."""
from repro.models.layers import rms_norm


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    return rms_norm(x, {"scale": scale}, eps)
