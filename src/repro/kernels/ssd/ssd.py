"""Mamba2 SSD chunked-scan Pallas kernel.

TPU adaptation of the SSD algorithm: quadratic-within-chunk, linear
across chunks. grid = (B*H, n_chunks); the chunk axis is last (sequential
on TPU), so the (P, N) recurrent state lives in VMEM scratch and flows
chunk-to-chunk without HBM round-trips — the TPU analogue of keeping the
accumulation buffer on-chip in the paper's generic structure.

Per (head, chunk) block the kernel computes
  y_intra = ((C B^T) .* L) x      (MXU: (Q,N)x(N,Q) then (Q,Q)x(Q,P))
  y_inter = (C state^T) .* decay  (MXU: (Q,N)x(N,P))
  state'  = exp(da_tot) state + x^T (B .* decay_out)
with all decay terms precomputed by the ops wrapper (cheap elementwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, dacum_ref, b_ref, c_ref, y_ref, state_ref, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dacum = dacum_ref[0, 0].astype(jnp.float32)    # (Q,) cumulative da
    bmat = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    state = state_ref[...]                         # (P, N)

    da_tot = dacum[-1]

    # intra-chunk: L[i, j] = exp(dacum_i - dacum_j) for j <= i
    li = dacum[:, None] - dacum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l = jnp.where(mask, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (Q, Q)
    y_intra = jax.lax.dot_general(cb * l, xdt, (((1,), (0,)), ((), ())))

    # inter-chunk: y += exp(dacum) .* (C @ state^T)
    cs = jax.lax.dot_general(cmat, state, (((1,), (1,)), ((), ())))  # (Q, P)
    y_inter = jnp.exp(dacum)[:, None] * cs

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: state' = exp(da_tot)*state + x^T @ (B .* decay_out)
    decay_out = jnp.exp(da_tot - dacum)[:, None]   # (Q, 1)
    upd = jax.lax.dot_general(xdt, bmat * decay_out,
                              (((0,), (0,)), ((), ())))  # (P, N)
    state_ref[...] = jnp.exp(da_tot) * state + upd


def ssd_scan(xdt, dacum, b, c, *, p: int, n: int, interpret: bool = False):
    """xdt (BH, NC, Q, P); dacum (BH, NC, Q); b, c (BH, NC, Q, N).
    Returns y (BH, NC, Q, P)."""
    bh, nc, q, _ = xdt.shape
    kernel = functools.partial(_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nc, q, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdt, dacum, b, c)
