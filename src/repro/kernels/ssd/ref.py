"""Oracle for the SSD kernel: the model's own chunked-jnp implementation
(itself validated against a per-step recurrence in the model tests)."""
from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, a_log, b, c, chunk: int = 128):
    return ssd_chunked(x, dt, a_log, b, c, chunk)
