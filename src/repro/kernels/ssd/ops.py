"""jit'd wrapper: model-native SSD interface over the Pallas chunk kernel.

Precomputes the elementwise decay terms (dt*A cumulative sums) in jnp and
hands MXU-shaped blocks to the kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ssd import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, *, chunk: int = 128, interpret: bool = True):
    """Same contract as repro.models.ssm.ssd_chunked:
    x (B, S, H, P); dt (B, S, H); a_log (H,); b, c (B, S, N) -> (B, S, H, P).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} % chunk {q} != 0"
    nc = s // q

    a = -jnp.exp(a_log.astype(jnp.float32))
    da = dt.astype(jnp.float32) * a[None, None, :]            # (B, S, H)
    dacum = jnp.cumsum(da.reshape(bsz, nc, q, h), axis=2)     # (B, NC, Q, H)

    xdt = (x * dt[..., None]).reshape(bsz, nc, q, h, p)

    # arrange to (B*H, NC, Q, ...)
    xdt_bh = xdt.transpose(0, 3, 1, 2, 4).reshape(bsz * h, nc, q, p)
    dacum_bh = dacum.transpose(0, 3, 1, 2).reshape(bsz * h, nc, q)
    b_bh = jnp.repeat(b.reshape(bsz, 1, nc, q, n), h, axis=1).reshape(
        bsz * h, nc, q, n)
    c_bh = jnp.repeat(c.reshape(bsz, 1, nc, q, n), h, axis=1).reshape(
        bsz * h, nc, q, n)

    y = ssd_scan(xdt_bh, dacum_bh, b_bh, c_bh, p=p, n=n, interpret=interpret)
    return y.reshape(bsz, h, nc, q, p).transpose(0, 2, 3, 1, 4).reshape(
        bsz, s, h, p).astype(x.dtype)
