"""jit'd wrapper for the blocked matmul kernel (padding + block choice)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .matmul import matmul_blocked


def _pad_to(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
           interpret: bool = True):
    """General (M, K) @ (K, N) with auto padding to block multiples."""
    m, k = a.shape
    _, n = b.shape
    bm_, bn_, bk_ = (min(bm, 1 << max(3, (m - 1).bit_length())),
                     min(bn, 1 << max(3, (n - 1).bit_length())),
                     min(bk, 1 << max(3, (k - 1).bit_length())))
    ap = _pad_to(_pad_to(a, bm_, 0), bk_, 1)
    bp = _pad_to(_pad_to(b, bk_, 0), bn_, 1)
    out = matmul_blocked(ap, bp, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:m, :n]
