"""Blocked MXU matmul Pallas kernel — the TPU analogue of the paper's
generic-structure MAC array (Sec. 5.3.1): a reusable (bm x bn) compute tile
fed by double-buffered VMEM operand tiles, fp32 accumulation in scratch.

grid = (M/bm, N/bn, K/bk); the K axis is last (sequential on TPU) so the
accumulator lives in VMEM scratch across K steps — exactly the paper's
accumulation-buffer + ping-pong weight-buffer structure mapped onto the
TPU memory hierarchy (HBM -> VMEM -> MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_blocked(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
                   out_dtype=None, interpret: bool = False):
    """a (M, K) @ b (K, N) -> (M, N). M/N/K must be multiples of bm/bn/bk
    (the ops wrapper pads)."""
    m, k = a.shape
    _, n = b.shape
    nk = k // bk
    out_dtype = out_dtype or a.dtype
    kernel = functools.partial(_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
