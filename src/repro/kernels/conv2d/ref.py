"""Oracle: lax.conv 'same' conv, NCHW."""
import jax
import jax.numpy as jnp


def conv2d_ref(x, w):
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW")).astype(x.dtype)
