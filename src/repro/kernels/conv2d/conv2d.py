"""Direct conv2d Pallas kernel — the TPU analogue of the paper's pipeline
computation engine (Sec. 5.2.1) with DNNBuilder's column/row buffer.

Layout NCHW, stride 1, 'same' padding (the VGG workloads; pools are
separate ops). grid = (N, K/bk, H): each step produces one output row for
a block of bk output channels. The input arrives as per-output-row
sliding windows (N, H, C, R, Wp) staged by the wrapper — the VMEM
incarnation of the paper's row buffer (Sec. 5.2.2: "the next stage
launches once the first few rows are ready"). Pallas BlockSpecs index in
block units and cannot express overlapping row windows; on real hardware
this kernel would instead issue explicit row DMAs
(pltpu.make_async_copy) from an HBM-resident frame, which is the faithful
line-buffer dataflow — the windowed re-layout here trades xR input bytes
for wrapper simplicity and identical arithmetic.

The (r, s) taps are static python loops; each tap is an MXU
(bk, C) x (C, W) matmul — CPF=C, KPF=bk in the paper's terms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, rr: int, ss: int, width: int):
    # x_ref: (1, 1, C, R, W + S - 1) sliding window for one output row
    # w_ref: (bk, C, R, S); o_ref: (1, bk, 1, W)
    acc = jnp.zeros((w_ref.shape[0], width), jnp.float32)
    for r in range(rr):
        for s in range(ss):
            xs = x_ref[0, 0, :, r, s:s + width].astype(jnp.float32)  # (C, W)
            wk = w_ref[:, :, r, s].astype(jnp.float32)               # (bk, C)
            acc += jax.lax.dot_general(wk, xs, (((1,), (0,)), ((), ())))
    o_ref[0, :, 0, :] = acc.astype(o_ref.dtype)


def conv2d_windows(x_win, w, *, bk: int = 64, interpret: bool = False):
    """x_win (N, H, C, R, W + S - 1): per-output-row sliding windows;
    w (K, C, R, S). Returns (N, K, H, W). stride 1."""
    n, h, c, rr, wp = x_win.shape
    k, _, _, ss = w.shape
    width = wp - ss + 1
    bk = min(bk, k)
    assert k % bk == 0, f"K {k} % bk {bk}"

    kernel = functools.partial(_kernel, rr=rr, ss=ss, width=width)
    return pl.pallas_call(
        kernel,
        grid=(n, k // bk, h),
        in_specs=[
            pl.BlockSpec((1, 1, c, rr, wp), lambda ni, ki, hi: (ni, hi, 0, 0, 0)),
            pl.BlockSpec((bk, c, rr, ss), lambda ni, ki, hi: (ki, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, 1, width),
                               lambda ni, ki, hi: (ni, ki, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k, h, width), x_win.dtype),
        interpret=interpret,
    )(x_win, w)
