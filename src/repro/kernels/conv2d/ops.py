"""jit'd wrapper for the direct conv kernel ('same' padding, stride 1)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .conv2d import conv2d_windows


@partial(jax.jit, static_argnames=("bk", "interpret"))
def conv2d(x, w, *, bk: int = 64, interpret: bool = True):
    """x (N, C, H, W); w (K, C, R, S) -> (N, K, H, W), 'same' pad, stride 1."""
    n, c, h, width = x.shape
    k, _, rr, ss = w.shape
    xp = jnp.pad(x, ((0, 0), (0, 0),
                     ((rr - 1) // 2, rr // 2), ((ss - 1) // 2, ss // 2)))
    # per-output-row sliding windows (N, H, C, R, Wp) — see kernel docstring
    rows = jnp.arange(h)[:, None] + jnp.arange(rr)[None, :]
    x_win = xp[:, :, rows, :].transpose(0, 2, 1, 3, 4)
    bk_eff = bk
    while k % bk_eff:
        bk_eff //= 2
    return conv2d_windows(x_win, w, bk=max(1, bk_eff), interpret=interpret)
