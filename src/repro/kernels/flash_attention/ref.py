"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q (B, S, H, hd); k, v (B, Sk, KV, hd) -> (B, S, H, hd). fp32 math."""
    b, s, h, hd = q.shape
    _, s_k, kv, _ = k.shape
    g = h // kv
    qg = q.astype(jnp.float32).reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bsngh,btnh->bngst", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    qi = jnp.arange(s)[:, None] + (s_k - s)
    kj = jnp.arange(s_k)[None, :]
    ok = jnp.ones((s, s_k), bool)
    if causal:
        ok &= kj <= qi
    if window is not None:
        ok &= kj > qi - window
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)
