"""jit'd public wrapper around the flash-attention Pallas kernel.

Accepts the model-native layout q (B, S, H, hd), k/v (B, Sk, KV, hd);
handles GQA head mapping, padding to block/lane multiples, and exposes
``attn_fn`` with the signature ``repro.models.layers.gqa_attention``
expects for its kernel hook.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd

_LANE = 128


def _pad_to(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 256, bk: int = 256, interpret: bool = True):
    """q (B, S, H, hd); k, v (B, Sk, KV, hd) -> (B, S, H, hd)."""
    b, s, h, hd = q.shape
    _, s_k, kv, _ = k.shape
    bq = min(bq, max(8, 1 << (s - 1).bit_length()))
    bk = min(bk, max(8, 1 << (s_k - 1).bit_length()))

    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3).reshape(b * h, s, hd),
                         bq, 1), _LANE, 2)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3).reshape(b * kv, s_k, hd),
                         bk, 1), _LANE, 2)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3).reshape(b * kv, s_k, hd),
                         bk, 1), _LANE, 2)

    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               scale=1.0 / (hd ** 0.5), s_k=s_k,
                               bq=bq, bk=bk, interpret=interpret)
    out = out[:, :s, :hd].reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out


def attn_fn(q, k, v, *, causal: bool = True, window: int | None = None,
            interpret: bool = True):
    """Adapter matching gqa_attention's attn_fn hook: returns (B, S, H*hd)."""
    b, s, h, hd = q.shape
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=interpret)
    return out.reshape(b, s, h * hd)
