"""Flash attention Pallas TPU kernel (GQA, causal, sliding window).

Design for TPU (not a CUDA port):
* grid = (B*H, S/bq, S/bk); the kv axis is the LAST grid dim, so on TPU it
  executes sequentially per (head, q-block) and the online-softmax state
  lives in VMEM scratch across kv iterations.
* Blocks are MXU-aligned: (bq, hd) x (hd, bk) contractions with hd padded
  to a multiple of 128 by the wrapper.
* GQA is handled in the BlockSpec index maps: the kv operand row for query
  head h is ``b*KV + h // (H/KV)`` — no head replication in HBM.
* Fully-masked kv blocks are skipped with pl.when (structural win for
  causal: 2x fewer MACs).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None, s_k: int,
            bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # Block-level skip: causal/window structure known from indices alone.
    relevant = jnp.asarray(True)
    if causal:
        relevant = k_start <= q_start + bq - 1
    if window is not None:
        relevant = jnp.logical_and(relevant,
                                   k_start + bk - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        s = s * scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = k_pos < s_k                                 # padded keys masked
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window is not None:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_scr[...] = alpha * l_scr[...] + p.sum(-1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: int | None = None, scale: float | None = None,
                         s_k: int | None = None, bq: int = 256, bk: int = 256,
                         interpret: bool = False):
    """q (BH, S, hd); k, v (BKV, Sk, hd) with BH = B*H, BKV = B*KV.

    Shapes must be pre-padded: S % bq == 0, Sk % bk == 0, hd % 128 == 0
    (the ops wrapper does this); ``s_k`` is the true (unpadded) key length
    so padded keys are masked out.
    """
    bh, s, hd = q.shape
    bkv, s_kp, _ = k.shape
    group = bh // bkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    nq, nk = s // bq, s_kp // bk
    s_k = s_kp if s_k is None else s_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, s_k=s_k,
        bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
